"""PR-8 deprecation lint: keep the repo's own code off the legacy API.

Two things were deprecated by the emitter/config split and kept only as
compatibility shims for external callers:

* the pre-registry generator class names ``CodeGenerator`` /
  ``PallasGenerator`` (use ``repro.core.emit.get_emitter(...)`` or the
  renamed classes ``JaxCodeGenerator`` / ``SyncPallasGenerator``);
* the flat ``SaturatorConfig(...)`` keyword arguments (``schedule=``,
  ``beam_width=``, ``cache_dir=``, ... — use the grouped
  ``search_cfg`` / ``schedule_cfg`` / ``cache_cfg`` / ``verify_cfg``
  sub-configs).

This script AST-scans ``src``, ``benchmarks``, ``tests`` and
``examples`` and fails on any use of either, so the shims never creep
back into first-party code. Intentional uses (the defining modules, the
tests that pin the deprecation behaviour itself) carry a
``# deprecated-ok`` comment on the offending line.

Run from the repo root:
    python tools/deprecation_lint.py
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
SCAN_DIRS = ("src", "benchmarks", "tests", "examples", "tools")

# the modules that define/alias/document the deprecated names
DEFINING = {
    ROOT / "src/repro/core/codegen.py",
    ROOT / "src/repro/core/pallasgen.py",
    ROOT / "src/repro/core/emit.py",
    ROOT / "src/repro/core/pipeline.py",
    ROOT / "tools/deprecation_lint.py",
}

OLD_CLASS_NAMES = {"CodeGenerator", "PallasGenerator"}

# mirror repro.core.pipeline._LEGACY_TO_GROUP without importing repro
# (the lint must run under a bare CI python, pre-dependency-install)
LEGACY_KWARGS = {
    "iter_limit", "node_limit", "time_limit_s", "extract_time_limit_s",
    "local_search", "search", "beam_width", "beam_expansions",
    "hillclimb_evals", "beam_coordinated", "schedule", "device_profile",
    "cache_dir", "cache_warm_start", "verify",
}


def _ok_lines(text: str) -> set:
    return {i for i, line in enumerate(text.splitlines(), 1)
            if "# deprecated-ok" in line}


def lint_file(path: pathlib.Path) -> list:
    text = path.read_text()
    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    ok = _ok_lines(text)
    rel = path.relative_to(ROOT)
    problems = []
    for node in ast.walk(tree):
        # old class names, as bare names or attribute access; alias
        # re-exports (`from x import PallasGenerator`) count too
        name = None
        if isinstance(node, ast.Name) and node.id in OLD_CLASS_NAMES:
            name = node.id
        elif isinstance(node, ast.Attribute) and node.attr in OLD_CLASS_NAMES:
            name = node.attr
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name in OLD_CLASS_NAMES:
                    name = alias.name
        if name is not None and node.lineno not in ok:
            problems.append(
                f"{rel}:{node.lineno}: deprecated class name {name!r} "
                f"(use repro.core.emit.get_emitter or the renamed class)")
        # flat SaturatorConfig kwargs
        if isinstance(node, ast.Call):
            callee = node.func
            cname = (callee.id if isinstance(callee, ast.Name)
                     else callee.attr if isinstance(callee, ast.Attribute)
                     else None)
            if cname == "SaturatorConfig":
                for kw in node.keywords:
                    if kw.arg in LEGACY_KWARGS and \
                            (kw.value.lineno not in ok and
                             node.lineno not in ok):
                        problems.append(
                            f"{rel}:{kw.value.lineno}: deprecated flat "
                            f"SaturatorConfig kwarg {kw.arg!r} (use the "
                            f"grouped sub-config)")
    return problems


def main() -> int:
    problems = []
    for d in SCAN_DIRS:
        base = ROOT / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            if path in DEFINING:
                continue
            problems.extend(lint_file(path))
    if problems:
        print(f"deprecation lint: {len(problems)} problem(s)",
              file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print("deprecation lint OK: no first-party use of deprecated "
          "generator names or flat SaturatorConfig kwargs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
