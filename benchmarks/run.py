"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes full JSON to
experiments/out/bench/ (gitignored — benchmark outputs never get
committed by accident). Tables:
  ablation          — Fig. 2 / Fig. 4 (CSE / CSE+SAT / CSE+BULK / ACCSAT)
  breakdown         — Table IV (per-kernel instruction/load/FMA deltas)
  saturation_stats  — §VII pipeline timing statistics
  rule_ablation     — §V-A validation (restricted vs extended rule sets)
  measure           — measured per-instance kernel times (the calibration
                      harness, benchmarks/measure.py) vs the roofline
                      model's predictions
  lm_step           — framework train/decode step per architecture
(The Tables II/III inventory — suite × sizes — is the kernel_suite itself;
the dry-run roofline table lives in experiments/dryrun/.)

Runs as ``python -m benchmarks.run`` or ``python benchmarks/run.py``.
"""
import json
import pathlib
import sys

if __package__ in (None, ""):        # direct script invocation
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bootstrap import OUT_ROOT, die_with_import_help

OUT = OUT_ROOT / "bench"


def main() -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    try:
        from benchmarks.ablation import run_ablation
        from benchmarks.breakdown import run_breakdown
        from benchmarks.saturation_stats import run_saturation_stats
        from benchmarks.lm_step import run_lm_step
        from benchmarks.measure import measure_all
    except ImportError as e:
        die_with_import_help(e)

    print("name,us_per_call,derived")

    abl = run_ablation(n=64 * 64)
    (OUT / "ablation.json").write_text(json.dumps(abl, indent=1))
    for kernel, modes in abl.items():
        for mode, r in modes.items():
            print(f"ablation/{kernel}/{mode},{r['us_per_thread']:.4f},"
                  f"speedup={r['speedup_wall']:.3f};cost={r['dag_cost']:.0f};"
                  f"ops={r['n_ops']};loads={r['n_loads']};fma={r['n_fma']}")

    brk = run_breakdown()
    (OUT / "breakdown.json").write_text(json.dumps(brk, indent=1))
    for row in brk:
        print(f"breakdown/{row['kernel']},0,"
              f"ops_delta={row['ops_delta_pct']:.1f}%;"
              f"loads_saved={row['loads_saved_pct']:.1f}%;"
              f"fma={row['fma_formed']};"
              f"tpu_cost_red={row['tpu_cost_reduction_pct']:.1f}%")

    from benchmarks.rule_ablation import run_rule_ablation
    ra = run_rule_ablation()
    (OUT / "rule_ablation.json").write_text(json.dumps(ra, indent=1))
    for row in ra:
        pk, ek = row["paper"], row["extended"]
        print(f"rule_ablation/{row['kernel']},{pk['sat_s']*1e6:.0f},"
              f"paper_nodes={pk['e_nodes']};ext_nodes={ek['e_nodes']};"
              f"paper_cost={pk['dag_cost']:.0f};ext_cost={ek['dag_cost']:.0f};"
              f"ext_sat_slowdown={ek['sat_s']/max(pk['sat_s'],1e-6):.1f}x")

    sat = run_saturation_stats()
    (OUT / "saturation_stats.json").write_text(json.dumps(sat, indent=1))
    print(f"saturation_stats/ssa_codegen,"
          f"{sat['ssa_codegen_ms_mean']*1e3:.1f},"
          f"mean_ms={sat['ssa_codegen_ms_mean']:.2f};"
          f"stdev={sat['ssa_codegen_ms_stdev']:.2f};"
          f"paper_mean_ms=91.8")
    print(f"saturation_stats/saturation,"
          f"{sat['saturation_s_mean']*1e6:.1f},"
          f"mean_s={sat['saturation_s_mean']:.4f};"
          f"stdev={sat['saturation_s_stdev']:.4f};paper_mean_s=0.63")

    mea = measure_all()
    (OUT / "measure.json").write_text(json.dumps(mea, indent=1))
    for row in mea["rows"]:
        print(f"measure/{row['kernel']},{row['measured_ns']/1e3:.3f},"
              f"kind={row['measured_kind']};"
              f"predicted_ns={row['predicted_ns']:.1f}")

    lm = run_lm_step()
    (OUT / "lm_step.json").write_text(json.dumps(lm, indent=1))
    for row in lm:
        print(f"lm_step/{row['arch']}/train,{row['train_step_ms']*1e3:.1f},"
              f"ms={row['train_step_ms']:.1f}")
        print(f"lm_step/{row['arch']}/decode,{row['decode_step_ms']*1e3:.1f},"
              f"ms={row['decode_step_ms']:.1f}")


if __name__ == '__main__':
    main()
