"""§VII analog: saturation/codegen timing statistics + extraction quality.

The paper reports 91.8 ms (σ=253.3) SSA+codegen per kernel and 0.63 s
(σ=3.37) saturation under the 10k-node/10-iteration/10 s limits. Same
measurement over our suite + the framework's model tile programs.

Since PR 3 each kernel is extracted twice — with the beam search (the
default) and with the PR-2 multi-start hill climb — so the table carries
the beam-vs-hillclimb delta in roofline-predicted latency and DAG cost.
The beam result must never be worse on the extraction objective (DAG
cost, store-free); the CI gate (``benchmarks/bench_regression.py``)
enforces that invariant plus a 2% regression bound on every kernel's
predicted latency/cost vs the committed baseline.
On e-graphs small enough to enumerate, the brute-force oracle
(`extract_exact`) also reports the beam's optimality gap.
"""
from __future__ import annotations

import statistics
from typing import Callable, Dict, List, Optional

from repro.core import (SaturatorConfig, SearchConfig, compute_schedule,
                        extract_dag, optimality_gap, saturate_program)
from repro.core.pipeline import predict_choice
from repro.kernels.tile_programs import PROGRAMS
from repro.verify import (VerifyReport, verify_rules, verify_saturated,
                          verify_schedule)
from .kernel_suite import SUITE

# Deterministic-run limits for the regression gate: generous wall-clock
# ceilings so the node/iteration/expansion budgets (machine-independent)
# are what actually stop saturation and extraction.
GATE_CONFIG = dict(mode="accsat",
                   search_cfg=SearchConfig(time_limit_s=120.0,
                                           extract_time_limit_s=120.0))


def all_programs() -> Dict[str, Callable]:
    return {**{k: v for k, v in SUITE.items()},
            **{f"tile:{k}": v for k, v in PROGRAMS.items()}}


def _hillclimb_prediction(sk, cfg) -> Dict:
    """Re-extract the already-saturated e-graph with the PR-2 hill climb
    and price the result exactly as the pipeline does (same store
    traffic), so the beam-vs-hillclimb delta compares one e-graph under
    one unit system — no second saturation, no cross-process noise."""
    prog = sk.ssa.prog
    roots = sk.ssa.roots()
    ex = extract_dag(sk.ssa.egraph, tuple(roots) if roots else (),
                     cost_model=cfg.make_cost_model(prog),
                     time_limit_s=cfg.extract_time_limit_s,
                     search="hillclimb", beam_width=cfg.beam_width,
                     beam_expansions=cfg.beam_expansions,
                     hillclimb_evals=cfg.hillclimb_evals)
    pred = predict_choice(sk.ssa, ex.choice, ex.roots,
                          sk.kernel.stats.n_stores)
    return {"latency_ns": pred["latency_ns"], "dag_cost": ex.dag_cost}


def run_saturation_stats(compare_hillclimb: bool = True,
                         oracle_max_classes: int = 12) -> Dict:
    rows: List[Dict] = []
    agg_verify = VerifyReport()
    for name, mk in all_programs().items():
        sk = saturate_program(mk(), SaturatorConfig(**GATE_CONFIG))
        rep = sk.report()
        row = {
            "kernel": name,
            "ssa_codegen_ms": rep["ssa_ms"] + rep["codegen_ms"],
            "saturation_s": rep["sat_s"],
            "extract_s": rep["extract_s"],
            "e_nodes": rep["sat_nodes"],
            "iterations": rep["sat_iterations"],
            "stop": rep["sat_stop"],
            # roofline-calibrated prediction of the extracted term
            # (unified analysis subsystem; per-tile-instance units,
            # shape/dtype-aware since PR 3)
            "predicted_flops": rep["predicted_flops"],
            "predicted_bytes": rep["predicted_bytes"],
            "predicted_latency_ns": rep["predicted_latency_ns"],
            "predicted_bound": rep["predicted_bound"],
            "search": rep["search"],
            "dag_cost": rep["dag_cost"],
            "beam_generations": rep["beam_generations"],
            "beam_expanded": rep["beam_expanded"],
        }
        # schedule-aware predicted latency of every named statement
        # order (analytic units, deterministic search budget) — the
        # gate's cost <= bulk <= source leg reads these
        sched = compute_schedule(sk.ssa, dict(sk.extraction.choice),
                                 mode="cost")
        row["schedule_predicted"] = dict(sched.predicted_by_mode)
        # the oracle must judge in the same units the extraction used:
        # same dtype-aware model, bound to the same e-graph
        gap: Optional[float] = optimality_gap(
            sk.ssa.egraph, sk.extraction,
            SaturatorConfig(**GATE_CONFIG).make_cost_model(sk.ssa.prog),
            max_classes=oracle_max_classes)
        row["oracle_gap"] = gap
        if compare_hillclimb:
            hill = _hillclimb_prediction(sk, SaturatorConfig(**GATE_CONFIG))
            row["hillclimb_latency_ns"] = hill["latency_ns"]
            row["hillclimb_dag_cost"] = hill["dag_cost"]
            row["beam_vs_hillclimb_pct"] = (
                100.0 * (rep["predicted_latency_ns"] - hill["latency_ns"])
                / hill["latency_ns"] if hill["latency_ns"] else 0.0)
        # PR-7 static verification: e-graph invariants, emitted-source
        # lint, plus independent certification of the cost order priced
        # above — per-kernel digest in the row, aggregates at top level
        vrep = verify_saturated(sk, "cheap")
        scr = verify_schedule(sk.ssa, sk.extraction.choice, sched)
        vrep.extend(scr.findings)
        vrep.schedules_certified += scr.regions_certified
        agg_verify.merge(vrep)
        row["verify"] = vrep.summary()
        rows.append(row)
    # rule soundness is per-rule-set, not per-kernel: validate the gate
    # configuration's active rules once
    rres = verify_rules(SaturatorConfig(**GATE_CONFIG).rules())
    agg_verify.extend(rres.findings)
    agg_verify.rules_checked += rres.rules_checked
    ssa_ms = [r["ssa_codegen_ms"] for r in rows]
    sat_s = [r["saturation_s"] for r in rows]
    from repro.core.telemetry import telemetry
    return {
        "rows": rows,
        "verify": agg_verify.summary(),
        "verify_findings_by_pass": agg_verify.by_pass(),
        "rules_checked": agg_verify.rules_checked,
        "schedules_certified": agg_verify.schedules_certified,
        # PR-6 runtime counters: persistent-cache hits/misses/warm starts
        # and per-primitive jaxpr-bridge fallbacks observed this process
        "telemetry": telemetry().snapshot(),
        "ssa_codegen_ms_mean": statistics.mean(ssa_ms),
        "ssa_codegen_ms_stdev": statistics.pstdev(ssa_ms),
        "ssa_codegen_ms_range": (min(ssa_ms), max(ssa_ms)),
        "saturation_s_mean": statistics.mean(sat_s),
        "saturation_s_stdev": statistics.pstdev(sat_s),
        "saturation_s_range": (min(sat_s), max(sat_s)),
        "paper_reference": {
            "ssa_codegen_ms": (91.8, 253.3, (1.4, 1885.0)),
            "saturation_s": (0.63, 3.37, (0.0, 31.2)),
        },
    }
