"""§VII analog: saturation/codegen timing statistics.

The paper reports 91.8 ms (σ=253.3) SSA+codegen per kernel and 0.63 s
(σ=3.37) saturation under the 10k-node/10-iteration/10 s limits. Same
measurement over our suite + the framework's model tile programs."""
from __future__ import annotations

import statistics
from typing import Dict, List

from repro.core import SaturatorConfig, saturate_program
from repro.kernels.tile_programs import PROGRAMS
from .kernel_suite import SUITE


def run_saturation_stats() -> Dict:
    rows: List[Dict] = []
    all_programs = {**{k: v for k, v in SUITE.items()},
                    **{f"tile:{k}": v for k, v in PROGRAMS.items()}}
    for name, mk in all_programs.items():
        sk = saturate_program(mk(), SaturatorConfig(mode="accsat"))
        rep = sk.report()
        rows.append({
            "kernel": name,
            "ssa_codegen_ms": rep["ssa_ms"] + rep["codegen_ms"],
            "saturation_s": rep["sat_s"],
            "extract_s": rep["extract_s"],
            "e_nodes": rep["sat_nodes"],
            "iterations": rep["sat_iterations"],
            "stop": rep["sat_stop"],
            # roofline-calibrated prediction of the extracted term
            # (unified analysis subsystem; per-tile-instance units)
            "predicted_flops": rep["predicted_flops"],
            "predicted_bytes": rep["predicted_bytes"],
            "predicted_latency_ns": rep["predicted_latency_ns"],
            "predicted_bound": rep["predicted_bound"],
        })
    ssa_ms = [r["ssa_codegen_ms"] for r in rows]
    sat_s = [r["saturation_s"] for r in rows]
    return {
        "rows": rows,
        "ssa_codegen_ms_mean": statistics.mean(ssa_ms),
        "ssa_codegen_ms_stdev": statistics.pstdev(ssa_ms),
        "ssa_codegen_ms_range": (min(ssa_ms), max(ssa_ms)),
        "saturation_s_mean": statistics.mean(sat_s),
        "saturation_s_stdev": statistics.pstdev(sat_s),
        "saturation_s_range": (min(sat_s), max(sat_s)),
        "paper_reference": {
            "ssa_codegen_ms": (91.8, 253.3, (1.4, 1885.0)),
            "saturation_s": (0.63, 3.37, (0.0, 31.2)),
        },
    }
