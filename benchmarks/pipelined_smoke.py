"""PR-8 pipelined-emitter smoke check.

For a small kernel subset, emits each tile program with the
``pallas_pipelined`` backend and asserts the contract the emitter makes:

* the recorded interpret-mode **fallback source is byte-identical** to
  what the synchronous ``pallas`` emitter produces under the same
  (cost) schedule — CPU runs lose nothing but the async staging;
* running the pipelined op on CPU (interpret fallback) produces
  **bit-identical outputs** to the synchronous op;
* the emitted async source + copy plan pass the static verifier
  (``verify_pallas_kernel``) with **zero error findings** — every
  ``make_async_copy`` start has exactly one wait, waits dominate first
  use, buffer/semaphore parity alternates, ≤2 copies in flight.

Deterministic (no timing); used by the ``pipelined-smoke`` CI job and
as a leg of ``bench_regression.py``.

Usage:
    python benchmarks/pipelined_smoke.py
    python benchmarks/pipelined_smoke.py --kernels rmsnorm,swiglu
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):        # direct script invocation
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bootstrap import die_with_import_help
from benchmarks.hashseed import reexec_with_fixed_hashseed

reexec_with_fixed_hashseed()

try:
    import numpy as np
    import jax
except ImportError as e:
    die_with_import_help(e)

SMOKE_KERNELS = ("rmsnorm", "swiglu", "softmax")


def check_kernel(name: str, schedule: str = "cost") -> list:
    """Failure strings (empty = the kernel passes all three contracts)."""
    from benchmarks.measure import tile_inputs_for
    from repro.kernels.tile_programs import get_tile_op
    from repro.verify import verify_pallas_kernel

    failures = []
    piped = get_tile_op(name, schedule=schedule, emitter="pallas_pipelined")
    sync = get_tile_op(name, schedule=schedule)

    if piped.pk.emitter != "pallas_pipelined":
        failures.append(f"{name}: op built by {piped.pk.emitter!r}, "
                        "not the pipelined emitter")
    if not piped.pk.async_plan:
        failures.append(f"{name}: pipelined emitter recorded no async "
                        "copies (nothing was actually pipelined)")
    if piped.pk.fallback_source != sync.pk.source:
        failures.append(
            f"{name}: interpret fallback source is not byte-identical to "
            f"the synchronous emitter under the {schedule} schedule")

    rep = verify_pallas_kernel(piped.pk, piped.sk.ssa)
    errs = rep.errors()
    if errs:
        failures.extend(f"{name}: verify: [{f.code}] {f.message}"
                        for f in errs)

    arrays, scalars = tile_inputs_for(piped.sk.ssa.prog)
    args = [jax.numpy.asarray(a) for a in arrays]
    out_p = piped.apply(*args, **scalars)
    out_s = sync.apply(*args, **scalars)
    outs_p = out_p if isinstance(out_p, tuple) else (out_p,)
    outs_s = out_s if isinstance(out_s, tuple) else (out_s,)
    for i, (a, b) in enumerate(zip(outs_p, outs_s)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            failures.append(f"{name}: output {i} of the interpret "
                            "fallback differs from the synchronous op")
    return failures


def run_pipelined_smoke(kernels=SMOKE_KERNELS, schedule: str = "cost",
                        quiet: bool = False) -> list:
    failures = []
    for name in kernels:
        fails = check_kernel(name, schedule=schedule)
        failures.extend(fails)
        if not quiet:
            from repro.kernels.tile_programs import get_tile_op
            op = get_tile_op(name, schedule=schedule,
                             emitter="pallas_pipelined")
            plan = ", ".join(
                f"{c.array}(sem{c.sem} s{c.start_slot}->w{c.wait_slot})"
                for c in op.pk.async_plan)
            status = "FAIL" if fails else "ok"
            print(f"  {name:16s} [{status}] async: {plan or 'none'}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", default=",".join(SMOKE_KERNELS),
                    help="comma-separated tile kernels "
                         f"(default {','.join(SMOKE_KERNELS)})")
    ap.add_argument("--schedule", default="cost",
                    choices=("source", "bulk", "cost"))
    args = ap.parse_args(argv)
    kernels = tuple(args.kernels.split(","))
    print(f"pipelined smoke over {len(kernels)} kernels "
          f"({args.schedule} schedule):")
    failures = run_pipelined_smoke(kernels, schedule=args.schedule)
    if failures:
        print(f"FAIL: {len(failures)} problem(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("pipelined smoke OK: fallback byte-identical, outputs "
          "bit-identical, async plans verified clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
