"""Cross-process persistent-cache smoke gate (PR 6 CI job).

Two worker subprocesses share one fresh cache directory:

  1. the **cold** worker builds a set of tile ops, populating the cache
     (every build must be a cache miss that stores an entry);
  2. the **warm** worker — launched with a *different* PYTHONHASHSEED,
     so e-class ids and set-iteration orders differ — rebuilds the same
     ops. Every build must be an exact cache hit that skips saturation
     and search, the total saturation wall time must drop by at least
     ``SPEEDUP_FLOOR``x, and both the emitted kernel sources (JAX and
     Pallas) and the numeric outputs must hash identically to the cold
     run (replay is bit-for-bit, not merely equivalent).

Exit code 0 on success, 1 on any violation (CI gates on this).

Run:  python benchmarks/cache_smoke.py
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parents[1]

# a spread of tile programs: norms (shared-subexpression heavy), the
# multi-store optimizer, and the two-output gating kernel with a tuple
# phi payload — these dominate cold search time, so the speedup
# measurement isn't noise-bound the way trivial kernels would be
KERNELS = ("rmsnorm", "rmsnorm_gated", "layernorm", "adamw", "ssd_gate")
SPEEDUP_FLOOR = 10.0
_MARK = "CACHE_SMOKE_JSON:"


def _worker(cache_dir: str) -> None:
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import TILE_SHAPE
    from repro.core.telemetry import telemetry
    from repro.kernels.tile_programs import PROGRAMS, get_tile_op

    report = {}
    for name in KERNELS:
        # cost schedule = the full pipeline (saturation + beam extraction
        # + schedule search); replay must skip all three
        op = get_tile_op(name, schedule="cost", cache_dir=cache_dir)
        sk = op.sk
        events = [e for e in telemetry().events
                  if e["kind"] == "cache" and e["kernel"] == name]
        prog = PROGRAMS[name]()
        rng = np.random.default_rng(0)
        arrays = []
        for spec in prog.arrays.values():
            shape = tuple(TILE_SHAPE[i] if d is None else int(d)
                          for i, d in enumerate(
                              getattr(spec, "shape", None) or TILE_SHAPE))
            arrays.append(rng.uniform(0.1, 1.0,
                                      size=shape).astype(np.float32))
        args = [jnp.asarray(a) for a in arrays] \
            + [0.5 for _ in sk.kernel.scalars]
        outs = sk.kernel.fn(*args)
        report[name] = {
            "status": sk.cache_status,
            "wall_s": events[-1]["wall_s"],
            "jax_src": hashlib.sha256(
                sk.kernel.source.encode()).hexdigest(),
            "pallas_src": hashlib.sha256(op.source.encode()).hexdigest(),
            "out": hashlib.sha256(
                b"".join(np.asarray(o).tobytes() for o in outs)
            ).hexdigest(),
        }
    print(_MARK + json.dumps(report))


def _run_worker(cache_dir: str, hashseed: str) -> dict:
    env = dict(os.environ,
               PYTHONPATH=str(ROOT / "src"),
               PYTHONHASHSEED=hashseed)
    env.pop("REPRO_SAT_CACHE", None)   # the explicit dir is the subject
    p = subprocess.run([sys.executable, __file__, "--worker", cache_dir],
                       env=env, capture_output=True, text=True,
                       timeout=600)
    if p.returncode != 0:
        sys.stderr.write(p.stdout + p.stderr)
        raise SystemExit(f"worker (hashseed={hashseed}) failed")
    lines = [ln for ln in p.stdout.splitlines() if ln.startswith(_MARK)]
    return json.loads(lines[-1][len(_MARK):])


def main() -> int:
    cache_dir = tempfile.mkdtemp(prefix="repro_cache_smoke_")
    cold = _run_worker(cache_dir, hashseed="11")
    warm = _run_worker(cache_dir, hashseed="23")

    failures = []
    for name in KERNELS:
        c, w = cold[name], warm[name]
        if c["status"] != "miss":
            failures.append(f"{name}: cold run was {c['status']!r}, "
                            "expected a miss on a fresh cache")
        if w["status"] != "hit":
            failures.append(f"{name}: warm run was {w['status']!r}, "
                            "expected an exact hit")
        for k, label in (("jax_src", "generated JAX source"),
                         ("pallas_src", "Pallas source"),
                         ("out", "numeric output")):
            if c[k] != w[k]:
                failures.append(f"{name}: {label} differs cold vs warm "
                                f"({c[k][:12]} != {w[k][:12]})")
        print(f"  {name:14s} cold {c['wall_s']*1e3:8.1f} ms ({c['status']})"
              f" -> warm {w['wall_s']*1e3:7.2f} ms ({w['status']})")

    cold_s = sum(cold[k]["wall_s"] for k in KERNELS)
    warm_s = sum(warm[k]["wall_s"] for k in KERNELS)
    speedup = cold_s / warm_s if warm_s > 0 else float("inf")
    print(f"saturation+search wall: cold {cold_s:.2f}s, warm "
          f"{warm_s:.3f}s -> {speedup:.0f}x (floor {SPEEDUP_FLOOR:.0f}x)")
    if speedup < SPEEDUP_FLOOR:
        failures.append(f"replay speedup {speedup:.1f}x below the "
                        f"{SPEEDUP_FLOOR:.0f}x floor")

    if failures:
        print(f"\nFAIL: {len(failures)} cache-smoke violation(s):",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nOK: {len(KERNELS)} kernels replayed bit-identically from "
          f"{cache_dir} across PYTHONHASHSEED 11 -> 23")
    return 0


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--worker":
        _worker(sys.argv[2])
    else:
        sys.exit(main())
