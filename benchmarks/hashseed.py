"""Shared hash-seed pin for the deterministic benchmark entry points.

E-node sets iterate in hash order, which drives rule-match ordering and
plateau tie-breaks in extraction — so any script whose output is
committed or gated (bench_regression.py, roofline_table.py --kernels)
must run under one fixed seed or its numbers drift per process.
"""
from __future__ import annotations

import os
import sys


def reexec_with_fixed_hashseed() -> None:
    """Re-exec the current script with PYTHONHASHSEED=0 (no-op when the
    seed is already pinned)."""
    if os.environ.get("PYTHONHASHSEED") != "0":
        os.environ["PYTHONHASHSEED"] = "0"
        os.execv(sys.executable, [sys.executable] + sys.argv)
