"""Timing harness: measured per-instance kernel latencies → calibration.

Closes the predicted-vs-measured loop the cost model was missing: every
kernel the saturator prices analytically is *run* and timed, and
``--fit`` feeds the timings to :mod:`repro.analysis.calibrate` to fit
the roofline latency model's free parameters (per-op-class VPU pass
coefficients, HBM efficiency, per-bound overlap slack, launch overhead),
persisting them as versioned device profiles under
``experiments/device_profiles/``.

Three measurement lanes, each flagged with an explicit ``measured_kind``
(profiles are fitted per kind — the units are not comparable):

* **model tile programs** (``repro.kernels.tile_programs``) run through
  their *generated Pallas kernels* on one (8, 128) tile — compiled on
  TPU/GPU (``pallas_compiled``), interpret mode on CPU
  (``pallas_interpret``: the kernel body executes op-by-op in Python, so
  absolute times are dispatch-dominated; the fitted coefficients and the
  rank ordering are what carry signal).
* **compiled lane** (PR 8, first-class): the same tile kernels timed
  under one ``jax.jit`` per schedule (``pallas_compiled``). Each row
  records ``compile_path`` — ``"native"`` when the Pallas primitives
  lower to the accelerator, ``"xla_interpret"`` on CPU where the
  interpret-mode kernel is traced and compiled by XLA (dispatch
  overhead gone, op costs remain; the honest label keeps the two from
  being conflated). ``--backend pallas_pipelined`` swaps in the
  pipelined emitter (interpret fallback on CPU — bit-identical source,
  so CPU rows measure the same code with a compiled-lane label).
* **NPB/SPEC suite kernels** (``benchmarks.kernel_suite`` — indexed
  loads/loops, not Pallas-tilable) run their saturated JAX thread body
  sequentially over the grid under one jit (``jax_<backend>_grid``);
  measured per-instance time is wall / n_threads. Their features carry
  the PR-8 trip-count profile (``cg_like``'s ``nnz`` loop).

Warmup iterations are discarded, the median of ``--reps`` repeats is
kept, and inputs are seeded deterministically; the process re-execs with
``PYTHONHASHSEED=0`` (shared ``hashseed`` machinery) so the *extraction
choice* being timed is the exact one the committed tables predict.

Usage:
    python -m benchmarks.measure              # measure, write JSON
    python benchmarks/measure.py --fit        # measure + fit + save profiles
    python benchmarks/measure.py --smoke      # 2-kernel CI smoke check
    python benchmarks/measure.py --kernels rmsnorm,swiglu --reps 3
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import time

if __package__ in (None, ""):        # direct script invocation
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bootstrap import OUT_ROOT, ROOT, die_with_import_help
from benchmarks.hashseed import reexec_with_fixed_hashseed

reexec_with_fixed_hashseed()

try:
    import numpy as np
    import jax
except ImportError as e:
    die_with_import_help(e)

MEASUREMENTS_SCHEMA_VERSION = 3   # 1 = PR-4; 2 = +schedule (PR 5);
                                  # 3 = +emitter/compile_path (PR 8)
PROFILE_DIR = ROOT / "experiments" / "device_profiles"
DEFAULT_OUT = OUT_ROOT / "measurements.json"

# Tile programs measured for calibration; a couple of e-graphs
# (e.g. adamw) exceed the straight-line Pallas checks' comfort zone on
# row-block autosizing, so the set is explicit and ordered.
TILE_KERNELS = ("rmsnorm", "rmsnorm_gated", "layernorm", "swiglu", "gelu",
                "rotary", "residual_scale", "softmax", "adamw",
                "sgd_momentum", "ssd_gate", "moe_router", "l2_clip")
SMOKE_KERNELS = ("swiglu", "rmsnorm")
# every tile kernel is timed under each statement order (PR 5): same
# extracted term, different emission schedule
SCHEDULES = ("source", "bulk", "cost")
# the cost-driven schedule is priced with the committed PR-4 interpret
# profile when present, so the measured order is the calibrated
# objective's pick, not the analytic guess
SCHED_PROFILE = "cpu_pallas_interpret"
# Pallas emission backends the tile lanes can measure (repro.core.emit)
BACKENDS = ("pallas", "pallas_pipelined")


def _backend() -> str:
    return jax.default_backend()


# ---------------------------------------------------------------------------
# Tile programs through their generated Pallas kernels
# ---------------------------------------------------------------------------
def tile_inputs_for(prog, seed: int = 0):
    """Deterministic (arrays, scalars) for a tile program from its
    declared shapes ((8, 128) when undeclared); values in [0.1, 1.0) so
    log/rsqrt/recip domains stay safe."""
    from repro.analysis import TILE_SHAPE
    rng = np.random.default_rng(seed)
    arrays = []
    for spec in prog.arrays.values():
        if spec.role not in ("in", "inout"):
            continue
        shape = getattr(spec, "shape", None) or TILE_SHAPE
        shape = tuple(TILE_SHAPE[i] if d is None else int(d)
                      for i, d in enumerate(shape))
        arrays.append(rng.uniform(0.1, 1.0, size=shape).astype(np.float32))
    scalars = {s: 0.5 for s in prog.scalars}
    return arrays, scalars


def _sched_profile_name():
    """The committed calibrated profile driving the cost-schedule
    search, if present (fresh checkouts without profiles fall back to
    the analytic model)."""
    return (SCHED_PROFILE
            if (PROFILE_DIR / f"{SCHED_PROFILE}.json").exists() else None)


def _tile_op_for(name: str, schedule: str, emitter: str = None):
    from repro.kernels.tile_programs import get_tile_op
    # None (not "pallas") keeps pre-PR-8 cache fingerprints byte-identical
    return get_tile_op(name, schedule=schedule,
                       device_profile=(_sched_profile_name()
                                       if schedule == "cost" else None),
                       emitter=(emitter if emitter not in (None, "pallas")
                                else None))


def _tile_features(op, schedule: str) -> dict:
    """Schedule features of the order actually emitted: the Pallas
    generator's own ScheduleResult for "cost", a recomputed named order
    otherwise (deterministic either way)."""
    from repro.analysis import kernel_features
    from repro.core import compute_schedule
    sr = op.pk.schedule
    if sr is None:
        sr = compute_schedule(op.sk.ssa, dict(op.sk.extraction.choice),
                              mode=schedule)
    return kernel_features(op.sk, schedule=sr).to_dict()


def measure_tile_schedules(name: str, reps: int, warmup: int = 3,
                           schedules=SCHEDULES, emitter: str = None) -> list:
    """Median per-call wall time of one tile program's Pallas kernel on
    a single (8, 128) tile (grid of one → per-call == per-instance),
    under every statement ``schedule``.

    The schedules are timed **interleaved round-robin** (rep 1 of every
    schedule, then rep 2, ...): all orders run the same number of ops,
    so sequential blocks would hand whichever schedule ran first any
    machine-load drift; interleaving gives every schedule the same
    drift profile and the medians compare cleanly. The within-cycle
    order additionally *rotates* every rep — a fixed order hands the
    later slots the earlier calls' GC/allocator debt, which showed up
    as a systematic per-position bias — and collection runs between
    cycles, outside the timed region.
    """
    import gc
    ops = {s: _tile_op_for(name, s, emitter) for s in schedules}
    arrays, scalars = tile_inputs_for(next(iter(ops.values())).sk.ssa.prog)
    args = [jax.numpy.asarray(a) for a in arrays]

    def call(op):
        return jax.block_until_ready(op.apply(*args, **scalars))

    for _ in range(warmup):
        for op in ops.values():
            call(op)
    times = {s: [] for s in schedules}
    order = list(schedules)
    gc_was_enabled = gc.isenabled()
    try:
        for rep in range(reps):
            gc.collect()
            gc.disable()
            rot = rep % len(order)
            for s in order[rot:] + order[:rot]:
                t0 = time.perf_counter()
                call(ops[s])
                times[s].append(time.perf_counter() - t0)
            if gc_was_enabled:
                gc.enable()
    finally:
        if gc_was_enabled:
            gc.enable()
    kind = ("pallas_interpret" if _backend() == "cpu"
            else "pallas_compiled")
    rows = []
    for s in schedules:
        row = {"kernel": name, "group": "tile", "measured_kind": kind,
               "schedule": s, "emitter": ops[s].pk.emitter,
               "measured_ns": statistics.median(times[s]) * 1e9,
               "reps": reps, "warmup": warmup,
               "features": _tile_features(ops[s], s)}
        if s != "bulk" and "bulk" in times:
            # paired per-rep delta vs the bulk order measured in the
            # same interleaved cycle: correlated machine-load noise
            # cancels, so this is the statistic the measured gate uses
            row["paired_vs_bulk_pct"] = statistics.median(
                100.0 * (c - b) / b
                for c, b in zip(times[s], times["bulk"]))
        rows.append(row)
    return rows


def measure_tile_kernel(name: str, reps: int, warmup: int = 3,
                        schedule: str = "bulk") -> dict:
    """Single-schedule measurement (the PR-4 entry point, kept for the
    smoke path and ad-hoc use)."""
    return measure_tile_schedules(name, reps, warmup,
                                  schedules=(schedule,))[0]


def measure_tile_compiled(name: str, reps: int, warmup: int = 3,
                          schedules=SCHEDULES, emitter: str = None) -> list:
    """The compiled lane (PR 8): the same tile kernels, each schedule
    jitted once and timed hot — ``measured_kind: "pallas_compiled"``.

    On CPU the Pallas call still runs in interpret mode, but *traced
    under jit*: XLA compiles the interpreted op graph, so the Python
    dispatch overhead that dominates the eager interpret lane is gone
    while the op costs remain. Rows record which it was in
    ``compile_path`` (``"xla_interpret"`` vs ``"native"``) so a fitted
    ``*_pallas_compiled_sched`` profile is never mistaken for real
    accelerator numbers. Interleaving/rotation/gc discipline matches
    :func:`measure_tile_schedules`."""
    import gc
    ops = {s: _tile_op_for(name, s, emitter) for s in schedules}
    arrays, scalars = tile_inputs_for(next(iter(ops.values())).sk.ssa.prog)
    args = [jax.numpy.asarray(a) for a in arrays]
    native = _backend() != "cpu"
    fns = {}
    for s, op in ops.items():
        fns[s] = jax.jit(lambda *a, _op=op: _op.apply(*a, **scalars))

    def call(fn):
        return jax.block_until_ready(fn(*args))

    for _ in range(warmup + 1):      # +1: jit compile outside the clock
        for fn in fns.values():
            call(fn)
    times = {s: [] for s in schedules}
    order = list(schedules)
    gc_was_enabled = gc.isenabled()
    try:
        for rep in range(reps):
            gc.collect()
            gc.disable()
            rot = rep % len(order)
            for s in order[rot:] + order[:rot]:
                t0 = time.perf_counter()
                call(fns[s])
                times[s].append(time.perf_counter() - t0)
            if gc_was_enabled:
                gc.enable()
    finally:
        if gc_was_enabled:
            gc.enable()
    rows = []
    for s in schedules:
        row = {"kernel": name, "group": "tile",
               "measured_kind": "pallas_compiled",
               "compile_path": "native" if native else "xla_interpret",
               "schedule": s, "emitter": ops[s].pk.emitter,
               "measured_ns": statistics.median(times[s]) * 1e9,
               "reps": reps, "warmup": warmup,
               "features": _tile_features(ops[s], s)}
        if s != "bulk" and "bulk" in times:
            row["paired_vs_bulk_pct"] = statistics.median(
                100.0 * (c - b) / b
                for c, b in zip(times[s], times["bulk"]))
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# NPB/SPEC suite kernels through the jitted grid runner
# ---------------------------------------------------------------------------
def measure_suite_kernel(name: str, reps: int, n: int = 64 * 64,
                         warmup: int = 1) -> dict:
    from repro.analysis import kernel_features
    from repro.core import SaturatorConfig, saturate_program
    from benchmarks.ablation import build_grid_runner
    from benchmarks.kernel_suite import SUITE, inputs_for
    arrays, gscalar, grid, scalars = inputs_for(name, n=n)
    sk = saturate_program(SUITE[name](), SaturatorConfig())
    fn, init_state, n_threads = build_grid_runner(sk, arrays, gscalar,
                                                  grid, scalars)
    for _ in range(warmup + 1):       # +1: jit compile
        jax.block_until_ready(fn(init_state))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(init_state))
        times.append(time.perf_counter() - t0)
    return {"kernel": name, "group": "suite",
            "measured_kind": f"jax_{_backend()}_grid",
            "measured_ns": statistics.median(times) / n_threads * 1e9,
            "reps": reps, "warmup": warmup, "n_threads": n_threads,
            # scalars resolve runtime-bound trip counts (cg_like's nnz)
            "features": kernel_features(sk, scalars=scalars).to_dict()}


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------
def measure_all(kernels=None, reps: int = 5, n: int = 64 * 64,
                schedules=SCHEDULES, backend: str = "pallas",
                compiled: bool = True) -> dict:
    """Measure every requested kernel; returns the measurements document
    (also the ``measure`` section of ``benchmarks/run.py``). Tile
    kernels are timed once per statement schedule — same extracted
    term, different emission order — and, with ``compiled`` on a CPU
    host, once more per schedule under jit (the compiled lane; on
    accelerators the eager lane already *is* ``pallas_compiled``, so no
    second lane runs). ``backend`` picks the Pallas emitter."""
    from benchmarks.kernel_suite import SUITE
    from repro.analysis import DEFAULT_PARAMS, predict_ns, KernelFeatures
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {backend!r}")
    rows = []
    for name in TILE_KERNELS:
        if kernels and name not in kernels:
            continue
        rows.extend(measure_tile_schedules(name, reps, schedules=schedules,
                                           emitter=backend))
        if compiled and _backend() == "cpu":
            rows.extend(measure_tile_compiled(name, reps,
                                              schedules=schedules,
                                              emitter=backend))
    for name in SUITE:
        if kernels and name not in kernels:
            continue
        rows.append(measure_suite_kernel(name, reps, n=n))
    for r in rows:
        feat = KernelFeatures.from_dict(r["features"])
        r["predicted_ns"] = predict_ns(feat, DEFAULT_PARAMS)
    return {"schema_version": MEASUREMENTS_SCHEMA_VERSION,
            "backend": _backend(), "emitter": backend, "rows": rows}


def fit_profiles(doc: dict, out_dir: pathlib.Path = PROFILE_DIR) -> list:
    """Fit one device profile per measured_kind group.

    Tile-kernel groups are fitted from their **cost-schedule** rows with
    the PR-5 schedule features (per-load overlap windows), yielding a
    schedule-aware ``<backend>_<kind>_sched`` profile; the committed
    PR-4 ``<backend>_<kind>`` profile (bulk schedule, no schedule
    features) is left untouched so the two stay comparable in CI. The
    fitted sched profile additionally embeds every schedule's measured
    medians (``fit["schedule_medians"]``) — the bench-regression gate's
    measured cost-vs-bulk leg reads them without re-timing.

    A fit is *promoted* into ``experiments/device_profiles/`` (and from
    there enforced by the bench-regression CI gate) only when it clears
    the acceptance bar — Spearman >= 0.8 and strictly better MAPE than
    the uncalibrated defaults. Fits that fail land in the gitignored out
    dir with a warning: a profile the model cannot rank faithfully would
    make extraction *worse*, not better (e.g. the jitted-grid suite
    path, where XLA fuses the scalar thread bodies so tile-semantics
    features cannot explain the measured ordering).
    """
    from repro.analysis import SPEARMAN_FLOOR, KernelFeatures, fit_profile
    groups = {}
    for r in doc["rows"]:
        sched = r.get("schedule")
        if r.get("group") == "tile" and sched is not None \
                and sched != "cost":
            continue   # only the cost-schedule rows are fitted
        groups.setdefault(r["measured_kind"], []).append(r)
    medians = {}   # per measured_kind: the lanes must not mix (PR 8)
    for r in doc["rows"]:
        if r.get("group") == "tile" and r.get("schedule") is not None:
            entry = medians.setdefault(r["measured_kind"], {}) \
                .setdefault(r["kernel"], {})
            entry[r["schedule"]] = r["measured_ns"]
            if r["schedule"] == "cost" and "paired_vs_bulk_pct" in r:
                entry["cost_vs_bulk_paired_pct"] = r["paired_vs_bulk_pct"]
    written = []
    for kind, rows in sorted(groups.items()):
        if len(rows) < 2:
            print(f"skip {kind}: need >= 2 kernels to fit, have {len(rows)}")
            continue
        feats = [KernelFeatures.from_dict(r["features"]) for r in rows]
        meas = [r["measured_ns"] for r in rows]
        backend = doc["backend"]
        sched_group = rows[0].get("group") == "tile"
        # profile file stem: <measured device>_<path>, e.g.
        # cpu_pallas_interpret_sched, cpu_jax_grid, tpu_pallas_compiled
        name = (f"{backend}_jax_grid" if kind == f"jax_{backend}_grid"
                else f"{backend}_{kind}")
        if sched_group:
            name += "_sched"
        prof = fit_profile(feats, meas, name=name, chip=backend,
                           measured_kind=kind)
        if sched_group and medians.get(kind):
            prof.fit["schedule_medians"] = medians[kind]
            prof.fit["schedule_mode"] = "cost"
            cp = rows[0].get("compile_path")
            if cp is not None:
                prof.fit["compile_path"] = cp
        f = prof.fit
        ok = (f["spearman"] >= SPEARMAN_FLOOR
              and f["mape_pct"] < f["uncalibrated_mape_pct"])
        path = prof.save((out_dir if ok else OUT_ROOT) / f"{name}.json")
        print(f"fitted {name}: {len(rows)} kernels  "
              f"MAPE {f['mape_pct']:.1f}% (uncal {f['uncalibrated_mape_pct']:.1f}%)  "
              f"Spearman {f['spearman']:.3f} (uncal {f['uncalibrated_spearman']:.3f})")
        if f.get("kernels") and prof.params.overlap_efficiency is not None:
            print(f"  fitted overlap_efficiency "
                  f"{prof.params.overlap_efficiency:.3f}")
        if ok:
            written.append(path)
        else:
            print(f"  NOT promoted (needs Spearman >= {SPEARMAN_FLOOR} and "
                  f"MAPE < uncalibrated): kept at {path}")
    return written


def smoke() -> int:
    """CI calibration smoke: fit 2 tile kernels in interpret mode and
    assert the resulting profile round-trips and scores sanely. Uses
    the cost-driven schedule, so the schedule features (per-load
    overlap windows) flow through fit and persistence end-to-end."""
    from repro.analysis import (DeviceProfile, KernelFeatures, check_profile,
                                fit_profile, load_profile)
    rows = [measure_tile_kernel(k, reps=3, schedule="cost")
            for k in SMOKE_KERNELS]
    for r in rows:
        assert r["features"].get("sched_loads"), \
            "cost-schedule measurement lost its schedule features"
    feats = [KernelFeatures.from_dict(r["features"]) for r in rows]
    meas = [r["measured_ns"] for r in rows]
    prof = fit_profile(feats, meas, name="smoke", chip=_backend(),
                       measured_kind=rows[0]["measured_kind"])
    back = DeviceProfile.from_json(prof.to_json(), name="smoke")
    assert back.params == prof.params, "profile params did not round-trip"
    assert back.fit == prof.fit, "profile fit evidence did not round-trip"
    out = OUT_ROOT / "smoke_profile.json"
    prof.save(out)
    loaded = load_profile(out)
    assert loaded.params == prof.params, "saved profile did not load back"
    lm = loaded.latency_model()
    assert lm.hbm_efficiency == prof.params.hbm_efficiency
    # 2 points / many params → the fit must interpolate near-exactly
    assert prof.fit["mape_pct"] < 5.0, \
        f"2-kernel fit MAPE {prof.fit['mape_pct']:.2f}% unexpectedly large"
    fails = check_profile(loaded, spearman_floor=0.0)
    assert not fails, f"smoke profile failed checks: {fails}"
    print(f"calibration smoke OK: {len(rows)} kernels, "
          f"MAPE {prof.fit['mape_pct']:.2f}%, profile round-trips ({out})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", help="comma-separated subset")
    ap.add_argument("--reps", type=int, default=9,
                    help="median-of-N timing repeats (default 9)")
    ap.add_argument("--n", type=int, default=64 * 64,
                    help="suite grid size (default 4096 threads)")
    ap.add_argument("--schedules", default=",".join(SCHEDULES),
                    help="comma-separated statement schedules to time "
                         f"per tile kernel (default {','.join(SCHEDULES)})")
    ap.add_argument("--backend", choices=BACKENDS, default="pallas",
                    help="Pallas emission backend for the tile lanes "
                         "(default pallas; pallas_pipelined emits "
                         "double-buffered async copies, interpret "
                         "fallback on CPU)")
    ap.add_argument("--no-compiled", action="store_true",
                    help="skip the jitted compiled lane on CPU hosts")
    ap.add_argument("--fit", action="store_true",
                    help="fit device profiles from the measurements and "
                         f"save them under {PROFILE_DIR}")
    ap.add_argument("--smoke", action="store_true",
                    help="2-kernel interpret-mode fit + round-trip check")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT,
                    help="measurements JSON path")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    kernels = set(args.kernels.split(",")) if args.kernels else None
    doc = measure_all(kernels=kernels, reps=args.reps, n=args.n,
                      schedules=tuple(args.schedules.split(",")),
                      backend=args.backend,
                      compiled=not args.no_compiled)
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {args.out} ({len(doc['rows'])} rows, "
          f"backend={doc['backend']}, emitter={doc['emitter']})")
    for r in doc["rows"]:
        sched = r.get("schedule", "-")
        lane = r["measured_kind"] + (
            f"/{r['compile_path']}" if "compile_path" in r else "")
        print(f"  {r['kernel']:24s} {sched:>6s} {r['measured_ns']:14.1f} ns"
              f"  [{lane}]")
    if args.fit:
        written = fit_profiles(doc)
        for p in written:
            print(f"wrote {p}")
        print("NOTE: refresh the committed predicted-vs-measured table + "
              "baseline with `python benchmarks/bench_regression.py "
              "--update` and commit the diffs.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
