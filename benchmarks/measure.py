"""Timing harness: measured per-instance kernel latencies → calibration.

Closes the predicted-vs-measured loop the cost model was missing: every
kernel the saturator prices analytically is *run* and timed, and
``--fit`` feeds the timings to :mod:`repro.analysis.calibrate` to fit
the roofline latency model's free parameters (per-op-class VPU pass
coefficients, HBM efficiency, per-bound overlap slack, launch overhead),
persisting them as versioned device profiles under
``experiments/device_profiles/``.

Two measurement paths, each flagged with an explicit ``measured_kind``
(profiles are fitted per kind — the units are not comparable):

* **model tile programs** (``repro.kernels.tile_programs``) run through
  their *generated Pallas kernels* on one (8, 128) tile — compiled on
  TPU/GPU (``pallas_compiled``), interpret mode on CPU
  (``pallas_interpret``: the kernel body executes op-by-op in Python, so
  absolute times are dispatch-dominated; the fitted coefficients and the
  rank ordering are what carry signal).
* **NPB/SPEC suite kernels** (``benchmarks.kernel_suite`` — indexed
  loads/loops, not Pallas-tilable) run their saturated JAX thread body
  sequentially over the grid under one jit (``jax_<backend>_grid``);
  measured per-instance time is wall / n_threads.

Warmup iterations are discarded, the median of ``--reps`` repeats is
kept, and inputs are seeded deterministically; the process re-execs with
``PYTHONHASHSEED=0`` (shared ``hashseed`` machinery) so the *extraction
choice* being timed is the exact one the committed tables predict.

Usage:
    python -m benchmarks.measure              # measure, write JSON
    python benchmarks/measure.py --fit        # measure + fit + save profiles
    python benchmarks/measure.py --smoke      # 2-kernel CI smoke check
    python benchmarks/measure.py --kernels rmsnorm,swiglu --reps 3
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import time

if __package__ in (None, ""):        # direct script invocation
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bootstrap import OUT_ROOT, ROOT, die_with_import_help
from benchmarks.hashseed import reexec_with_fixed_hashseed

reexec_with_fixed_hashseed()

try:
    import numpy as np
    import jax
except ImportError as e:
    die_with_import_help(e)

MEASUREMENTS_SCHEMA_VERSION = 1
PROFILE_DIR = ROOT / "experiments" / "device_profiles"
DEFAULT_OUT = OUT_ROOT / "measurements.json"

# Tile programs measured for calibration; a couple of e-graphs
# (e.g. adamw) exceed the straight-line Pallas checks' comfort zone on
# row-block autosizing, so the set is explicit and ordered.
TILE_KERNELS = ("rmsnorm", "rmsnorm_gated", "layernorm", "swiglu", "gelu",
                "rotary", "residual_scale", "softmax", "adamw",
                "sgd_momentum", "ssd_gate", "moe_router", "l2_clip")
SMOKE_KERNELS = ("swiglu", "rmsnorm")


def _backend() -> str:
    return jax.default_backend()


# ---------------------------------------------------------------------------
# Tile programs through their generated Pallas kernels
# ---------------------------------------------------------------------------
def tile_inputs_for(prog, seed: int = 0):
    """Deterministic (arrays, scalars) for a tile program from its
    declared shapes ((8, 128) when undeclared); values in [0.1, 1.0) so
    log/rsqrt/recip domains stay safe."""
    from repro.analysis import TILE_SHAPE
    rng = np.random.default_rng(seed)
    arrays = []
    for spec in prog.arrays.values():
        if spec.role not in ("in", "inout"):
            continue
        shape = getattr(spec, "shape", None) or TILE_SHAPE
        shape = tuple(TILE_SHAPE[i] if d is None else int(d)
                      for i, d in enumerate(shape))
        arrays.append(rng.uniform(0.1, 1.0, size=shape).astype(np.float32))
    scalars = {s: 0.5 for s in prog.scalars}
    return arrays, scalars


def measure_tile_kernel(name: str, reps: int, warmup: int = 3) -> dict:
    """Median per-call wall time of one tile program's Pallas kernel on a
    single (8, 128) tile (grid of one → per-call == per-instance)."""
    from repro.analysis import kernel_features
    from repro.kernels.tile_programs import get_tile_op
    op = get_tile_op(name)
    arrays, scalars = tile_inputs_for(op.sk.ssa.prog)
    args = [jax.numpy.asarray(a) for a in arrays]

    def call():
        out = op.apply(*args, **scalars)
        return jax.block_until_ready(out)

    for _ in range(warmup):
        call()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        call()
        times.append(time.perf_counter() - t0)
    kind = ("pallas_interpret" if _backend() == "cpu"
            else "pallas_compiled")
    return {"kernel": name, "group": "tile", "measured_kind": kind,
            "measured_ns": statistics.median(times) * 1e9,
            "reps": reps, "warmup": warmup,
            "features": kernel_features(op.sk).to_dict()}


# ---------------------------------------------------------------------------
# NPB/SPEC suite kernels through the jitted grid runner
# ---------------------------------------------------------------------------
def measure_suite_kernel(name: str, reps: int, n: int = 64 * 64,
                         warmup: int = 1) -> dict:
    from repro.analysis import kernel_features
    from repro.core import SaturatorConfig, saturate_program
    from benchmarks.ablation import build_grid_runner
    from benchmarks.kernel_suite import SUITE, inputs_for
    arrays, gscalar, grid, scalars = inputs_for(name, n=n)
    sk = saturate_program(SUITE[name](), SaturatorConfig())
    fn, init_state, n_threads = build_grid_runner(sk, arrays, gscalar,
                                                  grid, scalars)
    for _ in range(warmup + 1):       # +1: jit compile
        jax.block_until_ready(fn(init_state))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(init_state))
        times.append(time.perf_counter() - t0)
    return {"kernel": name, "group": "suite",
            "measured_kind": f"jax_{_backend()}_grid",
            "measured_ns": statistics.median(times) / n_threads * 1e9,
            "reps": reps, "warmup": warmup, "n_threads": n_threads,
            "features": kernel_features(sk).to_dict()}


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------
def measure_all(kernels=None, reps: int = 5, n: int = 64 * 64) -> dict:
    """Measure every requested kernel; returns the measurements document
    (also the ``measure`` section of ``benchmarks/run.py``)."""
    from benchmarks.kernel_suite import SUITE
    from repro.analysis import DEFAULT_PARAMS, predict_ns, KernelFeatures
    rows = []
    for name in TILE_KERNELS:
        if kernels and name not in kernels:
            continue
        rows.append(measure_tile_kernel(name, reps))
    for name in SUITE:
        if kernels and name not in kernels:
            continue
        rows.append(measure_suite_kernel(name, reps, n=n))
    for r in rows:
        feat = KernelFeatures.from_dict(r["features"])
        r["predicted_ns"] = predict_ns(feat, DEFAULT_PARAMS)
    return {"schema_version": MEASUREMENTS_SCHEMA_VERSION,
            "backend": _backend(), "rows": rows}


def fit_profiles(doc: dict, out_dir: pathlib.Path = PROFILE_DIR) -> list:
    """Fit one device profile per measured_kind group.

    A fit is *promoted* into ``experiments/device_profiles/`` (and from
    there enforced by the bench-regression CI gate) only when it clears
    the acceptance bar — Spearman >= 0.8 and strictly better MAPE than
    the uncalibrated defaults. Fits that fail land in the gitignored out
    dir with a warning: a profile the model cannot rank faithfully would
    make extraction *worse*, not better (e.g. the jitted-grid suite
    path, where XLA fuses the scalar thread bodies so tile-semantics
    features cannot explain the measured ordering).
    """
    from repro.analysis import SPEARMAN_FLOOR, KernelFeatures, fit_profile
    groups = {}
    for r in doc["rows"]:
        groups.setdefault(r["measured_kind"], []).append(r)
    written = []
    for kind, rows in sorted(groups.items()):
        if len(rows) < 2:
            print(f"skip {kind}: need >= 2 kernels to fit, have {len(rows)}")
            continue
        feats = [KernelFeatures.from_dict(r["features"]) for r in rows]
        meas = [r["measured_ns"] for r in rows]
        backend = doc["backend"]
        # profile file stem: <measured device>_<path>, e.g.
        # cpu_pallas_interpret, cpu_jax_grid, tpu_pallas_compiled
        name = (f"{backend}_jax_grid" if kind == f"jax_{backend}_grid"
                else f"{backend}_{kind}")
        prof = fit_profile(feats, meas, name=name, chip=backend,
                           measured_kind=kind)
        f = prof.fit
        ok = (f["spearman"] >= SPEARMAN_FLOOR
              and f["mape_pct"] < f["uncalibrated_mape_pct"])
        path = prof.save((out_dir if ok else OUT_ROOT) / f"{name}.json")
        print(f"fitted {name}: {len(rows)} kernels  "
              f"MAPE {f['mape_pct']:.1f}% (uncal {f['uncalibrated_mape_pct']:.1f}%)  "
              f"Spearman {f['spearman']:.3f} (uncal {f['uncalibrated_spearman']:.3f})")
        if ok:
            written.append(path)
        else:
            print(f"  NOT promoted (needs Spearman >= {SPEARMAN_FLOOR} and "
                  f"MAPE < uncalibrated): kept at {path}")
    return written


def smoke() -> int:
    """CI calibration smoke: fit 2 tile kernels in interpret mode and
    assert the resulting profile round-trips and scores sanely."""
    from repro.analysis import (DeviceProfile, KernelFeatures, check_profile,
                                fit_profile, load_profile)
    rows = [measure_tile_kernel(k, reps=3) for k in SMOKE_KERNELS]
    feats = [KernelFeatures.from_dict(r["features"]) for r in rows]
    meas = [r["measured_ns"] for r in rows]
    prof = fit_profile(feats, meas, name="smoke", chip=_backend(),
                       measured_kind=rows[0]["measured_kind"])
    back = DeviceProfile.from_json(prof.to_json(), name="smoke")
    assert back.params == prof.params, "profile params did not round-trip"
    assert back.fit == prof.fit, "profile fit evidence did not round-trip"
    out = OUT_ROOT / "smoke_profile.json"
    prof.save(out)
    loaded = load_profile(out)
    assert loaded.params == prof.params, "saved profile did not load back"
    lm = loaded.latency_model()
    assert lm.hbm_efficiency == prof.params.hbm_efficiency
    # 2 points / many params → the fit must interpolate near-exactly
    assert prof.fit["mape_pct"] < 5.0, \
        f"2-kernel fit MAPE {prof.fit['mape_pct']:.2f}% unexpectedly large"
    fails = check_profile(loaded, spearman_floor=0.0)
    assert not fails, f"smoke profile failed checks: {fails}"
    print(f"calibration smoke OK: {len(rows)} kernels, "
          f"MAPE {prof.fit['mape_pct']:.2f}%, profile round-trips ({out})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", help="comma-separated subset")
    ap.add_argument("--reps", type=int, default=9,
                    help="median-of-N timing repeats (default 9)")
    ap.add_argument("--n", type=int, default=64 * 64,
                    help="suite grid size (default 4096 threads)")
    ap.add_argument("--fit", action="store_true",
                    help="fit device profiles from the measurements and "
                         f"save them under {PROFILE_DIR}")
    ap.add_argument("--smoke", action="store_true",
                    help="2-kernel interpret-mode fit + round-trip check")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT,
                    help="measurements JSON path")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    kernels = set(args.kernels.split(",")) if args.kernels else None
    doc = measure_all(kernels=kernels, reps=args.reps, n=args.n)
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {args.out} ({len(doc['rows'])} kernels, "
          f"backend={doc['backend']})")
    for r in doc["rows"]:
        print(f"  {r['kernel']:24s} {r['measured_ns']:14.1f} ns  "
              f"[{r['measured_kind']}]")
    if args.fit:
        written = fit_profiles(doc)
        for p in written:
            print(f"wrote {p}")
        print("NOTE: refresh the committed predicted-vs-measured table + "
              "baseline with `python benchmarks/bench_regression.py "
              "--update` and commit the diffs.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
