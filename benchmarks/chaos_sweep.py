"""Chaos sweep gate (PR 10 CI job): every fault site, every tile kernel.

For each kernel the sweep first builds three *un-faulted* baselines —
one per degradation-ladder level the guarded runtime can land on:

  * **full**  — the configured pipeline (also pre-populates a cache
    directory, so cache-fault cases start from a valid entry);
  * **cheap** — the ladder's reduced-search rung
    (``repro.core.pipeline._cheap_config``);
  * **ref**   — the reference-interpreter floor
    (``repro.core.pipeline._reference_kernel``).

Then every fault site from :data:`repro.runtime.chaos.FAULT_SITES` is
injected (plus an un-faulted control case that must be an exact cache
hit) and the sweep asserts, per (kernel, site):

  1. **zero unhandled exceptions** — the guarded entry points never
     raise, whatever the fault;
  2. the build lands on the **expected ladder level** (cache faults
     degrade to a cold rebuild, search/verify faults to the cheap rung,
     codegen faults to the reference floor);
  3. the generated kernel's outputs are **bit-identical** to the
     un-faulted baseline *of that level* — degradation changes how hard
     we searched, never what the kernel computes;
  4. the op-level outputs are **allclose to the full baseline** (all
     rungs agree numerically);
  5. telemetry recorded the chaos fire (and, for cache sites, the
     rejected/failed entry).

The JSON report contains only hashseed-invariant facts (levels, match
booleans, deterministic fire counts — no wall times, no raw hashes), so
CI runs the sweep under two ``PYTHONHASHSEED`` values and ``cmp``s the
reports byte-for-byte.

Run:  python benchmarks/chaos_sweep.py [--smoke] [--out report.json]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import pathlib
import shutil
import sys
import tempfile
import traceback
from typing import Dict, List, Optional

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.analysis import TILE_SHAPE  # noqa: E402
from repro.core import (CacheConfig, SaturatorConfig, ScheduleConfig,  # noqa: E402
                        VerifyConfig, make_tile_op)
from repro.core.pipeline import (_cheap_config, _reference_kernel,  # noqa: E402
                                 _saturate_attempt)
from repro.core.telemetry import telemetry  # noqa: E402
from repro.kernels.tile_programs import PROGRAMS  # noqa: E402
from repro.runtime import chaos  # noqa: E402
from repro.runtime.guard import reset_breakers  # noqa: E402

KERNELS = tuple(sorted(PROGRAMS))
SMOKE_KERNELS = ("rmsnorm", "adamw", "ssd_gate")
SCALAR_VAL = 0.5
_MARK = "CHAOS_SWEEP_JSON:"

# (site, FaultPlan kwargs, expected ladder level, cache-dir setup).
# Setup: "prepop" = copy of a directory holding the kernel's valid
# entry; "fresh" = empty writable directory; None = cache disabled.
CASES = (
    ("none",           None,                   "hit",   "prepop"),
    ("cache_read_io",  dict(max_fires=None),   "cold",  "prepop"),
    ("cache_corrupt",  dict(max_fires=None),   "cold",  "prepop"),
    ("cache_write_io", dict(max_fires=None),   "cold",  "fresh"),
    ("rule_raise",     dict(max_fires=1),      "cheap", None),
    ("egraph_budget",  dict(max_fires=1),      "cheap", None),
    ("verify_error",   dict(max_fires=1),      "cheap", None),
    ("slow_stage",     dict(max_fires=1),      "cheap", None),
    ("exec_fail",      dict(max_fires=None),   "ref",   None),
)
CACHE_SITES = ("cache_read_io", "cache_corrupt", "cache_write_io")


def _site_config(site: str, cache_dir) -> SaturatorConfig:
    """The full-path config a given case runs under. ``verify_error``
    needs the verifier in the loop; ``slow_stage`` needs the cost
    schedule search (that is where the stall is injected)."""
    verify = "cheap" if site == "verify_error" else None
    return SaturatorConfig(
        mode="accsat", cost_model="tpu_v5e", tpu_rules=True,
        schedule_cfg=ScheduleConfig(
            schedule="cost" if site == "slow_stage" else None),
        cache_cfg=CacheConfig(cache_dir=cache_dir),
        verify_cfg=VerifyConfig(verify=verify) if verify else None)


def _make_arrays(prog) -> Dict[str, np.ndarray]:
    """Deterministic operand set: seeded uniforms for inputs, zero
    buffers for outputs (the reference interpreter requires them)."""
    rng = np.random.default_rng(0)
    arrays = {}
    for name, spec in prog.arrays.items():
        shape = tuple(TILE_SHAPE[i] if d is None else int(d)
                      for i, d in enumerate(
                          getattr(spec, "shape", None) or TILE_SHAPE))
        if spec.role == "out":
            arrays[name] = np.zeros(shape, np.float32)
        else:
            arrays[name] = rng.uniform(
                0.1, 1.0, size=shape).astype(np.float32)
    return arrays


def _eval_fn(sk, arrays) -> str:
    """sha256 over the generated kernel's outputs (generated-kernel
    calling convention: every declared array in order, then scalars)."""
    args = [jnp.asarray(arrays[n]) for n in sk.kernel.in_arrays] \
        + [SCALAR_VAL for _ in sk.kernel.scalars]
    outs = sk.kernel.fn(*args)
    return hashlib.sha256(
        b"".join(np.asarray(o).tobytes() for o in outs)).hexdigest()


def _eval_apply(op, prog, arrays) -> List[np.ndarray]:
    """Outputs through the op-level entry (Pallas interpret on CPU, or
    the degraded jax_ref path when emission/codegen was lost)."""
    ins = [jnp.asarray(arrays[n]) for n, spec in prog.arrays.items()
           if spec.role != "out"]
    scalars = {s: SCALAR_VAL for s in op.sk.kernel.scalars}
    out = op.apply(*ins, **scalars)
    outs = out if isinstance(out, tuple) else (out,)
    return [np.asarray(o) for o in outs]


def _build_baselines(name: str, prepop_root: str, arrays):
    """Un-faulted outputs at each ladder level; the full build also
    populates ``prepop_root`` with the kernel's cache entry."""
    prog = PROGRAMS[name]()
    full_op = make_tile_op(prog, _site_config("none", prepop_root))
    cheap_sk = _saturate_attempt(
        prog, _cheap_config(_site_config("none", False)))
    ref_sk = _reference_kernel(prog, _site_config("none", False))
    return {
        "full": _eval_fn(full_op.sk, arrays),
        # "hit"/"warm"/"cold" all replay/rebuild the full search result
        "hit": _eval_fn(full_op.sk, arrays),
        "cold": _eval_fn(full_op.sk, arrays),
        "cheap": _eval_fn(cheap_sk, arrays),
        "ref": _eval_fn(ref_sk, arrays),
    }, _eval_apply(full_op, prog, arrays)


def run_case(name: str, site: str, plan_kw: Optional[dict],
             expected: str, setup: Optional[str], prepop_root: str,
             fn_baselines: Dict[str, str], apply_baseline,
             tmp_base: str) -> dict:
    telemetry().reset()
    reset_breakers()
    if setup == "prepop":
        cache_dir = os.path.join(tmp_base, f"{name}_{site}_cache")
        shutil.copytree(prepop_root, cache_dir)
    elif setup == "fresh":
        cache_dir = tempfile.mkdtemp(
            prefix=f"{name}_{site}_", dir=tmp_base)
    else:
        cache_dir = False
    prog = PROGRAMS[name]()
    arrays = _make_arrays(prog)
    cfg = _site_config(site, cache_dir)
    plan = chaos.FaultPlan(sites=(site,), **plan_kw) \
        if plan_kw is not None else None

    with chaos.plan_scope(plan):
        op = make_tile_op(prog, cfg)
        fn_hash = _eval_fn(op.sk, arrays)
        apply_outs = _eval_apply(op, prog, arrays)

    snap = telemetry().snapshot()
    level = op.sk.ladder_level
    rec = {
        "expected": expected,
        "level": level,
        "bitwise": fn_hash == fn_baselines[expected],
        "allclose": all(
            np.allclose(a, b, rtol=2e-4, atol=1e-6)
            for a, b in zip(apply_outs, apply_baseline)),
        "chaos_fires": int(
            snap["guard"]["chaos_fires"].get(site, 0)),
        "cache_invalid": int(snap["cache_invalid"]),
    }
    ok = (level == expected and rec["bitwise"] and rec["allclose"]
          and len(apply_outs) == len(apply_baseline))
    if site != "none" and rec["chaos_fires"] < 1:
        ok = False
    if site in CACHE_SITES and rec["cache_invalid"] < 1:
        ok = False
    rec["ok"] = ok
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3-kernel subset (the CI chaos-smoke job)")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated kernel subset")
    ap.add_argument("--out", default=None,
                    help="write the JSON report to this path")
    args = ap.parse_args()

    # the sweep owns its chaos/cache environment
    os.environ.pop(chaos.ENV_VAR, None)
    os.environ.pop("REPRO_SAT_CACHE", None)
    chaos.clear_plan()

    kernels = (tuple(args.kernels.split(",")) if args.kernels
               else SMOKE_KERNELS if args.smoke else KERNELS)
    tmp_base = tempfile.mkdtemp(prefix="repro_chaos_sweep_")
    report: Dict[str, dict] = {"kernels": list(kernels), "cases": {}}
    failures: List[str] = []

    for name in kernels:
        prepop_root = os.path.join(tmp_base, f"{name}_prepop")
        arrays = _make_arrays(PROGRAMS[name]())
        telemetry().reset()
        reset_breakers()
        try:
            fn_baselines, apply_baseline = _build_baselines(
                name, prepop_root, arrays)
        except Exception:
            failures.append(f"{name}: baseline build raised:\n"
                            + traceback.format_exc())
            continue
        report["cases"][name] = {}
        for site, plan_kw, expected, setup in CASES:
            try:
                rec = run_case(name, site, plan_kw, expected, setup,
                               prepop_root, fn_baselines,
                               apply_baseline, tmp_base)
            except Exception:
                rec = {"ok": False, "expected": expected,
                       "level": "<raised>"}
                failures.append(f"{name}/{site}: unhandled exception "
                                f"(the guarded path must never raise):\n"
                                + traceback.format_exc())
            report["cases"][name][site] = rec
            if not rec["ok"]:
                failures.append(
                    f"{name}/{site}: expected level "
                    f"{rec.get('expected')}, got {rec.get('level')} "
                    f"(bitwise={rec.get('bitwise')}, "
                    f"allclose={rec.get('allclose')}, "
                    f"chaos_fires={rec.get('chaos_fires')}, "
                    f"cache_invalid={rec.get('cache_invalid')})")
            status = "ok" if rec["ok"] else "FAIL"
            print(f"  {name:16s} {site:16s} -> {rec.get('level'):6s} "
                  f"(want {expected:6s}) {status}")

    report["ok"] = not failures
    payload = json.dumps(report, sort_keys=True, indent=1)
    if args.out:
        pathlib.Path(args.out).write_text(payload + "\n")
    print(_MARK + json.dumps(report, sort_keys=True))
    shutil.rmtree(tmp_base, ignore_errors=True)

    if failures:
        print(f"\nFAIL: {len(failures)} chaos-sweep violation(s):",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nOK: {len(kernels)} kernels x {len(CASES)} cases — every "
          f"fault degraded to the expected rung with bit-identical "
          f"outputs and no unhandled exceptions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
