"""Exhaustive static-verification sweep (PR 7 acceptance gate).

Certifies every tile kernel under both rule sets and all three
statement orders with ``repro.verify``:

* per (kernel, rule set): one saturation, then **e-graph invariants**;
* per (kernel, rule set, schedule mode): **schedule legality** of the
  explicitly computed order (searchless for source/bulk so the
  certified order is exactly what the emitter/cache replays) and the
  **generated-code AST pass** over both the JAX source and — for
  tilable programs — the Pallas source;
* per rule set: **rule soundness** (random/bf16/adversarial
  differential validation);
* per (kernel, schedule mode, Pallas emitter): the **grid pass** (PR 9)
  — the emitted kernel's ``plan_tile_call`` launch plan is certified
  coverage-complete, write-disjoint, in-bounds (padded remainder tile
  modeled) and inside the exact VMEM budget, at a geometry that forces
  a ragged remainder tile. The hand-written flash-attention and
  SSD-scan BlockSpec layouts are audited once through the same engine.

Exit status is non-zero on any error-severity finding, so CI's
``verify-smoke`` job (a 3-kernel subset via ``--kernels``) gates on
zero errors. Run the full sweep with::

    PYTHONPATH=src python -m benchmarks.verify_sweep [--json out.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List

from repro.core import (SaturatorConfig, SearchConfig, compute_schedule,
                        get_emitter, saturate_program)
from repro.core.pallasgen import SyncPallasGenerator
from repro.core.pipeline import _schedule_cm
from repro.core.schedule import SCHEDULE_MODES
from repro.kernels.tile_programs import PROGRAMS
from repro.verify import (VerifyReport, check_egraph, check_generated,
                          check_grid, flash_attention_model, shapes_of,
                          ssd_scan_model, verify_rules, verify_schedule)
from repro.verify.grid_check import check_tile_kernel_grid

RULE_SETS = ("paper", "extended")
GRID_EMITTERS = ("pallas", "pallas_pipelined")


def _config(rule_set: str) -> SaturatorConfig:
    return SaturatorConfig(mode="accsat",
                           extended_rules=(rule_set == "extended"),
                           search_cfg=SearchConfig(
                               time_limit_s=120.0,
                               extract_time_limit_s=120.0))


def sweep(kernels: List[str]) -> Dict:
    report = VerifyReport()
    rows: List[Dict] = []
    for rule_set in RULE_SETS:
        cfg = _config(rule_set)
        rres = verify_rules(cfg.rules())
        report.extend(rres.findings)
        report.rules_checked += rres.rules_checked
        for kname in kernels:
            prog = PROGRAMS[kname]()
            sk = saturate_program(prog, cfg)
            kfs = list(check_egraph(sk.ssa.egraph))
            report.egraphs_checked += 1
            certified = 0
            grids = 0
            scheds = {}
            for mode in SCHEDULE_MODES:
                # searchless for source/bulk — certify exactly the order
                # the legacy emitters/cache replay; the cost mode keeps
                # its deterministic search budget
                kw = {} if mode == "cost" else {"move_budget": 0}
                sched = compute_schedule(
                    sk.ssa, dict(sk.extraction.choice), mode=mode,
                    cost_model=_schedule_cm(cfg, prog, sk.ssa.egraph),
                    **kw)
                scheds[mode] = sched
                scr = verify_schedule(sk.ssa, sk.extraction.choice, sched)
                kfs.extend(scr.findings)
                certified += scr.regions_certified
            kfs.extend(check_generated(sk.kernel.source, shapes_of(prog),
                                       subject=f"{kname}:jax"))
            report.sources_checked += 1
            try:
                pk = SyncPallasGenerator(sk.ssa, sk.extraction,
                                         bulk=True).generate_pallas()
            except NotImplementedError:
                pk = None          # not tilable: JAX source only
            if pk is not None:
                kfs.extend(check_generated(pk.source, shapes_of(prog),
                                           subject=f"{kname}:pallas"))
                report.sources_checked += 1
                # grid pass: one emission per (schedule mode, emitter)
                # reuses the saturation above — geometry certification
                # needs only the emitted kernel, not a fresh pipeline run
                for mode, emitter in ((m, e) for m in SCHEDULE_MODES
                                      for e in GRID_EMITTERS):
                    epk = get_emitter(emitter).emit(
                        sk.ssa, sk.extraction, bulk=True,
                        schedule=scheds[mode])
                    gres = check_tile_kernel_grid(epk, prog)
                    kfs.extend(dataclasses.replace(
                        f, subject=f"{mode}/{emitter}:{f.subject}")
                        for f in gres.findings)
                    grids += gres.grids_checked
            report.extend(kfs)
            report.schedules_certified += certified
            report.grids_checked += grids
            errors = [f for f in kfs if f.severity == "error"]
            rows.append({
                "kernel": kname, "rule_set": rule_set,
                "schedules_certified": certified,
                "grids_checked": grids,
                "findings": len(kfs), "errors": len(errors),
            })
            for f in errors:
                print(f"  {kname}/{rule_set}: {f}", file=sys.stderr)

    # the hand-written Pallas kernels outside the saturator pipeline:
    # their BlockSpec layouts (attention_layout / ssd_layout) feed the
    # same symbolic engine. flash attention is the inert-axis case — the
    # output map ignores the kv step, a legal revisit, not a race.
    handwritten = (
        ("flash_attention", flash_attention_model(2, 4, 2, 512, 128)),
        ("ssd_scan", ssd_scan_model(2, 4, 512, 64, 128)),
    )
    for hname, model in handwritten:
        gres = check_grid(model)
        report.extend(gres.findings)
        report.grids_checked += gres.grids_checked
        errors = [f for f in gres.findings if f.severity == "error"]
        rows.append({
            "kernel": hname, "rule_set": "handwritten",
            "schedules_certified": 0,
            "grids_checked": gres.grids_checked,
            "findings": len(gres.findings), "errors": len(errors),
        })
        for f in errors:
            print(f"  {hname}: {f}", file=sys.stderr)
    out = report.summary()
    out["rows"] = rows
    out["kernels"] = list(kernels)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", nargs="*", default=None,
                    help="subset of tile kernels (default: all "
                         f"{len(PROGRAMS)})")
    ap.add_argument("--json", default=None,
                    help="write the full summary to this path")
    args = ap.parse_args(argv)
    kernels = args.kernels or list(PROGRAMS)
    unknown = [k for k in kernels if k not in PROGRAMS]
    if unknown:
        ap.error(f"unknown kernels {unknown}; available: {list(PROGRAMS)}")
    summary = sweep(kernels)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(summary, fh, indent=2, sort_keys=True)
    sev = summary["by_severity"]
    print(f"verify_sweep: {len(kernels)} kernels x {len(RULE_SETS)} rule "
          f"sets x {len(SCHEDULE_MODES)} schedules")
    print(f"  rules_checked={summary['rules_checked']} "
          f"schedules_certified={summary['schedules_certified']} "
          f"egraphs={summary['egraphs_checked']} "
          f"sources={summary['sources_checked']} "
          f"grids={summary['grids_checked']}")
    print(f"  findings: {sev['error']} error / {sev['warning']} warning "
          f"/ {sev['info']} info")
    if not summary["ok"]:
        print("FAIL: error-severity findings present", file=sys.stderr)
        return 1
    print("OK: zero error-severity findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
