"""Framework-level step microbenchmark: smoke-scale train + decode step
per architecture on CPU (wall time), plus pointers to the dry-run roofline
table for the full-size cells (experiments/dryrun/)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke_config
from repro.models import get_model
from repro.optim import OptConfig, apply_updates, init_opt_state


def run_lm_step(archs=None, B=2, S=64, repeats=2) -> List[Dict]:
    rows = []
    for arch in (archs or ARCHS):
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt_cfg = OptConfig()
        opt = init_opt_state(params, opt_cfg)
        kt, kl, kf = jax.random.split(jax.random.PRNGKey(1), 3)
        batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
                 "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab)}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(kf, (B, S, cfg.d_model),
                                                jnp.float32)

        @jax.jit
        def step(p, o, b):
            loss, g = jax.value_and_grad(model.loss)(p, b)
            p2, o2 = apply_updates(p, g, o, opt_cfg)
            return p2, o2, loss

        p2, o2, loss = step(params, opt, batch)
        jax.block_until_ready(loss)
        t0 = time.perf_counter()
        for _ in range(repeats):
            p2, o2, loss = step(p2, o2, batch)
        jax.block_until_ready(loss)
        train_ms = (time.perf_counter() - t0) / repeats * 1e3

        if cfg.family == "encdec":
            logits, cache = model.prefill(params, batch["tokens"],
                                          batch["frames"])
        else:
            logits, cache = model.prefill(params, batch["tokens"])
        dstep = jax.jit(model.decode_step)
        tok = batch["tokens"][:, :1]
        logits, cache = dstep(params, cache, tok)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(repeats):
            logits, cache = dstep(params, cache, tok)
        jax.block_until_ready(logits)
        decode_ms = (time.perf_counter() - t0) / repeats * 1e3

        rows.append({"arch": arch, "train_step_ms": train_ms,
                     "decode_step_ms": decode_ms,
                     "loss": float(loss)})
    return rows
