"""Benchmark-regression gate for CI (PR 3 satellite, PR 4 calibration).

Runs the saturator over the full kernel suite (NPB/SPEC-style kernels +
model tile programs), extracts every kernel with both the beam search and
the PR-2 hill climb, and compares the roofline-predicted latency and
extracted DAG cost against the committed baseline
(``experiments/bench_baseline.json``).

The build fails when any kernel:

* regresses more than ``TOLERANCE_PCT`` (2%) in predicted latency or DAG
  cost vs the baseline, or
* extracts *worse* with the beam than with the hill climb (the beam is
  seeded with the hill climb's restarts, so this indicates a search
  regression, not noise);

or when the committed device profiles (``experiments/device_profiles/``,
the calibrated predicted-vs-measured loop) stop holding their bar:

* no committed profile exists at all,
* a profile's calibrated Spearman rank correlation — recomputed from its
  stored measurements with the *current* model code — falls below the
  0.8 floor or below the value stored at fit time, or
* its calibrated MAPE stops beating the uncalibrated defaults.

Predicted metrics are model-computed (chip constants) and every search
pass stops on a deterministic evaluation budget (`beam_expansions`,
`hillclimb_evals`) rather than the wall clock, with generous time
ceilings as pure safety nets (``saturation_stats.GATE_CONFIG``) — so
the gate is exact on any runner regardless of machine speed or load,
unlike wall-clock benchmarks. The calibration checks are equally exact:
they re-score committed measurements, they do not re-time anything. The
hill-climb comparison re-extracts the *same* saturated e-graph, so
beam <= hillclimb holds structurally within one run. The script re-execs
itself with ``PYTHONHASHSEED=0`` — e-node sets iterate in hash order, so
rule-match ordering (and with it plateau tie-breaks in extraction) would
otherwise drift per process. Kernels new since the baseline are reported
but do not fail the gate; refresh the baseline with ``--update`` after
intentional cost-model or extraction changes and commit the diff.

All regenerated artifacts live under gitignored ``experiments/out/``;
only the baseline, the device profiles, and the latency table are
committed. The baseline is schema-versioned: a version mismatch fails
loudly instead of silently comparing incompatible numbers.

Usage:
    python benchmarks/bench_regression.py            # check vs baseline
    python benchmarks/bench_regression.py --update   # rewrite baseline
"""
from __future__ import annotations

import json
import pathlib
import sys

if __package__ in (None, ""):        # direct script invocation
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bootstrap import OUT_ROOT, ROOT  # noqa: E402
from benchmarks.hashseed import reexec_with_fixed_hashseed  # noqa: E402

reexec_with_fixed_hashseed()

BASELINE = ROOT / "experiments" / "bench_baseline.json"
PROFILE_DIR = ROOT / "experiments" / "device_profiles"
CURRENT = OUT_ROOT / "bench_current.json"
BEAM_STATS = OUT_ROOT / "beam_stats.json"

BASELINE_SCHEMA_VERSION = 2   # 1 = bare {kernel: metrics} map (PR 3)
TOLERANCE_PCT = 2.0
ABS_EPS = 1e-6          # ignore float dust on tiny costs
BEAM_EPS = 1e-6


def collect():
    from benchmarks.saturation_stats import run_saturation_stats
    res = run_saturation_stats(compare_hillclimb=True)
    metrics = {}
    for r in res["rows"]:
        metrics[r["kernel"]] = {
            "predicted_latency_ns": r["predicted_latency_ns"],
            "dag_cost": r["dag_cost"],
            "hillclimb_latency_ns": r["hillclimb_latency_ns"],
            "hillclimb_dag_cost": r["hillclimb_dag_cost"],
            "beam_vs_hillclimb_pct": r["beam_vs_hillclimb_pct"],
            "oracle_gap": r["oracle_gap"],
        }
    return res, metrics


def load_baseline() -> dict:
    """Parse the committed baseline, failing loudly on schema drift."""
    try:
        doc = json.loads(BASELINE.read_text())
    except json.JSONDecodeError as e:
        print(f"ERROR: baseline {BASELINE} is not valid JSON: {e}",
              file=sys.stderr)
        raise SystemExit(2)
    ver = doc.get("schema_version") if isinstance(doc, dict) else None
    if ver != BASELINE_SCHEMA_VERSION:
        print(
            f"ERROR: baseline {BASELINE} has schema_version {ver!r}, this "
            f"gate expects {BASELINE_SCHEMA_VERSION}. A silent comparison "
            "of incompatible schemas hides real regressions — regenerate "
            "with `python benchmarks/bench_regression.py --update` and "
            "commit the diff.", file=sys.stderr)
        raise SystemExit(2)
    return doc["kernels"]


def check(metrics, baseline) -> list:
    failures = []
    # losing a kernel is itself a regression (coverage silently shrank)
    missing = sorted(set(baseline) - set(metrics))
    if missing:
        failures.append(
            f"kernel(s) in baseline but absent from this run: {missing} "
            "(remove them with --update if intentional)")
    for kernel, cur in sorted(metrics.items()):
        # structural invariant: beam never worse than hill climb ON THE
        # EXTRACTION OBJECTIVE (dag_cost, store-free). The reported
        # latencies add constant store traffic, and a roofline max does
        # not preserve ordering under a shift on one axis — a genuinely
        # better but more memory-leaning beam pick could legally show a
        # higher store-inclusive latency, so that pair is not gated.
        if cur["dag_cost"] > cur["hillclimb_dag_cost"] + BEAM_EPS:
            failures.append(
                f"{kernel}: beam dag_cost {cur['dag_cost']:.6f} worse "
                f"than hill climb {cur['hillclimb_dag_cost']:.6f}")
        base = baseline.get(kernel)
        if base is None:
            print(f"  NEW    {kernel} (not in baseline; add with --update)")
            continue
        for metric in ("predicted_latency_ns", "dag_cost"):
            b, c = base[metric], cur[metric]
            if c > b + ABS_EPS and (c - b) > abs(b) * TOLERANCE_PCT / 100.0:
                pct = f"+{100.0 * (c - b) / b:.2f}%" if b else "from zero"
                failures.append(
                    f"{kernel}: {metric} regressed "
                    f"{b:.4f} -> {c:.4f} ({pct} > {TOLERANCE_PCT}%)")
    return failures


def check_calibration() -> list:
    """The predicted-vs-measured leg of the gate: every committed device
    profile must still rank kernels faithfully under the current model
    code (Spearman >= floor, >= its committed baseline, MAPE better than
    uncalibrated). Deterministic — re-scores stored measurements only."""
    from repro.analysis import check_profile, load_profile
    paths = sorted(PROFILE_DIR.glob("*.json"))
    if not paths:
        return [f"no committed device profiles under {PROFILE_DIR}; the "
                "calibrated predicted-vs-measured loop is unverified "
                "(fit one with `python benchmarks/measure.py --fit`)"]
    failures = []
    for p in paths:
        try:
            prof = load_profile(p)
        except Exception as e:
            failures.append(f"{p.name}: unloadable profile: {e}")
            continue
        fails = check_profile(prof)
        failures.extend(fails)
        f = prof.fit
        status = "FAIL" if fails else "ok"
        print(f"  profile {prof.name:24s} [{status}] "
              f"spearman {f.get('spearman', float('nan')):.3f} "
              f"(uncal {f.get('uncalibrated_spearman', float('nan')):.3f})  "
              f"MAPE {f.get('mape_pct', float('nan')):.1f}% "
              f"(uncal {f.get('uncalibrated_mape_pct', float('nan')):.1f}%)")
    return failures


def main() -> int:
    update = "--update" in sys.argv
    res, metrics = collect()

    CURRENT.parent.mkdir(parents=True, exist_ok=True)
    CURRENT.write_text(json.dumps(metrics, indent=2, sort_keys=True) + "\n")
    beam_rows = [{k: r[k] for k in
                  ("kernel", "search", "predicted_latency_ns",
                   "hillclimb_latency_ns", "beam_vs_hillclimb_pct",
                   "dag_cost", "hillclimb_dag_cost", "beam_generations",
                   "beam_expanded", "oracle_gap", "extract_s")}
                 for r in res["rows"]]
    BEAM_STATS.write_text(json.dumps(beam_rows, indent=2) + "\n")
    print(f"wrote {CURRENT} and {BEAM_STATS} ({len(metrics)} kernels)")

    # refresh the latency table from the same run (artifact-uploaded by
    # CI) — includes the predicted-vs-measured calibration section
    from benchmarks.roofline_table import kernel_table
    kernel_table(res)

    if update:
        BASELINE.write_text(json.dumps(
            {"schema_version": BASELINE_SCHEMA_VERSION, "kernels": metrics},
            indent=2, sort_keys=True) + "\n")
        print(f"baseline updated: {BASELINE}")
        return 0

    if not BASELINE.exists():
        print(f"ERROR: no baseline at {BASELINE}; "
              "run with --update and commit it", file=sys.stderr)
        return 2
    baseline = load_baseline()
    failures = check(metrics, baseline)
    for kernel, cur in sorted(metrics.items()):
        base = baseline.get(kernel, {})
        b = base.get("predicted_latency_ns")
        print(f"  {kernel:24s} lat {cur['predicted_latency_ns']:10.2f} ns"
              f" (base {b if b is None else format(b, '10.2f')})"
              f"  beamΔ {cur['beam_vs_hillclimb_pct']:+.2f}%")
    print("calibrated predicted-vs-measured check:")
    failures += check_calibration()
    if failures:
        print(f"\nFAIL: {len(failures)} regression(s) "
              f"(tolerance {TOLERANCE_PCT}%):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nOK: {len(metrics)} kernels within {TOLERANCE_PCT}% of "
          "baseline; beam never worse than hill climb; calibrated "
          "profiles rank >= 0.8 Spearman and beat uncalibrated MAPE")
    return 0


if __name__ == "__main__":
    sys.exit(main())
