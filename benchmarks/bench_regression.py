"""Benchmark-regression gate for CI (PR 3 satellite, PR 4 calibration).

Runs the saturator over the full kernel suite (NPB/SPEC-style kernels +
model tile programs), extracts every kernel with both the beam search and
the PR-2 hill climb, and compares the roofline-predicted latency and
extracted DAG cost against the committed baseline
(``experiments/bench_baseline.json``).

The build fails when any kernel:

* regresses more than ``TOLERANCE_PCT`` (2%) in predicted latency or DAG
  cost vs the baseline, or
* extracts *worse* with the beam than with the hill climb (the beam is
  seeded with the hill climb's restarts, so this indicates a search
  regression, not noise);

or when the committed device profiles (``experiments/device_profiles/``,
the calibrated predicted-vs-measured loop) stop holding their bar:

* no committed profile exists at all,
* a profile's calibrated Spearman rank correlation — recomputed from its
  stored measurements with the *current* model code — falls below the
  0.8 floor or below the value stored at fit time, or
* its calibrated MAPE stops beating the uncalibrated defaults;

or when the PR-5 **scheduling legs** break:

* predicted schedule latency must rank cost <= bulk <= source for every
  kernel (recomputed deterministically in-run from the saturated
  e-graphs),
* the committed schedule-aware profile's embedded measured medians must
  show the cost-driven order within ``TOLERANCE_PCT`` of bulk per
  kernel (paired per-rep deltas — nothing is re-timed in CI), and
* the schedule-aware profile must keep beating the PR-4 profile on
  Spearman or MAPE over the cost-schedule measurements;

or when the PR-8 **pipelined-emitter leg** breaks: over a kernel
subset, the ``pallas_pipelined`` emitter's interpret fallback must stay
byte-identical to the synchronous emitter (and its CPU outputs
bit-identical), and its recorded async copy plans must verify clean
(see ``benchmarks/pipelined_smoke.py``).

The gate also (re)writes the top-level ``BENCH_5.json`` perf
trajectory (per-kernel predicted + measured ns by schedule, profile
id); CI fails if the committed copy drifts.

Predicted metrics are model-computed (chip constants) and every search
pass stops on a deterministic evaluation budget (`beam_expansions`,
`hillclimb_evals`) rather than the wall clock, with generous time
ceilings as pure safety nets (``saturation_stats.GATE_CONFIG``) — so
the gate is exact on any runner regardless of machine speed or load,
unlike wall-clock benchmarks. The calibration checks are equally exact:
they re-score committed measurements, they do not re-time anything. The
hill-climb comparison re-extracts the *same* saturated e-graph, so
beam <= hillclimb holds structurally within one run. The script re-execs
itself with ``PYTHONHASHSEED=0`` — e-node sets iterate in hash order, so
rule-match ordering (and with it plateau tie-breaks in extraction) would
otherwise drift per process. Kernels new since the baseline are reported
but do not fail the gate; refresh the baseline with ``--update`` after
intentional cost-model or extraction changes and commit the diff.

All regenerated artifacts live under gitignored ``experiments/out/``;
only the baseline, the device profiles, and the latency table are
committed. The baseline is schema-versioned: a version mismatch fails
loudly instead of silently comparing incompatible numbers.

Usage:
    python benchmarks/bench_regression.py            # check vs baseline
    python benchmarks/bench_regression.py --update   # rewrite baseline
"""
from __future__ import annotations

import json
import pathlib
import sys

if __package__ in (None, ""):        # direct script invocation
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bootstrap import OUT_ROOT, ROOT  # noqa: E402
from benchmarks.hashseed import reexec_with_fixed_hashseed  # noqa: E402

reexec_with_fixed_hashseed()

BASELINE = ROOT / "experiments" / "bench_baseline.json"
PROFILE_DIR = ROOT / "experiments" / "device_profiles"
CURRENT = OUT_ROOT / "bench_current.json"
BEAM_STATS = OUT_ROOT / "beam_stats.json"
BENCH5 = ROOT / "BENCH_5.json"
BENCH6 = ROOT / "BENCH_6.json"
BENCH9 = ROOT / "BENCH_9.json"
SCHED_PROFILE = "cpu_pallas_interpret_sched"   # PR-5 schedule-aware fit
BASE_PROFILE = "cpu_pallas_interpret"          # PR-4 bulk-order fit

BASELINE_SCHEMA_VERSION = 3   # 2 = PR 4 (no schedule block); 1 = PR 3
BENCH5_SCHEMA_VERSION = 1
BENCH6_SCHEMA_VERSION = 1
BENCH9_SCHEMA_VERSION = 1
BENCH6_REPLAY_FLOOR = 10.0   # committed cold/replay saturation speedup
TOLERANCE_PCT = 2.0
ABS_EPS = 1e-6          # ignore float dust on tiny costs
BEAM_EPS = 1e-6
SCHED_EPS = 1e-6


def collect():
    from benchmarks.saturation_stats import run_saturation_stats
    res = run_saturation_stats(compare_hillclimb=True)
    metrics = {}
    for r in res["rows"]:
        metrics[r["kernel"]] = {
            "predicted_latency_ns": r["predicted_latency_ns"],
            "dag_cost": r["dag_cost"],
            "hillclimb_latency_ns": r["hillclimb_latency_ns"],
            "hillclimb_dag_cost": r["hillclimb_dag_cost"],
            "beam_vs_hillclimb_pct": r["beam_vs_hillclimb_pct"],
            "oracle_gap": r["oracle_gap"],
            "schedule_predicted": r["schedule_predicted"],
        }
    return res, metrics


def load_baseline() -> dict:
    """Parse the committed baseline, failing loudly on schema drift."""
    try:
        doc = json.loads(BASELINE.read_text())
    except json.JSONDecodeError as e:
        print(f"ERROR: baseline {BASELINE} is not valid JSON: {e}",
              file=sys.stderr)
        raise SystemExit(2)
    ver = doc.get("schema_version") if isinstance(doc, dict) else None
    if ver != BASELINE_SCHEMA_VERSION:
        print(
            f"ERROR: baseline {BASELINE} has schema_version {ver!r}, this "
            f"gate expects {BASELINE_SCHEMA_VERSION}. A silent comparison "
            "of incompatible schemas hides real regressions — regenerate "
            "with `python benchmarks/bench_regression.py --update` and "
            "commit the diff.", file=sys.stderr)
        raise SystemExit(2)
    return doc["kernels"]


def check(metrics, baseline) -> list:
    failures = []
    # losing a kernel is itself a regression (coverage silently shrank)
    missing = sorted(set(baseline) - set(metrics))
    if missing:
        failures.append(
            f"kernel(s) in baseline but absent from this run: {missing} "
            "(remove them with --update if intentional)")
    for kernel, cur in sorted(metrics.items()):
        # structural invariant: beam never worse than hill climb ON THE
        # EXTRACTION OBJECTIVE (dag_cost, store-free). The reported
        # latencies add constant store traffic, and a roofline max does
        # not preserve ordering under a shift on one axis — a genuinely
        # better but more memory-leaning beam pick could legally show a
        # higher store-inclusive latency, so that pair is not gated.
        if cur["dag_cost"] > cur["hillclimb_dag_cost"] + BEAM_EPS:
            failures.append(
                f"{kernel}: beam dag_cost {cur['dag_cost']:.6f} worse "
                f"than hill climb {cur['hillclimb_dag_cost']:.6f}")
        base = baseline.get(kernel)
        if base is None:
            print(f"  NEW    {kernel} (not in baseline; add with --update)")
            continue
        for metric in ("predicted_latency_ns", "dag_cost"):
            b, c = base[metric], cur[metric]
            if c > b + ABS_EPS and (c - b) > abs(b) * TOLERANCE_PCT / 100.0:
                pct = f"+{100.0 * (c - b) / b:.2f}%" if b else "from zero"
                failures.append(
                    f"{kernel}: {metric} regressed "
                    f"{b:.4f} -> {c:.4f} ({pct} > {TOLERANCE_PCT}%)")
    return failures


def check_schedule_predicted(metrics) -> list:
    """Scheduling leg 1 (deterministic, recomputed in-run): for every
    kernel the cost-driven schedule's predicted latency must be <= the
    bulk-load schedule's, which must be <= the source order's — the
    paper's computational-reordering claim, as an invariant."""
    failures = []
    for kernel, cur in sorted(metrics.items()):
        sp = cur.get("schedule_predicted") or {}
        if not sp:
            failures.append(f"{kernel}: no schedule predictions in run")
            continue
        if sp["cost"] > sp["bulk"] + SCHED_EPS:
            failures.append(
                f"{kernel}: cost schedule predicted {sp['cost']:.4f} ns "
                f"worse than bulk {sp['bulk']:.4f} ns")
        if sp["bulk"] > sp["source"] + SCHED_EPS:
            failures.append(
                f"{kernel}: bulk schedule predicted {sp['bulk']:.4f} ns "
                f"worse than source {sp['source']:.4f} ns")
    return failures


def _load_profile_or_none(name):
    from repro.analysis import load_profile
    path = PROFILE_DIR / f"{name}.json"
    if not path.exists():
        return None
    return load_profile(path)


def check_schedule_measured() -> list:
    """Scheduling leg 2 (deterministic — committed medians only): the
    schedule-aware profile's embedded per-schedule measured medians
    must show the cost-driven order no slower than bulk beyond the
    noise tolerance, and the schedule-aware fit must beat the PR-4
    profile on Spearman or MAPE when both are re-scored with the
    current model code."""
    from repro.analysis import evaluate_params
    prof = _load_profile_or_none(SCHED_PROFILE)
    if prof is None:
        return [f"no committed schedule-aware profile "
                f"{SCHED_PROFILE} under {PROFILE_DIR}; fit one with "
                "`python benchmarks/measure.py --fit`"]
    failures = []
    medians = prof.fit.get("schedule_medians", {})
    if not medians:
        failures.append(f"profile {prof.name}: no embedded "
                        "schedule_medians evidence")
    worse = 0
    for kernel, by_sched in sorted(medians.items()):
        bulk, cost = by_sched.get("bulk"), by_sched.get("cost")
        if bulk is None or cost is None:
            failures.append(f"{kernel}: schedule_medians missing "
                            "bulk/cost entries")
            continue
        # the gated statistic is the *paired* per-rep delta (cost and
        # bulk timed in the same interleaved cycle — machine-load noise
        # cancels); the raw medians are evidence, not the gate
        from repro.analysis import schedule_paired_pct
        delta = schedule_paired_pct(by_sched)
        if delta > TOLERANCE_PCT:
            failures.append(
                f"{kernel}: measured cost schedule {delta:+.2f}% vs bulk "
                f"(paired median) beyond the {TOLERANCE_PCT}% tolerance")
        if delta > 0:
            worse += 1
    if medians:
        print(f"  schedule medians: cost <= bulk (paired, within "
              f"{TOLERANCE_PCT}%) on {len(medians)} kernels "
              f"({len(medians) - worse} at-or-better outright)")
    base = _load_profile_or_none(BASE_PROFILE)
    if base is None:
        failures.append(f"committed PR-4 profile {BASE_PROFILE} missing — "
                        "cannot compare the schedule-aware fit against it")
        return failures

    # both parameter sets are re-scored against the SAME measurements —
    # the schedule-aware profile's stored cost-schedule rows (PR-4
    # params see the same features; without a fitted overlap term the
    # schedule fields are inert for them), so the comparison asks one
    # question deterministically: which calibration explains the
    # measured data better under the current model code?
    from repro.analysis.calibrate import chip_by_name
    feats = prof.stored_features()
    meas = prof.stored_measurements()

    def rescore(p):
        return evaluate_params(feats, meas, p.params,
                               chip=chip_by_name(p.model_chip),
                               tile_elems=p.tile_elems)
    s, b = rescore(prof), rescore(base)
    print(f"  on the cost-schedule measurements — sched profile vs PR-4: "
          f"Spearman {s['spearman']:.3f} vs {b['spearman']:.3f}, "
          f"MAPE {s['mape_pct']:.2f}% vs {b['mape_pct']:.2f}%")
    if not (s["spearman"] > b["spearman"] + 1e-12
            or s["mape_pct"] < b["mape_pct"] - 1e-12):
        failures.append(
            f"schedule-aware profile {prof.name} no longer beats "
            f"{base.name} on Spearman ({s['spearman']:.3f} vs "
            f"{b['spearman']:.3f}) or MAPE ({s['mape_pct']:.2f}% vs "
            f"{b['mape_pct']:.2f}%) on the cost-schedule measurements")
    return failures


def write_bench5(metrics) -> None:
    """Top-level machine-readable perf trajectory: per kernel, the
    predicted latency of every statement schedule (this run,
    deterministic) and the measured medians embedded in the committed
    schedule-aware profile. Committed and drift-checked by CI, so the
    trajectory is comparable across PRs."""
    prof = _load_profile_or_none(SCHED_PROFILE)
    medians = prof.fit.get("schedule_medians", {}) if prof else {}
    kernels = {}
    for kernel, cur in sorted(metrics.items()):
        bare = kernel.split(":", 1)[-1]
        row = {
            "schedule": "cost",
            "predicted_ns": {k: round(v, 4) for k, v in
                             (cur.get("schedule_predicted") or {}).items()},
            "extraction_predicted_latency_ns":
                round(cur["predicted_latency_ns"], 4),
        }
        if bare in medians:
            row["measured_ns"] = {k: round(v, 1) for k, v in
                                  sorted(medians[bare].items())}
            row["measured_kind"] = prof.measured_kind
            row["profile"] = prof.name
        kernels[kernel] = row
    doc = {"schema_version": BENCH5_SCHEMA_VERSION,
           "pr": 5,
           "description": "per-kernel predicted + measured median ns by "
                          "statement schedule (see benchmarks/"
                          "bench_regression.py and docs/cost_model.md)",
           "kernels": kernels}
    BENCH5.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"wrote {BENCH5} ({len(kernels)} kernels)")


def check_bench6() -> list:
    """Drift check for the committed PR-6 serve-decode cache report.

    Wall clocks are machine-dependent, so unlike the BENCH_5 leg this
    does not recompute anything: it validates that the committed report
    parses, matches the expected schema, and that its invariant facts
    hold — a fully-warm second boot (hit rate 1.0, no warm-boot
    misses), positive throughputs, and a cold/replay saturation-time
    speedup at or above the floor the cache exists to deliver."""
    if not BENCH6.exists():
        return [f"missing {BENCH6}; regenerate with `PYTHONPATH=src "
                "python examples/serve_decode.py --out BENCH_6.json` "
                "and commit it"]
    try:
        doc = json.loads(BENCH6.read_text())
    except json.JSONDecodeError as e:
        return [f"{BENCH6.name}: invalid JSON: {e}"]
    ver = doc.get("schema_version")
    if ver != BENCH6_SCHEMA_VERSION:
        return [f"{BENCH6.name}: schema_version {ver!r}, expected "
                f"{BENCH6_SCHEMA_VERSION} — regenerate and commit"]
    failures = []
    for sec in ("saturated", "reference"):
        tps = (doc.get(sec) or {}).get("tokens_per_s", 0)
        if not tps or tps <= 0:
            failures.append(f"{BENCH6.name}: {sec}.tokens_per_s missing "
                            "or non-positive")
    cache = doc.get("cache")
    if not isinstance(cache, dict):
        failures.append(f"{BENCH6.name}: no cache section (was it "
                        "generated with --no-cache?)")
        return failures
    cold, warm = cache.get("cold") or {}, cache.get("warm") or {}
    if cold.get("misses", 0) < 1 or cold.get("stores", 0) < 1:
        failures.append(f"{BENCH6.name}: cold boot recorded no cache "
                        "misses/stores — the cache was never exercised")
    if warm.get("hit_rate") != 1.0 or warm.get("misses", 1) != 0:
        failures.append(
            f"{BENCH6.name}: warm boot not fully served from cache "
            f"(hit_rate={warm.get('hit_rate')!r}, "
            f"misses={warm.get('misses')!r})")
    speedup = cache.get("replay_speedup", 0)
    if not speedup or speedup < BENCH6_REPLAY_FLOOR:
        failures.append(
            f"{BENCH6.name}: committed replay_speedup {speedup!r} below "
            f"the {BENCH6_REPLAY_FLOOR:.0f}x floor")
    if not failures:
        print(f"  BENCH_6 ok: warm hit_rate=1.0, replay "
              f"{speedup:.0f}x, saturated "
              f"{doc['saturated']['tokens_per_s']:.1f} tok/s vs ref "
              f"{doc['reference']['tokens_per_s']:.1f} tok/s")
    return failures


def check_bench9() -> list:
    """Drift check for the committed PR-9 tuning summary (BENCH_9.json).

    Winners are measured, hence machine-dependent — they are validated
    structurally (a legal, sublane-aligned survivor). The *static* half
    is recomputed exactly: candidate/pruned counts, prune reasons, and
    survivor sets come from ``benchmarks.tune.static_prune`` (grid-pass
    legality + headroom budget), so any change to the candidate list,
    the prune rules, or the verifier's legality verdicts shows up as
    drift against the committed document."""
    if not BENCH9.exists():
        return [f"missing {BENCH9}; regenerate with `PYTHONPATH=src "
                "python benchmarks/tune.py --update-bench` and commit it"]
    try:
        doc = json.loads(BENCH9.read_text())
    except json.JSONDecodeError as e:
        return [f"{BENCH9.name}: invalid JSON: {e}"]
    ver = doc.get("schema_version")
    if ver != BENCH9_SCHEMA_VERSION:
        return [f"{BENCH9.name}: schema_version {ver!r}, expected "
                f"{BENCH9_SCHEMA_VERSION} — regenerate and commit"]
    from benchmarks.tune import static_prune
    rows = doc.get("rows")
    kernels = doc.get("kernels") or {}
    failures = []
    if not kernels:
        return [f"{BENCH9.name}: no kernels section"]
    for name, rec in sorted(kernels.items()):
        cur = static_prune(name, rows=rows)
        for key in ("n_candidates", "n_pruned", "pruned_reasons",
                    "survivors", "default_row_block"):
            if rec.get(key) != cur[key]:
                failures.append(
                    f"{BENCH9.name}: {name}.{key} drifted — committed "
                    f"{rec.get(key)!r}, recomputed {cur[key]!r}")
        win = rec.get("winner_row_block")
        if win is not None:
            if win not in cur["survivors"]:
                failures.append(f"{BENCH9.name}: {name} winner {win} is "
                                "not a legal survivor")
            elif win % 8:
                failures.append(f"{BENCH9.name}: {name} winner {win} is "
                                "not sublane-aligned")
    if not failures:
        total = sum(r["n_pruned"] for r in kernels.values())
        avg = total / len(kernels)
        if avg < 1.0:
            failures.append(
                f"{BENCH9.name}: avg {avg:.2f} candidates pruned per "
                "kernel — the static filter prunes nothing")
        else:
            print(f"  BENCH_9 ok: {len(kernels)} kernels, avg {avg:.1f} "
                  f"candidates statically pruned, winners all legal "
                  f"survivors")
    return failures


def check_pipelined() -> list:
    """PR-8 pipelined-emitter leg (deterministic — no timing): over a
    kernel subset, the ``pallas_pipelined`` emitter's interpret fallback
    must stay byte-identical to the synchronous emitter, its outputs
    bit-identical on CPU, and its async copy plan verify clean (every
    start waited, waits dominate first use, semaphore parity, ≤2 in
    flight). Reuses ``benchmarks/pipelined_smoke.py`` — CI's standalone
    smoke job and this gate certify the same contract."""
    from benchmarks.pipelined_smoke import SMOKE_KERNELS, run_pipelined_smoke
    return run_pipelined_smoke(SMOKE_KERNELS)


def check_calibration() -> list:
    """The predicted-vs-measured leg of the gate: every committed device
    profile must still rank kernels faithfully under the current model
    code (Spearman >= floor, >= its committed baseline, MAPE better than
    uncalibrated). Deterministic — re-scores stored measurements only."""
    from repro.analysis import check_profile, load_profile
    paths = sorted(PROFILE_DIR.glob("*.json"))
    if not paths:
        return [f"no committed device profiles under {PROFILE_DIR}; the "
                "calibrated predicted-vs-measured loop is unverified "
                "(fit one with `python benchmarks/measure.py --fit`)"]
    failures = []
    for p in paths:
        try:
            prof = load_profile(p)
        except Exception as e:
            failures.append(f"{p.name}: unloadable profile: {e}")
            continue
        fails = check_profile(prof)
        failures.extend(fails)
        f = prof.fit
        status = "FAIL" if fails else "ok"
        print(f"  profile {prof.name:24s} [{status}] "
              f"spearman {f.get('spearman', float('nan')):.3f} "
              f"(uncal {f.get('uncalibrated_spearman', float('nan')):.3f})  "
              f"MAPE {f.get('mape_pct', float('nan')):.1f}% "
              f"(uncal {f.get('uncalibrated_mape_pct', float('nan')):.1f}%)")
    return failures


def main() -> int:
    update = "--update" in sys.argv
    res, metrics = collect()

    CURRENT.parent.mkdir(parents=True, exist_ok=True)
    CURRENT.write_text(json.dumps(metrics, indent=2, sort_keys=True) + "\n")
    beam_rows = [{k: r[k] for k in
                  ("kernel", "search", "predicted_latency_ns",
                   "hillclimb_latency_ns", "beam_vs_hillclimb_pct",
                   "dag_cost", "hillclimb_dag_cost", "beam_generations",
                   "beam_expanded", "oracle_gap", "extract_s")}
                 for r in res["rows"]]
    BEAM_STATS.write_text(json.dumps(beam_rows, indent=2) + "\n")
    print(f"wrote {CURRENT} and {BEAM_STATS} ({len(metrics)} kernels)")

    # refresh the latency table from the same run (artifact-uploaded by
    # CI) — includes the predicted-vs-measured calibration section
    from benchmarks.roofline_table import kernel_table
    kernel_table(res)
    # machine-readable perf trajectory (committed; CI checks drift)
    write_bench5(metrics)

    if update:
        BASELINE.write_text(json.dumps(
            {"schema_version": BASELINE_SCHEMA_VERSION, "kernels": metrics},
            indent=2, sort_keys=True) + "\n")
        print(f"baseline updated: {BASELINE}")
        return 0

    if not BASELINE.exists():
        print(f"ERROR: no baseline at {BASELINE}; "
              "run with --update and commit it", file=sys.stderr)
        return 2
    baseline = load_baseline()
    failures = check(metrics, baseline)
    for kernel, cur in sorted(metrics.items()):
        base = baseline.get(kernel, {})
        b = base.get("predicted_latency_ns")
        print(f"  {kernel:24s} lat {cur['predicted_latency_ns']:10.2f} ns"
              f" (base {b if b is None else format(b, '10.2f')})"
              f"  beamΔ {cur['beam_vs_hillclimb_pct']:+.2f}%")
    print("schedule leg (predicted cost <= bulk <= source):")
    failures += check_schedule_predicted(metrics)
    print("schedule leg (committed measured medians):")
    failures += check_schedule_measured()
    print("calibrated predicted-vs-measured check:")
    failures += check_calibration()
    print("pipelined emitter leg (fallback identity + async plan):")
    failures += check_pipelined()
    print("BENCH_6 serve-decode cache report:")
    failures += check_bench6()
    print("BENCH_9 statically-pruned block tuning:")
    failures += check_bench9()
    if failures:
        print(f"\nFAIL: {len(failures)} regression(s) "
              f"(tolerance {TOLERANCE_PCT}%):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nOK: {len(metrics)} kernels within {TOLERANCE_PCT}% of "
          "baseline; beam never worse than hill climb; schedules ranked "
          "cost <= bulk <= source with measured cost medians inside the "
          "bulk tolerance; calibrated profiles rank >= 0.8 Spearman and "
          "beat uncalibrated MAPE")
    return 0


if __name__ == "__main__":
    sys.exit(main())
