"""§V-A validation: WHY the paper restricts its rule set.

The paper: "ACC Saturator can rewrite subtraction, division, memory
access order, ... these rules can increase the size of e-graphs and lead
to slow extraction ... we restrict the tool to only use the set of rules
mentioned earlier." This benchmark quantifies that trade-off on our
suite: Table-I rules vs Table-I + the extended set (sub/div/neg/square
rewrites) vs + TPU strength reductions.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import SaturatorConfig, saturate_program
from .kernel_suite import SUITE


def run_rule_ablation() -> List[Dict]:
    rows = []
    variants = {
        "paper": dict(extended_rules=False, tpu_rules=False),
        "paper+tpu": dict(extended_rules=False, tpu_rules=True),
        "extended": dict(extended_rules=True, tpu_rules=False),
        "extended+tpu": dict(extended_rules=True, tpu_rules=True),
    }
    for name, mk in SUITE.items():
        row = {"kernel": name}
        for vname, kw in variants.items():
            cfg = SaturatorConfig(mode="accsat", **kw)
            sk = saturate_program(mk(), cfg)
            rep = sk.report()
            row[vname] = {
                "e_nodes": rep["sat_nodes"],
                "sat_s": round(rep["sat_s"], 4),
                "extract_s": round(rep["extract_s"], 4),
                "dag_cost": rep["dag_cost"],
                "stop": rep["sat_stop"],
            }
        rows.append(row)
    return rows
