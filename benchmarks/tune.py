"""Statically-pruned row-block autotuner for the tile kernels (PR 9).

``make_tile_op`` autosizes one ``row_block`` per kernel from the declared
geometry (``pick_row_block``); this driver searches the block-shape space
around that default — but instead of timing every candidate, it first
runs each through the symbolic grid verifier
(:func:`repro.verify.grid_check.check_tile_kernel_grid`) and **prunes
statically**:

* ``sublane-misaligned`` — ``row_block % 8 != 0`` (the fp32 native tile
  is 8 sublanes; misaligned blocks relayout on every load);
* ``exceeds-rows``       — larger than the tuning geometry's row count
  (``plan_tile_call`` would clamp it to a duplicate of ``rows``);
* any grid-pass **error** (``grid-vmem-overflow``, ``grid-oob-read``,
  ...) — the candidate is illegal, not merely slow;
* ``vmem-headroom``      — the exact double-buffer-aware footprint
  busts the 4x-headroom autosizing budget (legal but compiler-hostile:
  the same register-pressure concern, paper §VIII, that caps the
  default).

Only the survivors are measured (interleaved round-robin with the
``measure.py`` gc/rotation discipline — every candidate runs the same
op on the same inputs, only the launch grid moves); the winner is the
fastest median. ``--fit`` persists winners into the committed device
profile (``fit["tuned_row_blocks"]``) — ``row_block`` is deliberately
outside the saturation-cache fingerprint (``repro.cache.keys``), so
tuned defaults never invalidate committed cache entries.

The committed ``BENCH_9.json`` records the *invariant* facts only
(candidate/pruned counts, prune reasons, survivor sets, winner shapes —
no wall clocks); ``bench_regression.py`` recomputes the static half and
gates on it. The static report is hash-seed invariant (the plan depends
only on declared program geometry), which ``--static --keep-hashseed``
lets CI check under rotated ``PYTHONHASHSEED``.

Usage:
    python -m benchmarks.tune                  # tune all tile kernels
    python benchmarks/tune.py --smoke          # 2-kernel CI gate
    python benchmarks/tune.py --static         # prune report only, no timing
    python benchmarks/tune.py --update-bench   # refresh BENCH_9.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import statistics
import sys
import time

if __package__ in (None, ""):        # direct script invocation
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bootstrap import OUT_ROOT, ROOT, die_with_import_help
from benchmarks.hashseed import reexec_with_fixed_hashseed

# --keep-hashseed skips the PYTHONHASHSEED=0 pin: the static prune
# report must not depend on hash order (CI runs it under rotated seeds
# and diffs), while timed runs keep the deterministic-extraction pin.
if "--keep-hashseed" not in sys.argv:
    reexec_with_fixed_hashseed()

try:
    import numpy as np
    import jax
except ImportError as e:
    die_with_import_help(e)

from benchmarks.measure import PROFILE_DIR, SMOKE_KERNELS, TILE_KERNELS

TUNE_SCHEMA_VERSION = 1
BENCH9 = ROOT / "BENCH_9.json"
DEFAULT_OUT = OUT_ROOT / "tune.json"

# Geometrically-spaced candidates around the 8..512 autosizing range,
# plus deliberate illegal probes: 4 and 12 are never sublane-aligned,
# 768/1024 overshoot most tuning geometries — the static filter must
# always have something to reject.
CANDIDATE_ROW_BLOCKS = (4, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256,
                        384, 512, 768, 1024)
TUNE_ROWS = 1000     # ragged against every aligned candidate above 8
SMOKE_ROWS = 264     # small CI geometry, still ragged for most blocks


def _op_for(name: str):
    from repro.kernels.tile_programs import get_tile_op
    return get_tile_op(name)


def static_prune(name: str, rows: int = TUNE_ROWS) -> dict:
    """Classify every candidate row block for one kernel — no timing,
    no randomness; the grid verifier is the only legality oracle."""
    from repro.core.hardware import DEFAULT_CHIP
    from repro.core.pallasgen import _declared_feature_dim
    from repro.verify.grid_check import check_tile_kernel_grid

    if rows % 8:
        raise ValueError(f"tuning rows must be sublane-aligned (multiple "
                         f"of 8), got {rows}")
    op = _op_for(name)
    prog = op.sk.ssa.prog
    budget = DEFAULT_CHIP.vmem_bytes // 4     # pick_row_block's headroom
    default_rb = op.row_block
    # what the default actually runs at this geometry: plan_tile_call
    # clamps row_block to the row count, so the baseline the winner must
    # beat is the clamped block, not the (possibly larger) autosized one
    eff_default = min(default_rb, rows)
    cands = sorted(set(CANDIDATE_ROW_BLOCKS) | {eff_default})
    entries = []
    for rb in cands:
        entry = {"row_block": rb, "default": rb == eff_default}
        if rb % 8:
            entry.update(status="pruned", reason="sublane-misaligned")
        elif rb > rows:
            entry.update(status="pruned", reason="exceeds-rows")
        else:
            res = check_tile_kernel_grid(op.pk, prog, row_block=rb,
                                         rows=rows)
            errors = [f for f in res.findings if f.severity == "error"]
            if errors:
                entry.update(status="pruned", reason=errors[0].code)
            elif res.vmem_bytes > budget:
                entry.update(status="pruned", reason="vmem-headroom",
                             vmem_bytes=res.vmem_bytes)
            else:
                entry.update(status="survivor", vmem_bytes=res.vmem_bytes)
        entries.append(entry)
    survivors = [e["row_block"] for e in entries
                 if e["status"] == "survivor"]
    assert eff_default in survivors, \
        f"{name}: autosized default {default_rb} (clamped {eff_default})" \
        f" failed its own legality check — pick_row_block and " \
        f"grid_check disagree"
    reasons: dict = {}
    for e in entries:
        if e["status"] == "pruned":
            reasons[e["reason"]] = reasons.get(e["reason"], 0) + 1
    return {"kernel": name, "rows": rows,
            "d": _declared_feature_dim(prog) or 256,
            "default_row_block": default_rb,
            "effective_default": eff_default,
            "candidates": entries,
            "n_candidates": len(entries),
            "n_pruned": len(entries) - len(survivors),
            "pruned_reasons": dict(sorted(reasons.items())),
            "survivors": survivors}


def _tune_inputs(op, rows: int, d: int):
    """Deterministic operand arrays at the tuning geometry (values in
    [0.1, 1.0) for log/rsqrt/recip domain safety, like measure.py)."""
    from repro.verify.grid_check import tile_input_shapes
    rng = np.random.default_rng(0)
    shapes = tile_input_shapes(op.pk, op.sk.ssa.prog, rows, d)
    args = [jax.numpy.asarray(
        rng.uniform(0.1, 1.0, size=s).astype(np.float32)) for s in shapes]
    scalars = {s: 0.5 for s in op.sk.ssa.prog.scalars}
    return args, scalars


def tune_kernel(name: str, rows: int = TUNE_ROWS, reps: int = 5,
                warmup: int = 2) -> dict:
    """Static prune, then measure the survivors and pick the winner.

    Candidates share one saturated op (``dataclasses.replace`` swaps
    only ``row_block`` — the launch grid, not the kernel body), one
    input set, and the interleaved-rotation/gc timing discipline of
    ``measure.py``, so medians compare cleanly."""
    import gc
    rep = static_prune(name, rows)
    op = _op_for(name)
    args, scalars = _tune_inputs(op, rows, rep["d"])
    ops = {rb: dataclasses.replace(op, row_block=rb)
           for rb in rep["survivors"]}

    def call(o):
        return jax.block_until_ready(o.apply(*args, **scalars))

    for _ in range(warmup):
        for o in ops.values():
            call(o)
    times: dict = {rb: [] for rb in ops}
    order = list(ops)
    gc_was_enabled = gc.isenabled()
    try:
        for r in range(reps):
            gc.collect()
            gc.disable()
            rot = r % len(order)
            for rb in order[rot:] + order[:rot]:
                t0 = time.perf_counter()
                call(ops[rb])
                times[rb].append(time.perf_counter() - t0)
            if gc_was_enabled:
                gc.enable()
    finally:
        if gc_was_enabled:
            gc.enable()
    medians = {rb: statistics.median(ts) * 1e9 for rb, ts in times.items()}
    # fastest median; ties break to the smaller block (deterministic)
    winner = min(medians, key=lambda rb: (medians[rb], rb))
    default = rep["effective_default"]
    rep.update(
        measured_ns={str(rb): medians[rb] for rb in sorted(medians)},
        winner_row_block=winner,
        winner_ns=medians[winner],
        default_ns=medians[default],
        winner_vs_default_pct=(100.0 * (medians[winner]
                                        - medians[default])
                               / medians[default]),
        reps=reps, warmup=warmup)
    return rep


def persist_winners(results, out_dir: pathlib.Path = PROFILE_DIR):
    """Fold the winners into the committed device profile's ``fit``
    section (``tuned_row_blocks``). Safe by construction: ``row_block``
    never enters a cache fingerprint, so default-config cache entries
    keep their keys byte-identical."""
    from repro.analysis import load_profile
    backend = jax.default_backend()
    kind = "pallas_interpret" if backend == "cpu" else "pallas_compiled"
    path = out_dir / f"{backend}_{kind}.json"
    if not path.exists():
        print(f"no device profile at {path}; run "
              "`python benchmarks/measure.py --fit` first — winners "
              "not persisted", file=sys.stderr)
        return None
    prof = load_profile(path)
    tuned = prof.fit.setdefault("tuned_row_blocks", {})
    for r in results:
        tuned[r["kernel"]] = {"row_block": r["winner_row_block"],
                              "rows": r["rows"]}
    prof.save(path)
    return path


def bench9_doc(results) -> dict:
    """The committed, machine-independent view: static facts + winner
    shapes, no wall clocks."""
    kernels = {}
    for r in results:
        kernels[r["kernel"]] = {
            "default_row_block": r["default_row_block"],
            "n_candidates": r["n_candidates"],
            "n_pruned": r["n_pruned"],
            "pruned_reasons": r["pruned_reasons"],
            "survivors": r["survivors"],
            "winner_row_block": r.get("winner_row_block"),
        }
    return {"schema_version": TUNE_SCHEMA_VERSION, "pr": 9,
            "rows": results[0]["rows"] if results else TUNE_ROWS,
            "description": "statically-pruned row-block tuning summary "
                           "(invariants only — see benchmarks/tune.py "
                           "and docs/verification.md)",
            "kernels": kernels}


def smoke() -> int:
    """CI gate: 2 kernels at the small geometry — every kernel must
    prune statically, the winner must be a legal survivor, and the
    winner can never be slower than the default (it is the argmin over
    a set containing the default)."""
    results = []
    for k in SMOKE_KERNELS:
        r = tune_kernel(k, rows=SMOKE_ROWS, reps=3, warmup=1)
        assert r["n_pruned"] >= 1, f"{k}: nothing statically pruned"
        assert r["winner_row_block"] in r["survivors"]
        assert r["winner_row_block"] % 8 == 0
        assert r["winner_ns"] <= r["default_ns"], \
            f"{k}: winner slower than default?!"
        results.append(r)
        print(f"  {k:16s} default {r['effective_default']:4d} -> winner "
              f"{r['winner_row_block']:4d}  ({r['n_pruned']} pruned / "
              f"{r['n_candidates']} candidates, "
              f"{r['winner_vs_default_pct']:+.1f}% vs default)")
    avg = sum(r["n_pruned"] for r in results) / len(results)
    print(f"tune smoke OK: {len(results)} kernels, "
          f"avg {avg:.1f} candidates pruned statically")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", help="comma-separated subset")
    ap.add_argument("--rows", type=int, default=TUNE_ROWS,
                    help=f"tuning row count (default {TUNE_ROWS})")
    ap.add_argument("--reps", type=int, default=5,
                    help="median-of-N timing repeats (default 5)")
    ap.add_argument("--static", action="store_true",
                    help="static prune report only — no timing, no "
                         "randomness; deterministic across hash seeds")
    ap.add_argument("--keep-hashseed", action="store_true",
                    help="don't re-exec with PYTHONHASHSEED=0 (the "
                         "static report must not need the pin)")
    ap.add_argument("--smoke", action="store_true",
                    help="2-kernel CI gate at the small geometry")
    ap.add_argument("--fit", action="store_true",
                    help="persist winners into the committed device "
                         f"profile under {PROFILE_DIR}")
    ap.add_argument("--update-bench", action="store_true",
                    help=f"write the invariant summary to {BENCH9}")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT,
                    help="full tuning report JSON path")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    kernels = (args.kernels.split(",") if args.kernels
               else list(TILE_KERNELS))
    unknown = [k for k in kernels if k not in TILE_KERNELS]
    if unknown:
        ap.error(f"unknown kernels {unknown}; "
                 f"available: {list(TILE_KERNELS)}")
    results = []
    for name in kernels:
        if args.static:
            r = static_prune(name, rows=args.rows)
        else:
            r = tune_kernel(name, rows=args.rows, reps=args.reps)
        results.append(r)
        win = (f" -> winner {r['winner_row_block']:4d} "
               f"({r['winner_vs_default_pct']:+.1f}% vs default)"
               if "winner_row_block" in r else "")
        print(f"  {name:16s} default {r['default_row_block']:4d}  "
              f"{r['n_pruned']}/{r['n_candidates']} pruned "
              f"{r['pruned_reasons']}{win}")
    avg = sum(r["n_pruned"] for r in results) / max(len(results), 1)
    print(f"tune: {len(results)} kernels, avg {avg:.1f} candidates "
          f"pruned statically per kernel"
          + (" (static only — nothing measured)" if args.static else ""))
    if args.static:
        # canonical JSON on stdout-adjacent file for determinism diffs
        doc = {"schema_version": TUNE_SCHEMA_VERSION, "static": True,
               "rows": args.rows, "results": results}
    else:
        doc = {"schema_version": TUNE_SCHEMA_VERSION, "static": False,
               "rows": args.rows, "results": results}
        if args.fit:
            path = persist_winners(results)
            if path is not None:
                print(f"persisted winners into {path}")
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"wrote {args.out}")
    if args.update_bench:
        BENCH9.write_text(json.dumps(bench9_doc(results), indent=1,
                                     sort_keys=True) + "\n")
        print(f"wrote {BENCH9}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
