"""Shared sys.path / dependency bootstrap for the benchmark drivers.

Every script in ``benchmarks/`` must work both ways:

    python -m benchmarks.run          # package invocation, from repo root
    python benchmarks/run.py          # direct script invocation, anywhere

Direct invocation puts only ``benchmarks/`` on ``sys.path`` — neither the
repo root (for ``import benchmarks``) nor ``src/`` (for ``import repro``)
is importable, and any relative import dies with
"attempted relative import with no known parent package".
:func:`ensure_repo_imports` fixes both path entries idempotently, and
:func:`die_with_import_help` turns the remaining ImportErrors (missing
third-party deps) into actionable guidance instead of a traceback.
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
# Single gitignored home for every generated benchmark artifact
# (measurements, bench JSONs, regression-gate outputs). Only
# experiments/bench_baseline.json and experiments/device_profiles/ are
# committed.
OUT_ROOT = ROOT / "experiments" / "out"

_HELP = """\
ERROR: {exc}

The benchmark drivers need the repo root and src/ importable plus the
runtime deps. Checklist:
  * run from the repo root:    python -m benchmarks.run
    (direct script invocation  python benchmarks/run.py  also works —
    the driver bootstraps sys.path itself)
  * the saturator package lives in src/; this bootstrap inserts
    {root}/src automatically, so a failing `import repro`
    means the checkout is incomplete
  * third-party deps: pip install "jax[cpu]" numpy
"""


def ensure_repo_imports() -> None:
    """Make ``import benchmarks`` and ``import repro`` resolvable from any
    invocation style (idempotent)."""
    for p in (str(ROOT), str(ROOT / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)


def die_with_import_help(exc: ImportError) -> "NoReturn":  # noqa: F821
    print(_HELP.format(exc=exc, root=ROOT), file=sys.stderr)
    raise SystemExit(2)


ensure_repo_imports()
