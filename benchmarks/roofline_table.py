"""Generate experiments/roofline_table.md from the dry-run JSONs, plus
experiments/kernel_latency_table.md: the unified analysis subsystem's
predicted FLOPs/bytes/latency per extracted kernel (run with --kernels),
so the perf trajectory can track predicted vs measured throughput."""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"
OUT = ROOT / "experiments" / "roofline_table.md"
KOUT = ROOT / "experiments" / "kernel_latency_table.md"


def load_cells():
    cells = {}
    for p in sorted(DRY.glob("*.json")):
        cells[p.stem] = json.loads(p.read_text())
    return cells


def fmt_row(d):
    r = d["roofline"]
    acc = d.get("accum_steps", "")
    return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | {r['dominant']} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_frac']:.4f} | "
            f"{r['bytes_per_device']/2**30:.2f} | "
            f"{'Y' if r['fits_hbm'] else 'OVER'} | {acc} |")


def main():
    cells = load_cells()
    lines = [
        "# Roofline table — all (arch × shape × mesh) dry-run cells",
        "",
        "Terms are per-device seconds ×1e3 (ms) from the trip-count-aware",
        "HLO walk; v5e constants 197 TFLOP/s bf16, 819 GB/s HBM,",
        "50 GB/s/link ICI. `useful` = MODEL_FLOPS/(HLO_FLOPs×devices);",
        "`frac` = roofline fraction (no-overlap lower bound).",
        "",
        "| arch | shape | mesh | comp_ms | mem_ms | coll_ms | dominant |"
        " useful | frac | GiB/dev | fit | accum |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    skipped = []
    for stem in sorted(cells):
        d = cells[stem]
        if d.get("status") == "ok":
            lines.append(fmt_row(d))
        elif d.get("status") == "skipped":
            skipped.append(f"{d['arch']} × {d['shape']} × {d['mesh']}")
    lines += ["", "## Skipped cells (assigned policy)",
              "", "Pure full-attention architectures skip `long_500k` "
              "(quadratic attention; run for SSM/hybrid as assigned):", ""]
    lines += [f"* {s}" for s in skipped]
    # collective breakdowns for the hillclimb cells
    lines += ["", "## Collective breakdown (hillclimb cells, single-pod)",
              ""]
    for stem in ("minitron_4b_train_4k_sp",
                 "mistral_large_123b_prefill_32k_sp",
                 "arctic_480b_train_4k_sp"):
        d = cells.get(stem)
        if d and d.get("status") == "ok":
            br = d["roofline"]["collective_breakdown"]
            tot = sum(br.values()) or 1
            pieces = ", ".join(f"{k} {v/1e9:.1f} GB ({v/tot:.0%})"
                               for k, v in sorted(br.items(),
                                                  key=lambda kv: -kv[1]))
            lines.append(f"* **{stem}**: {pieces}")
    OUT.write_text("\n".join(lines) + "\n")
    print(f"wrote {OUT} ({len(cells)} cells)")


def schedule_lines():
    """Measured statement-schedule section from the committed
    schedule-aware profile's embedded medians (deterministic: renders
    committed evidence, never re-times)."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.analysis import load_profile
    path = (ROOT / "experiments" / "device_profiles"
            / "cpu_pallas_interpret_sched.json")
    lines = [
        "",
        "## Statement schedules (measured, committed evidence)",
        "",
        "Median per-call times of every tile kernel under each emitted",
        "statement order — same extracted term, only the load/compute/",
        "store order moves (`benchmarks/measure.py --schedules ...`).",
        "`measured_kind` flags the regime; on `pallas_interpret` the",
        "body executes op-by-op in Python, so per-op dispatch dominates",
        "and order effects sit near the noise floor — the CI gate",
        "requires cost <= bulk within 2%, and the",
        "schedule-aware *predicted* ordering (cost <= bulk <= source) is",
        "the deterministic invariant. On compiled backends the overlap",
        "distance is physical (DMA issue vs consumer).",
    ]
    if not path.exists():
        lines += ["", "*(no committed schedule-aware profile)*"]
        return lines
    prof = load_profile(path)
    medians = prof.fit.get("schedule_medians", {})
    if not medians:
        lines += ["", "*(profile has no embedded schedule medians)*"]
        return lines
    from repro.analysis import schedule_paired_pct  # single owner of
    # the gated statistic — the table must report what CI enforces
    better = [k for k, m in medians.items()
              if (schedule_paired_pct(m) or 0.0) < 0.0]
    lines += [
        "",
        f"`{prof.name}` — {prof.chip}, `{prof.measured_kind}`; "
        f"cost schedule measured faster than bulk (paired per-rep "
        f"median) on **{len(better)}/{len(medians)}** kernels "
        f"({', '.join(sorted(better)) or 'none'}).",
        "",
        "| kernel | source_ns | bulk_ns | cost_ns | cost vs bulk "
        "(paired %) |",
        "|---|---|---|---|---|",
    ]

    def fmt(x, spec):
        return format(x, spec) if x is not None else "—"

    for k in sorted(medians):
        m = medians[k]
        lines.append(
            f"| {k} | {fmt(m.get('source'), '.0f')} | "
            f"{fmt(m.get('bulk'), '.0f')} | {fmt(m.get('cost'), '.0f')} | "
            f"{fmt(schedule_paired_pct(m), '+.2f')} |")
    return lines


def calibration_lines():
    """Predicted-vs-measured section from the committed device profiles
    (deterministic: renders each profile's stored fit evidence, so the
    committed table never drifts with runner speed)."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.analysis import SPEARMAN_FLOOR, load_profile
    paths = sorted((ROOT / "experiments" / "device_profiles").glob("*.json"))
    lines = [
        "",
        "## Predicted vs measured (calibrated device profiles)",
        "",
        "Per committed profile under `experiments/device_profiles/`: the",
        "measured per-instance times it was fitted on",
        "(`benchmarks/measure.py`, warmup + median-of-k, `measured_kind`",
        "flagged), the uncalibrated analytic prediction, and the",
        "calibrated prediction. MAPE and Spearman rank correlation are",
        f"gated in CI: calibrated Spearman >= {SPEARMAN_FLOOR} and >= the",
        "fit-time value, calibrated MAPE strictly below uncalibrated.",
        "Re-fit with `python benchmarks/measure.py --fit` on new hardware.",
    ]
    if not paths:
        lines += ["", "*(no committed device profiles)*"]
        return lines
    for p in paths:
        prof = load_profile(p)
        f = prof.fit
        lines += [
            "",
            f"### `{prof.name}` — {prof.chip}, `{prof.measured_kind}`"
            f" ({len(f.get('kernels', []))} kernels)",
            "",
            f"MAPE **{f['mape_pct']:.1f}%** (uncalibrated "
            f"{f['uncalibrated_mape_pct']:.1f}%) · Spearman "
            f"**{f['spearman']:.3f}** (uncalibrated "
            f"{f['uncalibrated_spearman']:.3f})",
            "",
            "| kernel | measured_ns | uncal_pred_ns | cal_pred_ns | err% |",
            "|---|---|---|---|---|",
        ]
        for r in f.get("kernels", []):
            err = 100.0 * (r["predicted_ns"] - r["measured_ns"]) \
                / r["measured_ns"]
            lines.append(
                f"| {r['kernel']} | {r['measured_ns']:.0f} | "
                f"{r['uncalibrated_ns']:.1f} | {r['predicted_ns']:.0f} | "
                f"{err:+.1f} |")
    return lines


def kernel_table(res=None):
    """Per-kernel roofline predictions from the unified analysis engine
    (no dry-run artifacts needed): extracted-term FLOPs, HBM bytes, and
    predicted latency under the default chip's compute/memory roofs,
    plus the beam-vs-hillclimb extraction delta and the calibrated
    predicted-vs-measured section. Pass precomputed
    ``run_saturation_stats()`` results to avoid re-running the suite
    (``bench_regression.py`` does)."""
    sys.path.insert(0, str(ROOT / "src"))
    sys.path.insert(0, str(ROOT))
    if res is None:
        from benchmarks.saturation_stats import run_saturation_stats
        res = run_saturation_stats()
    lines = [
        "# Kernel roofline predictions (unified analysis subsystem)",
        "",
        "Per extracted tile body: predicted VPU FLOPs, HBM bytes, and",
        "roofline latency (v5e peaks; one tile instance; shape/dtype-aware",
        "load/store pricing). `beam Δ%` is the beam-search extraction's",
        "predicted-latency delta vs the PR-2 multi-start hill climb; the",
        "structural beam <= hillclimb guarantee is on the store-free DAG",
        "objective (gated in CI), so a negative delta marks a strictly",
        "better selection. `sched Δ%` is the cost-driven statement",
        "schedule's predicted latency vs the paper's bulk load under the",
        "schedule-aware objective (load→compute overlap distance + VMEM",
        "pressure, repro.core.schedule); CI gates cost <= bulk <= source",
        "per kernel. The calibration section below tracks predictions",
        "against measured times (benchmarks/measure.py).",
        "",
        "| kernel | flops | bytes | latency_ns | bound | beam Δ% |"
        " sched Δ% |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in res["rows"]:
        delta = r.get("beam_vs_hillclimb_pct")
        sp = r.get("schedule_predicted") or {}
        sched_delta = (100.0 * (sp["cost"] - sp["bulk"]) / sp["bulk"]
                       if sp.get("bulk") else None)
        lines.append(
            f"| {r['kernel']} | {r['predicted_flops']:.0f} | "
            f"{r['predicted_bytes']:.0f} | "
            f"{r['predicted_latency_ns']:.2f} | {r['predicted_bound']} | "
            f"{'' if delta is None else format(delta, '+.2f')} | "
            f"{'' if sched_delta is None else format(sched_delta, '+.2f')}"
            " |")
    lines += schedule_lines()
    lines += calibration_lines()
    KOUT.parent.mkdir(parents=True, exist_ok=True)
    KOUT.write_text("\n".join(lines) + "\n")
    print(f"wrote {KOUT} ({len(res['rows'])} kernels)")


if __name__ == "__main__":
    if "--kernels" in sys.argv:
        # pin the hash seed so the committed table always matches what
        # the bench-regression CI gate computes
        sys.path.insert(0, str(ROOT / "benchmarks"))
        from hashseed import reexec_with_fixed_hashseed
        reexec_with_fixed_hashseed()
        kernel_table()
    else:
        main()
