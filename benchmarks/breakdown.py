"""Table IV analog: per-kernel breakdown of what ACCSAT changed.

Columns mirror the paper's: instruction count deltas, loads/stores saved,
FMA formed, bulk-load hoist fraction, plus a TPU-cost-model cycle estimate
(the A100 wall-clock column has no CPU analogue; the cost model is the
architecture-transferable signal)."""
from __future__ import annotations

from typing import Dict, List

from repro.core import (MODES, SaturatorConfig, TPUCostModel,
                        saturate_program)
from repro.core.extract import extract_dag
from .kernel_suite import PAPER_REF, SUITE


def run_breakdown() -> List[Dict]:
    rows = []
    for name, mk in SUITE.items():
        per_mode = {}
        for mode in MODES:
            sk = saturate_program(mk(), SaturatorConfig(mode=mode))
            st = sk.kernel.stats
            tpu_cost = extract_dag(sk.ssa.egraph, tuple(sk.ssa.roots()),
                                   cost_model=TPUCostModel(),
                                   local_search=False).dag_cost
            per_mode[mode] = dict(
                ops=st.n_ops, loads=st.n_loads, stores=st.n_stores,
                fma=st.n_fma, temps=st.n_temps,
                bulk_hoisted=st.loads_before_compute,
                cost=sk.extraction.dag_cost, tpu_cost=tpu_cost)
        b = per_mode["baseline"]
        a = per_mode["accsat"]
        rows.append({
            "kernel": name,
            "paper_ref": PAPER_REF[name],
            "baseline_ops": b["ops"], "accsat_ops": a["ops"],
            "ops_delta_pct": 100.0 * (a["ops"] - b["ops"]) / max(b["ops"], 1),
            "baseline_loads": b["loads"], "accsat_loads": a["loads"],
            "loads_saved_pct": 100.0 * (b["loads"] - a["loads"])
            / max(b["loads"], 1),
            "stores": a["stores"],
            "fma_formed": a["fma"],
            "bulk_hoist_frac": a["bulk_hoisted"] / max(a["loads"], 1),
            "paper_cost_reduction_pct": 100.0 * (b["cost"] - a["cost"])
            / max(b["cost"], 1),
            "tpu_cost_reduction_pct": 100.0 * (b["tpu_cost"] - a["tpu_cost"])
            / max(b["tpu_cost"], 1),
        })
    return rows
