"""Fig. 2 / Fig. 4 analog: per-kernel ablation over the paper's four
configurations (CSE / CSE+SAT / CSE+BULK / ACCSAT) plus the unoptimized
baseline.

Wall time on CPU executes the generated thread body sequentially over the
grid under one jit (XLA-CPU applies its own CSE, so wall-clock deltas are
conservative — mirroring the paper's NVHPC rows, where CSE was ~1.0x
because the compiler already does it). The cost-model and instruction
columns carry the architecture-independent signal.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import MODES, SaturatorConfig, saturate_program
from .kernel_suite import SUITE, inputs_for


def build_grid_runner(sk, arrays, grid_scalar, grid, scalars):
    in_names = sk.kernel.in_arrays
    out_names = sk.kernel.out_arrays
    scalar_names = sk.kernel.scalars
    lo, hi = grid if isinstance(grid, tuple) else (0, grid)
    const_args = {n: jnp.asarray(arrays[n]) for n in in_names
                  if n not in out_names}
    init_state = {n: jnp.asarray(arrays[n]) for n in out_names}

    def run(state):
        def step(i, st):
            args = [st[n] if n in st else const_args[n] for n in in_names]
            scal = [i if s == grid_scalar else scalars[s]
                    for s in scalar_names]
            outs = sk.fn(*args, *scal)
            return dict(zip(out_names, outs))
        return lax.fori_loop(lo, hi, step, state)

    return jax.jit(run), init_state, hi - lo


def time_runner(fn, init_state, repeats: int = 3) -> float:
    out = fn(init_state)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(init_state)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run_ablation(kernels=None, n: int = 64 * 64, repeats: int = 3
                 ) -> Dict[str, Dict[str, dict]]:
    kernels = kernels or list(SUITE)
    results: Dict[str, Dict[str, dict]] = {}
    for name in kernels:
        results[name] = {}
        arrays, gscalar, grid, scalars = inputs_for(name, n=n)
        for mode in MODES:
            prog = SUITE[name]()
            sk = saturate_program(prog, SaturatorConfig(mode=mode))
            fn, init_state, n_threads = build_grid_runner(
                sk, arrays, gscalar, grid, scalars)
            wall = time_runner(fn, init_state, repeats)
            st = sk.kernel.stats
            results[name][mode] = {
                "wall_s": wall,
                "us_per_thread": wall / n_threads * 1e6,
                "dag_cost": sk.extraction.dag_cost,
                "n_ops": st.n_ops,
                "n_loads": st.n_loads,
                "n_stores": st.n_stores,
                "n_fma": st.n_fma,
                "n_temps": st.n_temps,
                "loads_before_compute": st.loads_before_compute,
                "sat_s": sk.saturation.wall_s if sk.saturation else 0.0,
                "sat_nodes": sk.saturation.n_nodes if sk.saturation else 0,
                "ssa_ms": sk.ssa_wall_s * 1e3,
                "extract_s": sk.extraction.wall_s,
                "codegen_ms": sk.codegen_wall_s * 1e3,
            }
        base = results[name]["baseline"]
        for mode in MODES:
            r = results[name][mode]
            r["speedup_wall"] = base["wall_s"] / r["wall_s"]
            r["cost_reduction"] = (base["dag_cost"] - r["dag_cost"]) \
                / base["dag_cost"]
            r["ops_reduction"] = (base["n_ops"] - r["n_ops"]) \
                / max(base["n_ops"], 1)
            r["loads_reduction"] = (base["n_loads"] - r["n_loads"]) \
                / max(base["n_loads"], 1)
    return results
