"""NPB/SPEC-style benchmark kernels (paper Tables II/III analog).

Each kernel mirrors the computational/access pattern of a paper benchmark
and is written in the saturator DSL as the *body of one parallel thread*
(the code under the innermost OpenACC loop). Execution on CPU vmaps the
generated body over the thread grid — the same body × threads structure
the GPU runs.

  bt_like   — NPB-BT z_solve block (Listing 2): dense 3×3 jacobian
              combinations, dt·tz products shared everywhere, 18 loads
  sp_like   — NPB-SP halo stencil: second differences, shared coefficients
  cg_like   — NPB-CG irregular SpMV row: indirect gather loop
  ep_like   — NPB-EP random-pair Box-Muller tail: arithmetic-dense
  mg_like   — NPB-MG long+short range 1-D stencil
  lbm_like  — SPEC olbm collide-stream: 9 distribution loads, ~50%
              redundant subexpressions (paper: CSE removes ~50% of loads)
  ft_like   — NPB-FT twiddle: complex multiply (FMA2/FMA3 shaped)
"""
from __future__ import annotations

import numpy as np

from repro.core import KernelProgram, c, exp, log, sqrt, toint, v

GRID = 96  # threads per axis for the CPU vmap grid


def bt_like() -> KernelProgram:
    p = KernelProgram("bt_like")
    njac = p.array_in("njac", shape=(3, 3, None))
    fjac = p.array_in("fjac", shape=(3, 3, None))
    u = p.array_in("u", shape=(3, None))
    for name in ("lhsa", "lhsb"):
        p.array_out(name, shape=(3, 3, None))
    i = p.scalar("i")
    dt = p.scalar("dt")
    tz1 = p.scalar("tz1")
    tz2 = p.scalar("tz2")
    dz = p.scalar("dz")
    # the original names tmp1/tmp2 (paper Listing 2) but re-loads njac/
    # fjac/u per statement — exactly what CSE+BULK clean up
    tmp1 = p.let("tmp1", dt * tz1)
    tmp2 = p.let("tmp2", dt * tz2)
    for m in range(3):
        for n in range(3):
            nj = njac[c(m), c(n), v("i")]
            fj = fjac[c(m), c(n), v("i")]
            diag = (tmp1 * dz) if m == n else c(0.0)
            p.store("lhsa", -tmp1 * nj - tmp2 * fj - diag,
                    c(m), c(n), v("i"))
            p.store("lhsb", tmp1 * nj + tmp2 * fj + diag
                    + u[c(m), v("i")] * tmp2, c(m), c(n), v("i"))
    return p


def sp_like() -> KernelProgram:
    p = KernelProgram("sp_like")
    u = p.array_in("u", shape=(None,))
    ws = p.array_in("ws", shape=(None,))
    p.array_out("rhs", shape=(None,))
    i = p.scalar("i")
    c1 = p.scalar("c1")
    c2 = p.scalar("c2")
    um = u[v("i") - 1]
    uc = u[v("i")]
    up = u[v("i") + 1]
    wm = ws[v("i") - 1]
    wc = ws[v("i")]
    wp = ws[v("i") + 1]
    p.store("rhs", c1 * (up - 2.0 * uc + um)
            + c2 * (wp * up - 2.0 * wc * uc + wm * um)
            + c2 * (wp * up + wm * um), v("i"))
    return p


def cg_like() -> KernelProgram:
    p = KernelProgram("cg_like")
    a = p.array_in("a", shape=(None,))
    col = p.array_in("col", shape=(None,))
    x = p.array_in("x", shape=(None,))
    p.array_out("y", shape=(None,))
    row = p.scalar("row")
    nnz = p.scalar("nnz")
    p.let("acc", c(0.0))
    with p.for_("k", 0, v("nnz")):
        idx = v("row") * v("nnz") + v("k")
        p.let("acc", v("acc") + a[idx] * x[toint(col[idx])])
    p.store("y", v("acc"), v("row"))
    return p


def ep_like() -> KernelProgram:
    p = KernelProgram("ep_like")
    ax = p.array_in("ax", shape=(None,))
    ay = p.array_in("ay", shape=(None,))
    p.array_out("ox", shape=(None,))
    p.array_out("oy", shape=(None,))
    i = p.scalar("i")
    x = p.let("x", 2.0 * ax[v("i")] - 1.0)
    y = p.let("y", 2.0 * ay[v("i")] - 1.0)
    t = p.let("t", x * x + y * y)
    # Box-Muller tail: the original recomputes sqrt(-2 ln t / t) per output
    p.store("ox", x * sqrt((c(-2.0) * log(t)) / t), v("i"))
    p.store("oy", y * sqrt((c(-2.0) * log(t)) / t), v("i"))
    return p


def mg_like() -> KernelProgram:
    p = KernelProgram("mg_like")
    u = p.array_in("u", shape=(None,))
    p.array_out("o", shape=(None,))
    i = p.scalar("i")
    c0 = p.scalar("c0")
    c1 = p.scalar("c1")
    c2 = p.scalar("c2")
    p.store("o", c0 * u[v("i")]
            + c1 * (u[v("i") - 1] + u[v("i") + 1])
            + c2 * (u[v("i") - 2] + u[v("i") + 2]), v("i"))
    return p


def lbm_like() -> KernelProgram:
    p = KernelProgram("lbm_like")
    f = p.array_in("f", shape=(9, None))
    p.array_out("fo", shape=(9, None))
    i = p.scalar("i")
    omega = p.scalar("omega")
    loads = [f[c(k), v("i")] for k in range(9)]
    # programmer-style locals (the 'original code' has these, via p.let)
    acc = loads[0]
    for k in range(1, 9):
        acc = acc + loads[k]
    rho = p.let("rho", acc)
    cxs = [0, 1, 0, -1, 0, 1, -1, -1, 1]
    cys = [0, 0, 1, 0, -1, 1, 1, -1, -1]
    ux_e = c(0.0)
    uy_e = c(0.0)
    for k in range(9):
        if cxs[k]:
            ux_e = ux_e + float(cxs[k]) * loads[k]
        if cys[k]:
            uy_e = uy_e + float(cys[k]) * loads[k]
    ux = p.let("ux", ux_e / rho)
    uy = p.let("uy", uy_e / rho)
    usqr = p.let("usqr", ux * ux + uy * uy)
    w = [4 / 9] + [1 / 9] * 4 + [1 / 36] * 4
    for k in range(9):
        cu = p.let("cu", float(cxs[k]) * ux + float(cys[k]) * uy)
        feq = p.let("feq", float(w[k]) * rho
                    * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usqr))
        p.store("fo", loads[k] + omega * (feq - loads[k]), c(k), v("i"))
    return p


def ft_like() -> KernelProgram:
    p = KernelProgram("ft_like")
    xr = p.array_in("xr", shape=(None,))
    xi = p.array_in("xi", shape=(None,))
    tr = p.array_in("tr", shape=(None,))
    ti = p.array_in("ti", shape=(None,))
    p.array_out("yr", shape=(None,))
    p.array_out("yi", shape=(None,))
    i = p.scalar("i")
    ar = xr[v("i")]
    ai = xi[v("i")]
    br = tr[v("i")]
    bi = ti[v("i")]
    p.store("yr", ar * br - ai * bi, v("i"))   # FMA2 shape
    p.store("yi", ar * bi + ai * br, v("i"))   # FMA1 shape
    return p


SUITE = {
    "bt_like": bt_like,
    "sp_like": sp_like,
    "cg_like": cg_like,
    "ep_like": ep_like,
    "mg_like": mg_like,
    "lbm_like": lbm_like,
    "ft_like": ft_like,
}

# paper tables these kernels mirror (for the report)
PAPER_REF = {
    "bt_like": "NPB-BT z_solve (Table IV, Listings 2-3)",
    "sp_like": "NPB-SP / SPEC csp halo (Table II/III)",
    "cg_like": "NPB-CG irregular SpMV (Table II)",
    "ep_like": "NPB-EP random pairs (Table II)",
    "mg_like": "NPB-MG long+short stencil (Table II)",
    "lbm_like": "SPEC olbm collide (Table III)",
    "ft_like": "NPB-FT all-to-all twiddle (Table II)",
}


def inputs_for(name: str, n: int = GRID * GRID, seed: int = 0):
    """(arrays dict, grid scalar name, grid size, extra scalars)."""
    rng = np.random.default_rng(seed)
    N = n
    if name == "bt_like":
        return (dict(njac=rng.normal(size=(3, 3, N)),
                     fjac=rng.normal(size=(3, 3, N)),
                     u=rng.normal(size=(3, N)),
                     lhsa=np.zeros((3, 3, N)), lhsb=np.zeros((3, 3, N))),
                "i", N, dict(dt=0.01, tz1=0.3, tz2=0.7, dz=0.5))
    if name == "sp_like":
        return (dict(u=rng.normal(size=(N + 2,)),
                     ws=rng.normal(size=(N + 2,)),
                     rhs=np.zeros(N + 2)),
                "i", (1, N + 1), dict(c1=0.2, c2=0.05))
    if name == "cg_like":
        nnz = 8
        rows = N // nnz
        return (dict(a=rng.normal(size=(rows * nnz,)),
                     col=rng.integers(0, rows, size=(rows * nnz,))
                     .astype(np.float64),
                     x=rng.normal(size=(rows,)), y=np.zeros(rows)),
                "row", rows, dict(nnz=nnz))
    if name == "ep_like":
        u1 = rng.uniform(0.1, 0.9, size=(N,))
        u2 = rng.uniform(0.1, 0.9, size=(N,))
        return (dict(ax=u1, ay=u2, ox=np.zeros(N), oy=np.zeros(N)),
                "i", N, dict())
    if name == "mg_like":
        return (dict(u=rng.normal(size=(N + 4,)), o=np.zeros(N + 4)),
                "i", (2, N + 2), dict(c0=0.5, c1=0.25, c2=0.125))
    if name == "lbm_like":
        return (dict(f=rng.uniform(0.1, 1.0, size=(9, N)),
                     fo=np.zeros((9, N))),
                "i", N, dict(omega=1.2))
    if name == "ft_like":
        return (dict(xr=rng.normal(size=(N,)), xi=rng.normal(size=(N,)),
                     tr=rng.normal(size=(N,)), ti=rng.normal(size=(N,)),
                     yr=np.zeros(N), yi=np.zeros(N)),
                "i", N, dict())
    raise KeyError(name)
