"""Shape/dtype-aware roofline pricing (PR 3 tentpole): bf16/f8 byte
widths, broadcast scalar/row operand extents, uniform-vs-varying index
semantics, and the dtype threading through the whole pipeline."""
import pytest

from repro.analysis import (ArrayInfo, LatencyModel, RooflineCostModel,
                            TILE_ELEMS, dtype_byte_width, node_stats,
                            store_stats)
from repro.core import EGraph, KernelProgram, SaturatorConfig, add_expr, c, v
from repro.core.hardware import DEFAULT_CHIP
from repro.core.ir import ENode
from repro.core.pipeline import saturate_program
from repro.core.ssa import build_ssa


# -- dtype byte widths --------------------------------------------------------------
def test_dtype_byte_widths():
    assert dtype_byte_width("f32") == 4
    assert dtype_byte_width("bf16") == 2
    assert dtype_byte_width("f16") == 2
    assert dtype_byte_width("f8") == 1
    assert dtype_byte_width("f64") == 8


def test_unknown_dtype_raises():
    with pytest.raises(ValueError, match="unknown dtype"):
        dtype_byte_width("q4")


# -- per-node pricing with ArrayInfo ------------------------------------------------
def test_bf16_tile_halves_hbm_bytes():
    load = ENode("load", (0,))
    f32 = node_stats(load, info=ArrayInfo(shape=(8, 128), dtype="f32"))
    bf16 = node_stats(load, info=ArrayInfo(shape=(8, 128), dtype="bf16"))
    f8 = node_stats(load, info=ArrayInfo(shape=(8, 128), dtype="f8"))
    assert f32.bytes_read == TILE_ELEMS * 4
    assert bf16.bytes_read == f32.bytes_read / 2
    assert f8.bytes_read == f32.bytes_read / 4


def test_broadcast_row_and_scalar_extents():
    load = ENode("load", (0,))
    row = node_stats(load, info=ArrayInfo(shape=(1, 128), dtype="f32"))
    scalar = node_stats(load, info=ArrayInfo(shape=(), dtype="f32"))
    assert row.bytes_read == 128 * 4       # one row, not a full tile
    assert scalar.bytes_read == 4          # one element
    # unknown shape falls back to the full tile at the declared width
    unknown = node_stats(load, info=ArrayInfo(shape=None, dtype="bf16"))
    assert unknown.bytes_read == TILE_ELEMS * 2


def test_extent_capped_at_tile():
    load = ENode("load", (0,))
    huge = node_stats(load, info=ArrayInfo(shape=(4096, 4096), dtype="f32"))
    assert huge.bytes_read == TILE_ELEMS * 4  # one tile per instance


def test_symbolic_dim_prices_full_tile():
    load = ENode("load", (0,))
    sym = node_stats(load, info=ArrayInfo(shape=(None,), dtype="f32"))
    assert sym.bytes_read == TILE_ELEMS * 4


def test_array_info_index():
    info = ArrayInfo(shape=(3, 3, None), dtype="f32")
    assert info.index(2).shape == (None,)
    assert info.index(3).shape == ()
    assert info.index(3).elems() == 1
    assert info.index(0) is info


def test_store_stats_infos_and_dtype():
    full = store_stats(2)
    assert full.bytes_written == 2 * TILE_ELEMS * 4
    half = store_stats(2, dtype_bytes=2)
    assert half.bytes_written == full.bytes_written / 2
    mixed = store_stats(0, infos=[ArrayInfo(shape=(1, 128), dtype="f32"),
                                  None,
                                  ArrayInfo(shape=(8, 128), dtype="bf16")])
    assert mixed.bytes_written == 128 * 4 + TILE_ELEMS * 4 + TILE_ELEMS * 2


# -- uniform vs varying index semantics ---------------------------------------------
def _norm_program(dtype="f32"):
    p = KernelProgram("t", dtype=dtype)
    x = p.array_in("x", shape=(8, 128))
    g = p.array_in("g", shape=(1, 128))
    p.array_out("o", shape=(8, 128))
    p.store("o", x.load() * g.load())
    return p


def test_egraph_operand_info_uniform_vs_varying():
    p = KernelProgram("t")
    f = p.array_in("f", shape=(9, None))
    p.scalar("i")
    p.array_out("o", shape=(None,))
    p.store("o", f[c(0), v("i")], v("i"))
    ssa = build_ssa(p)
    eg = ssa.egraph
    info = ssa.array_info["f"]
    const_idx = eg.add(ENode("const", (), 0))
    var_idx = eg.add(ENode("var", (), "i"))
    # constant index selects a slice; varying index gathers per lane
    assert eg.operand_info(info, (const_idx,)).shape == (None,)
    varying = eg.operand_info(info, (const_idx, var_idx))
    assert varying.shape is None and varying.dtype == "f32"
    # a fully-indexed load with a varying lane index prices a full tile
    assert ssa.store_infos()[0].shape is None


def test_bound_cost_model_prices_declared_rows():
    ssa = build_ssa(_norm_program())
    eg = ssa.egraph
    cm = RooflineCostModel(egraph=eg)
    loads = [n for n in eg.hashcons if n.op == "load"]
    by_bytes = sorted(cm.node_stats(eg.canonicalize(n)).bytes_read
                      for n in loads)
    assert by_bytes == [128 * 4, TILE_ELEMS * 4]  # g row + x tile


def test_set_array_info_rederives_existing_classes():
    """Re-registering an array with corrected (shape, dtype) overwrites
    the stale analysis on already-added symbol/load classes."""
    ssa = build_ssa(_norm_program())
    eg = ssa.egraph
    eg.set_array_info("x", ArrayInfo(shape=(8, 128), dtype="bf16"))
    cm = RooflineCostModel(egraph=eg)
    loads = [eg.canonicalize(n) for n in eg.hashcons if n.op == "load"]
    by_bytes = sorted(cm.node_stats(n).bytes_read for n in loads)
    assert by_bytes == [128 * 4, TILE_ELEMS * 2]  # g row + bf16 x tile


def test_rebind_after_redeclaration_clears_stale_prices():
    """A bound model re-bound to the same graph after a re-declaration
    must drop its cached load prices (extract_dag rebinds per call)."""
    ssa = build_ssa(_norm_program())
    eg = ssa.egraph
    cm = RooflineCostModel(egraph=eg)
    load_x = next(eg.canonicalize(n) for n in eg.hashcons
                  if n.op == "load" and
                  eg.classes[eg.find(n.children[0])].ainfo.shape == (8, 128))
    assert cm.node_stats(load_x).bytes_read == TILE_ELEMS * 4
    eg.set_array_info("x", ArrayInfo(shape=(8, 128), dtype="bf16"))
    cm.bind_egraph(eg)
    assert cm.node_stats(load_x).bytes_read == TILE_ELEMS * 2


def test_unbound_model_keeps_full_tile_pricing():
    cm = RooflineCostModel()
    st = cm.node_stats(ENode("load", (0,)))
    assert st.bytes_read == TILE_ELEMS * 4


# -- kernel dtype threading through the pipeline ------------------------------------
def test_pipeline_dtype_halves_predicted_bytes():
    cfg = SaturatorConfig(mode="accsat")
    sk32 = saturate_program(_norm_program("f32"), cfg)
    sk16 = saturate_program(_norm_program("bf16"), cfg)
    b32 = sk32.extraction.predicted
    b16 = sk16.extraction.predicted
    total32 = b32["bytes_read"] + b32["bytes_written"]
    total16 = b16["bytes_read"] + b16["bytes_written"]
    assert total16 == pytest.approx(total32 / 2)
    assert b16["latency_ns"] <= b32["latency_ns"]


def test_pipeline_row_declaration_lowers_prediction():
    """The ROADMAP 'broadcast rows' item: declaring the gain as a row
    strictly lowers predicted HBM traffic vs an undeclared twin."""
    undeclared = KernelProgram("t")
    x = undeclared.array_in("x")
    g = undeclared.array_in("g")
    undeclared.array_out("o")
    undeclared.store("o", x.load() * g.load())
    cfg = SaturatorConfig(mode="accsat")
    sk_row = saturate_program(_norm_program(), cfg)
    sk_flat = saturate_program(undeclared, cfg)
    row_bytes = sk_row.extraction.predicted["bytes_read"]
    flat_bytes = sk_flat.extraction.predicted["bytes_read"]
    assert row_bytes == flat_bytes - (TILE_ELEMS - 128) * 4


# -- LatencyModel dtype-selected MXU peak -------------------------------------------
def test_mxu_peak_scales_with_dtype():
    from repro.analysis import OpStats
    st = OpStats(mxu_flops=1e12)
    legacy = LatencyModel(DEFAULT_CHIP)
    f32 = LatencyModel(DEFAULT_CHIP, mxu_dtype="f32")
    bf16 = LatencyModel(DEFAULT_CHIP, mxu_dtype="bf16")
    f8 = LatencyModel(DEFAULT_CHIP, mxu_dtype="f8")
    assert legacy.compute_ns(st) == pytest.approx(bf16.compute_ns(st))
    assert f32.compute_ns(st) == pytest.approx(2 * bf16.compute_ns(st))
    assert f8.compute_ns(st) == pytest.approx(bf16.compute_ns(st) / 2)


def test_choice_stats_store_infos():
    eg = EGraph()
    root = add_expr(eg, ("mul", ("var", "a"), ("var", "b")))
    from repro.core import extract_dag
    res = extract_dag(eg, root)
    rep_full = eg.choice_stats(res.choice, root, n_stores=1)
    rep_row = eg.choice_stats(
        res.choice, root, n_stores=1,
        store_infos=[ArrayInfo(shape=(1, 128), dtype="f32")])
    assert rep_full["bytes_written"] == TILE_ELEMS * 4
    assert rep_row["bytes_written"] == 128 * 4
