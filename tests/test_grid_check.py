"""PR-9 grid/block legality pass: mutation tests (one planted defect →
exactly one finding of exactly that code), the clean-suite zero-finding
sweep, dtype-aware row-block autosizing, the legacy-heuristic drift
detector, and a property fuzz asserting that every certified (rows,
row_block) geometry executes bit-identically to the unblocked baseline."""
import dataclasses

import numpy as np

from _hypothesis_compat import given, settings, st
from repro.analysis.access import BlockAccess, GridModel
from repro.core import (KernelProgram, SaturatorConfig, VerifyConfig,
                        make_tile_op)
from repro.core.pallasgen import pick_row_block
from repro.core.telemetry import telemetry
from repro.kernels.tile_programs import PROGRAMS, get_tile_op
from repro.verify import (check_grid, check_tile_op, flash_attention_model,
                          ssd_scan_model, verify_tile_op)
from repro.verify.grid_check import check_tile_kernel_grid

RB, D = 8, 128


def _codes(res):
    return [f.code for f in res.findings]


# -- mutation 1: overlapping writes → grid-write-race -------------------------
def test_write_overlap_caught_exactly():
    """Grid of 3 over a 2-block output with an i%2 map: instances 0 and
    2 both own block 0 — a write-write race — while blocks 0 and 1 stay
    covered, so the race is the *only* finding."""
    m = GridModel(
        "mut_race", (3,),
        reads=(BlockAccess("x", "read", (RB, D), (3 * RB, D),
                           lambda i: (i, 0)),),
        writes=(BlockAccess("o", "write", (RB, D), (2 * RB, D),
                            lambda i: (i % 2, 0)),))
    res = check_grid(m)
    assert _codes(res) == ["grid-write-race"]
    assert not res.ok


# -- mutation 2: dropped remainder tile → grid-coverage-gap -------------------
def test_dropped_tile_caught_exactly():
    """Identity map but a grid one step short of the 3-block buffer:
    block 2 is never written."""
    m = GridModel(
        "mut_gap", (2,),
        reads=(),
        writes=(BlockAccess("o", "write", (RB, D), (3 * RB, D),
                            lambda i: (i, 0)),))
    res = check_grid(m)
    assert _codes(res) == ["grid-coverage-gap"]


# -- mutation 3: off-by-one index map → grid-oob-read -------------------------
def test_off_by_one_read_caught_exactly():
    """Read map shifted by one block: the last grid step reads block 3
    of a 3-block buffer. The (clean) write side must not double-report."""
    m = GridModel(
        "mut_oob", (3,),
        reads=(BlockAccess("x", "read", (RB, D), (3 * RB, D),
                           lambda i: (i + 1, 0)),),
        writes=(BlockAccess("o", "write", (RB, D), (3 * RB, D),
                            lambda i: (i, 0)),))
    res = check_grid(m)
    assert _codes(res) == ["grid-oob-read"]


# -- mutation 4: oversized block → grid-vmem-overflow -------------------------
def test_vmem_overflow_caught_exactly():
    """A (4096, 4096) f32 block read + written is 2 x 64 MiB — past the
    whole chip VMEM. The drift warning is suppressed when the hard
    overflow fires, so the error is the only finding."""
    big = (4096, 4096)
    m = GridModel(
        "mut_vmem", (1,),
        reads=(BlockAccess("x", "read", big, big, lambda i: (0, 0)),),
        writes=(BlockAccess("o", "write", big, big, lambda i: (0, 0)),))
    res = check_grid(m)
    assert _codes(res) == ["grid-vmem-overflow"]


# -- clean suite: zero findings ----------------------------------------------
def test_all_tile_kernels_certify_clean():
    for name in PROGRAMS:
        res = check_tile_op(get_tile_op(name))
        assert res.findings == [], \
            f"{name}: {[str(f) for f in res.findings]}"
        assert res.provable and res.grids_checked == 1


def test_handwritten_layouts_certify_clean():
    """The flash-attention and SSD-scan BlockSpec layouts — including
    the inert kv axis on flash's output map (a legal revisit the race
    detector must not flag)."""
    for model in (flash_attention_model(2, 4, 2, 512, 128),
                  ssd_scan_model(2, 4, 512, 64, 128)):
        res = check_grid(model)
        assert res.findings == [], [str(f) for f in res.findings]
        assert res.vmem_bytes > 0


# -- satellite 1+2: declared-geometry, dtype-aware autosizing -----------------
def _wide_prog(name, dtype):
    """4 in + 3 out at d=1024: 9 heuristic tiles, so a 512 row block
    costs 512*1024*4B*9 = 18.9 MB f32 — past the 16 MiB autosizing
    budget — but only 9.4 MB in bf16."""
    p = KernelProgram(name, dtype=dtype)
    a = p.array_in("a", shape=(8, 1024), dtype=dtype)
    b = p.array_in("b", shape=(8, 1024), dtype=dtype)
    c_ = p.array_in("c", shape=(8, 1024), dtype=dtype)
    d_ = p.array_in("d", shape=(8, 1024), dtype=dtype)
    p.array_out("o1", shape=(8, 1024), dtype=dtype)
    p.array_out("o2", shape=(8, 1024), dtype=dtype)
    p.array_out("o3", shape=(8, 1024), dtype=dtype)
    av, bv, cv, dv = a.load(), b.load(), c_.load(), d_.load()
    p.store("o1", av * bv + cv)
    p.store("o2", av + dv)
    p.store("o3", bv * dv)
    return p


def test_pick_row_block_is_dtype_aware():
    assert pick_row_block(1024, 9, 4) == 256    # f32 at d=1024 halves
    assert pick_row_block(1024, 9, 2) == 512    # bf16 affords the default
    assert pick_row_block(128, 7, 4) == 512     # the model kernels' case


def test_d1024_program_autosizes_smaller_block():
    """Regression for the hardcoded d=256 in make_tile_op: a d=1024 f32
    program must pick the VMEM-fitting 256, not the blanket 512 — and
    its certified exact footprint must fit the autosizing budget."""
    op = make_tile_op(_wide_prog("wide1024_f32", "f32"))
    assert op.row_block == 256
    res = check_tile_op(op)
    assert [f for f in res.findings if f.severity == "error"] == []


def test_d1024_bf16_program_keeps_large_block():
    op = make_tile_op(_wide_prog("wide1024_bf16", "bf16"))
    assert op.row_block == 512


# -- satellite 2: legacy heuristic drift --------------------------------------
def test_vmem_heuristic_drift_flagged():
    """At row_block=768 the wide f32 program's exact footprint
    (768*1024*4B*7 = 22 MB) busts the 16 MiB budget, while the legacy
    d=256 estimate (7.1 MB) says it fits: exactly one under-budgeted
    drift warning, and no hard overflow (22 MB < 64 MiB VMEM)."""
    op = make_tile_op(_wide_prog("wide1024_drift", "f32"))
    res = check_tile_kernel_grid(op.pk, op.sk.ssa.prog, row_block=768)
    assert _codes(res) == ["vmem-heuristic-drift"]
    (w,) = res.findings
    assert w.severity == "warning" and "under-budgeted" in w.message
    assert res.ok     # warnings don't fail certification


# -- wiring: make_tile_op + telemetry -----------------------------------------
def test_make_tile_op_verify_wiring_counts_grids():
    before = telemetry().snapshot()["verify"]["grids_checked"]
    op = make_tile_op(_wide_prog("wide1024_wired", "f32"),
                      SaturatorConfig(mode="accsat",
                                      verify_cfg=VerifyConfig("cheap")))
    after = telemetry().snapshot()["verify"]["grids_checked"]
    assert after == before + 1
    assert verify_tile_op(op).grids_checked == 1


# -- property fuzz: certified geometry == unblocked execution ----------------
def _swiglu_op():
    if not hasattr(_swiglu_op, "_op"):
        _swiglu_op._op = get_tile_op("swiglu")
    return _swiglu_op._op


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=1, max_value=48),
       st.integers(min_value=1, max_value=48))
def test_certified_blockings_are_bit_identical(rows, rb_raw):
    """Any (rows, row_block) the grid pass certifies error-free must
    execute bit-identically to row_block=rows (one tile, no padding
    path): coverage + disjointness + bounds together are exactly the
    property that blocking cannot change results."""
    rb = min(rb_raw, rows)
    base = _swiglu_op()
    res = check_tile_kernel_grid(base.pk, base.sk.ssa.prog,
                                 row_block=rb, rows=rows)
    assert [f for f in res.findings if f.severity == "error"] == [], \
        [str(f) for f in res.findings]
    rng = np.random.default_rng(rows * 49 + rb)
    a = rng.uniform(0.1, 1.0, size=(rows, 128)).astype(np.float32)
    b = rng.uniform(0.1, 1.0, size=(rows, 128)).astype(np.float32)
    blocked = dataclasses.replace(base, row_block=rb)
    unblocked = dataclasses.replace(base, row_block=rows)
    out_b = np.asarray(blocked.apply(a, b))
    out_u = np.asarray(unblocked.apply(a, b))
    np.testing.assert_array_equal(out_b, out_u)
