"""Import shim: real hypothesis when installed, deterministic fallback
otherwise.

The property tests only need ``given``/``settings`` plus the
``integers``/``sampled_from`` strategies, so when the container has no
``hypothesis`` wheel (no network at test time) we run each property over a
small deterministic sample sweep instead of skipping the module outright.
The fallback caps example counts (`_MAX_EXAMPLES_CAP`) to keep the suite's
wall time close to the hypothesis-enabled run.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import functools
    import inspect

    HAVE_HYPOTHESIS = False
    _MAX_EXAMPLES_CAP = 8

    class _Strategy:
        """Deterministic example stream standing in for a strategy."""

        def __init__(self, fn):
            self._fn = fn  # example index -> value

        def example_at(self, i: int):
            return self._fn(i)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            span = max_value - min_value
            # low-discrepancy sweep: endpoints first, then golden-ratio hops
            def pick(i):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return min_value + (i * 2654435761) % (span + 1)
            return _Strategy(pick)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda i: options[i % len(options)])

    st = _Strategies()

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = min(max_examples, _MAX_EXAMPLES_CAP)
            return fn
        return deco

    def given(*gargs, **gkwargs):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            names = [p.name for p in params]
            # positional strategies bind the trailing parameters (the
            # leading ones stay for pytest fixtures/parametrize)
            kwmap = dict(gkwargs)
            if gargs:
                for name, strat in zip(names[len(names) - len(gargs):],
                                       gargs):
                    kwmap[name] = strat

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for i in range(wrapper._max_examples):
                    drawn = {k: s.example_at(i) for k, s in kwmap.items()}
                    fn(*args, **kwargs, **drawn)

            wrapper._max_examples = _MAX_EXAMPLES_CAP
            # hide strategy-bound params from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(
                parameters=[p for p in params if p.name not in kwmap])
            return wrapper
        return deco
