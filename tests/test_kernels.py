"""Per-kernel validation: Pallas (interpret=True) + saturated-jnp vs the
pure-jnp oracles in repro.kernels.ref, swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_jnp, ssd_decode_step
from repro.kernels.tile_programs import PROGRAMS, get_tile_op

SHAPES = [(4, 128), (3, 256), (16, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_rmsnorm_sweep(shape, dtype, impl, rng):
    x = jnp.asarray(rng.normal(size=shape), dtype)
    g = jnp.asarray(rng.normal(size=shape[-1:]), dtype)
    op = get_tile_op("rmsnorm")
    fn = op.apply if impl == "pallas" else op.jax_ref
    out = fn(x, g, eps=1e-6)
    want = ref.rmsnorm_ref(x.astype(jnp.float32), g.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("name,n_in", [
    ("swiglu", 2), ("softmax", 1), ("gelu", 1)])
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_elementwise_sweep(name, n_in, impl, rng):
    for shape in SHAPES:
        xs = [jnp.asarray(rng.normal(size=shape), jnp.float32)
              for _ in range(n_in)]
        op = get_tile_op(name)
        fn = op.apply if impl == "pallas" else op.jax_ref
        out = fn(*xs)
        want = {"swiglu": lambda: ref.swiglu_ref(*xs),
                "softmax": lambda: ref.softmax_ref(*xs),
                "gelu": lambda: ref.gelu_ref(*xs)}[name]()
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_layernorm(impl, rng):
    x = jnp.asarray(rng.normal(size=(6, 256)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    op = get_tile_op("layernorm")
    fn = op.apply if impl == "pallas" else op.jax_ref
    np.testing.assert_allclose(np.asarray(fn(x, g, b, eps=1e-6)),
                               np.asarray(ref.layernorm_ref(x, g, b)),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_rmsnorm_gated(impl, rng):
    x = jnp.asarray(rng.normal(size=(6, 128)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(6, 128)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    op = get_tile_op("rmsnorm_gated")
    fn = op.apply if impl == "pallas" else op.jax_ref
    np.testing.assert_allclose(np.asarray(fn(x, z, g, eps=1e-6)),
                               np.asarray(ref.rmsnorm_gated_ref(x, z, g)),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_rotary(impl, rng):
    q = jnp.asarray(rng.normal(size=(2, 3, 4, 128)), jnp.float32)
    cos = jnp.asarray(rng.normal(size=(1, 3, 1, 128)), jnp.float32)
    sin = jnp.asarray(rng.normal(size=(1, 3, 1, 128)), jnp.float32)
    ops.set_impl(impl)
    try:
        out = ops.rotary(q, cos, sin)
    finally:
        ops.set_impl(None)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.rotary_ref(q, cos, sin)),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_adamw_kernel(impl, rng):
    p = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(8, 256)) * 0.1, jnp.float32)
    v = jnp.asarray(abs(rng.normal(size=(8, 256))) * 0.01, jnp.float32)
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
              inv_bc1=1.3, inv_bc2=1.1)
    op = get_tile_op("adamw")
    fn = op.apply if impl == "pallas" else op.jax_ref
    out = fn(p, g, m, v, **kw)
    want = ref.adamw_ref(p, g, m, v, **kw)
    for a, b in zip(out, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-6, rtol=2e-6)


def test_adamw_bulk_load_and_fma():
    st = get_tile_op("adamw").pk.stats
    assert st.loads_before_compute == st.n_loads == 4
    assert st.n_fma >= 2


# -- flash attention ------------------------------------------------------------
@pytest.mark.parametrize("B,H,KH,S,D", [
    (2, 4, 2, 128, 64), (1, 2, 2, 256, 128), (2, 8, 1, 128, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, KH, S, D, causal, rng):
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KH, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KH, S, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, q_block=64, kv_block=64)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, q_block=64, kv_block=64)
    want = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=5e-2, rtol=5e-2)


def test_decode_attention_matches_full(rng):
    B, H, KH, S, D = 2, 4, 2, 64, 32
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KH, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KH, S, D)), jnp.float32)
    full = ref.attention_ref(q, k, v, causal=True)
    got = ops.attention_decode(q[:, :, -1:], k, v)
    np.testing.assert_allclose(np.asarray(got)[:, :, 0],
                               np.asarray(full)[:, :, -1],
                               atol=2e-5, rtol=2e-5)


# -- SSD -------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 2, 16, 16, 16), (1, 128, 4, 32, 64, 32), (2, 96, 3, 16, 8, 32)])
def test_ssd_sweep(B, S, H, P, N, chunk, rng):
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(H,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
    d = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    want = ref.ssd_ref(x, dt, a_log, bm, cm, d)
    got_pl = ssd_scan(x, dt, a_log, bm, cm, d, chunk=chunk)
    got_jnp = ssd_scan_jnp(x, dt, a_log, bm, cm, d, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_ssd_decode_consistency(rng):
    B, S, H, P, N = 1, 32, 2, 8, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(H,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
    d = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    want = ref.ssd_ref(x, dt, a_log, bm, cm, d)
    h = jnp.zeros((B, H, N, P), jnp.float32)
    outs = []
    for t in range(S):
        h, y = ssd_decode_step(h, x[:, t], dt[:, t], a_log, bm[:, t],
                               cm[:, t], d)
        outs.append(y)
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_ssd_state_handoff(rng):
    """Prefill state == decode-from-scratch state (cache correctness)."""
    B, S, H, P, N = 1, 64, 2, 16, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(H,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
    d = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    _, h_pref = ssd_scan_jnp(x, dt, a_log, bm, cm, d, chunk=16,
                             return_state=True)
    h = jnp.zeros((B, H, N, P), jnp.float32)
    for t in range(S):
        h, _ = ssd_decode_step(h, x[:, t], dt[:, t], a_log, bm[:, t],
                               cm[:, t], d)
    np.testing.assert_allclose(np.asarray(h_pref), np.asarray(h),
                               atol=2e-4, rtol=2e-4)


# -- property: tile ops are deterministic and shape-preserving ---------------------
@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 9), d=st.sampled_from([128, 256]),
       seed=st.integers(0, 100))
def test_tile_op_shape_property(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    op = get_tile_op("rmsnorm")
    out = op.apply(x, g, eps=1e-6)
    assert out.shape == x.shape
    out2 = op.apply(x, g, eps=1e-6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
