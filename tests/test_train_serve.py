"""End-to-end training (loss decreases, elastic recovery, determinism) and
serving (continuous batching) on smoke configs."""
import numpy as np
import jax
import pytest

from repro.launch.train import build_trainer
from repro.launch.serve import Request, Server


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    tr = build_trainer("minitron-4b", smoke=True, steps=20, batch=8,
                       seq=64, ckpt_dir=str(tmp_path), lr=1e-3)
    out = tr.run()
    losses = out["losses"]
    assert len(losses) == 20
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


@pytest.mark.slow
def test_train_recovers_from_failure(tmp_path):
    tr = build_trainer("granite-8b", smoke=True, steps=16, batch=4,
                       seq=32, ckpt_dir=str(tmp_path),
                       inject={9: ("node_loss", 1)})
    out = tr.run()
    assert out["recoveries"] == 1
    assert out["final_step"] == 16
    assert out["elastic_events"][0]["kind"] == "node_loss"
    assert np.isfinite(out["losses"]).all()


@pytest.mark.slow
def test_train_failure_replay_matches_clean_run(tmp_path):
    """Deterministic data replay: a run interrupted+recovered converges to
    the same losses as an uninterrupted run (same seeds, same steps)."""
    t1 = build_trainer("qwen2-vl-2b", smoke=True, steps=12, batch=4,
                       seq=32, ckpt_dir=str(tmp_path / "a"), seed=5)
    clean = t1.run()["losses"]
    t2 = build_trainer("qwen2-vl-2b", smoke=True, steps=12, batch=4,
                       seq=32, ckpt_dir=str(tmp_path / "b"), seed=5,
                       inject={7: ("node_loss", 1)})
    recovered = t2.run()["losses"]
    # after recovery the replayed steps recompute identical losses
    np.testing.assert_allclose(clean, recovered, rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_train_with_compression(tmp_path):
    tr = build_trainer("minitron-4b", smoke=True, steps=10, batch=4,
                       seq=32, ckpt_dir=str(tmp_path), compress="int8_ef",
                       lr=1e-3)
    out = tr.run()
    assert np.isfinite(out["losses"]).all()
    assert out["losses"][-1] < out["losses"][0] * 1.2


@pytest.mark.slow
def test_serve_continuous_batching():
    srv = Server("mamba2-1.3b", smoke=True, max_batch=3)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        1, srv.cfg.vocab, size=8 + i).astype(np.int32), max_new=4)
        for i in range(5)]
    out = srv.generate(reqs)
    assert set(out) == set(range(5))
    assert all(len(v) == 4 for v in out.values())
    assert srv.metrics["prefills"] == 2  # 3 + 2 under max_batch=3


def test_serve_greedy_deterministic():
    srv = Server("minitron-4b", smoke=True, max_batch=2)
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, srv.cfg.vocab, size=8).astype(np.int32)
    r1 = srv.generate([Request(0, prompt.copy(), 5)])
    r2 = srv.generate([Request(0, prompt.copy(), 5)])
    assert r1[0] == r2[0]
