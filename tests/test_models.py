"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus prefill↔decode consistency
against the full-sequence logits."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, applicable, cells, get_config, \
    get_smoke_config
from repro.models import get_model


def _batch(cfg, B=2, S=32, seed=1):
    kt, kl, kf = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kf, (B, S, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    if cfg.family == "encdec":
        logits, cache = model.prefill(params, batch["tokens"],
                                      batch["frames"])
    else:
        logits, cache = model.prefill(params, batch["tokens"])
    assert logits.shape == (B, 1, cfg.vocab)
    tok = batch["labels"][:, :1]
    logits2, cache2 = model.decode_step(params, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache2["pos"]) == S + 1


@pytest.mark.parametrize("arch", ["minitron_4b", "qwen2_vl_2b",
                                  "mamba2_1p3b", "dbrx_132b",
                                  "zamba2_2p7b"])
def test_prefill_matches_full_forward(arch):
    """Last-position prefill logits == full forward logits at last pos."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full = model.logits(params, tokens)
    pre, _ = model.prefill(params, tokens)
    np.testing.assert_allclose(np.asarray(pre[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-2,
                               rtol=2e-2)


@pytest.mark.parametrize("arch", ["minitron_4b", "mamba2_1p3b",
                                  "zamba2_2p7b"])
@pytest.mark.slow
def test_decode_matches_teacher_forcing(arch):
    """decode_step over a prompt reproduces full-forward logits stepwise."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab)
    full = np.asarray(model.logits(params, tokens))
    pre_len = 4
    logits, cache = model.prefill(params, tokens[:, :pre_len])
    np.testing.assert_allclose(np.asarray(logits)[:, 0],
                               full[:, pre_len - 1], atol=3e-2, rtol=3e-2)
    for t in range(pre_len, S):
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits)[:, 0], full[:, t],
                                   atol=3e-2, rtol=3e-2,
                                   err_msg=f"step {t}")


def test_cells_enumeration():
    cs = cells()
    assert len(cs) == 40
    runnable = [c for c in cs if c[2]]
    skipped = [c for c in cs if not c[2]]
    assert len(skipped) == 8  # long_500k × pure-attention archs
    assert all(c[1] == "long_500k" for c in skipped)
    for arch in ("mamba2_1p3b", "zamba2_2p7b"):
        assert any(c[0] == arch and c[1] == "long_500k" and c[2]
                   for c in cs)


def test_param_counts_sane():
    expect = {
        "minitron_4b": (4e9, 6e9), "mistral_nemo_12b": (11e9, 13.5e9),
        "mistral_large_123b": (115e9, 130e9), "granite_8b": (7e9, 9e9),
        "mamba2_1p3b": (1.1e9, 1.6e9), "qwen2_vl_2b": (1.3e9, 1.8e9),
        "dbrx_132b": (125e9, 140e9), "arctic_480b": (450e9, 500e9),
        "whisper_small": (0.2e9, 0.35e9), "zamba2_2p7b": (2.0e9, 3.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    dbrx = get_config("dbrx_132b")
    arctic = get_config("arctic_480b")
    assert dbrx.active_param_count() < 0.35 * dbrx.param_count()
    assert arctic.active_param_count() < 0.05 * arctic.param_count()


def test_vlm_mrope_positions():
    """Vision-style 3-axis positions change the logits (M-RoPE active)."""
    cfg = get_smoke_config("qwen2_vl_2b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)
    text_pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    img_pos = text_pos.at[1].set(text_pos[1] * 2).at[2].set(text_pos[2] * 3)
    h1, _ = model.forward(params, tokens, text_pos)
    h2, _ = model.forward(params, tokens, img_pos)
    assert not np.allclose(np.asarray(h1, np.float32),
                           np.asarray(h2, np.float32))


def test_hybrid_shared_block_is_tied():
    """zamba2's shared attention params are one block, reused."""
    cfg = get_smoke_config("zamba2_2p7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "shared" in params
    # layer stack has no attention weights of its own
    assert "attn" not in params["layers"]


@pytest.mark.slow
def test_f8_kv_cache_decode():
    """fp8 KV cache (100B+ serving option): decode tracks the bf16-cache
    full-forward logits within fp8 quantization tolerance."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("mistral_large_123b"),
                              kv_cache_dtype="f8")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, 10), 0,
                                cfg.vocab)
    full = np.asarray(model.logits(params, tokens))
    logits, cache = model.prefill(params, tokens[:, :4])
    assert str(cache["k"].dtype) == "float8_e4m3fn"
    errs = []
    for t in range(4, 10):
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        errs.append(np.abs(np.asarray(logits)[:, 0] - full[:, t]).max())
    assert max(errs) < 0.35 * np.abs(full).max()
