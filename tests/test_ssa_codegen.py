"""DSL → SSA → saturate → codegen, validated against the reference
interpreter across all paper configurations (baseline/CSE/SAT/BULK).

Includes the bulk-load scheduling property: with BULK on, every load in a
straight-line region is emitted before the first compute op (paper §VI-B,
Listing 3)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (KernelProgram, MODES, SaturatorConfig,
                        SearchConfig, c,
                        run_reference, rsqrt, rmean, saturate_all_modes,
                        saturate_program, select, v)


def matmul_program():
    p = KernelProgram("mm")
    a = p.array_in("a")
    b = p.array_in("b")
    cm = p.array_in("cm")
    p.array_out("r")
    for s in ("alpha", "beta", "i", "j", "ax"):
        p.scalar(s)
    p.let("tmp", c(0.0))
    with p.for_("l", 0, v("ax")):
        p.let("tmp", v("tmp") + a[v("i"), v("l")] * b[v("l"), v("j")])
    p.store("r", v("alpha") * v("tmp") + v("beta") * cm[v("i"), v("j")],
            v("i"), v("j"))
    return p


def stencil_program():
    """1-D 3-point stencil with shared subexpressions (paper's bread and
    butter: redundant loads + FMA chances)."""
    p = KernelProgram("stencil")
    x = p.array_in("x")
    p.array_out("o")
    i = p.scalar("i")
    w = p.scalar("w")
    left = x[v("i") - 1]
    mid = x[v("i")]
    right = x[v("i") + 1]
    # redundancy: mid referenced twice, w*mid twice
    p.store("o", w * mid + left + right + w * mid, v("i"))
    return p


def branch_program():
    p = KernelProgram("branch")
    x = p.array_in("x")
    p.array_out("o")
    k = p.scalar("k")
    t = p.scalar("t")
    p.let("val", x[v("k")] * 2.0)
    with p.if_(v("val") > v("t")):
        p.let("val", v("t") * 1.0)
    p.store("o", v("val"), v("k"))
    return p


def _mm_inputs(rng):
    A = rng.normal(size=(4, 5))
    B = rng.normal(size=(5, 6))
    C = rng.normal(size=(4, 6))
    return dict(a=A, b=B, cm=C, r=np.zeros((4, 6)), alpha=1.5, beta=0.5,
                i=2, j=3, ax=5)


@pytest.mark.parametrize("mode", MODES)
def test_matmul_all_modes(mode, rng):
    p = matmul_program()
    inputs = _mm_inputs(rng)
    ref = run_reference(p, inputs)
    sk = saturate_program(p, SaturatorConfig(mode=mode))
    out = sk(*[jnp.asarray(np.asarray(inputs[n], np.float64))
               if isinstance(inputs[n], np.ndarray) else inputs[n]
               for n in sk.kernel.in_arrays + sk.kernel.scalars])
    np.testing.assert_allclose(np.asarray(out[0]), ref["r"], rtol=1e-5)


@pytest.mark.parametrize("mode", MODES)
def test_stencil_all_modes(mode, rng):
    p = stencil_program()
    X = rng.normal(size=(8,))
    inputs = dict(x=X, o=np.zeros(8), i=3, w=0.25)
    ref = run_reference(p, inputs)
    sk = saturate_program(p, SaturatorConfig(mode=mode))
    out = sk(jnp.asarray(X), jnp.zeros(8), 3, 0.25)
    np.testing.assert_allclose(np.asarray(out[0]), ref["o"], rtol=1e-6)


def test_branch_program(rng):
    p = branch_program()
    for k in range(4):
        X = rng.normal(size=(4,))
        inputs = dict(x=X, o=np.zeros(4), k=k, t=0.1)
        ref = run_reference(p, inputs)
        sk = saturate_program(p)
        out = sk(jnp.asarray(X), jnp.zeros(4), k, 0.1)
        np.testing.assert_allclose(np.asarray(out[0]), ref["o"], rtol=1e-6)


def test_cse_reduces_loads_vs_baseline(rng):
    p = stencil_program()
    ks = saturate_all_modes(p)
    base = ks["baseline"].kernel.stats
    cse = ks["cse"].kernel.stats
    # mid is loaded twice in the source; CSE loads it once
    assert cse.n_loads < base.n_loads
    assert cse.n_temps <= base.n_temps


def test_sat_forms_fma(rng):
    p = stencil_program()
    ks = saturate_all_modes(p)
    assert ks["accsat"].kernel.stats.n_fma >= 1
    assert ks["cse"].kernel.stats.n_fma == 0


def test_accsat_cost_ordering(rng):
    """dag cost: accsat <= cse <= tree(baseline) (paper Fig. 2 direction)."""
    p = stencil_program()
    ks = saturate_all_modes(p)
    assert ks["accsat"].extraction.dag_cost <= \
        ks["cse"].extraction.dag_cost + 1e-9
    assert ks["cse"].extraction.dag_cost <= \
        ks["cse"].extraction.tree_cost + 1e-9


def test_bulk_load_hoists_loads():
    """BULK: every load is emitted before the first (non-address) compute
    of its region — the Listing-3 property. Without BULK, loads sit at
    their use sites (counter stays 0)."""
    p = stencil_program()
    sk = saturate_program(p, SaturatorConfig(mode="accsat"))
    st = sk.kernel.stats
    assert st.loads_before_compute == st.n_loads > 0
    sk2 = saturate_program(p, SaturatorConfig(mode="cse"))
    assert sk2.kernel.stats.loads_before_compute == 0


def test_loop_carried_array():
    """Stores inside a loop (array carry) round-trip correctly."""
    p = KernelProgram("accum_arr")
    p.array_in("x")
    p.array_out("o")
    n = p.scalar("n")
    x = p.array_in("x") if False else None
    xh = [a for a in (p.arrays.values())][0]
    with p.for_("i", 0, v("n")):
        p.store("o", v("i") * 2.0, v("i"))
    rng = np.random.default_rng(0)
    X = rng.normal(size=(6,))
    inputs = dict(x=X, o=np.zeros(6), n=6)
    ref = run_reference(p, inputs)
    sk = saturate_program(p)
    out = sk(jnp.asarray(X), jnp.zeros(6), 6)
    np.testing.assert_allclose(np.asarray(out[0]), ref["o"], rtol=1e-6)


def test_saturation_limits_respected():
    p = stencil_program()
    cfg = SaturatorConfig(mode="accsat", search_cfg=SearchConfig(
        iter_limit=2, node_limit=50, time_limit_s=1.0))
    sk = saturate_program(p, cfg)
    assert sk.saturation.iterations <= 2
    rep = sk.report()
    assert rep["sat_stop"] in ("saturated", "node_limit", "iter_limit",
                               "time_limit")


def test_report_fields():
    p = matmul_program()
    sk = saturate_program(p)
    rep = sk.report()
    for key in ("dag_cost", "n_loads", "n_fma", "ssa_ms", "sat_s",
                "extract_s", "codegen_ms"):
        assert key in rep
