# NOTE: no XLA_FLAGS here — smoke tests and benches must see ONE device.
# Multi-device tests spawn subprocesses with their own flags.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
