"""Unified analysis subsystem: op statistics, latency model, and the
roofline-calibrated extraction objective (ISSUE 2 tentpole)."""
import numpy as np
import pytest

from repro.analysis import (LatencyModel, OpStats, RooflineCostModel,
                            TILE_ELEMS, node_stats, stats_from_hlo)
from repro.analysis.opstats import (FREE_OPS, INPUT_OPS, MEMORY_OPS,
                                    SERIAL_ARITH, TRANSCENDENTALS)
from repro.core import (CostModel, EGraph, SaturatorConfig,
                        SearchConfig, TPUCostModel,
                        add_expr, extract_dag, saturate_program)
from repro.core.extract import choice_nodes, dag_cost_of
from repro.core.hardware import DEFAULT_CHIP
from repro.core.ir import ENode
from repro.core.rules import PAPER_RULES, run_rules


# -- OpStats / node accounting ----------------------------------------------------
def test_node_stats_load_is_pure_memory():
    st = node_stats(ENode("load", (0,)))
    assert st.bytes_read == TILE_ELEMS * 4
    assert st.vpu_passes == 0
    assert st.flops == 0


def test_node_stats_arith_and_fma():
    add = node_stats(ENode("add", (0, 1)))
    fma = node_stats(ENode("fma", (0, 1, 2)))
    assert add.vpu_passes == 1 and add.flops == TILE_ELEMS
    # fma: twice the flops of add, same single issue slot
    assert fma.vpu_passes == 1 and fma.flops == 2 * TILE_ELEMS


def test_node_stats_expensive_classes():
    div = node_stats(ENode("div", (0, 1)))
    exp = node_stats(ENode("exp", (0,)))
    assert div.vpu_passes > node_stats(ENode("add", (0, 1))).vpu_passes
    assert exp.vpu_passes > 1
    for op in ("const", "var", "array", "tuple"):
        st = node_stats(ENode(op, (), "x" if op in ("var", "array") else 0))
        assert st.vpu_passes == 0 and st.total_bytes == 0


def test_opstats_additive():
    a = OpStats(flops=1.0, bytes_read=2.0, vpu_passes=3.0, n_ops=1)
    b = OpStats(flops=10.0, bytes_written=5.0, mxu_flops=7.0, n_ops=2)
    s = a + b
    assert (s.flops, s.bytes_read, s.bytes_written) == (11.0, 2.0, 5.0)
    assert s.total_flops == 18.0 and s.total_bytes == 7.0 and s.n_ops == 3


# -- LatencyModel -----------------------------------------------------------------
def test_latency_roofline_max():
    lm = LatencyModel(DEFAULT_CHIP)
    mem = OpStats(bytes_read=DEFAULT_CHIP.hbm_bw)       # exactly 1 s of HBM
    cmp_ = OpStats(vpu_passes=DEFAULT_CHIP.clock_hz)    # exactly 1 s of VPU
    assert lm.memory_ns(mem) == pytest.approx(1e9)
    assert lm.compute_ns(cmp_) == pytest.approx(1e9)
    assert lm.bound(mem) == "memory"
    assert lm.bound(cmp_) == "compute"
    both = mem + cmp_
    # roofline max plus the overlap-slack tie-break term
    assert lm.latency_ns(both) == pytest.approx(1e9 * 1.05)


def test_latency_monotone():
    """More work on either axis never predicts lower latency."""
    lm = LatencyModel(DEFAULT_CHIP)
    base = OpStats(bytes_read=8192.0, vpu_passes=4.0)
    more_c = base + OpStats(vpu_passes=1.0)
    more_m = base + OpStats(bytes_read=4096.0)
    assert lm.latency_ns(more_c) > lm.latency_ns(base)
    assert lm.latency_ns(more_m) > lm.latency_ns(base)


def test_paper_adapters_share_classification():
    """The flat-weight adapters derive from the same op classification."""
    cm, tpu = CostModel(), TPUCostModel()
    for op in MEMORY_OPS | SERIAL_ARITH:
        assert cm.node_cost(ENode(op, (0, 0))) == cm.EXPENSIVE
    for op in FREE_OPS:
        assert cm.node_cost(ENode(op, (), 0)) == 0.0
    for op in INPUT_OPS:
        assert cm.node_cost(ENode(op, (), "x")) == cm.VAR
    for op in TRANSCENDENTALS:
        assert tpu.node_cost(ENode(op, (0,))) == tpu.TRANSCENDENTAL


# -- extraction objective ----------------------------------------------------------
def test_extract_defaults_to_roofline():
    eg = EGraph()
    root = add_expr(eg, ("add", ("var", "x"),
                         ("mul", ("var", "y"), ("var", "z"))))
    run_rules(eg, PAPER_RULES)
    res = extract_dag(eg, root)
    assert res.term(eg)[0] == "fma"            # 1 issue slot beats 2
    assert res.predicted is not None
    assert res.predicted["latency_ns"] > 0
    assert res.predicted["bound"] in ("compute", "memory")


def test_aggregate_counts_shared_classes_once():
    eg = EGraph()
    ab = ("add", ("var", "a"), ("var", "b"))
    root = add_expr(eg, ("mul", ab, ab))
    res = extract_dag(eg, root)
    cm = RooflineCostModel()
    nodes = choice_nodes(eg, res.choice, res.roots)
    # add counted once + mul: exactly 2 VPU passes
    assert cm.choice_stats(nodes).vpu_passes == 2.0
    assert res.dag_cost == pytest.approx(cm.aggregate_cost(nodes))


def test_surrogate_upper_bounds_aggregate():
    """node_cost sums (tree seed) always >= the roofline aggregate."""
    cm = RooflineCostModel()
    nodes = [ENode("load", (0,)), ENode("fma", (1, 2, 3)),
             ENode("exp", (4,)), ENode("add", (5, 6))]
    additive = sum(cm.node_cost(n) for n in nodes)
    assert cm.aggregate_cost(nodes) <= additive + 1e-12


def test_dag_cost_of_flat_model_unchanged():
    eg = EGraph()
    ab = ("add", ("var", "a"), ("var", "b"))
    root = add_expr(eg, ("mul", ab, ab))
    res = extract_dag(eg, root, cost_model=CostModel())
    assert dag_cost_of(eg, CostModel(), res.choice, res.roots) == \
        pytest.approx(22.0)


# -- the acceptance criterion: roofline choice never slower than paper's -----------
def _latency_of(eg, choice, roots):
    cm = RooflineCostModel()
    nodes = choice_nodes(eg, choice, roots)
    assert nodes is not None
    return cm.latency.latency_ns(cm.choice_stats(nodes))


@pytest.mark.parametrize("kernel", ["bt_like", "sp_like", "lbm_like",
                                    "ft_like", "ep_like"])
@pytest.mark.slow
def test_roofline_extraction_never_slower_than_paper(kernel):
    from benchmarks.kernel_suite import SUITE
    prog = SUITE[kernel]()
    lim = SearchConfig(iter_limit=6, node_limit=4000)
    sk_paper = saturate_program(prog, SaturatorConfig(
        mode="accsat", cost_model="paper", search_cfg=lim))
    sk_roof = saturate_program(prog, SaturatorConfig(
        mode="accsat", cost_model="roofline", search_cfg=lim))
    eg_p, ex_p = sk_paper.ssa.egraph, sk_paper.extraction
    eg_r, ex_r = sk_roof.ssa.egraph, sk_roof.extraction
    lat_paper = _latency_of(eg_p, ex_p.choice, ex_p.roots)
    lat_roof = _latency_of(eg_r, ex_r.choice, ex_r.roots)
    assert lat_roof <= lat_paper + 1e-9, kernel
    # pipeline-level prediction additionally prices the root stores'
    # write traffic (constant across choices)
    n_stores = sk_roof.kernel.stats.n_stores
    want = eg_r.choice_stats(ex_r.choice, ex_r.roots, n_stores=n_stores)
    assert ex_r.predicted["latency_ns"] == pytest.approx(want["latency_ns"])
    assert ex_r.predicted["bytes_written"] > 0


@pytest.mark.parametrize("name", ["rmsnorm", "gelu"])
def test_roofline_extraction_never_slower_tile_programs(name):
    from repro.kernels.tile_programs import PROGRAMS
    prog = PROGRAMS[name]()
    lim = SearchConfig(iter_limit=6, node_limit=4000)
    sk_paper = saturate_program(prog, SaturatorConfig(
        mode="accsat", cost_model="tpu_v5e", tpu_rules=True,
        search_cfg=lim))
    sk_roof = saturate_program(prog, SaturatorConfig(
        mode="accsat", cost_model="roofline", tpu_rules=True,
        search_cfg=lim))
    lat_paper = _latency_of(sk_paper.ssa.egraph, sk_paper.extraction.choice,
                            sk_paper.extraction.roots)
    lat_roof = _latency_of(sk_roof.ssa.egraph, sk_roof.extraction.choice,
                           sk_roof.extraction.roots)
    assert lat_roof <= lat_paper + 1e-9, name


# -- HLO bridge -------------------------------------------------------------------
def test_hlo_bridge_shares_units():
    import jax
    import jax.numpy as jnp
    from jax import lax

    D, L = 64, 8

    def f(x, ws):
        def body(h, w):
            return h @ w, None
        return lax.scan(body, x, ws)[0]

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    st = stats_from_hlo(comp.as_text())
    assert st.mxu_flops == pytest.approx(L * 2 * D ** 3, rel=1e-6)
    lm = LatencyModel(DEFAULT_CHIP)
    rep = lm.report(st)
    assert rep["latency_ns"] >= rep["compute_ns"]
    assert rep["bound"] in ("compute", "memory")


def test_egraph_choice_stats_helper():
    eg = EGraph()
    root = add_expr(eg, ("mul", ("var", "a"), ("var", "b")))
    res = extract_dag(eg, root)
    rep = eg.choice_stats(res.choice, root)
    assert rep is not None and rep["vpu_passes"] == 1.0
