"""Persistent saturation cache (PR 6): exact-hit replay, warm starts,
robustness against corrupt/stale entries, concurrent writers, and the
telemetry the launch drivers surface."""
import json
import os
import pathlib
import stat
import subprocess
import sys
import threading

import jax.numpy as jnp
import pytest

from repro.core import (CacheConfig, KernelProgram, SaturatorConfig,
                        ScheduleConfig, SearchConfig, VerifyConfig,
                        maybe_saturate, reset_telemetry, rmean, rsqrt,
                        saturate_program, telemetry)
from repro.cache import (FORMAT_VERSION, SaturationCache, cache_key_for,
                         entry_digest)


def _norm_prog(tile=(8, 128)):
    """rmsnorm-shaped program with a parameterized tile: same structure
    (= same warm key) for every tile, different exact key per shape."""
    p = KernelProgram("cache_norm")
    x = p.array_in("x", shape=tile)
    g = p.array_in("g", shape=(1, tile[1]))
    p.array_out("o", shape=tile)
    eps = p.scalar("eps")
    xv = x.load()
    inv = rsqrt(rmean(xv * xv) + eps)
    p.store("o", xv * inv * g.load())
    return p


def _cfg(tmp_path, *, mode="accsat", tpu_rules=True, cost_model="tpu_v5e",
         schedule=None, verify="off", cache_warm_start=True,
         beam_width=None):
    search = (SearchConfig(beam_width=beam_width)
              if beam_width is not None else SearchConfig())
    return SaturatorConfig(
        mode=mode, tpu_rules=tpu_rules, cost_model=cost_model,
        search_cfg=search,
        schedule_cfg=ScheduleConfig(schedule=schedule),
        cache_cfg=CacheConfig(cache_dir=str(tmp_path),
                              cache_warm_start=cache_warm_start),
        verify_cfg=VerifyConfig(verify=verify))


def _entry_files(tmp_path):
    return sorted(pathlib.Path(tmp_path).rglob("*.json"))


# -- exact hits -------------------------------------------------------------
@pytest.mark.parametrize("schedule", [None, "cost"])
def test_exact_hit_bit_identical_and_skips_search(tmp_path, schedule):
    """A second build of the same program+config replays from disk:
    no saturation, no beam search, no schedule search — and the
    generated kernel is bit-for-bit the cold one."""
    cfg = _cfg(tmp_path, schedule=schedule)
    cold = saturate_program(_norm_prog(), cfg)
    assert cold.cache_status == "miss"
    assert _entry_files(tmp_path), "cold run stored no entry"

    hit = saturate_program(_norm_prog(), cfg)
    assert hit.cache_status == "hit"
    assert hit.saturation is None            # run_rules never executed
    assert hit.extraction.search == "cache"  # beam/hillclimb never ran
    assert hit.kernel.source == cold.kernel.source
    assert hit.report()["sat_stop"] == "cached"
    # PR 7: grafting the cached choice must leave a consistent e-graph
    hit.ssa.egraph.check_invariants(strict=True)


def test_hit_and_miss_telemetry(tmp_path):
    reset_telemetry()
    cfg = _cfg(tmp_path)
    saturate_program(_norm_prog(), cfg)
    saturate_program(_norm_prog(), cfg)
    snap = telemetry().snapshot()
    assert snap["cache_misses"] == 1
    assert snap["cache_hits"] == 1
    assert snap["cache_stores"] == 1
    assert snap["cache_hit_rate"] == 0.5
    assert snap["cold_wall_s"] > snap["hit_wall_s"] > 0


def test_no_cache_reports_off(tmp_path):
    sk = saturate_program(_norm_prog(), SaturatorConfig(mode="accsat"))
    assert sk.cache_status == "off"
    assert not _entry_files(tmp_path)


# -- warm starts ------------------------------------------------------------
def test_warm_start_on_shape_change(tmp_path):
    """Same kernel structure at a new shape: the entry seeds the beam
    and schedule search (status 'warm'), and the new shape's committed
    result is stored so the third build is an exact hit."""
    cfg = _cfg(tmp_path, schedule="cost")
    k8 = cache_key_for(_norm_prog((8, 128)), cfg)
    k16 = cache_key_for(_norm_prog((16, 128)), cfg)
    assert k8.warm_key == k16.warm_key
    assert k8.exact_key != k16.exact_key

    assert saturate_program(_norm_prog((8, 128)), cfg).cache_status == "miss"
    warm = saturate_program(_norm_prog((16, 128)), cfg)
    assert warm.cache_status == "warm"
    # PR 7: the warm graft (cached choice unioned into the saturated
    # e-graph) must leave every invariant intact
    warm.ssa.egraph.check_invariants(strict=True)
    hit = saturate_program(_norm_prog((16, 128)), cfg)
    assert hit.cache_status == "hit"
    assert hit.kernel.source == warm.kernel.source


def test_hit_path_verified_when_enabled(tmp_path):
    """PR 7: verify="cheap" audits the replayed build too (invariants,
    certified cached order, emitted source) — and stays off the key, so
    verified and unverified builds share entries."""
    cfg = _cfg(tmp_path, schedule="cost", verify="cheap")
    cold = saturate_program(_norm_prog(), cfg)
    assert cold.verify_report is not None and cold.verify_report.ok
    hit = saturate_program(_norm_prog(), cfg)
    assert hit.cache_status == "hit"       # verify didn't change the key
    assert hit.verify_report is not None and hit.verify_report.ok
    assert hit.verify_report.schedules_certified >= 1
    off = saturate_program(_norm_prog(), _cfg(tmp_path, schedule="cost"))
    assert off.cache_status == "hit"
    assert off.verify_report is None       # off = no verification work


def test_warm_start_can_be_disabled(tmp_path):
    cfg = _cfg(tmp_path)
    saturate_program(_norm_prog((8, 128)), cfg)
    cfg_nw = _cfg(tmp_path, cache_warm_start=False)
    assert saturate_program(
        _norm_prog((16, 128)), cfg_nw).cache_status == "miss"


# -- key determinism & invalidation -----------------------------------------
def test_keys_deterministic_across_builds(tmp_path):
    cfg = _cfg(tmp_path)
    a = cache_key_for(_norm_prog(), cfg)
    b = cache_key_for(_norm_prog(), cfg)   # a *fresh* program object
    assert (a.warm_key, a.exact_key) == (b.warm_key, b.exact_key)


def test_rules_change_invalidates(tmp_path):
    """Dropping the TPU rule set changes the rules fingerprint: the old
    entry must not be served (not even as a warm seed)."""
    saturate_program(_norm_prog(), _cfg(tmp_path, tpu_rules=True))
    sk = saturate_program(_norm_prog(), _cfg(tmp_path, tpu_rules=False))
    assert sk.cache_status == "miss"


def test_config_change_invalidates(tmp_path):
    saturate_program(_norm_prog(), _cfg(tmp_path))
    sk = saturate_program(_norm_prog(), _cfg(tmp_path, beam_width=4))
    assert sk.cache_status == "miss"


# -- robustness -------------------------------------------------------------
def test_truncated_entry_falls_back_cold(tmp_path):
    cfg = _cfg(tmp_path)
    cold = saturate_program(_norm_prog(), cfg)
    [f] = _entry_files(tmp_path)
    f.write_text(f.read_text()[: len(f.read_text()) // 2])  # truncate

    reset_telemetry()
    again = saturate_program(_norm_prog(), cfg)
    assert again.cache_status == "miss"
    assert again.kernel.source == cold.kernel.source
    assert telemetry().snapshot()["cache_invalid"] >= 1
    # ... and the cold rebuild repaired the entry
    assert saturate_program(_norm_prog(), cfg).cache_status == "hit"


def test_garbage_payload_falls_back_cold(tmp_path):
    cfg = _cfg(tmp_path)
    saturate_program(_norm_prog(), cfg)
    [f] = _entry_files(tmp_path)
    doc = json.loads(f.read_text())
    doc["choice"]["nodes"] = doc["choice"]["nodes"][:1]  # valid JSON, bogus
    f.write_text(json.dumps(doc))
    assert saturate_program(_norm_prog(), cfg).cache_status == "miss"


@pytest.mark.parametrize("field", ["format", "extractor_version"])
def test_version_mismatch_ignored(tmp_path, field):
    cfg = _cfg(tmp_path)
    saturate_program(_norm_prog(), cfg)
    [f] = _entry_files(tmp_path)
    doc = json.loads(f.read_text())
    doc[field] = doc.get(field, FORMAT_VERSION) + 1
    f.write_text(json.dumps(doc))
    reset_telemetry()
    assert saturate_program(_norm_prog(), cfg).cache_status == "miss"
    assert telemetry().snapshot()["cache_invalid"] >= 1


def test_concurrent_writers_do_not_clobber(tmp_path):
    """Many threads racing put() on the same key: atomic tmp+rename
    means the entry file is always one complete JSON document."""
    cfg = _cfg(tmp_path)
    saturate_program(_norm_prog(), cfg)
    cache = SaturationCache(tmp_path)
    key = cache_key_for(_norm_prog(), cfg)
    entry, status = cache.lookup(key)
    assert status == "hit"

    errors = []

    def writer():
        try:
            for _ in range(25):
                assert cache.put(key, entry)
                got, st = cache.lookup(key)
                assert st == "hit" and got["choice"] == entry["choice"]
        except Exception as e:   # pragma: no cover - failure detail
            errors.append(e)

    threads = [threading.Thread(target=writer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # no half-written temp files left behind
    assert not list(pathlib.Path(tmp_path).rglob("*.tmp"))
    assert saturate_program(_norm_prog(), cfg).cache_status == "hit"


def test_bitflip_entry_falls_back_cold(tmp_path):
    """Corruption that stays valid JSON (a mutated sealed field, stale
    stored digest) is caught by the content digest and never replayed
    as a semantically different kernel."""
    cfg = _cfg(tmp_path)
    cold = saturate_program(_norm_prog(), cfg)
    [f] = _entry_files(tmp_path)
    doc = json.loads(f.read_text())
    doc["dag_cost"] = float(doc["dag_cost"]) + 1.0   # digest left stale
    f.write_text(json.dumps(doc))
    reset_telemetry()
    again = saturate_program(_norm_prog(), cfg)
    assert again.cache_status == "miss"
    assert again.kernel.source == cold.kernel.source
    assert any("digest" in e.get("reason", "")
               for e in telemetry().events)


def test_var_payload_injection_rejected(tmp_path):
    """codegen emits 'var' payloads verbatim into exec'd source, so a
    crafted entry (with a *valid* digest — the digest is integrity, not
    authentication) must be refused at graft time when its var payload
    is not a variable of the kernel."""
    cfg = _cfg(tmp_path)
    cold = saturate_program(_norm_prog(), cfg)
    [f] = _entry_files(tmp_path)
    doc = json.loads(f.read_text())
    planted = False
    for node in doc["choice"]["nodes"]:
        if node[0] == "var":
            node[2] = ["str", "__import__('os').getpid()"]
            planted = True
            break
    assert planted, "expected a var node (eps) in the cached choice"
    doc["digest"] = entry_digest(doc)
    f.write_text(json.dumps(doc))
    reset_telemetry()
    again = saturate_program(_norm_prog(), cfg)
    assert again.cache_status == "miss"
    assert again.kernel.source == cold.kernel.source
    assert "__import__" not in again.kernel.source
    assert any("not a variable" in e.get("reason", "")
               for e in telemetry().events)


def test_world_writable_root_disables_cache(tmp_path):
    """A pre-existing group/other-writable cache root (another local
    user could have planted entries) is refused: the cache silently
    stays off — no reads, no writes, build still works."""
    shared = tmp_path / "shared"
    shared.mkdir()
    os.chmod(shared, 0o777)
    reset_telemetry()
    cfg = _cfg(shared)
    assert saturate_program(_norm_prog(), cfg).cache_status == "miss"
    assert saturate_program(_norm_prog(), cfg).cache_status == "miss"
    assert not _entry_files(shared)
    assert telemetry().snapshot()["cache_invalid"] >= 1


def test_fresh_root_is_created_private(tmp_path):
    root = tmp_path / "newdir"
    saturate_program(_norm_prog(), _cfg(root))
    assert stat.S_IMODE(os.stat(root).st_mode) == 0o700
    assert saturate_program(_norm_prog(), _cfg(root)).cache_status == "hit"


def test_warm_graft_failure_falls_back_clean(tmp_path):
    """A digest-valid entry whose schedule cannot graft must not poison
    the warm path: the pipeline rebuilds + re-saturates and produces
    exactly what a cache-less cold build produces."""
    cfg = _cfg(tmp_path, schedule="cost")
    saturate_program(_norm_prog((8, 128)), cfg)
    [f] = _entry_files(tmp_path)
    doc = json.loads(f.read_text())
    path_key = next(iter(doc["schedule"]["orders"]))
    doc["schedule"]["orders"][path_key][0] = ["bogus", 0]
    doc["digest"] = entry_digest(doc)
    f.write_text(json.dumps(doc))
    reset_telemetry()
    poisoned = saturate_program(_norm_prog((16, 128)), cfg)
    assert poisoned.cache_status == "miss"
    assert telemetry().snapshot()["cache_invalid"] >= 1
    nocache = saturate_program(
        _norm_prog((16, 128)),
        SaturatorConfig(mode="accsat", tpu_rules=True,
                        cost_model="tpu_v5e",
                        schedule_cfg=ScheduleConfig(schedule="cost")))
    assert poisoned.kernel.source == nocache.kernel.source


def test_profile_refit_invalidates_key(tmp_path):
    """Re-fitting a device profile under the same file name changes the
    fitted-params digest in the key, so entries tuned for the stale
    calibration are not replayed."""
    from repro.analysis.calibrate import CalibrationParams, DeviceProfile
    prof_path = tmp_path / "prof.json"

    def save(base_ns):
        DeviceProfile(name="prof", chip="cpu", measured_kind="test",
                      params=CalibrationParams(base_ns=base_ns)
                      ).save(prof_path)

    save(0.0)
    cfg = SaturatorConfig(
        mode="accsat", cost_model="roofline",
        schedule_cfg=ScheduleConfig(device_profile=str(prof_path)),
        cache_cfg=CacheConfig(cache_dir=str(tmp_path / "c")))
    k1 = cache_key_for(_norm_prog(), cfg)
    assert cache_key_for(_norm_prog(), cfg).warm_key == k1.warm_key
    save(5.0)
    k2 = cache_key_for(_norm_prog(), cfg)
    assert k1.warm_key != k2.warm_key
    assert "@" in str(k2.components["device_profile"])


def test_unwritable_cache_dir_is_nonfatal(tmp_path):
    """A cache that cannot store (read-only dir) must never break the
    build — it just stays cold."""
    ro = tmp_path / "ro"
    ro.mkdir()
    os.chmod(ro, 0o555)
    try:
        sk = saturate_program(_norm_prog(), _cfg(ro))
        assert sk.cache_status == "miss"
        assert sk.kernel.source
    finally:
        os.chmod(ro, 0o755)


# -- cross-process ----------------------------------------------------------
_SUB = """
import hashlib, sys
from repro.core import (CacheConfig, SaturatorConfig, ScheduleConfig,
                        saturate_program)
from repro.kernels.tile_programs import PROGRAMS
cfg = SaturatorConfig(mode="accsat", tpu_rules=True, cost_model="tpu_v5e",
                      schedule_cfg=ScheduleConfig(schedule="cost"),
                      cache_cfg=CacheConfig(cache_dir=sys.argv[1]))
sk = saturate_program(PROGRAMS["rmsnorm_gated"](), cfg)
print("CACHE", sk.cache_status,
      hashlib.sha256(sk.kernel.source.encode()).hexdigest())
"""


def _run_sub(code, cache_dir, hashseed):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    env["PYTHONHASHSEED"] = hashseed
    env.pop("REPRO_SAT_CACHE", None)
    out = subprocess.run([sys.executable, "-c", code, str(cache_dir)],
                         env=env, capture_output=True, text=True,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_cross_process_hit_different_hashseed(tmp_path):
    """An entry written by one process is an exact, bit-identical hit
    in another process with a different PYTHONHASHSEED (e-class ids and
    set-iteration orders differ — nothing id-dependent may leak into
    the entry)."""
    first = _run_sub(_SUB, tmp_path, hashseed="3")
    second = _run_sub(_SUB, tmp_path, hashseed="19")
    _, st1, sha1 = first.split()
    _, st2, sha2 = second.split()
    assert st1 == "miss" and st2 == "hit"
    assert sha1 == sha2


# -- env-var enablement & bridge telemetry ----------------------------------
def test_env_var_enables_cache(tmp_path, monkeypatch):
    from repro.core import CACHE_ENV_VAR
    monkeypatch.setenv(CACHE_ENV_VAR, str(tmp_path))
    cfg = SaturatorConfig(mode="accsat", tpu_rules=True)
    assert saturate_program(_norm_prog(), cfg).cache_status == "miss"
    assert saturate_program(_norm_prog(), cfg).cache_status == "hit"


def test_bridge_fallback_is_counted():
    reset_telemetry()

    def f(x):
        return jnp.sort(x)

    fn, info = maybe_saturate(f, (jnp.ones((8,), jnp.float32),),
                              name="sorty")
    assert info is None and fn is f
    snap = telemetry().snapshot()
    # exactly one fallback, attributed to the offending primitive
    # (jnp.sort stages as a pjit-wrapped call at the top level)
    assert sum(snap["bridge_fallbacks"].values()) == 1
    assert any(e.get("fn") == "sorty" for e in telemetry().events)
