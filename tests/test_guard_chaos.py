"""Guarded saturation runtime (PR 10): budgets, degradation ladder,
circuit breaker, deterministic chaos harness, cache-fault hardening,
straggler policy, and elastic-recovery state preservation."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.keys import cache_key_for
from repro.cache.store import SaturationCache, make_entry
from repro.core import CacheConfig, SaturatorConfig, make_tile_op
from repro.core.pipeline import saturate_program
from repro.core.telemetry import telemetry
from repro.kernels.tile_programs import PROGRAMS, get_tile_op
from repro.runtime import chaos
from repro.runtime.ft import (ElasticTrainer, FailureEvent, FailureInjector,
                              StragglerPolicy, TrainLoopConfig)
from repro.runtime.guard import (BudgetExceeded, CircuitBreaker, GuardConfig,
                                 SaturationGuard, breaker_for,
                                 breakers_snapshot, classify_failure,
                                 guard_tick, reset_breakers, run_ladder)


def _base_cfg(**kw):
    return SaturatorConfig(mode="accsat", cost_model="tpu_v5e",
                           tpu_rules=True,
                           cache_cfg=CacheConfig(cache_dir=False), **kw)


@pytest.fixture(autouse=True)
def _clean_guard_state():
    telemetry().reset()
    reset_breakers()
    chaos.clear_plan()
    yield
    chaos.clear_plan()
    reset_breakers()


# -- chaos harness ---------------------------------------------------------------
def test_fault_plan_rejects_unknown_sites():
    with pytest.raises(ValueError, match="unknown fault site"):
        chaos.FaultPlan(sites=("not_a_site",))


def test_plan_from_env_parsing():
    p = chaos.plan_from_env(
        "rule_raise,exec_fail:seed=3:max_fires=inf:p=0.25:kernels=a|b")
    assert p.sites == ("rule_raise", "exec_fail")
    assert p.seed == 3
    assert p.max_fires is None
    assert p.probability == 0.25
    assert p.kernels == ("a", "b")
    assert chaos.plan_from_env("verify_error:max_fires=2").max_fires == 2
    with pytest.raises(ValueError):
        chaos.plan_from_env("nope_site")
    with pytest.raises(ValueError):
        chaos.plan_from_env("rule_raise:bogus=1")


def test_chaos_fire_pattern_is_seed_deterministic():
    plan = chaos.FaultPlan(sites=("rule_raise",), seed=5, max_fires=None,
                           probability=0.5)

    def pattern():
        with chaos.plan_scope(plan):
            return [chaos.chaos_point("rule_raise") for _ in range(64)]

    p1, p2 = pattern(), pattern()
    assert p1 == p2
    assert 5 < sum(p1) < 60   # actually probabilistic, not all/none
    # the published contract: occurrence n fires iff u01(seed, site, n) < p
    assert p1 == [chaos._u01(5, "rule_raise", i) < 0.5 for i in range(64)]


def test_chaos_max_fires_and_kernel_filter():
    with chaos.plan_scope(chaos.FaultPlan(sites=("rule_raise",),
                                          max_fires=1)):
        assert chaos.chaos_point("rule_raise")
        assert not chaos.chaos_point("rule_raise")   # budget spent
    plan = chaos.FaultPlan(sites=("rule_raise",), kernels=("rmsnorm",),
                           max_fires=None)
    with chaos.plan_scope(plan):
        assert not chaos.chaos_point("rule_raise", kernel="adamw")
        assert not chaos.chaos_point("rule_raise")   # no kernel context
        with chaos.kernel_scope("rmsnorm"):
            assert chaos.chaos_point("rule_raise")
    assert telemetry().snapshot()["guard"]["chaos_fires"]["rule_raise"] == 2


def test_chaos_inactive_is_noop():
    assert not chaos.chaos_point("rule_raise")
    chaos.maybe_raise("exec_fail")          # must not raise
    chaos.maybe_raise_os("cache_read_io", 5, "x")


# -- guard ceilings ---------------------------------------------------------------
def test_guard_tick_noop_without_active_guard():
    guard_tick("saturation", n=10**9)   # no ambient guard: free pass


def test_guard_eval_budget_trips():
    g = SaturationGuard("k", GuardConfig(eval_budget=10))
    for _ in range(10):
        g.tick("saturation")
    with pytest.raises(BudgetExceeded) as ei:
        g.tick("saturation")
    assert ei.value.trigger == "eval_budget"


def test_guard_node_class_ceilings():
    g = SaturationGuard("k", GuardConfig(node_ceiling=100,
                                         class_ceiling=50))
    g.tick("egraph", nodes=100, classes=50)   # at the ceiling: fine
    with pytest.raises(BudgetExceeded) as ei:
        g.tick("egraph", nodes=101)
    assert ei.value.trigger == "node_ceiling"
    with pytest.raises(BudgetExceeded) as ei:
        g.tick("egraph", classes=51)
    assert ei.value.trigger == "class_ceiling"


def test_guard_deadline_sampled():
    g = SaturationGuard("k", GuardConfig(deadline_s=0.0))
    with g.activate():
        with pytest.raises(BudgetExceeded) as ei:
            for _ in range(1024):   # deadline checked every 1024 ticks
                guard_tick("beam")
    assert ei.value.trigger == "deadline"


def test_classify_failure_labels():
    assert classify_failure(BudgetExceeded("deadline"), "s") \
        == "budget:deadline"
    assert classify_failure(chaos.InjectedFault("exec_fail"), "s") \
        == "chaos:exec_fail"
    os_err = OSError(28, "boom")
    os_err.chaos_site = "cache_write_io"
    assert classify_failure(os_err, "s") == "chaos:cache_write_io"
    assert classify_failure(ValueError("x"), "extract") \
        == "extract:ValueError"


# -- circuit breaker --------------------------------------------------------------
def test_breaker_state_machine():
    br = CircuitBreaker("k", threshold=2, cooldown=2)
    assert br.admit() is None and br.state == "closed"
    br.record_failure(fallback_level="ref")
    assert br.state == "closed"              # below threshold
    br.record_failure()
    assert br.state == "open"
    assert br.admit() == "ref"               # cooling down: skip
    assert br.admit() is None                # half-open: the one trial
    assert br.state == "half_open"
    br.record_failure()                      # trial failed: re-open
    assert br.state == "open"
    assert br.admit() == "ref"
    assert br.admit() is None
    br.record_success()                      # trial passed: close
    assert br.state == "closed" and br.failures == 0
    ev = telemetry().snapshot()["guard"]["breaker_events"]
    assert ev["open"] == 2 and ev["half_open"] == 2 and ev["close"] == 1


def test_breaker_registry():
    a = breaker_for(("apply", "x"), threshold=5)
    assert breaker_for(("apply", "x"), threshold=9) is a
    assert a.threshold == 5                  # first caller's policy wins
    snap = breakers_snapshot()
    assert snap["total"] == 1 and snap["states"] == {"closed": 1}


# -- run_ladder -------------------------------------------------------------------
def test_run_ladder_degrades_in_order():
    calls = []

    def fail(level):
        def f():
            calls.append(level)
            raise RuntimeError(level)
        return f

    level, result = run_ladder("k", [("full", fail("full")),
                                     ("cheap", fail("cheap")),
                                     ("ref", lambda: "floor")])
    assert (level, result) == ("ref", "floor")
    assert calls == ["full", "cheap"]
    g = telemetry().snapshot()["guard"]
    assert g["degradations"] == {"ref": 1}
    assert g["degradation_triggers"] == {"init:RuntimeError": 1}
    assert g["guard_failures"] == {"full:init:RuntimeError": 1,
                                   "cheap:init:RuntimeError": 1}


def test_run_ladder_floor_reraises():
    def f():
        raise ValueError("x")
    with pytest.raises(ValueError):
        run_ladder("k", [("full", f), ("ref", f)])


# -- the pipeline ladder end to end -----------------------------------------------
def test_ladder_cheap_on_injected_rule_failure():
    prog = PROGRAMS["residual_scale"]()
    with chaos.plan_scope(chaos.FaultPlan(sites=("rule_raise",),
                                          max_fires=1)):
        sk = saturate_program(prog, _base_cfg())
    assert sk.ladder_level == "cheap"
    guard = telemetry().snapshot()["guard"]
    assert guard["degradations"].get("cheap") == 1
    assert guard["degradation_triggers"].get("chaos:rule_raise") == 1
    assert guard["ladder_levels"].get("cheap") == 1


def test_ladder_ref_floor_on_codegen_failure():
    prog = PROGRAMS["residual_scale"]()
    x = np.random.default_rng(0).uniform(
        0.1, 1, (8, 128)).astype(np.float32)
    y = np.random.default_rng(1).uniform(
        0.1, 1, (8, 128)).astype(np.float32)
    with chaos.plan_scope(chaos.FaultPlan(sites=("exec_fail",),
                                          max_fires=None)):
        op = make_tile_op(prog, _base_cfg())
        out = op.apply(jnp.asarray(x), jnp.asarray(y), alpha=0.5)
    assert op.sk.ladder_level == "ref"
    assert op.pk is None           # no Pallas kernel on the floor
    np.testing.assert_allclose(np.asarray(out), x + 0.5 * y, rtol=1e-6)


def test_saturate_breaker_opens_then_recovers():
    cfg = _base_cfg(guard_cfg=GuardConfig(breaker_threshold=2,
                                          breaker_cooldown=2))
    with chaos.plan_scope(chaos.FaultPlan(sites=("exec_fail",),
                                          max_fires=None)):
        for _ in range(2):
            sk = saturate_program(PROGRAMS["residual_scale"](), cfg)
            assert sk.ladder_level == "ref"
    # breaker open: even fault-free calls skip to the recorded rung
    sk = saturate_program(PROGRAMS["residual_scale"](), cfg)
    assert sk.ladder_level == "ref"
    guard = telemetry().snapshot()["guard"]
    assert guard["breaker_events"].get("open", 0) >= 1
    assert guard["breaker_events"].get("skip", 0) >= 1
    # cool-down spent: the half-open trial runs the full path and closes
    sk = saturate_program(PROGRAMS["residual_scale"](), cfg)
    assert sk.ladder_level == "cold"
    assert telemetry().snapshot()["guard"]["breaker_events"] \
        .get("close", 0) >= 1


def test_guard_config_not_in_cache_fingerprint():
    prog = PROGRAMS["rmsnorm"]()
    k1 = cache_key_for(prog, SaturatorConfig())
    k2 = cache_key_for(prog, SaturatorConfig(
        guard_cfg=GuardConfig(eval_budget=7, deadline_s=1.0,
                              breaker_threshold=1)))
    assert k1.exact_key == k2.exact_key
    assert k1.warm_key == k2.warm_key


# -- cache store under filesystem faults ------------------------------------------
def _store_fixture(tmp_path):
    prog = PROGRAMS["rmsnorm"]()
    key = cache_key_for(prog, SaturatorConfig())
    cache = SaturationCache(tmp_path / "root")
    entry = make_entry(key, choice_doc={"roots": []}, schedule_doc=None,
                       predicted=None, dag_cost=1.0, report={})
    return cache, key, entry


def test_cache_put_enospc_disables_cache(tmp_path):
    cache, key, entry = _store_fixture(tmp_path)
    with chaos.plan_scope(chaos.FaultPlan(sites=("cache_write_io",),
                                          max_fires=None)):
        assert cache.put(key, entry) is False
        assert cache._usable is False
        # disabled for the process: the next put never reaches the
        # write path (the injected fault does not fire again)
        assert cache.put(key, entry) is False
        assert chaos.fire_counts() == {"cache_write_io": 1}
    snap = telemetry().snapshot()
    assert snap["cache_invalid"] >= 1
    assert any("cache write failed" in e.get("reason", "")
               for e in telemetry().events if e["kind"] == "cache_invalid")
    assert not list((tmp_path / "root").rglob("*.json"))   # nothing torn


def test_cache_read_fault_degrades_to_miss(tmp_path):
    cache, key, entry = _store_fixture(tmp_path)
    assert cache.put(key, entry) is True
    doc, status = cache.lookup(key)
    assert status == "hit" and doc is not None
    with chaos.plan_scope(chaos.FaultPlan(sites=("cache_read_io",),
                                          max_fires=None)):
        doc, status = cache.lookup(key)
    assert status == "miss" and doc is None
    assert telemetry().snapshot()["cache_invalid"] >= 1
    # the volume recovered: the entry is still intact on disk
    doc, status = cache.lookup(key)
    assert status == "hit"


def test_cache_corrupt_entry_rejected_by_digest(tmp_path):
    cache, key, entry = _store_fixture(tmp_path)
    assert cache.put(key, entry) is True
    with chaos.plan_scope(chaos.FaultPlan(sites=("cache_corrupt",),
                                          max_fires=None)):
        doc, status = cache.lookup(key)
    assert status == "miss" and doc is None


# -- ops-layer runtime floor -------------------------------------------------------
def test_ops_layer_never_raises(monkeypatch):
    from repro.kernels import ops
    from repro.kernels import ref as kref

    def boom(*a, **k):
        raise RuntimeError("build exploded")

    monkeypatch.setattr(ops, "get_tile_op", boom)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0.1, 1, (8, 128)).astype(np.float32))
    g = jnp.ones((1, 128), jnp.float32)
    for _ in range(4):
        out = ops.rmsnorm(x, g)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(kref.rmsnorm_ref(x, g)),
                               rtol=1e-6)
    guard = telemetry().snapshot()["guard"]
    assert guard["runtime_fallbacks"].get("rmsnorm") == 4
    # after threshold consecutive failures the breaker skips the build
    assert breaker_for(("apply", "rmsnorm")).state == "open"
    assert guard["breaker_events"].get("open", 0) >= 1


# -- ft.py: injector unification + straggler policy + recovery ---------------------
def test_failure_injector_unified_with_chaos():
    inj = FailureInjector({3: ("node_loss", 2)})
    inj.check(0)
    with pytest.raises(FailureEvent) as ei:
        inj.check(3)
    assert (ei.value.kind, ei.value.lost_hosts) == ("node_loss", 2)
    inj.check(3)                       # one-shot
    assert inj.fired == [3]
    assert telemetry().snapshot()["guard"]["chaos_fires"] \
        .get("train_host_loss") == 1
    # an ambient chaos plan can drive host loss with no step schedule
    with chaos.plan_scope(chaos.FaultPlan(sites=("train_host_loss",),
                                          max_fires=1)):
        inj2 = FailureInjector()
        with pytest.raises(FailureEvent) as ei:
            inj2.check(0)
        assert ei.value.kind == "chaos_host_loss"
        inj2.check(1)                  # max_fires spent


def _mini_trainer(tmp_path, steps=6, inject=None, **loop_kw):
    cfg = TrainLoopConfig(total_steps=steps, ckpt_every=2,
                          ckpt_dir=str(tmp_path / "ckpt"), **loop_kw)

    def build_step(n_shards):
        class Pipe:
            def batch_at(self, step):
                return {"step": np.asarray(float(step))}

        def step(params, opt_state, batch):
            return params + 1.0, opt_state, float(batch["step"])

        return step, Pipe()

    return ElasticTrainer(cfg, build_step, np.zeros(2, np.float32),
                          {"m": np.zeros(2, np.float32)}, num_shards=2,
                          injector=FailureInjector(inject))


def test_straggler_policy_tracking(tmp_path):
    tr = _mini_trainer(tmp_path, steps=2,
                       straggler=StragglerPolicy(factor=2.0, patience=2,
                                                 ewma=0.1))
    tr._track_straggler(0.1)            # seeds the EWMA
    assert tr._ewma_time == pytest.approx(0.1)
    tr._track_straggler(0.5)            # slow: streak 1, EWMA frozen
    assert tr._slow_streak == 1
    assert tr._ewma_time == pytest.approx(0.1)
    tr._track_straggler(0.5)            # patience hit: degrade + reset
    assert tr._slow_streak == 0
    assert tr.elastic_events[-1]["kind"] == "straggler_degrade"
    tr._track_straggler(0.12)           # fast again: EWMA moves
    assert tr._ewma_time == pytest.approx(0.9 * 0.1 + 0.1 * 0.12)
    assert sum(1 for e in tr.log if e["straggler"]) == 2


def test_recovery_preserves_saturation_settings(tmp_path):
    from repro.kernels import ops
    prev = (ops.current_saturation_cache(), ops.current_saturation_verify())
    try:
        sat_dir = str(tmp_path / "sat")
        ops.set_saturation_cache(sat_dir)
        ops.set_saturation_verify("cheap")
        tr = _mini_trainer(tmp_path, steps=6,
                           inject={3: ("node_loss", 1)})
        # a replacement host boots with process defaults — recovery
        # must re-apply the run's snapshot, not inherit these
        ops.set_saturation_cache(None)
        ops.set_saturation_verify(None)
        out = tr.run()
        assert out["recoveries"] == 1 and out["final_step"] == 6
        assert ops.current_saturation_cache() == sat_dir
        assert ops.current_saturation_verify() == "cheap"
        snap = telemetry().snapshot()["guard"]
        assert snap["elastic_recoveries"] == 1
    finally:
        ops.set_saturation_cache(prev[0])
        ops.set_saturation_verify(prev[1])


@pytest.mark.slow
def test_simulate_host_restart_clears_tile_ops(tmp_path):
    get_tile_op("l2_clip")
    assert get_tile_op.cache_info().currsize >= 1
    tr = _mini_trainer(tmp_path, steps=4, inject={2: ("node_loss", 1)},
                       simulate_host_restart=True)
    out = tr.run()
    assert out["recoveries"] == 1
    # the replacement host starts with no in-process tile ops; the
    # persistent cache (if configured) is what makes it warm again
    assert get_tile_op.cache_info().currsize == 0


# -- concurrent serving under cache faults -----------------------------------------
@pytest.mark.slow
def test_server_hammer_under_cache_faults(tmp_path):
    from repro.kernels import ops
    from repro.launch.serve import Request, Server
    prev = ops.current_saturation_cache()
    try:
        sat_dir = str(tmp_path / "sat")
        ops.set_saturation_cache(sat_dir)
        get_tile_op.cache_clear()
        srv = Server("mamba2-1.3b", smoke=True, max_batch=2)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, srv.cfg.vocab,
                                size=12).astype(np.int32)
                   for _ in range(8)]
        baseline = {}
        for i, p in enumerate(prompts):
            baseline[i] = srv.generate(
                [Request(rid=i, prompt=p, max_new=4)])[i]

        # rebuild every tile op mid-flight, with reads of the (now
        # populated) cache failing half the time, under 8 threads
        get_tile_op.cache_clear()
        telemetry().reset()
        reset_breakers()
        chaos.install_plan(chaos.FaultPlan(
            sites=("cache_read_io",), max_fires=None,
            probability=0.5, seed=7))
        results, errors = {}, []

        def worker(i):
            try:
                out = srv.generate(
                    [Request(rid=100 + i, prompt=prompts[i], max_new=4)])
                results[i] = out[100 + i]
            except Exception as e:   # noqa: BLE001 — the assertion target
                errors.append((i, repr(e)))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        chaos.clear_plan()

        assert errors == []
        for i in range(8):   # every response correct despite the faults
            assert results[i] == baseline[i], f"request {i} diverged"
        assert srv.metrics["prefills"] == 16     # no lost increments
        snap = telemetry().snapshot()
        guard = snap["guard"]
        assert all(isinstance(v, int) and v >= 0
                   for v in guard["chaos_fires"].values())
        bs = breakers_snapshot()
        assert sum(bs["states"].values()) == bs["total"]
        # cache faults degrade below the ladder: no breaker ever opened
        assert bs["states"].get("open", 0) == 0
        assert guard["breaker_events"].get("open", 0) == 0
        # the metrics snapshot itself is attached and well-formed
        assert "guard" in srv.metrics["saturation"]
    finally:
        chaos.clear_plan()
        ops.set_saturation_cache(prev)
        get_tile_op.cache_clear()


# -- lazy runtime facade -----------------------------------------------------------
def test_runtime_package_lazy_exports():
    import repro.runtime as rt
    assert rt.SaturationGuard is SaturationGuard
    assert rt.FaultPlan is chaos.FaultPlan
    assert rt.ElasticTrainer is ElasticTrainer
    with pytest.raises(AttributeError):
        rt.definitely_not_a_name
