"""Calibration subsystem (PR 4): synthetic ground-truth recovery, profile
round-trip/versioning, calibrated LatencyModel semantics, and the loaded
profile actually changing beam extraction's chosen e-nodes."""
import dataclasses
import json
import subprocess
import sys
import pathlib

import pytest

from repro.analysis import (DEFAULT_PARAMS, ArrayInfo, CalibrationError,
                            CalibrationParams, DeviceProfile, KernelFeatures,
                            LatencyModel, OpStats, RooflineCostModel,
                            check_profile, evaluate_params, fit_params,
                            fit_profile, kernel_features, load_profile,
                            mape_pct, predict_ns, spearman)
from repro.analysis.calibrate import SCHEMA_VERSION
from repro.core import EGraph, SaturatorConfig, ScheduleConfig, \
    add_expr, extract_dag, \
    saturate_program
from repro.core.pipeline import predict_choice

ROOT = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# Ground truth the fitter must recover. Features are built identifiable:
# compute-bound kernels isolate each pass-class coefficient, memory-bound
# ones pin hbm_efficiency, and mixed ones pin the per-bound slacks.
# ---------------------------------------------------------------------------
TRUE = CalibrationParams(
    overlap_slack_compute=0.30, overlap_slack_memory=0.15,
    hbm_efficiency=0.5, base_ns=0.0,
    vpu_pass_coeffs={"simple": 3.0, "transcendental": 0.5})

SYN_FEATS = [
    KernelFeatures("c_simple_small", {"simple": 10.0}, hbm_bytes=16.0),
    KernelFeatures("c_simple_big", {"simple": 40.0}, hbm_bytes=16.0),
    KernelFeatures("c_trans_small", {"transcendental": 16.0},
                   hbm_bytes=16.0),
    KernelFeatures("c_trans_big", {"transcendental": 48.0}, hbm_bytes=16.0),
    KernelFeatures("m_small", {}, hbm_bytes=100_000.0),
    KernelFeatures("m_big", {}, hbm_bytes=400_000.0),
    KernelFeatures("mixed_mem", {"simple": 20.0}, hbm_bytes=200_000.0),
    KernelFeatures("mixed_cmp", {"simple": 100.0}, hbm_bytes=50_000.0),
    KernelFeatures("mixed_both", {"simple": 30.0, "transcendental": 24.0},
                   hbm_bytes=80_000.0),
]
SYN_MEASURED = [predict_ns(f, TRUE) for f in SYN_FEATS]


def test_fitter_recovers_synthetic_ground_truth():
    params, loss, rounds = fit_params(SYN_FEATS, SYN_MEASURED)
    assert loss < 1e-4
    ev = evaluate_params(SYN_FEATS, SYN_MEASURED, params)
    assert ev["mape_pct"] < 1.0
    assert ev["spearman"] == pytest.approx(1.0)
    # parameter recovery (the features were built identifiable)
    assert params.hbm_efficiency == pytest.approx(TRUE.hbm_efficiency,
                                                  rel=0.15)
    for kls, want in TRUE.vpu_pass_coeffs.items():
        assert params.coeff(kls) == pytest.approx(want, rel=0.15), kls
    assert params.overlap_slack_compute == pytest.approx(
        TRUE.overlap_slack_compute, abs=0.1)
    assert params.overlap_slack_memory == pytest.approx(
        TRUE.overlap_slack_memory, abs=0.1)


def test_fitter_rejects_bad_input():
    with pytest.raises(CalibrationError):
        fit_params([], [])
    with pytest.raises(CalibrationError):
        fit_params(SYN_FEATS, SYN_MEASURED[:-1])
    with pytest.raises(CalibrationError):
        fit_params(SYN_FEATS[:2], [1.0, -5.0])


def test_spearman_and_mape():
    assert spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
    assert spearman([1.0, 1.0], [1.0, 2.0]) == 0.0     # degenerate: ties
    assert mape_pct([110.0], [100.0]) == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# Profile persistence
# ---------------------------------------------------------------------------
def _syn_profile(name="syn") -> DeviceProfile:
    return fit_profile(SYN_FEATS, SYN_MEASURED, name=name, chip="test",
                       measured_kind="synthetic")


def test_profile_roundtrip(tmp_path):
    prof = _syn_profile()
    path = prof.save(tmp_path / "syn.json")
    back = load_profile(path)
    assert back.params == prof.params
    assert back.fit == prof.fit
    assert back.measured_kind == "synthetic"
    assert back.stored_measurements() == SYN_MEASURED
    assert [f.kernel for f in back.stored_features()] \
        == [f.kernel for f in SYN_FEATS]
    # fit evidence carries both sides of the predicted-vs-measured report
    assert prof.fit["mape_pct"] < prof.fit["uncalibrated_mape_pct"]
    assert prof.fit["spearman"] >= 0.99


def test_profile_schema_version_mismatch_fails_loudly(tmp_path):
    prof = _syn_profile()
    doc = json.loads(prof.to_json())
    doc["schema_version"] = SCHEMA_VERSION + 1
    p = tmp_path / "future.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(CalibrationError, match="schema_version"):
        load_profile(p)
    with pytest.raises(CalibrationError, match="not valid JSON"):
        DeviceProfile.from_json("{nope")


def test_load_profile_unknown_name_is_actionable(tmp_path):
    with pytest.raises(CalibrationError, match="measure.py --fit"):
        load_profile(tmp_path / "missing.json")


def test_check_profile_detects_degradation():
    prof = _syn_profile()
    assert check_profile(prof) == []
    # sabotage the params: ranking quality collapses vs the stored fit
    bad = dataclasses.replace(prof)
    bad.params = CalibrationParams(vpu_pass_coeffs={"simple": 1e9})
    fails = check_profile(bad)
    assert fails and any("degraded" in f or "floor" in f for f in fails)


def test_committed_cpu_profile_meets_acceptance():
    """The repo ships a CPU device profile that holds the acceptance
    bar: loads via LatencyModel.from_profile, Spearman >= 0.8, MAPE
    strictly better than the uncalibrated defaults."""
    committed = sorted(
        (ROOT / "experiments" / "device_profiles").glob("*.json"))
    assert committed, "no committed device profile"
    for path in committed:
        prof = load_profile(path)
        assert prof.chip == "cpu"
        assert check_profile(prof) == [], path.name
        lm = LatencyModel.from_profile(path.stem)
        assert lm.profile_name == path.stem
        assert lm.hbm_efficiency == prof.params.hbm_efficiency


# ---------------------------------------------------------------------------
# Calibrated LatencyModel semantics
# ---------------------------------------------------------------------------
def test_latency_model_defaults_unchanged():
    """With no calibration fields set, the split-slack/efficiency/base
    formula reduces exactly to the legacy model."""
    lm = LatencyModel()
    st = OpStats(vpu_passes=4.0, bytes_read=8192.0)
    legacy = max(lm.compute_ns(st), lm.memory_ns(st)) \
        + 0.05 * min(lm.compute_ns(st), lm.memory_ns(st))
    assert lm.latency_ns(st) == pytest.approx(legacy)
    assert lm.slack_compute == lm.slack_memory == 0.05


def test_latency_model_from_profile_matches_predict_ns():
    """LatencyModel.from_profile + coefficient-scaled passes compute the
    same number as calibrate.predict_ns — the fitter and the extractor
    price with one formula."""
    params = CalibrationParams(
        overlap_slack_compute=0.2, overlap_slack_memory=0.4,
        hbm_efficiency=0.25, base_ns=100.0,
        vpu_pass_coeffs={"simple": 2.0, "transcendental": 0.5,
                         "memory_dispatch": 3.0})
    prof = DeviceProfile(name="t", chip="test", measured_kind="synthetic",
                         params=params)
    lm = LatencyModel.from_profile(prof)
    feat = KernelFeatures("k", {"simple": 6.0, "transcendental": 16.0,
                                "memory_dispatch": 2.0},
                          hbm_bytes=30_000.0)
    # what RooflineCostModel aggregates: passes pre-scaled by class coeff
    scaled = sum(p * params.coeff(k)
                 for k, p in feat.class_passes.items())
    st = OpStats(vpu_passes=scaled, bytes_read=30_000.0)
    assert lm.latency_ns(st) == pytest.approx(predict_ns(feat, params))
    # per-bound slack: force each side and check the right slack applies
    st_c = OpStats(vpu_passes=1e6, bytes_read=8.0)
    c, m = lm.compute_ns(st_c), lm.memory_ns(st_c)
    assert lm.latency_ns(st_c) == pytest.approx(100.0 + c + 0.2 * m)
    st_m = OpStats(vpu_passes=0.001, bytes_read=1e9)
    c, m = lm.compute_ns(st_m), lm.memory_ns(st_m)
    assert lm.latency_ns(st_m) == pytest.approx(100.0 + m + 0.4 * c)


def test_profile_model_chip_and_tile_elems_are_honored():
    """A profile fitted against non-default chip constants / tile size
    must be re-priced with exactly those, never the defaults."""
    from repro.core.hardware import A100_PCIE_40GB
    prof = fit_profile(SYN_FEATS, SYN_MEASURED, name="a100", chip="gpu",
                       measured_kind="synthetic", model_chip=A100_PCIE_40GB,
                       tile_elems=512)
    assert prof.model_chip == "a100_pcie_40gb"
    lm = LatencyModel.from_profile(prof)
    assert lm.chip is A100_PCIE_40GB
    assert lm.tile_elems == 512
    assert check_profile(prof) == []          # re-scores with the A100 spec
    cm = RooflineCostModel(profile=prof)
    assert cm.tile_elems == 512 and cm.chip is A100_PCIE_40GB
    bad = dataclasses.replace(prof)
    bad.model_chip = "no_such_chip"
    with pytest.raises(CalibrationError, match="model_chip"):
        LatencyModel.from_profile(bad)


def test_cost_model_applies_pass_coeffs_and_dispatch():
    from repro.core.ir import ENode
    params = CalibrationParams(vpu_pass_coeffs={"simple": 10.0,
                                                "memory_dispatch": 7.0})
    prof = DeviceProfile(name="t", chip="test", measured_kind="synthetic",
                         params=params)
    cal = RooflineCostModel(profile=prof)
    plain = RooflineCostModel()
    add = ENode("add", (1, 2))
    assert plain.node_stats(add).vpu_passes == 1.0
    assert cal.node_stats(add).vpu_passes == 10.0
    load = ENode("load", (3,))
    assert plain.node_stats(load).vpu_passes == 0.0
    assert cal.node_stats(load).vpu_passes == 7.0     # dispatch passes
    assert cal.node_stats(load).bytes_read \
        == plain.node_stats(load).bytes_read


# ---------------------------------------------------------------------------
# A loaded profile changes what the beam extracts
# ---------------------------------------------------------------------------
def _tradeoff_graph():
    """Root class with two equivalent implementations: a serial div
    (expensive compute, no traffic) vs a tile load (no compute, 4 KiB of
    traffic). The analytic model prefers the load (5 ns of HBM beats
    ~10.6 ns of serial passes); a profile measuring HBM as slow flips
    the choice."""
    eg = EGraph()
    a = add_expr(eg, ("div", ("var", "x"), ("var", "y")))
    b = add_expr(eg, ("load", ("array", "t@0")))
    eg.set_array_info("t", ArrayInfo(shape=(8, 128), dtype="f32"))
    root = eg.union(a, b)
    return eg, root


def test_device_profile_changes_beam_choice():
    eg, root = _tradeoff_graph()
    analytic = extract_dag(eg, root, cost_model=RooflineCostModel(),
                           search="beam")
    assert analytic.choice[eg.find(root)].op == "load"

    slow_hbm = DeviceProfile(
        name="slow_hbm", chip="test", measured_kind="synthetic",
        params=CalibrationParams(hbm_efficiency=1e-3))
    eg2, root2 = _tradeoff_graph()
    calibrated = extract_dag(eg2, root2,
                             cost_model=RooflineCostModel(profile=slow_hbm),
                             search="beam")
    assert calibrated.choice[eg2.find(root2)].op == "div"


def test_device_profile_threads_through_pipeline():
    """SaturatorConfig(device_profile=...) reaches extraction and the
    predicted report (profile name flagged, units rescaled)."""
    from repro.kernels.tile_programs import swiglu_program
    prof = DeviceProfile(
        name="synthetic_slow", chip="test", measured_kind="synthetic",
        params=CalibrationParams(hbm_efficiency=1e-6, base_ns=123.0))
    sk = saturate_program(swiglu_program(),
                          SaturatorConfig(schedule_cfg=ScheduleConfig(
                              device_profile=prof)))
    rep = sk.report()
    assert rep["device_profile"] == "synthetic_slow"
    base = saturate_program(swiglu_program(), SaturatorConfig())
    assert base.report()["device_profile"] is None
    # calibrated units: 1e-6 HBM efficiency makes the same term predict
    # ~1e6x the memory latency
    assert rep["predicted_latency_ns"] > \
        1e4 * base.report()["predicted_latency_ns"]


def test_kernel_features_counts_match_generated_kernel():
    from repro.kernels.tile_programs import rmsnorm_program
    sk = saturate_program(rmsnorm_program(), SaturatorConfig())
    feat = kernel_features(sk)
    assert feat.kernel == "rmsnorm"
    assert feat.class_passes.get("memory_dispatch") \
        == float(sk.kernel.stats.n_loads)
    # features price the same term the pipeline's report prices, minus
    # the store traffic the features add back explicitly
    pred = predict_choice(sk.ssa, sk.extraction.choice, sk.extraction.roots,
                          sk.kernel.stats.n_stores)
    assert feat.hbm_bytes == pytest.approx(pred["bytes_read"]
                                           + pred["bytes_written"])
    # uncalibrated predict_ns over features == the analytic report
    assert predict_ns(feat, DEFAULT_PARAMS) \
        == pytest.approx(pred["latency_ns"])


# ---------------------------------------------------------------------------
# Entry points: both invocation styles work (satellite: run.py imports)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("argv", [
    [sys.executable, str(ROOT / "benchmarks" / "measure.py"), "--help"],
    [sys.executable, "-m", "benchmarks.measure", "--help"],
])
def test_measure_entry_points(argv):
    r = subprocess.run(argv, cwd=ROOT, capture_output=True, text=True,
                       timeout=120)
    assert r.returncode == 0, r.stderr
    assert "calibration" in (r.stdout + r.stderr).lower()
