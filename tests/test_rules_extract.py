"""Rule soundness (Table I) + extraction quality (CSE-aware DAG cost)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cost import CostModel, TPUCostModel, count_ops
from repro.core.egraph import EGraph, add_expr
from repro.core.extract import dag_cost_of, extract_dag, extract_exact
from repro.core.rules import (EXTENDED_RULES, PAPER_RULES, run_rules)

from helpers import eval_term, random_env, random_term


# -- per-rule semantic soundness ---------------------------------------------------
@pytest.mark.parametrize("rule", PAPER_RULES + EXTENDED_RULES,
                         ids=lambda r: r.name)
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_rule_sound(rule, seed):
    """lhs and rhs evaluate identically under random bindings."""
    rng = np.random.default_rng(seed)
    env = {}

    def to_term(pat):
        from repro.core.egraph import PatVar
        if isinstance(pat, PatVar):
            if pat.name not in env:
                env[pat.name] = float(rng.normal()) or 0.7
            return ("var", pat.name)
        return (pat.op,) + tuple(to_term(c) for c in pat.children)

    lhs = to_term(rule.lhs)
    rhs = to_term(rule.rhs)
    np.testing.assert_allclose(eval_term(lhs, env), eval_term(rhs, env),
                               rtol=1e-9)


def test_fma_formed():
    eg = EGraph()
    root = add_expr(eg, ("add", ("var", "x"),
                         ("mul", ("var", "y"), ("var", "z"))))
    run_rules(eg, PAPER_RULES)
    res = eg.extract(root)
    assert res.term(eg)[0] == "fma"


def test_fma_sub_variants():
    for term, sign in [
            (("sub", ("var", "a"), ("mul", ("var", "b"), ("var", "c"))), 1),
            (("sub", ("mul", ("var", "b"), ("var", "c")), ("var", "a")), 2)]:
        eg = EGraph()
        root = add_expr(eg, term)
        run_rules(eg, PAPER_RULES)
        # FMA2/3 cost-TIE with sub+mul under the paper model (fma+neg =
        # 20 = sub+mul); the TPU model folds the sign flip for free, so
        # the FMA form strictly wins — use it here.
        res = eg.extract(root, cost_model=TPUCostModel())
        ops = set()

        def walk(t):
            ops.add(t[0])
            for c in t[1:]:
                if isinstance(c, tuple):
                    walk(c)
        walk(res.term(eg))
        assert "fma" in ops


def test_extraction_beats_or_matches_tree():
    rng = np.random.default_rng(1)
    for _ in range(10):
        term = random_term(rng, 4)
        eg = EGraph()
        root = add_expr(eg, term)
        run_rules(eg, PAPER_RULES, iter_limit=5, node_limit=2000)
        res = extract_dag(eg, root)
        assert res.dag_cost <= res.tree_cost + 1e-9


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_local_search_matches_bruteforce(seed):
    """On tiny graphs the hill-climbing extractor (our ILP stand-in) finds
    the brute-force optimum."""
    rng = np.random.default_rng(seed)
    term = random_term(rng, 2)
    eg = EGraph()
    root = add_expr(eg, term)
    run_rules(eg, PAPER_RULES, iter_limit=3, node_limit=60)
    try:
        exact = extract_exact(eg, root, max_combos=50_000)
    except ValueError:
        pytest.skip("graph too large for brute force")
    ours = extract_dag(eg, root, time_limit_s=10.0)
    assert ours.dag_cost <= exact.dag_cost + 1e-9 or \
        abs(ours.dag_cost - exact.dag_cost) < 1e-6


def test_cse_counted_once():
    # (a+b)*(a+b): DAG cost counts a+b once (paper weight units)
    eg = EGraph()
    ab = ("add", ("var", "a"), ("var", "b"))
    root = add_expr(eg, ("mul", ab, ab))
    res = extract_dag(eg, root, cost_model=CostModel())
    # vars 2×1 + add 10 + mul 10 = 22
    assert res.dag_cost == pytest.approx(22.0)
    assert res.tree_cost == pytest.approx(34.0)


def test_multi_root_sharing():
    eg = EGraph()
    bc = ("mul", ("var", "b"), ("var", "c"))
    r1 = add_expr(eg, ("add", ("var", "a"), bc))
    r2 = add_expr(eg, ("mul", bc, ("var", "d")))
    res = extract_dag(eg, (r1, r2), cost_model=CostModel())
    # a,b,c,d + mul(b,c) + add + mul = 4 + 30
    assert res.dag_cost == pytest.approx(34.0)


def test_cost_model_paper_values():
    cm = CostModel()
    from repro.core.ir import ENode
    assert cm.node_cost(ENode("const", (), 1.0)) == 0
    assert cm.node_cost(ENode("var", (), "x")) == 1
    assert cm.node_cost(ENode("phi", (0, 1, 2))) == 1
    assert cm.node_cost(ENode("add", (0, 1))) == 10
    assert cm.node_cost(ENode("div", (0, 1))) == 100
    assert cm.node_cost(ENode("mod", (0, 1))) == 100
    assert cm.node_cost(ENode("load", (0,))) == 100
    assert cm.node_cost(ENode("call", (0,), "f")) == 100


def test_tpu_cost_model_transcendentals():
    cm = TPUCostModel()
    from repro.core.ir import ENode
    assert cm.node_cost(ENode("exp", (0,))) == 40
    assert cm.node_cost(ENode("rsqrt", (0,))) == 20
    assert cm.node_cost(ENode("add", (0, 1))) == 10


def test_extraction_acyclic():
    rng = np.random.default_rng(7)
    term = random_term(rng, 4)
    eg = EGraph()
    root = add_expr(eg, term)
    run_rules(eg, PAPER_RULES, iter_limit=6, node_limit=3000)
    res = extract_dag(eg, root)
    cost = dag_cost_of(eg, CostModel(), res.choice, res.roots)
    assert np.isfinite(cost)
