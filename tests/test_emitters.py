"""PR-8 emitter registry + pipelined Pallas emission.

Covers the registry front door (``repro.core.emit``), the deprecated
class aliases, the pipelined emitter's interpret-fallback bit-identity
golden contract across every tile kernel, the async-plan verifier's
mutation sensitivity, and the acceptance sweep (pipelined sources
verify clean under both rule sets).
"""
import dataclasses
import warnings

import numpy as np
import pytest

import jax

from repro.core import (SaturatorConfig, ScheduleConfig, make_tile_op,
                        saturate_program)
from repro.core.emit import (EMITTER_NAMES, Emitter, emitter_cache_id,
                             get_emitter)
from repro.kernels.tile_programs import PROGRAMS, get_tile_op
from repro.verify import verify_async_plan, verify_pallas_kernel

TILE_NAMES = tuple(sorted(PROGRAMS))


# -- registry ---------------------------------------------------------------
def test_registry_names_and_targets():
    assert EMITTER_NAMES == ("jax", "pallas", "pallas_pipelined")
    targets = {}
    for name in EMITTER_NAMES:
        em = get_emitter(name)
        assert isinstance(em, Emitter)
        assert em.info.name == name
        assert em.info.version >= 1
        targets[name] = em.info.target
    assert targets == {"jax": "jax", "pallas": "pallas",
                       "pallas_pipelined": "pallas"}


def test_unknown_emitter_rejected():
    with pytest.raises(ValueError, match="unknown emitter"):
        get_emitter("cuda")
    with pytest.raises(ValueError, match="unknown emitter"):
        emitter_cache_id("cuda")
    with pytest.raises(ValueError, match="emitter"):
        SaturatorConfig(schedule_cfg=ScheduleConfig(emitter="cuda"))


def test_default_emitters_contribute_no_cache_key():
    """Pre-registry configs must keep byte-identical fingerprints: the
    default emitters map to None, only new backends are versioned."""
    assert emitter_cache_id(None) is None
    assert emitter_cache_id("jax") is None
    assert emitter_cache_id("pallas") is None
    em = get_emitter("pallas_pipelined")
    assert emitter_cache_id("pallas_pipelined") == \
        f"pallas_pipelined@v{em.info.version}"


def test_registry_emit_matches_direct_generator():
    sk = saturate_program(PROGRAMS["rmsnorm"](),
                          SaturatorConfig(mode="accsat",
                                          cost_model="tpu_v5e",
                                          tpu_rules=True))
    from repro.core.codegen import JaxCodeGenerator
    direct = JaxCodeGenerator(sk.ssa, sk.extraction, bulk=True).generate()
    via_registry = get_emitter("jax").emit(sk.ssa, sk.extraction, bulk=True)
    assert via_registry.source == direct.source
    from repro.core.pallasgen import SyncPallasGenerator
    pdirect = SyncPallasGenerator(sk.ssa, sk.extraction,
                                  bulk=True).generate_pallas()
    pvia = get_emitter("pallas").emit(sk.ssa, sk.extraction, bulk=True)
    assert pvia.source == pdirect.source


def test_deprecated_aliases_warn_and_match():
    """The pre-PR-8 class names still work (they are the documented
    migration path) but raise DeprecationWarning on construction."""
    sk = saturate_program(PROGRAMS["swiglu"](),
                          SaturatorConfig(mode="accsat",
                                          cost_model="tpu_v5e",
                                          tpu_rules=True))
    from repro.core.codegen import (CodeGenerator,      # deprecated-ok
                                    JaxCodeGenerator)
    from repro.core.pallasgen import (PallasGenerator,  # deprecated-ok
                                      SyncPallasGenerator)
    with pytest.warns(DeprecationWarning, match="CodeGenerator"):
        old = CodeGenerator(sk.ssa, sk.extraction,         # deprecated-ok
                            bulk=True).generate()
    new = JaxCodeGenerator(sk.ssa, sk.extraction, bulk=True).generate()
    assert old.source == new.source
    with pytest.warns(DeprecationWarning, match="PallasGenerator"):
        pold = PallasGenerator(sk.ssa, sk.extraction,      # deprecated-ok
                               bulk=True).generate_pallas()
    pnew = SyncPallasGenerator(sk.ssa, sk.extraction,
                               bulk=True).generate_pallas()
    assert pold.source == pnew.source


# -- pipelined fallback golden contract -------------------------------------
@pytest.mark.parametrize("name", TILE_NAMES)
def test_pipelined_fallback_bit_identical(name):
    """For every tile kernel, the pipelined emitter's interpret-mode
    fallback source is byte-identical to what the synchronous emitter
    produces under the same cost schedule — CPU runs lose nothing but
    the async staging — and its async source verifies clean."""
    piped = get_tile_op(name, schedule="cost", emitter="pallas_pipelined")
    sync = get_tile_op(name, schedule="cost")
    assert piped.pk.emitter == "pallas_pipelined"
    assert piped.pk.fallback_source is not None
    assert piped.pk.fallback_source == sync.pk.source
    assert piped.pk.async_plan, f"{name}: nothing was pipelined"
    rep = verify_pallas_kernel(piped.pk, piped.sk.ssa)
    assert not rep.errors(), [f"[{f.code}] {f.message}" for f in rep.errors()]


def _tile_inputs(prog, seed=0):
    # mirrors benchmarks.measure.tile_inputs_for, which cannot be
    # imported here: benchmarks entry points re-exec on import to pin
    # PYTHONHASHSEED, which would replace the pytest process
    from repro.analysis import TILE_SHAPE
    rng = np.random.default_rng(seed)
    arrays = []
    for spec in prog.arrays.values():
        if spec.role not in ("in", "inout"):
            continue
        shape = getattr(spec, "shape", None) or TILE_SHAPE
        shape = tuple(TILE_SHAPE[i] if d is None else int(d)
                      for i, d in enumerate(shape))
        arrays.append(rng.uniform(0.1, 1.0, size=shape).astype(np.float32))
    return arrays, {s: 0.5 for s in prog.scalars}


def test_pipelined_outputs_bit_identical_on_cpu():
    for name in ("rmsnorm", "adamw", "softmax"):
        piped = get_tile_op(name, schedule="cost",
                            emitter="pallas_pipelined")
        sync = get_tile_op(name, schedule="cost")
        arrays, scalars = _tile_inputs(piped.sk.ssa.prog)
        args = [jax.numpy.asarray(a) for a in arrays]
        a = piped.apply(*args, **scalars)
        b = sync.apply(*args, **scalars)
        outs_a = a if isinstance(a, tuple) else (a,)
        outs_b = b if isinstance(b, tuple) else (b,)
        for x, y in zip(outs_a, outs_b):
            assert np.array_equal(np.asarray(x), np.asarray(y)), name


# -- mutation sensitivity ---------------------------------------------------
def test_verifier_catches_unmatched_async_start():
    """Planting one async start with no wait must surface as exactly
    one error finding (the verifier neither misses it nor cascades)."""
    op = get_tile_op("rmsnorm", schedule="cost",
                     emitter="pallas_pipelined")
    plan = op.pk.async_plan
    assert len(plan) >= 2
    clean = verify_async_plan(op.sk.ssa, op.pk.schedule, plan)
    assert not [f for f in clean if f.severity == "error"]
    mutated = plan[:-1] + (dataclasses.replace(plan[-1], wait_slot=-1),)
    findings = verify_async_plan(op.sk.ssa, op.pk.schedule, mutated)
    errors = [f for f in findings if f.severity == "error"]
    assert len(errors) == 1
    assert errors[0].code == "unmatched-async-start"
    assert plan[-1].array in errors[0].message


def test_verifier_catches_bad_parity_and_wait_order():
    op = get_tile_op("rmsnorm", schedule="cost",
                     emitter="pallas_pipelined")
    plan = op.pk.async_plan
    flipped = (dataclasses.replace(plan[0], sem=1 - plan[0].sem),) + plan[1:]
    codes = {f.code for f in verify_async_plan(op.sk.ssa, op.pk.schedule,
                                               flipped)
             if f.severity == "error"}
    assert "async-buffer-parity" in codes
    early = (dataclasses.replace(plan[0],
                                 wait_slot=plan[0].start_slot),) + plan[1:]
    codes = {f.code for f in verify_async_plan(op.sk.ssa, op.pk.schedule,
                                               early)
             if f.severity == "error"}
    assert "async-wait-order" in codes


# -- acceptance sweep: both rule sets ---------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("tpu_rules", [False, True])
def test_pipelined_verifies_clean_both_rule_sets(tpu_rules):
    """Acceptance: pipelined emitter sources pass cheap verification
    with zero errors across all 13 tile kernels under both the Table-I
    rule set and the +TPU strength-reduction set."""
    for name in TILE_NAMES:
        cfg = SaturatorConfig(
            mode="accsat", cost_model="tpu_v5e", tpu_rules=tpu_rules,
            schedule_cfg=ScheduleConfig(schedule="cost",
                                        emitter="pallas_pipelined"))
        op = make_tile_op(PROGRAMS[name](), cfg)
        rep = verify_pallas_kernel(op.pk, op.sk.ssa)
        assert not rep.errors(), (name, tpu_rules,
                                  [f"[{f.code}] {f.message}"
                                   for f in rep.errors()])
