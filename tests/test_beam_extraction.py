"""Beam-search extraction (PR 3 tentpole): beam vs hill climb vs the
brute-force oracle, the fast evaluator's exactness, and the enriched
unextractable-root diagnostics."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CostModel, EGraph, TPUCostModel, add_expr,
                        extract_dag, extract_exact, optimality_gap)
from repro.core.beam import BeamStats, Evaluator, beam_search
from repro.core.egraph import EClass
from repro.core.extract import _tree_costs, dag_cost_of
from repro.core.ir import ENode
from repro.core.rules import PAPER_RULES, run_rules
from repro.analysis import RooflineCostModel

from helpers import random_term


def _saturated_graph(seed: int, depth: int, iters: int = 3,
                     nodes: int = 200):
    rng = np.random.default_rng(seed)
    eg = EGraph()
    root = add_expr(eg, random_term(rng, depth))
    run_rules(eg, PAPER_RULES, iter_limit=iters, node_limit=nodes)
    return eg, root


# -- beam never worse than the hill climb ------------------------------------------
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_beam_never_worse_than_hillclimb(seed):
    """Property: on random saturated e-graphs, beam extraction's DAG cost
    is never worse than the PR-2 multi-start hill climb's (the beam
    polishes the same restart seeds)."""
    eg, root = _saturated_graph(seed, depth=3)
    beam = extract_dag(eg, root, time_limit_s=10.0, search="beam")
    hill = extract_dag(eg, root, time_limit_s=10.0, search="hillclimb")
    assert beam.dag_cost <= hill.dag_cost + 1e-9
    assert beam.search == "beam" and hill.search == "hillclimb"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_beam_never_worse_flat_model(seed):
    """Same property under the paper's flat-weight objective."""
    eg, root = _saturated_graph(seed, depth=3)
    cm = CostModel()
    beam = extract_dag(eg, root, cost_model=cm, time_limit_s=10.0,
                       search="beam")
    hill = extract_dag(eg, root, cost_model=CostModel(),
                       time_limit_s=10.0, search="hillclimb")
    assert beam.dag_cost <= hill.dag_cost + 1e-9


# -- oracle agreement on tiny graphs ------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_beam_matches_exact_on_small_graphs(seed):
    """On e-graphs with <= 6 classes the beam matches the brute-force
    oracle exactly (zero optimality gap)."""
    rng = np.random.default_rng(seed)
    eg = EGraph()
    root = add_expr(eg, random_term(rng, 1))
    run_rules(eg, PAPER_RULES, iter_limit=2, node_limit=40)
    if eg.num_classes() > 6:
        pytest.skip("grew past 6 classes")
    exact = extract_exact(eg, root, max_combos=100_000)
    beam = extract_dag(eg, root, time_limit_s=10.0, search="beam")
    assert beam.dag_cost == pytest.approx(exact.dag_cost, abs=1e-9)
    gap = optimality_gap(eg, beam, max_classes=6)
    assert gap == pytest.approx(0.0, abs=1e-12)


def test_optimality_gap_none_on_large_graphs():
    eg = EGraph()
    root = add_expr(eg, ("add", ("var", "a"),
                         ("mul", ("var", "b"),
                          ("add", ("var", "c"), ("var", "d")))))
    run_rules(eg, PAPER_RULES, iter_limit=4, node_limit=2000)
    assert eg.num_classes() > 6
    res = extract_dag(eg, root, time_limit_s=5.0)
    assert optimality_gap(eg, res, max_classes=6) is None


# -- the fast evaluator is exact ----------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_evaluator_matches_dag_cost_of(seed):
    """Evaluator (the beam's hot path) agrees with the reference
    dag_cost_of scoring for both model families."""
    eg, root = _saturated_graph(seed, depth=3)
    roots = (eg.find(root),)
    for cm in (RooflineCostModel(egraph=eg), CostModel(), TPUCostModel()):
        _, choice = _tree_costs(eg, cm)
        ev = Evaluator(eg, cm)
        want = dag_cost_of(eg, cm, choice, roots)
        got = ev.cost(choice.get, roots)
        assert got == pytest.approx(want, rel=1e-12)


def test_evaluator_detects_cycle_and_incomplete():
    eg = EGraph()
    a = add_expr(eg, ("add", ("var", "x"), ("var", "y")))
    cm = CostModel()
    ev = Evaluator(eg, cm)
    # incomplete: no binding for the root
    assert ev.cost({}.get, (eg.find(a),)) == float("inf")


# -- beam knobs ---------------------------------------------------------------------
def test_beam_width_one_still_valid():
    eg, root = _saturated_graph(11, depth=3)
    wide = extract_dag(eg, root, time_limit_s=10.0, beam_width=8)
    narrow = extract_dag(eg, root, time_limit_s=10.0, beam_width=1)
    assert np.isfinite(narrow.dag_cost)
    assert wide.dag_cost <= narrow.dag_cost + 1e-9


def test_beam_width_zero_rejected():
    eg = EGraph()
    root = add_expr(eg, ("add", ("var", "x"), ("var", "y")))
    cm = RooflineCostModel(egraph=eg)
    _, choice = _tree_costs(eg, cm)
    with pytest.raises(ValueError, match="width"):
        beam_search(eg, cm, [choice], (root,), width=0)


def test_extract_dag_rejects_unknown_search():
    eg = EGraph()
    root = add_expr(eg, ("var", "x"))
    with pytest.raises(ValueError, match="search"):
        extract_dag(eg, root, search="annealing")


def test_beam_stats_populated():
    eg, root = _saturated_graph(2, depth=3)
    res = extract_dag(eg, root, time_limit_s=10.0, search="beam")
    assert res.beam_stats is not None
    assert res.beam_stats.width == 8
    assert res.beam_stats.expanded >= 0
    assert res.beam_cost <= res.beam_stats.seed_cost + 1e-9
    # the polish pass can only improve on the beam stage
    assert res.dag_cost <= res.beam_cost + 1e-9


def test_beam_expansion_cap_deterministic():
    """Two runs with the same expansion budget land on the same cost."""
    eg, root = _saturated_graph(9, depth=4, iters=4, nodes=1500)
    a = extract_dag(eg, root, time_limit_s=30.0, beam_expansions=500)
    b = extract_dag(eg, root, time_limit_s=30.0, beam_expansions=500)
    assert a.dag_cost == b.dag_cost


def test_hillclimb_eval_budget_deterministic():
    """The hill climb stops on its evaluation budget, not the wall
    clock: repeated runs with a budget small enough to bind mid-search
    still produce identical costs (the bench-regression gate's
    machine-independence relies on this)."""
    eg, root = _saturated_graph(21, depth=4, iters=4, nodes=1500)
    runs = [extract_dag(eg, root, search="hillclimb", time_limit_s=30.0,
                        hillclimb_evals=700).dag_cost for _ in range(3)]
    assert runs[0] == runs[1] == runs[2]


# -- coordinated multi-class moves (PR 5 satellite) ---------------------------------
class _TwoAxisModel:
    """Toy non-additive objective: cost = max(Σa, Σb) over per-node
    (a, b) weights keyed by (op, payload). ``node_cost`` is a
    deliberately misleading additive surrogate so the tree fixed point
    seeds the search exactly onto the plateau state."""

    def __init__(self, ab, surrogate):
        self.ab = ab
        self.surrogate = surrogate

    def node_cost(self, node):
        return self.surrogate.get((node.op, node.payload), 0.0)

    def aggregate_cost(self, nodes):
        a = sum(self.ab.get((n.op, n.payload), (0.0, 0.0))[0]
                for n in nodes)
        b = sum(self.ab.get((n.op, n.payload), (0.0, 0.0))[1]
                for n in nodes)
        return max(a, b)


def _plateau_graph(n_pads=8):
    """Two load-bearing classes, two nodes each, under max(Σa, Σb):

        state (exp, x): max(4, 4) = 4   <- seed (plateau)
        state (tanh,x): max(0, 7) = 7   <- single swap, strictly worse
        state (exp, y): max(7, 0) = 7   <- single swap, strictly worse
        state (tanh,y): max(3, 3) = 3   <- only reachable by moving BOTH

    plus ``n_pads`` >= width free classes with two zero-cost
    alternatives each: every generation yields at least a full beam of
    equal-cost plateau siblings, so the strictly-worse single-swap
    intermediates are always squeezed out of the surviving beam — the
    1-swap beam is provably stuck at 4 at the default width, while one
    coordinated (parent, child) move reaches 3 directly.
    """
    eg = EGraph()
    cx = add_expr(eg, ("var", "x"))
    cy = add_expr(eg, ("var", "y"))
    ch = eg.find(eg.union(cx, cy))
    r1 = eg.add(ENode("exp", (ch,)))
    r2 = eg.add(ENode("tanh", (ch,)))
    root = eg.find(eg.union(r1, r2))
    ab = {("exp", None): (4.0, 0.0), ("tanh", None): (0.0, 3.0),
          ("var", "x"): (0.0, 4.0), ("var", "y"): (3.0, 0.0)}
    surrogate = {("exp", None): 1.0, ("tanh", None): 10.0,
                 ("var", "x"): 1.0, ("var", "y"): 10.0}
    pads = []
    seed = {eg.find(root): ENode("exp", (eg.find(ch),)),
            eg.find(ch): ENode("var", (), "x")}
    for k in range(n_pads):
        pa = add_expr(eg, ("var", f"pad{k}a"))
        pb = add_expr(eg, ("var", f"pad{k}b"))
        pc = eg.find(eg.union(pa, pb))
        pads.append(pc)
        seed[pc] = ENode("var", (), f"pad{k}a")
    eg.rebuild()
    roots = (eg.find(root),) + tuple(eg.find(p) for p in pads)
    seed = {eg.find(c): n for c, n in seed.items()}
    return eg, roots, eg.find(root), eg.find(ch), _TwoAxisModel(
        ab, surrogate), seed


def test_single_swap_beam_stuck_on_plateau():
    eg, roots, root, ch, cm, seed = _plateau_graph()
    _, cost = beam_search(eg, cm, [seed], roots, width=8,
                          coordinated=False)
    assert cost == pytest.approx(4.0)


def test_coordinated_move_escapes_plateau():
    eg, roots, root, ch, cm, seed = _plateau_graph()
    stats = BeamStats()
    choice, cost = beam_search(eg, cm, [seed], roots, width=8,
                               coordinated=True, stats=stats)
    assert cost == pytest.approx(3.0)
    assert stats.coordinated_expanded > 0
    assert choice[root].op == "tanh"
    assert choice[ch].payload == "y"


def test_extract_dag_with_coordinated_moves_finds_optimum():
    eg, roots, root, ch, cm, _ = _plateau_graph()
    res = extract_dag(eg, roots, cost_model=cm, search="beam",
                      coordinated=True)
    assert res.dag_cost == pytest.approx(3.0)


# -- unextractable-root diagnostics (PR 3 bugfix) -----------------------------------
def _cyclic_graph():
    """Two classes whose only nodes reference each other — extraction of
    either root is impossible (the blocking-cycle case)."""
    eg = EGraph()
    a = eg.uf.make()
    eg.classes[a] = EClass(a)
    b = eg.uf.make()
    eg.classes[b] = EClass(b)
    eg.classes[a].nodes.add(ENode("neg", (b,)))
    eg.classes[b].nodes.add(ENode("sqrt", (a,)))
    return eg, a, b


def test_unextractable_root_message_lists_nodes_and_cycle():
    eg, a, b = _cyclic_graph()
    with pytest.raises(ValueError) as ei:
        extract_dag(eg, a)
    msg = str(ei.value)
    assert f"no extractable term for e-class {a}" in msg
    assert "available e-nodes" in msg
    assert "neg" in msg                      # the root's own candidates
    assert f"blocked by e-class(es) [{b}]" in msg
    assert "blocking cycle:" in msg
    assert f"{a} -> {b} -> {a}" in msg


def test_unextractable_root_message_empty_class():
    eg = EGraph()
    a = eg.uf.make()
    eg.classes[a] = EClass(a)
    with pytest.raises(ValueError, match="contains no e-nodes"):
        extract_dag(eg, a)


def test_extractable_roots_unaffected_by_diagnostics():
    """Regression guard: ordinary extraction still works and raises
    nothing."""
    eg = EGraph()
    root = add_expr(eg, ("mul", ("var", "a"), ("var", "b")))
    res = extract_dag(eg, root)
    assert np.isfinite(res.dag_cost)
