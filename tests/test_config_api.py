"""PR-8 grouped SaturatorConfig API.

Pins the three compatibility contracts of the config split:

* grouped sub-configs and the deprecated flat kwargs build *equal*
  configs (and the flat path warns);
* ``config_fingerprint`` is byte-identical to the pre-split digests —
  golden hashes below were captured from the flat-kwarg constructor, so
  no persistent-cache entry invalidates;
* ``from_env`` is the one front door for the cache/verify side-channels
  with pinned precedence: explicit argument > CLI flag > environment
  variable > default.
"""
import argparse
import dataclasses
import warnings

import pytest

from repro.cache import cache_key_for, config_fingerprint
from repro.core import (CACHE_ENV_VAR, CacheConfig, SaturatorConfig,
                        ScheduleConfig, SearchConfig, VerifyConfig)
from repro.core.pipeline import VERIFY_ENV_VAR


# -- golden fingerprints (captured pre-split; must never drift) -------------
GOLDEN_FINGERPRINTS = {
    "default": (
        SaturatorConfig(),
        "383612fda9e1355840552031f6eb54a22605f6ddb17e694d61733a492efa04b1"),
    "tile_default": (
        SaturatorConfig(mode="accsat", cost_model="tpu_v5e",
                        tpu_rules=True),
        "707dc211eaacc1d04bb1c02501c56b42e3f70bd340a5ec5e49b7a4aee32de6be"),
    "tile_cost": (
        SaturatorConfig(mode="accsat", cost_model="tpu_v5e", tpu_rules=True,
                        schedule_cfg=ScheduleConfig(schedule="cost")),
        "4ff486817a2ba6ce15ea9d5939bde0051901274c1135850cf86968a70ecefbfb"),
    "cse": (
        SaturatorConfig(mode="cse",
                        schedule_cfg=ScheduleConfig(schedule="source"),
                        verify_cfg=VerifyConfig(verify="cheap")),
        "d556827b85e37ddbd6c28f95e59a2515724a2d4a6f9d6e27b7947acccf6ac197"),
    "beam_tweak": (
        SaturatorConfig(search_cfg=SearchConfig(beam_width=4,
                                                beam_expansions=500,
                                                hillclimb_evals=1000,
                                                local_search=False,
                                                search="hillclimb")),
        "ba571c01755ee13dfcc8983f634356439cd123177dad70f58c2cd7cf75b6c807"),
}


@pytest.mark.parametrize("case", sorted(GOLDEN_FINGERPRINTS))
def test_config_fingerprint_golden(case):
    cfg, want = GOLDEN_FINGERPRINTS[case]
    assert config_fingerprint(cfg) == want


def test_cache_key_golden():
    from repro.kernels.tile_programs import PROGRAMS
    cfg = GOLDEN_FINGERPRINTS["tile_cost"][0]
    key = cache_key_for(PROGRAMS["rmsnorm"](), cfg)
    assert key.warm_key == \
        "bf7bc460b908a1427c0f0c62553dc3d3b3878d413b53a280a7c63be403e33fca"
    assert key.exact_key == \
        "eb75f71bde3a077c35a460f44c056b9c4b458474f1019567399389905d3689da"


# -- flat-kwarg compatibility -----------------------------------------------
def test_legacy_flat_kwargs_warn_and_build_equal_config():
    with pytest.warns(DeprecationWarning, match="flat SaturatorConfig"):
        legacy = SaturatorConfig(mode="accsat",
                                 schedule="cost",      # deprecated-ok
                                 beam_width=4,         # deprecated-ok
                                 cache_dir="/tmp/x",   # deprecated-ok
                                 verify="cheap")       # deprecated-ok
    grouped = SaturatorConfig(
        mode="accsat",
        search_cfg=SearchConfig(beam_width=4),
        schedule_cfg=ScheduleConfig(schedule="cost"),
        cache_cfg=CacheConfig(cache_dir="/tmp/x"),
        verify_cfg=VerifyConfig(verify="cheap"))
    assert legacy == grouped
    assert config_fingerprint(legacy) == config_fingerprint(grouped)


def test_flat_read_properties_mirror_groups():
    cfg = SaturatorConfig(
        search_cfg=SearchConfig(iter_limit=3, beam_width=2),
        schedule_cfg=ScheduleConfig(schedule="cost", emitter="pallas"),
        cache_cfg=CacheConfig(cache_dir="/tmp/c", cache_warm_start=False),
        verify_cfg=VerifyConfig(verify="full"))
    assert cfg.iter_limit == 3
    assert cfg.beam_width == 2
    assert cfg.schedule == "cost"
    assert cfg.emitter == "pallas"
    assert cfg.cache_dir == "/tmp/c"
    assert cfg.cache_warm_start is False
    assert cfg.verify == "full"


def test_unknown_kwarg_still_typeerror():
    with pytest.raises(TypeError, match="unexpected keyword"):
        SaturatorConfig(bogus_knob=1)


def test_emitter_is_first_class_not_deprecated():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = SaturatorConfig(emitter="pallas_pipelined")
    assert cfg.emitter == "pallas_pipelined"
    assert cfg.schedule_cfg.emitter == "pallas_pipelined"


def test_dataclasses_replace_on_groups():
    base = SaturatorConfig(mode="accsat")
    tweaked = dataclasses.replace(
        base, search_cfg=dataclasses.replace(base.search_cfg, beam_width=2))
    assert tweaked.beam_width == 2
    assert tweaked.mode == "accsat"
    assert base.beam_width == SearchConfig().beam_width


def test_group_validation_still_applies():
    with pytest.raises(ValueError, match="schedule"):
        SaturatorConfig(schedule_cfg=ScheduleConfig(schedule="zigzag"))
    with pytest.raises(ValueError, match="verify"):
        SaturatorConfig(verify_cfg=VerifyConfig(verify="paranoid"))
    with pytest.raises(ValueError, match="search"):
        SaturatorConfig(search_cfg=SearchConfig(search="genetic"))


# -- from_env precedence ----------------------------------------------------
def _flags(**kw):
    ns = argparse.Namespace(cache_dir=None, no_cache=False, verify=None)
    for k, v in kw.items():
        setattr(ns, k, v)
    return ns


def test_from_env_default_is_off():
    cfg = SaturatorConfig.from_env(env={})
    assert cfg.cache_dir is None
    assert cfg.verify == "off"


def test_from_env_env_var_level():
    env = {CACHE_ENV_VAR: "/env/cache", VERIFY_ENV_VAR: "cheap"}
    cfg = SaturatorConfig.from_env(env=env)
    assert cfg.cache_dir == "/env/cache"
    assert cfg.verify == "cheap"


def test_from_env_flag_beats_env():
    env = {CACHE_ENV_VAR: "/env/cache", VERIFY_ENV_VAR: "cheap"}
    cfg = SaturatorConfig.from_env(
        flags=_flags(cache_dir="/flag/cache", verify="full"), env=env)
    assert cfg.cache_dir == "/flag/cache"
    assert cfg.verify == "full"


def test_from_env_explicit_beats_flag_and_env():
    env = {CACHE_ENV_VAR: "/env/cache", VERIFY_ENV_VAR: "cheap"}
    cfg = SaturatorConfig.from_env(
        cache_dir="/arg/cache", verify="off",
        flags=_flags(cache_dir="/flag/cache", verify="full"), env=env)
    assert cfg.cache_dir == "/arg/cache"
    assert cfg.verify == "off"


def test_from_env_no_cache_disables_even_with_env():
    env = {CACHE_ENV_VAR: "/env/cache"}
    cfg = SaturatorConfig.from_env(
        flags=_flags(cache_dir="/flag/cache", no_cache=True), env=env)
    assert cfg.cache_dir is False      # resolved --no-cache: cache off
    assert (cfg.cache_dir or None) is None


def test_from_env_accepts_mapping_flags_and_kwargs():
    cfg = SaturatorConfig.from_env(
        flags={"verify": "cheap"}, env={}, mode="cse",
        schedule_cfg=ScheduleConfig(schedule="source"))
    assert cfg.mode == "cse"
    assert cfg.schedule == "source"
    assert cfg.verify == "cheap"


def test_drivers_use_from_env():
    """Both launch drivers resolve their side-channels through the one
    front door (regression guard for ad-hoc os.environ reads)."""
    import inspect
    from repro.launch import serve, train
    assert "from_env" in inspect.getsource(serve.main)
    assert "from_env" in inspect.getsource(train.main)


def test_deprecation_lint_clean():
    """The repo's own code never uses the deprecated flat kwargs or the
    pre-registry generator class names (the CI lint step, run in-tree)."""
    import pathlib
    import subprocess
    import sys
    script = pathlib.Path(__file__).resolve().parents[1] / "tools" / \
        "deprecation_lint.py"
    out = subprocess.run([sys.executable, str(script)],
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr + out.stdout
