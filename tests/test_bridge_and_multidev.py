"""jaxpr bridge tests + multi-device subprocess tests (sharding rules and
pipeline parallelism run under XLA_FLAGS host-device counts in a child
process so the main test session keeps a single device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import BridgeUnsupported, maybe_saturate, saturate_jax_fn


def test_bridge_elementwise(rng):
    def f(x, y):
        t = x * y + x * y
        return t * jax.lax.logistic(t) + x * y

    x = jnp.ones((4, 64), jnp.float32)
    bk = saturate_jax_fn(f, (x, x))
    xa = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    ya = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    np.testing.assert_allclose(np.asarray(bk(xa, ya)),
                               np.asarray(f(xa, ya)), rtol=2e-5, atol=2e-5)
    # CSE found the shared x*y
    assert bk.sk.kernel.stats.n_ops < bk.n_eqns


def test_bridge_scalar_args(rng):
    def f(x, alpha):
        return x * alpha + x

    x = jnp.ones((8, 16), jnp.float32)
    bk = saturate_jax_fn(f, (x, jnp.float32(0.5)))
    xa = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    np.testing.assert_allclose(np.asarray(bk(xa, jnp.float32(2.0))),
                               np.asarray(f(xa, jnp.float32(2.0))),
                               rtol=1e-6)


def test_bridge_rejects_unsupported():
    def f(x):
        return jnp.sort(x)

    x = jnp.ones((8,), jnp.float32)
    with pytest.raises(BridgeUnsupported):
        saturate_jax_fn(f, (x,))
    fn, info = maybe_saturate(f, (x,))
    assert info is None and fn is f


_SUBPROC_SHARDING = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models import get_model
from repro.parallel import batch_specs, ctx, param_specs, to_named
from repro.launch import steps as S

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     devices=jax.devices()[:8])
cfg = get_smoke_config("minitron_4b")
model = get_model(cfg)
with ctx.activate(mesh):
    params = model.init(jax.random.PRNGKey(0))
    pspecs = param_specs(cfg, params, mesh, fsdp=True)
    psh = to_named(pspecs, mesh)
    params = jax.device_put(params, psh)
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
             "labels": jnp.zeros((4, 32), jnp.int32)}
    bsh = to_named(batch_specs(cfg, batch, mesh), mesh)
    batch = jax.device_put(batch, bsh)
    loss = jax.jit(model.loss, in_shardings=(psh, bsh))(params, batch)
    assert np.isfinite(float(loss)), loss
    # unsharded single-device loss must match the sharded one
    params_local = jax.device_get(params)
    loss_ref = model.loss(jax.tree.map(jnp.asarray, params_local),
                          jax.tree.map(jnp.asarray, jax.device_get(batch)))
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=2e-2)
print("SHARDED_OK", float(loss))
"""

_SUBPROC_PP = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline_pp import (make_stage_fn, pipeline_apply,
                                        split_layers_to_stages)

mesh = jax.make_mesh((4,), ("stage",), devices=jax.devices()[:4])
L, D, M, mb = 8, 16, 6, 4
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, D, D)) * 0.3

def layer_fn(w, x):
    return jnp.tanh(x @ w)

stage_params = split_layers_to_stages(ws, 4)
stage_fn = make_stage_fn(layer_fn)
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
out = pipeline_apply(mesh, stage_fn, 4, M, x, stage_params)
# reference: plain sequential stack
ref = x
for l in range(L):
    ref = jnp.tanh(ref @ ws[l])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-4, atol=2e-4)
print("PP_OK")
"""


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_loss_matches_single_device():
    assert "SHARDED_OK" in _run_sub(_SUBPROC_SHARDING)


@pytest.mark.slow
def test_pipeline_parallel_equivalence():
    assert "PP_OK" in _run_sub(_SUBPROC_PP)
