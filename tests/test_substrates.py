"""Checkpointing, data pipeline, compression, optimizer, fault tolerance."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, ShardedTokenPipeline
from repro.optim import OptConfig, apply_updates, init_opt_state, lr_at
from repro.parallel.compression import Compressor
from repro.runtime import FailureInjector, FailureEvent


# -- checkpoint ----------------------------------------------------------------
def _tree(rng):
    return {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.bfloat16),
            "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32),
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path, rng):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ck.save(3, tree, extra={"step": 3}, async_=False)
    restored, extra = ck.restore(tree)
    assert extra["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_async_and_latest(tmp_path, rng):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ck.save(1, tree, async_=True)
    ck.save(5, tree, async_=True)
    ck.wait()
    assert ck.latest_step() == 5


def test_checkpoint_atomicity(tmp_path, rng):
    """An uncommitted (torn) checkpoint is never restored."""
    ck = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ck.save(1, tree, async_=False)
    # simulate a crash mid-save of step 2: files but no commit marker
    d = tmp_path / "step_000000002"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert ck.latest_step() == 1


def test_checkpoint_elastic_restore(tmp_path, rng):
    """Saved from 4 hosts, restored anywhere (N→M resharding)."""
    ck = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    for h in range(4):
        ck.save(2, tree, host_id=h, n_hosts=4, async_=False)
    restored, _ = ck.restore(tree)
    np.testing.assert_array_equal(
        np.asarray(tree["w"], np.float32),
        np.asarray(restored["w"], np.float32))


# -- data pipeline -------------------------------------------------------------------
def test_pipeline_deterministic():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    p1 = ShardedTokenPipeline(cfg)
    p2 = ShardedTokenPipeline(cfg)
    b1 = p1.batch_at(11)
    b2 = p2.batch_at(11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_pipeline_sharding_partitions_batch():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=0)
    full = ShardedTokenPipeline(cfg).batch_at(5)["tokens"]
    parts = []
    for sh in range(4):
        p = ShardedTokenPipeline(
            DataConfig(vocab=100, seq_len=8, global_batch=8, seed=0,
                       shard_id=sh, num_shards=4))
        parts.append(p.batch_at(5)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_pipeline_reshard_view():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=0,
                     shard_id=0, num_shards=4)
    p = ShardedTokenPipeline(cfg)
    p2 = p.reshard(1, 2)
    assert p2.local_batch == 4
    np.testing.assert_array_equal(
        p2.batch_at(0)["tokens"],
        ShardedTokenPipeline(DataConfig(vocab=100, seq_len=8,
                                        global_batch=8, seed=0,
                                        shard_id=1, num_shards=2))
        .batch_at(0)["tokens"])


def test_pipeline_prefetch():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=4, seed=0)
    p = ShardedTokenPipeline(cfg)
    p.start(start_step=0)
    try:
        b = p.next_prefetched()
        np.testing.assert_array_equal(b["tokens"], p.batch_at(0)["tokens"])
    finally:
        p.stop()


# -- compression --------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["bf16", "int8", "int8_ef"])
def test_compression_roundtrip_error(mode, rng):
    grads = {"a": jnp.asarray(rng.normal(size=(16, 32)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    comp = Compressor(mode)
    state = comp.init_state(grads)
    c, state = comp.compress(grads, state)
    back = comp.decompress(c)
    for k in grads:
        rel = np.abs(np.asarray(back[k]) - np.asarray(grads[k])).max() \
            / np.abs(np.asarray(grads[k])).max()
        assert rel < (0.01 if mode == "bf16" else 0.02)


def test_error_feedback_reduces_bias(rng):
    """With EF, the *accumulated* compressed sum tracks the true sum."""
    comp_ef = Compressor("int8_ef")
    comp_plain = Compressor("int8")
    g = {"w": jnp.asarray(rng.normal(size=(4, 64)) * 1e-3, jnp.float32)}
    state = comp_ef.init_state(g)
    tot_ef = np.zeros((4, 64), np.float32)
    tot_plain = np.zeros((4, 64), np.float32)
    tot_true = np.zeros((4, 64), np.float32)
    for t in range(30):
        gt = {"w": g["w"] * (1.0 + 0.1 * t)}
        c_ef, state = comp_ef.compress(gt, state)
        tot_ef += np.asarray(comp_ef.decompress(c_ef)["w"])
        c_p, _ = comp_plain.compress(gt, None)
        tot_plain += np.asarray(comp_plain.decompress(c_p)["w"])
        tot_true += np.asarray(gt["w"])
    err_ef = np.abs(tot_ef - tot_true).mean()
    err_plain = np.abs(tot_plain - tot_true).mean()
    assert err_ef <= err_plain * 1.05


def test_compression_wire_bytes(rng):
    g = {"w": jnp.zeros((128, 256), jnp.float32)}
    assert Compressor("none").wire_bytes(g) == 128 * 256 * 4
    assert Compressor("bf16").wire_bytes(g) == 128 * 256 * 2
    assert Compressor("int8").wire_bytes(g) == 128 * 256 + 4 * 128


# -- optimizer -----------------------------------------------------------------------
@pytest.mark.parametrize("moment_dtype", ["f32", "bf16", "int8"])
def test_adamw_step_moves_params(moment_dtype, rng):
    cfg = OptConfig(lr=1e-2, warmup_steps=1, total_steps=10,
                    moment_dtype=moment_dtype)
    params = {"w": jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)}
    grads = {"w": jnp.ones((8, 128), jnp.float32)}
    state = init_opt_state(params, cfg)
    p2, s2 = apply_updates(params, grads, state, cfg)
    assert int(s2["step"]) == 1
    delta = np.asarray(p2["w"] - params["w"])
    assert (delta < 0).all()            # positive grads move params down


def test_adamw_matches_reference_trajectory(rng):
    """int8 moments track f32 within quantization tolerance over steps."""
    k = {"w": jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)}
    cfgs = {d: OptConfig(lr=1e-2, warmup_steps=1, total_steps=50,
                         moment_dtype=d) for d in ("f32", "int8")}
    ps = {d: dict(k) for d in cfgs}
    ss = {d: init_opt_state(k, c) for d, c in cfgs.items()}
    for t in range(10):
        g = {"w": jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)}
        for d, c in cfgs.items():
            ps[d], ss[d] = apply_updates(ps[d], g, ss[d], c)
    diff = np.abs(np.asarray(ps["f32"]["w"] - ps["int8"]["w"])).max()
    scale = np.abs(np.asarray(ps["f32"]["w"])).max()
    assert diff / scale < 0.05


def test_lr_schedule():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(lr_at(jnp.int32(0), cfg)) == 0.0
    assert float(lr_at(jnp.int32(10), cfg)) == pytest.approx(1.0, abs=1e-3)
    assert float(lr_at(jnp.int32(100), cfg)) == pytest.approx(0.1, abs=1e-3)


# -- fault injection -------------------------------------------------------------------
def test_failure_injector_fires_once():
    inj = FailureInjector({3: ("node_loss", 2)})
    inj.check(0)
    with pytest.raises(FailureEvent) as ei:
        inj.check(3)
    assert ei.value.lost_hosts == 2
    inj.check(3)  # does not re-fire
