"""Mutation tests for the static verifier (PR 7 satellite): seed one
unsound rule, one illegal statement order, and one out-of-bounds index,
and require each pass to report *exactly that* finding — no false
silence on the defect, no false alarms on the clean artifact."""
import copy

import pytest

from repro.core import (KernelProgram, SaturatorConfig, compute_schedule,
                        rmean, rsqrt, saturate_program)
from repro.core.egraph import P, V
from repro.core.rules import (EXTENDED_RULES, PAPER_RULES, TPU_RULES, Rule)
from repro.verify import (check_generated, shapes_of, verify_rules,
                          verify_schedule)

A, B = V("a"), V("b")


def _rms_prog():
    p = KernelProgram("mut_rms")
    x = p.array_in("x", shape=(8, 128))
    g = p.array_in("g", shape=(1, 128))
    p.array_out("o", shape=(8, 128))
    eps = p.scalar("eps")
    xv = x.load()
    p.store("o", xv * rsqrt(rmean(xv * xv) + eps) * g.load())
    return p


# -- defect 1: unsound rule ---------------------------------------------------
def test_seeded_unsound_rule_caught_exactly():
    bad = Rule("BAD-ADDSUB", P("add", A, B), P("sub", A, B))
    res = verify_rules([bad])
    errs = [f for f in res.findings if f.severity == "error"]
    assert len(errs) == 1
    assert errs[0].code == "unsound-rule" and errs[0].subject == "BAD-ADDSUB"
    # the defect is caught on the first (ordinary-math) tier: add vs sub
    # differ at O(1) on well-conditioned inputs
    assert "random" in errs[0].message


def test_seeded_rule_among_clean_suite_is_the_only_finding():
    bad = Rule("BAD-MULDIV", P("mul", A, B), P("div", A, B))
    res = verify_rules(list(PAPER_RULES) + [bad] + list(TPU_RULES))
    errs = [f for f in res.findings if f.severity == "error"]
    assert [f.subject for f in errs] == ["BAD-MULDIV"]


# -- defect 2: illegal statement order ---------------------------------------
@pytest.fixture(scope="module")
def rms_build():
    prog = _rms_prog()
    sk = saturate_program(prog, SaturatorConfig(mode="accsat"))
    sched = compute_schedule(sk.ssa, dict(sk.extraction.choice),
                             mode="source", move_budget=0)
    return sk, sched


def test_legal_order_certifies_clean(rms_build):
    sk, sched = rms_build
    res = verify_schedule(sk.ssa, sk.extraction.choice, sched)
    assert res.ok, [str(f) for f in res.findings]
    assert res.regions_certified == res.regions_checked > 0


def test_seeded_illegal_order_caught_exactly(rms_build):
    """Swapping one dependent (producer, consumer) adjacent pair must
    produce exactly one illegal-order error — the misplaced consumer."""
    sk, sched = rms_build
    base_order = list(sched.regions[()].order)
    seen_exact = 0
    for i in range(len(base_order) - 1):
        mut = copy.deepcopy(sched)
        o = mut.regions[()].order
        o[i], o[i + 1] = o[i + 1], o[i]
        res = verify_schedule(sk.ssa, sk.extraction.choice, mut)
        errs = [f for f in res.findings if f.severity == "error"]
        if errs:
            # an adjacent swap can only break the swapped consumer
            assert len(errs) == 1
            assert errs[0].code == "illegal-order"
            seen_exact += 1
    # the source order of this kernel has at least one adjacent
    # dependent pair (each load feeds the next compute)
    assert seen_exact >= 1


def test_dropped_unit_caught(rms_build):
    sk, sched = rms_build
    mut = copy.deepcopy(sched)
    rs = mut.regions[()]
    rs.order = [u for u in rs.order[:-1]]
    res = verify_schedule(sk.ssa, sk.extraction.choice, mut)
    assert [f.code for f in res.findings] == ["not-a-permutation"]


# -- defect 3: out-of-bounds index -------------------------------------------
def _oob_prog():
    p = KernelProgram("mut_oob")
    x = p.array_in("x", shape=(8, 128))
    p.array_out("o", shape=(8, 128))
    p.store("o", x[999, 0] + x.load())   # row 999 of an 8-row tile
    return p


def test_seeded_oob_index_caught_exactly():
    prog = _oob_prog()
    sk = saturate_program(prog, SaturatorConfig(mode="accsat"))
    findings = check_generated(sk.kernel.source, shapes_of(prog))
    errs = [f for f in findings if f.severity == "error"]
    assert len(errs) == 1
    assert errs[0].code == "oob-index"
    assert "999" in errs[0].message and "extent 8" in errs[0].message


def test_clean_kernels_no_codegen_errors():
    for mk in (_rms_prog, ):
        prog = mk()
        sk = saturate_program(prog, SaturatorConfig(mode="accsat"))
        findings = check_generated(sk.kernel.source, shapes_of(prog))
        assert not [f for f in findings if f.severity == "error"], \
            [str(f) for f in findings]


# -- defect 4 (bonus): corrupted e-graph -------------------------------------
def test_corrupted_union_find_caught():
    from repro.core.egraph import EGraph, add_expr
    eg = EGraph()
    add_expr(eg, ("add", ("var", "a"), ("mul", ("var", "b"), ("var", "c"))))
    assert not [f for f in eg.check_invariants()
                if f.severity == "error"]
    # point two roots at each other: a union-find cycle
    eg.uf.parent[0] = 1
    eg.uf.parent[1] = 0
    findings = eg.check_invariants()
    assert any(f.code == "uf-cycle" for f in findings)
    with pytest.raises(AssertionError):
        eg.check_invariants(strict=True)


def test_stale_hashcons_caught():
    from repro.core.egraph import EGraph, add_expr
    eg = EGraph()
    add_expr(eg, ("add", ("var", "a"), ("var", "b")))
    node = next(iter(eg.hashcons))
    eg.hashcons[node] = len(eg.uf.parent) + 7   # out-of-range class id
    assert any(f.code == "hashcons-out-of-range"
               for f in eg.check_invariants())
