"""HLO analyzer: trip-count-aware flop/byte/collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.roofline.hlo_analysis import (analyze, execution_counts,
                                         parse_hlo)


def test_scan_flops_exact():
    D = 128
    L = 8

    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return lax.scan(body, x, ws)[0]

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    rep = analyze(comp.as_text())
    assert rep.dot_flops == pytest.approx(L * 2 * D ** 3, rel=1e-6)
    assert L in rep.trip_counts


def test_nested_scan_flops():
    D = 64

    def f(x, ws):
        def outer(h, wgroup):
            def inner(hh, w):
                return hh @ w, None
            return lax.scan(inner, h, wgroup)[0], None
        return lax.scan(outer, x, ws)[0]

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32),
        jax.ShapeDtypeStruct((3, 4, D, D), jnp.float32)).compile()
    rep = analyze(comp.as_text())
    assert rep.dot_flops == pytest.approx(12 * 2 * D ** 3, rel=1e-6)


def test_xla_cost_analysis_undercounts_scan():
    """The reason this module exists: XLA counts the while body once."""
    D = 64
    L = 8

    def f(x, ws):
        def body(h, w):
            return h @ w, None
        return lax.scan(body, x, ws)[0]

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax returns [dict]
        ca = ca[0]
    xla_flops = ca["flops"]
    ours = analyze(comp.as_text()).dot_flops
    assert xla_flops == pytest.approx(2 * D ** 3, rel=1e-3)   # 1 layer!
    assert ours == pytest.approx(L * 2 * D ** 3, rel=1e-3)    # L layers


FAKE = """\
ENTRY %main (a: f32[1024,1024]) -> f32[1024,1024] {
  %a = f32[1024,1024]{1,0} parameter(0)
  %ag = bf16[64,2048]{1,0} all-gather(%a), replica_groups=[32,8]<=[256], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%a), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %ar = f32[1024,1024]{1,0} all-reduce(%a), replica_groups=[16,16]<=[256], to_apply=%add
}
"""


def test_collective_wire_math():
    rep = analyze(FAKE)
    ar = 2 * 1024 * 1024 * 4 * (15 / 16)
    ag = 64 * 2048 * 2 * (7 / 8)
    rs = 8 * 128 * 4 * 3
    assert rep.collective_breakdown["all-reduce"] == pytest.approx(ar)
    assert rep.collective_breakdown["all-gather"] == pytest.approx(ag)
    assert rep.collective_breakdown["reduce-scatter"] == pytest.approx(rs)
    assert rep.collective_wire_bytes == pytest.approx(ar + ag + rs)


def test_top_collectives():
    rep = analyze(FAKE)
    top = rep.top_collectives(2)
    assert top[0][0] == "all-reduce"
    assert len(top) == 2


def test_execution_counts_fixed_point():
    comps = parse_hlo(FAKE)
    counts = execution_counts(comps)
    assert counts["main"] == 1.0


def test_fusion_bodies_not_double_counted():
    D = 256

    def f(x):
        return jnp.tanh(x) * 2.0 + 1.0   # fuses into one kernel

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32)).compile()
    rep = analyze(comp.as_text())
    # traffic ~ read + write of (D,D) f32, not per-op
    assert rep.hbm_bytes <= 4 * D * D * 4
