"""Rule-soundness pass (PR 7): the built-in rule sets are clean, the
finite-math gates are documented info notes, and the structural lint
catches malformed rules."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.egraph import P, V
from repro.core.rules import (EXTENDED_RULES, PAPER_RULES, TPU_RULES, Rule)
from repro.verify import verify_rules

A, B, C = V("a"), V("b"), V("c")


# -- clean suites ------------------------------------------------------------
@pytest.mark.parametrize("rules", [PAPER_RULES, EXTENDED_RULES, TPU_RULES],
                         ids=["paper", "extended", "tpu"])
def test_builtin_rules_zero_errors(rules):
    res = verify_rules(rules)
    assert res.rules_checked == len(rules)
    errors = [f for f in res.findings if f.severity == "error"]
    assert errors == [], [str(f) for f in errors]


def test_finite_math_rules_are_gated_info():
    """The reassociation and div<->recip rules fail the adversarial tier
    (overflow / denormal divisors) but carry the documented
    finite_math=True gate — reported as info, never error."""
    res = verify_rules(PAPER_RULES + EXTENDED_RULES)
    gated = {f.subject for f in res.findings
             if f.code == "finite-math-gated"}
    assert {"ASSOC-ADD1", "ASSOC-ADD2", "ASSOC-MUL1",
            "ASSOC-MUL2"} <= gated
    assert {"DIV-AS-RECIP", "RECIP-AS-DIV"} <= gated
    for f in res.findings:
        if f.code == "finite-math-gated":
            assert f.severity == "info"
    # exact-value rules must not need the gate
    flagged = {r.name for r in PAPER_RULES + EXTENDED_RULES
               if r.finite_math}
    assert "COMM-ADD" not in flagged and "FMA1" not in flagged


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100_000))
def test_extended_rules_sound_across_seeds(seed):
    """Satellite: differential validation of EXTENDED_RULES under the
    (shimmed) hypothesis sweep — sound at every seed, not just seed 0."""
    res = verify_rules(EXTENDED_RULES, n_random=16, seed=seed)
    assert not [f for f in res.findings if f.severity == "error"]


def test_deterministic_across_runs():
    a = verify_rules(PAPER_RULES)
    b = verify_rules(PAPER_RULES)
    assert [str(f) for f in a.findings] == [str(f) for f in b.findings]


def test_every_evaluable_rule_checked_under_envs():
    res = verify_rules(PAPER_RULES)
    for rec in res.records:
        assert rec.envs_checked > 0, rec.name


# -- structural lint ---------------------------------------------------------
def test_lint_unbound_rhs_var():
    res = verify_rules([Rule("UNBOUND", P("add", A, B), P("add", A, C))])
    codes = [f.code for f in res.findings if f.severity == "error"]
    assert codes == ["unbound-rhs-var"]


def test_lint_catchall_lhs():
    res = verify_rules([Rule("CATCHALL", A, P("neg", P("neg", A)))])
    assert "catchall-lhs" in [f.code for f in res.findings]


def test_lint_unknown_op_and_arity():
    res = verify_rules([
        Rule("NOOP", P("frobnicate", A), A),
        Rule("ARITY", P("add", A, B, C), P("add", A, B)),
    ])
    codes = {f.subject: f.code for f in res.findings
             if f.severity == "error"}
    assert codes == {"NOOP": "unknown-op", "ARITY": "bad-arity"}


def test_lint_structural_op_warns():
    res = verify_rules([Rule("LOADRW", P("load", A), P("load", A))])
    assert "structural-op" in [f.code for f in res.findings
                               if f.severity == "warning"]


# -- growth classification ----------------------------------------------------
def test_growth_classification():
    res = verify_rules(PAPER_RULES + EXTENDED_RULES)
    growth = {r.name: r.growth for r in res.records}
    assert growth["FMA1"] == "contracting"     # add+mul -> fma
    assert growth["COMM-ADD"] == "neutral"
    assert growth["SUB-AS-ADDNEG"] == "expanding"
    assert growth["NEG-NEG"] == "contracting"  # neg(neg(a)) -> a
    assert growth["SQUARE"] == "neutral"       # mul(a,a) -> square(a)


# -- differential sensitivity -------------------------------------------------
def test_ungated_reassociation_is_an_error():
    """The same ASSOC rewrite without the finite_math flag must be
    reported as an unsound-rule error by the adversarial tier."""
    bare = Rule("ASSOC-NOGATE", P("add", A, P("add", B, C)),
                P("add", P("add", A, B), C))      # finite_math=False
    res = verify_rules([bare])
    errs = [f for f in res.findings if f.severity == "error"]
    assert len(errs) == 1 and errs[0].code == "unsound-rule"
