"""Shared test utilities: random term generation + reference evaluation."""
from __future__ import annotations

import numpy as np

from repro.core.ir import EVAL_FNS

VARS = ("a", "b", "c", "d")


def eval_term(term, env):
    """Evaluate a nested-tuple term with numpy semantics."""
    op = term[0]
    if op == "const":
        return term[1]
    if op == "var":
        return env[term[1]]
    if op == "call":
        raise NotImplementedError
    args = [eval_term(t, env) for t in term[1:]]
    return EVAL_FNS[op](*args)


def random_term(rng: np.random.Generator, depth: int,
                ops=("add", "sub", "mul", "fma", "neg")):
    """Random expression over VARS + small constants (mul/add/sub/fma/neg
    — the closure the paper's rule set touches)."""
    if depth <= 0 or rng.random() < 0.3:
        if rng.random() < 0.3:
            return ("const", float(rng.integers(-3, 4)))
        return ("var", VARS[rng.integers(0, len(VARS))])
    op = ops[rng.integers(0, len(ops))]
    if op == "neg":
        return ("neg", random_term(rng, depth - 1, ops))
    if op == "fma":
        return ("fma", random_term(rng, depth - 1, ops),
                random_term(rng, depth - 1, ops),
                random_term(rng, depth - 1, ops))
    return (op, random_term(rng, depth - 1, ops),
            random_term(rng, depth - 1, ops))


def random_env(rng: np.random.Generator):
    return {v: float(rng.normal()) for v in VARS}
