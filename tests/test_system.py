"""End-to-end system behaviour: the paper's claims validated on the
framework level (benchmark ablation direction, saturated kernels inside a
real train step, dry-run artifacts)."""
import json
import pathlib

import numpy as np
import jax
import pytest

from repro.core import MODES, SaturatorConfig, saturate_all_modes


@pytest.mark.slow
def test_paper_claim_direction_on_suite():
    """ACCSAT never worse than CSE, CSE never worse than baseline, on the
    paper cost model — the Fig. 2 ordering."""
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).parents[1]))
    from benchmarks.kernel_suite import SUITE
    for name, mk in SUITE.items():
        ks = saturate_all_modes(mk())
        base = ks["baseline"].kernel.stats
        cse = ks["cse"].kernel.stats
        acc = ks["accsat"].kernel.stats
        assert cse.n_loads <= base.n_loads, name
        assert cse.n_ops <= base.n_ops, name
        assert ks["accsat"].extraction.dag_cost <= \
            ks["cse"].extraction.dag_cost + 1e-9, name
        # SAT forms FMAs somewhere in the suite
    total_fma = sum(saturate_all_modes(mk())["accsat"].kernel.stats.n_fma
                    for mk in list(SUITE.values())[:3])
    assert total_fma > 0


def test_ep_fma_like_paper():
    """Paper §VIII: EP executes more FMA and fewer total ops under SAT."""
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).parents[1]))
    from benchmarks.kernel_suite import ep_like
    ks = saturate_all_modes(ep_like())
    assert ks["cse_sat"].kernel.stats.n_fma > ks["cse"].kernel.stats.n_fma
    assert ks["cse_sat"].kernel.stats.n_ops < ks["cse"].kernel.stats.n_ops


@pytest.mark.slow
def test_saturated_kernels_run_inside_jitted_train_step(tmp_path):
    """The saturator's generated code is live inside the real train path
    (rmsnorm/swiglu/rotary/adamw all route through generated kernels)."""
    from repro.launch.train import build_trainer
    tr = build_trainer("zamba2-2.7b", smoke=True, steps=4, batch=2,
                       seq=32, ckpt_dir=str(tmp_path))
    out = tr.run()
    assert np.isfinite(out["losses"]).all()


def test_dryrun_artifacts_complete():
    """All 40 cells × 2 meshes are present: ok or documented skip."""
    d = pathlib.Path(__file__).parents[1] / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run artifacts not generated yet")
    files = list(d.glob("*.json"))
    if len(files) < 80:
        pytest.skip(f"dry-run sweep incomplete ({len(files)}/80)")
    bad = []
    for p in files:
        doc = json.loads(p.read_text())
        if doc.get("status") == "error":
            bad.append(p.stem)
        elif doc.get("status") == "skipped":
            assert "quadratic" in doc["reason"]
    assert not bad, bad


# Cells on the two largest models that remain above 16 GiB/device after
# the §Perf iterations; each has a root-cause + next-lever analysis in
# EXPERIMENTS.md §Open items (deferred grad reduction, int8 KV cache,
# activation offload / PP). This guard pins the set so regressions on the
# 57 fitting cells are caught.
KNOWN_OVER_HBM = {
    "arctic_480b_decode_32k_sp", "arctic_480b_prefill_32k_sp",
    "arctic_480b_train_4k_sp", "arctic_480b_train_4k_mp",
    "mistral_large_123b_prefill_32k_sp",
    "mistral_large_123b_train_4k_sp",
}


def test_dryrun_memory_fits():
    d = pathlib.Path(__file__).parents[1] / "experiments" / "dryrun"
    if not d.exists() or len(list(d.glob("*.json"))) < 80:
        pytest.skip("dry-run artifacts incomplete")
    over = []
    for p in d.glob("*.json"):
        doc = json.loads(p.read_text())
        if doc.get("status") == "ok" and \
                not doc["roofline"]["fits_hbm"]:
            over.append(p.stem)
    unexpected = set(over) - KNOWN_OVER_HBM
    assert not unexpected, f"NEW cells over HBM: {sorted(unexpected)}"
