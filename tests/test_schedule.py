"""Schedule-aware codegen (PR 5): legality fuzz, named-order properties,
the cost-driven scheduler, and the schedule-aware calibration formula.

The legality property is the load-bearing one: any *legal topological
order* of the dependence DAG (loads/stores never crossing a dependence
or store-store/WAR hazard) must emit a kernel whose outputs are
bit-identical to the bulk-ordered kernel — reordering independent
statements never changes the arithmetic DAG — and numerically match the
reference interpreter.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.analysis import (CalibrationParams, KernelFeatures, LatencyModel,
                            RooflineCostModel, fit_params, predict_ns)
from repro.analysis.latency import ScheduleEvent
from repro.core import (KernelProgram, SaturatorConfig, ScheduleConfig, c,
                        compute_schedule, is_legal_order,
                        random_topological_order, run_reference,
                        saturate_program, v)
from repro.core.codegen import JaxCodeGenerator
from repro.core.schedule import SCHEDULE_MODES
from repro.kernels.tile_programs import PROGRAMS

TILE_NAMES = ("rmsnorm", "adamw", "layernorm", "ssd_gate", "sgd_momentum")


def _tile_inputs(prog, seed=0):
    from repro.analysis import TILE_SHAPE
    rng = np.random.default_rng(seed)
    arrays = []
    for spec in prog.arrays.values():
        shape = getattr(spec, "shape", None) or TILE_SHAPE
        shape = tuple(TILE_SHAPE[i] if d is None else int(d)
                      for i, d in enumerate(shape))
        arrays.append(rng.uniform(0.1, 1.0, size=shape).astype(np.float32))
    scalars = {s: 0.5 for s in prog.scalars}
    return arrays, scalars


def _run_jax_kernel(sk, kernel, prog):
    arrays, scalars = _tile_inputs(prog)
    args = [jnp.asarray(a) for a in arrays] \
        + [scalars[s] for s in kernel.scalars]
    out = kernel.fn(*args)
    return [np.asarray(o) for o in out]


def _randomized(sr, rng):
    regions = {p: dataclasses.replace(
        rs, order=random_topological_order(rs.units, rng))
        for p, rs in sr.regions.items()}
    return dataclasses.replace(sr, regions=regions)


# -- legality fuzz: random legal topological orders -------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_legal_orders_bit_identical(seed):
    """Any random legal topological order of the dependence DAG emits a
    kernel bit-identical to the bulk-scheduled one (and both match the
    reference interpreter numerically)."""
    rng = np.random.default_rng(seed)
    name = TILE_NAMES[int(rng.integers(len(TILE_NAMES)))]
    sk = saturate_program(PROGRAMS[name](), SaturatorConfig(mode="accsat"))
    ref_out = _run_jax_kernel(sk, sk.kernel, sk.ssa.prog)
    sr = compute_schedule(sk.ssa, dict(sk.extraction.choice), mode="cost")
    for rs in sr.regions.values():
        assert is_legal_order(rs.units, rs.order)
    rnd = _randomized(sr, rng)
    for rs in rnd.regions.values():
        assert is_legal_order(rs.units, rs.order)
    gen = JaxCodeGenerator(sk.ssa, sk.extraction, schedule=rnd)
    k = gen.generate()
    out = _run_jax_kernel(sk, k, sk.ssa.prog)
    for a, b in zip(ref_out, out):
        assert (a == b).all(), "schedule changed kernel outputs"


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_orders_match_reference_interpreter(seed):
    """Randomly ordered kernels still agree with the reference
    interpreter (float32 numerics, so allclose not bitwise vs numpy)."""
    rng = np.random.default_rng(seed)
    name = TILE_NAMES[int(rng.integers(len(TILE_NAMES)))]
    prog = PROGRAMS[name]()
    sk = saturate_program(prog, SaturatorConfig(mode="accsat"))
    sr = compute_schedule(sk.ssa, dict(sk.extraction.choice), mode="cost")
    rnd = _randomized(sr, rng)
    k = JaxCodeGenerator(sk.ssa, sk.extraction, schedule=rnd).generate()
    arrays, scalars = _tile_inputs(prog)
    inputs = {}
    ai = iter(arrays)
    for spec in prog.arrays.values():
        if spec.role in ("in", "inout"):
            inputs[spec.name] = next(ai)
        else:
            inputs[spec.name] = np.zeros_like(arrays[0])
    inputs.update(scalars)
    ref = run_reference(prog, {k_: (v_.copy() if isinstance(v_, np.ndarray)
                                    else v_) for k_, v_ in inputs.items()})
    args = [jnp.asarray(inputs[n]) for n in k.in_arrays] \
        + [scalars[s] for s in k.scalars]
    out = k.fn(*args)
    for o, name_ in zip(out, k.out_arrays):
        # float32 kernel vs the numpy interpreter: allclose, not bitwise
        np.testing.assert_allclose(np.asarray(o), ref[name_],
                                   rtol=1e-4, atol=1e-6)


def test_loop_kernel_random_orders(rng):
    """Legality fuzz through a loop region (loads, a loop unit, and
    stores that must respect the loop's version chain)."""
    p = KernelProgram("loopy")
    x = p.array_in("x")
    p.array_out("o")
    n = p.scalar("n")
    i = p.scalar("i")
    p.let("acc", c(0.0))
    with p.for_("l", 0, v("n")):
        p.let("acc", v("acc") + x[v("l")] * x[v("l")])
    p.store("o", v("acc") * x[v("i")], v("i"))
    sk = saturate_program(p, SaturatorConfig(mode="accsat"))
    X = rng.normal(size=(6,)).astype(np.float32)
    base_out = np.asarray(sk(jnp.asarray(X), jnp.zeros(6, np.float32),
                             6, 2)[0])
    sr = compute_schedule(sk.ssa, dict(sk.extraction.choice), mode="cost")
    for seed in range(5):
        rnd = _randomized(sr, np.random.default_rng(seed))
        k = JaxCodeGenerator(sk.ssa, sk.extraction, schedule=rnd).generate()
        out = np.asarray(k.fn(jnp.asarray(X), jnp.zeros(6, np.float32),
                              6, 2)[0])
        assert (out == base_out).all()


# -- named orders -----------------------------------------------------------
@pytest.mark.parametrize("name", TILE_NAMES)
def test_named_orders_are_legal_and_ranked(name):
    """cost <= bulk <= source in predicted schedule latency (analytic
    model; the bench-regression CI leg enforces the same invariant)."""
    sk = saturate_program(PROGRAMS[name](), SaturatorConfig(mode="accsat"))
    sr = compute_schedule(sk.ssa, dict(sk.extraction.choice), mode="cost")
    by = sr.predicted_by_mode
    assert by["cost"] <= by["bulk"] + 1e-9
    assert by["bulk"] <= by["source"] + 1e-9
    for mode in SCHEDULE_MODES:
        sr_m = compute_schedule(sk.ssa, dict(sk.extraction.choice),
                                mode=mode)
        for rs in sr_m.regions.values():
            assert is_legal_order(rs.units, rs.order)


def test_bulk_schedule_bit_identical_sources():
    """schedule="bulk" reproduces the legacy bulk emitter's sources
    bit-for-bit (the paper-baseline modes never drift)."""
    for name in ("rmsnorm", "adamw", "softmax"):
        legacy = saturate_program(PROGRAMS[name](),
                                  SaturatorConfig(mode="accsat"))
        sched = saturate_program(
            PROGRAMS[name](),
            SaturatorConfig(mode="accsat",
                            schedule_cfg=ScheduleConfig(schedule="bulk")))
        assert legacy.kernel.source == sched.kernel.source
        assert sched.kernel.schedule_mode == "bulk"


def test_source_schedule_matches_nonbulk_legacy():
    """schedule="source" under accsat equals the legacy bulk=False
    emission (loads at use sites)."""
    sk = saturate_program(
        PROGRAMS["rmsnorm"](),
        SaturatorConfig(mode="accsat",
                        schedule_cfg=ScheduleConfig(schedule="source")))
    gen = JaxCodeGenerator(sk.ssa, sk.extraction, bulk=False)
    assert sk.kernel.source == gen.generate().source


def test_cost_schedule_outputs_match_bulk():
    for name in TILE_NAMES:
        bulk = saturate_program(PROGRAMS[name](),
                                SaturatorConfig(mode="accsat"))
        cost = saturate_program(
            PROGRAMS[name](),
            SaturatorConfig(mode="accsat",
                            schedule_cfg=ScheduleConfig(schedule="cost")))
        a = _run_jax_kernel(bulk, bulk.kernel, bulk.ssa.prog)
        b = _run_jax_kernel(cost, cost.kernel, cost.ssa.prog)
        for x, y in zip(a, b):
            assert (x == y).all()
        assert cost.kernel.schedule is not None
        assert cost.report()["schedule"] == "cost"


def test_invalid_schedule_mode_rejected():
    with pytest.raises(ValueError, match="schedule"):
        SaturatorConfig(mode="accsat",
                        schedule_cfg=ScheduleConfig(schedule="random"))
    sk = saturate_program(PROGRAMS["rmsnorm"](), SaturatorConfig())
    with pytest.raises(ValueError, match="schedule"):
        JaxCodeGenerator(sk.ssa, sk.extraction, schedule="zigzag")


# -- the schedule-aware objective -------------------------------------------
def test_schedule_ns_overlap_is_position_dependent():
    """A load issued far before its consumer hides its transfer; the
    same load issued right before it stalls."""
    lm = LatencyModel()
    load = ScheduleEvent(kind="load", issue_ns=0.0, mem_ns=10.0,
                         bytes_live=4096.0, first_use=2, last_use=2)
    comp = ScheduleEvent(kind="compute", issue_ns=20.0)
    use = ScheduleEvent(kind="compute", issue_ns=1.0)
    hidden = lm.schedule_ns([load, comp, use])
    load_late = dataclasses.replace(load, first_use=1)
    exposed = lm.schedule_ns([comp, load_late, use])
    assert hidden["exposed_mem_ns"] == pytest.approx(0.0)
    assert exposed["exposed_mem_ns"] == pytest.approx(10.0)
    assert exposed["latency_ns"] > hidden["latency_ns"]


def test_schedule_ns_vmem_pressure_term():
    lm = LatencyModel(vmem_pressure_coeff=1.0)
    ev = [ScheduleEvent(kind="load", issue_ns=0.0, mem_ns=1.0,
                        bytes_live=2048.0, first_use=2, last_use=2),
          ScheduleEvent(kind="load", issue_ns=0.0, mem_ns=1.0,
                        bytes_live=2048.0, first_use=2, last_use=2),
          ScheduleEvent(kind="compute", issue_ns=1.0)]
    over = lm.schedule_ns(ev, vmem_budget_bytes=1024)
    under = lm.schedule_ns(ev, vmem_budget_bytes=1 << 20)
    assert over["peak_live_bytes"] == pytest.approx(4096.0)
    assert over["pressure_ns"] > 0.0
    assert under["pressure_ns"] == 0.0


def test_pressure_drives_scheduler_to_sink_loads():
    """With a tiny VMEM budget and a live pressure coefficient, the cost
    scheduler reduces the peak live set vs the bulk order (loads sink
    toward their consumers)."""
    lm = LatencyModel(vmem_pressure_coeff=10.0, overlap_efficiency=1.0)
    sk = saturate_program(PROGRAMS["adamw"](), SaturatorConfig(mode="accsat"))
    cm = RooflineCostModel(latency=lm, egraph=sk.ssa.egraph)
    sr = compute_schedule(sk.ssa, dict(sk.extraction.choice), mode="cost",
                          cost_model=cm, vmem_budget_bytes=4096)
    bulk = compute_schedule(sk.ssa, dict(sk.extraction.choice), mode="bulk",
                            cost_model=cm, vmem_budget_bytes=4096)
    assert sr.peak_live_bytes < bulk.peak_live_bytes


def test_latency_ns_overlap_efficiency_reduces_to_pr4():
    """eff=0 is bit-identical to the PR-4 aggregate formula; eff>0 can
    only lower the prediction (memory gets hidden, never added)."""
    from repro.analysis import OpStats
    st_ = OpStats(flops=1024.0, bytes_read=8192.0, vpu_passes=4.0)
    base = LatencyModel()
    zero = LatencyModel(overlap_efficiency=0.0)
    some = LatencyModel(overlap_efficiency=0.5)
    assert zero.latency_ns(st_) == base.latency_ns(st_)
    assert some.latency_ns(st_) <= base.latency_ns(st_)


# -- calibration plumbing ---------------------------------------------------
def test_kernel_features_schedule_round_trip():
    feat = KernelFeatures(
        kernel="k", class_passes={"simple": 3.0}, hbm_bytes=8192.0,
        sched_loads=((4096.0, 2.0, 1.0), (4096.0, 0.0, 0.0)),
        peak_live_bytes=8192.0, sched_mode="cost")
    back = KernelFeatures.from_dict(feat.to_dict())
    assert back == feat


def test_predict_ns_default_params_unchanged_by_sched_features():
    """Without a fitted overlap_efficiency the schedule features are
    inert — PR-4 profiles and predictions stay bit-identical."""
    plain = KernelFeatures(kernel="k", class_passes={"simple": 4.0},
                           hbm_bytes=16384.0)
    sched = dataclasses.replace(plain,
                                sched_loads=((8192.0, 2.0, 0.0),),
                                peak_live_bytes=8192.0)
    p = CalibrationParams()
    assert predict_ns(plain, p) == predict_ns(sched, p)


def test_predict_ns_overlap_uses_per_load_windows():
    feat = KernelFeatures(kernel="k", class_passes={"simple": 8.0},
                          hbm_bytes=8192.0,
                          sched_loads=((8192.0, 8.0, 0.0),))
    no_gap = dataclasses.replace(feat, sched_loads=((8192.0, 0.0, 0.0),))
    p = CalibrationParams(overlap_efficiency=1.0)
    assert predict_ns(feat, p) < predict_ns(no_gap, p)


def test_fit_recovers_overlap_efficiency():
    """Synthetic ground truth: timings generated with a known
    overlap_efficiency are recovered by the fitter (schedule features
    present -> the eff axis is swept)."""
    truth = CalibrationParams(overlap_slack_compute=0.0,
                              overlap_slack_memory=0.0,
                              overlap_efficiency=0.6)
    feats = []
    rng = np.random.default_rng(0)
    for i in range(8):
        nloads = int(rng.integers(1, 4))
        loads = tuple((float(rng.integers(1, 3) * 4096),
                       float(rng.integers(0, 12)), 0.0)
                      for _ in range(nloads))
        feats.append(KernelFeatures(
            kernel=f"k{i}",
            class_passes={"simple": float(rng.integers(1, 10)),
                          "transcendental": float(rng.integers(0, 3) * 8)},
            hbm_bytes=sum(b for b, _, _ in loads) + 4096.0,
            sched_loads=loads))
    meas = [predict_ns(f, truth) for f in feats]
    params, loss, _ = fit_params(feats, meas, fit_base=False)
    assert loss < 1e-3
    assert params.overlap_efficiency == pytest.approx(0.6, abs=0.15)


def test_schedule_report_fields():
    sk = saturate_program(
        PROGRAMS["rmsnorm"](),
        SaturatorConfig(mode="accsat",
                        schedule_cfg=ScheduleConfig(schedule="cost")))
    rep = sk.report()
    assert rep["schedule"] == "cost"
    assert rep["schedule_predicted_ns"] is not None
    windows = sk.kernel.schedule.load_windows()
    assert len(windows) == sk.kernel.stats.n_loads
    for nbytes, gap_passes, gap_loads in windows:
        assert nbytes > 0 and gap_passes >= 0 and gap_loads >= 0
