"""E-graph invariants: hash-consing, union-find, congruence closure.

Property tests (hypothesis) assert the egg invariants the paper's §II-D
relies on: canonical hashcons keys, congruence after rebuild, and
semantic soundness of saturation (every extractable term evaluates equal
to the original)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.egraph import EGraph, add_expr, extract_to_term
from repro.core.ir import ENode
from repro.core.rules import PAPER_RULES, run_rules

from helpers import eval_term, random_env, random_term


def test_hashcons_dedup():
    eg = EGraph()
    a1 = add_expr(eg, ("add", ("var", "x"), ("var", "y")))
    a2 = add_expr(eg, ("add", ("var", "x"), ("var", "y")))
    assert a1 == a2
    assert eg.num_nodes() == 3  # x, y, add


def test_union_find_merge():
    eg = EGraph()
    x = add_expr(eg, ("var", "x"))
    y = add_expr(eg, ("var", "y"))
    assert eg.find(x) != eg.find(y)
    eg.union(x, y)
    assert eg.find(x) == eg.find(y)


def test_congruence_closure():
    # f(a), f(b): union(a, b) must congruence-merge f(a) and f(b)
    eg = EGraph()
    a = add_expr(eg, ("var", "a"))
    b = add_expr(eg, ("var", "b"))
    fa = eg.add(ENode("neg", (a,)))
    fb = eg.add(ENode("neg", (b,)))
    assert eg.find(fa) != eg.find(fb)
    eg.union(a, b)
    eg.rebuild()
    assert eg.find(fa) == eg.find(fb)


def test_congruence_transitive():
    eg = EGraph()
    a = add_expr(eg, ("var", "a"))
    b = add_expr(eg, ("var", "b"))
    fa = eg.add(ENode("neg", (a,)))
    fb = eg.add(ENode("neg", (b,)))
    gfa = eg.add(ENode("exp", (fa,)))
    gfb = eg.add(ENode("exp", (fb,)))
    eg.union(a, b)
    eg.rebuild()
    assert eg.find(gfa) == eg.find(gfb)


def test_int_float_consts_distinct():
    eg = EGraph()
    ci = add_expr(eg, ("const", 0))
    cf = add_expr(eg, ("const", 0.0))
    assert eg.find(ci) != eg.find(cf)


def test_const_fold_analysis():
    eg = EGraph()
    r = add_expr(eg, ("mul", ("const", 3.0), ("const", 4.0)))
    eg.rebuild()
    const12 = add_expr(eg, ("const", 12.0))
    assert eg.find(r) == eg.find(const12)


def test_comm_assoc_equates():
    eg = EGraph()
    t1 = add_expr(eg, ("mul", ("mul", ("var", "a"), ("var", "b")),
                       ("var", "c")))
    t2 = add_expr(eg, ("mul", ("var", "c"),
                       ("mul", ("var", "b"), ("var", "a"))))
    run_rules(eg, PAPER_RULES)
    assert eg.find(t1) == eg.find(t2)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_saturation_sound(seed):
    """Extracted term after saturation evaluates equal to the input."""
    rng = np.random.default_rng(seed)
    term = random_term(rng, depth=3)
    env = random_env(rng)
    want = eval_term(term, env)
    eg = EGraph()
    root = add_expr(eg, term)
    run_rules(eg, PAPER_RULES, iter_limit=6, node_limit=3000,
              time_limit_s=3.0)
    res = eg.extract(root)
    got = eval_term(res.term(eg), env)
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_rebuild_idempotent_and_canonical(seed):
    rng = np.random.default_rng(seed)
    eg = EGraph()
    roots = [add_expr(eg, random_term(rng, depth=3)) for _ in range(3)]
    run_rules(eg, PAPER_RULES, iter_limit=4, node_limit=2000)
    eg.rebuild()
    n1 = eg.num_nodes()
    eg.rebuild()
    assert eg.num_nodes() == n1
    # every hashcons key must be canonical
    for node, cid in eg.hashcons.items():
        assert eg.canonicalize(node) == node or \
            eg.find(eg.hashcons[eg.canonicalize(node)]) == eg.find(cid)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_invariants_hold_after_saturation(seed):
    """PR 7: the full invariant audit (union-find, hashcons/congruence
    closure, analysis consistency) passes after run_rules + rebuild."""
    rng = np.random.default_rng(seed)
    eg = EGraph()
    for _ in range(3):
        add_expr(eg, random_term(rng, depth=3))
    run_rules(eg, PAPER_RULES, iter_limit=5, node_limit=2500)
    eg.rebuild()
    eg.check_invariants(strict=True)


def test_invariants_detect_cross_class_congruence():
    """Two congruent nodes planted in distinct classes must be caught."""
    eg = EGraph()
    a = add_expr(eg, ("var", "a"))
    b = add_expr(eg, ("var", "b"))
    n1 = add_expr(eg, ("add", ("var", "a"), ("var", "b")))
    # duplicate add(a,b) directly into b's class behind the union-find's
    # back — exactly what a buggy rebuild would leave behind
    dup = ENode("add", (a, b))
    eg.classes[eg.find(b)].nodes.add(dup)
    findings = eg.check_invariants()
    codes = {f.code for f in findings if f.severity == "error"}
    assert codes & {"congruence-violation", "member-maps-elsewhere"}, codes
    assert n1 is not None


def test_node_limit_respected():
    eg = EGraph()
    t = ("add", ("var", "a"), ("var", "b"))
    for _ in range(6):
        t = ("add", t, ("mul", t, ("var", "c")))
    add_expr(eg, t)
    rep = run_rules(eg, PAPER_RULES, iter_limit=50, node_limit=500,
                    time_limit_s=10.0)
    assert rep.stop_reason in ("node_limit", "saturated", "time_limit")
    # rebuild may dedup below the limit after the stop fires; the graph
    # must never grow far beyond it
    assert eg.num_nodes() <= 2 * 500
