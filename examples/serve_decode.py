"""Serving example: batched requests with continuous batching over the
Mamba2 (SSD) architecture — prefill builds the recurrent state, decode
advances all active sequences one token per tick.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np

from repro.launch.serve import Request, Server


def main():
    srv = Server("mamba2-1.3b", smoke=True, max_batch=4)
    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(1, srv.cfg.vocab,
                                    size=12 + 3 * (i % 3)).astype(np.int32),
                max_new=10)
        for i in range(7)
    ]
    out = srv.generate(requests)
    for rid in sorted(out):
        print(f"req{rid}: {out[rid]}")
    m = srv.metrics
    print(f"{len(out)} requests, {m['tokens']} tokens, "
          f"{m['prefills']} prefill batches, {m['decode_ticks']} ticks")


if __name__ == "__main__":
    main()
