"""Serving example + PR-6 benchmark: decode throughput with the
persistent saturation cache.

Batched requests run with continuous batching over the Mamba2 (SSD)
architecture — prefill builds the recurrent state, decode advances all
active sequences one token per tick. On top of the original demo this
script measures the numbers BENCH_6.json commits:

  * decode tokens/sec with saturation ON (the saturated tile kernels the
    models dispatch through repro.kernels.ops) vs OFF (the unsaturated
    reference oracle, ``ops.set_impl("ref")``);
  * persistent-cache behaviour: a cold pass populates ``--cache-dir``,
    a second pass replays from disk — hit rate and cold-vs-replay
    saturation wall time come from repro.core.telemetry.

Flags:
  --cache-dir DIR   saturation cache directory (default: a fresh temp
                    dir, so the cold/warm phases are well-defined)
  --no-cache        disable the on-disk cache entirely (the cache
                    section of the report is then omitted)
  --out PATH        write the measured report as JSON (CI commits this
                    as BENCH_6.json)

Run:  PYTHONPATH=src python examples/serve_decode.py --out BENCH_6.json
"""
import argparse
import json
import tempfile
import time

import jax
import numpy as np

from repro.core.telemetry import reset_telemetry, telemetry
from repro.kernels import ops
from repro.kernels.tile_programs import get_tile_op
from repro.launch.serve import Request, Server


def _requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        size=12 + 3 * (i % 3)).astype(
                                            np.int32),
                    max_new=max_new)
            for i in range(n)]


def _timed_generate(srv, reqs):
    """Run one warmup batch (jit compile) then time a full generate."""
    srv.generate(_requests(srv.cfg, len(reqs), reqs[0].max_new, seed=1))
    tokens_before = srv.metrics["tokens"]
    t0 = time.perf_counter()
    out = srv.generate(reqs)
    dt = time.perf_counter() - t0
    tokens = srv.metrics["tokens"] - tokens_before
    return out, tokens, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--requests", type=int, default=7)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--cache-dir", default=None,
                    help="saturation cache dir (default: fresh temp dir)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the persistent saturation cache")
    ap.add_argument("--out", default=None,
                    help="write the benchmark report JSON here")
    args = ap.parse_args(argv)

    cache_dir = None if args.no_cache else (
        args.cache_dir or tempfile.mkdtemp(prefix="repro_sat_cache_"))
    report = {"schema_version": 1, "pr": 6,
              "bench": "serve_decode", "arch": args.arch,
              "backend": jax.default_backend(),
              "requests": args.requests, "max_new": args.max_new,
              "cache_dir": cache_dir}

    # -- phase 1: cold boot — saturation searches run, cache populates --
    reset_telemetry()
    srv = Server(args.arch, smoke=True, max_batch=4, cache_dir=cache_dir)
    out, tokens, dt = _timed_generate(
        srv, _requests(srv.cfg, args.requests, args.max_new))
    for rid in sorted(out):
        print(f"req{rid}: {out[rid]}")
    cold = telemetry().snapshot()
    report["saturated"] = {"tokens": tokens, "wall_s": dt,
                           "tokens_per_s": tokens / dt}
    print(f"saturation ON : {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s)")

    if cache_dir is not None:
        # -- phase 2: warm boot — drop the in-process memo so every tile
        # op is rebuilt, now replayed from the on-disk entries ----------
        get_tile_op.cache_clear()
        reset_telemetry()
        srv2 = Server(args.arch, smoke=True, max_batch=4,
                      cache_dir=cache_dir)
        _, tokens2, dt2 = _timed_generate(
            srv2, _requests(srv2.cfg, args.requests, args.max_new))
        warm = telemetry().snapshot()
        replay_speedup = (cold["cold_wall_s"] / warm["hit_wall_s"]
                          if warm["hit_wall_s"] > 0 else float("inf"))
        report["cache"] = {
            "cold": {"misses": cold["cache_misses"],
                     "stores": cold["cache_stores"],
                     "saturation_wall_s": cold["cold_wall_s"]},
            "warm": {"hits": warm["cache_hits"],
                     "misses": warm["cache_misses"],
                     "hit_rate": warm["cache_hit_rate"],
                     "saturation_wall_s": warm["hit_wall_s"],
                     "tokens_per_s": tokens2 / dt2},
            "replay_speedup": replay_speedup,
        }
        print(f"cache: cold misses={cold['cache_misses']} "
              f"({cold['cold_wall_s']:.2f}s search) -> warm "
              f"hits={warm['cache_hits']} hit_rate="
              f"{warm['cache_hit_rate']:.2f} "
              f"({warm['hit_wall_s']:.3f}s replay, "
              f"{replay_speedup:.0f}x)")

    # -- phase 3: saturation OFF — unsaturated reference kernels --------
    ops.set_impl("ref")
    try:
        srv3 = Server(args.arch, smoke=True, max_batch=4)
        _, tokens3, dt3 = _timed_generate(
            srv3, _requests(srv3.cfg, args.requests, args.max_new))
    finally:
        ops.set_impl(None)
    report["reference"] = {"tokens": tokens3, "wall_s": dt3,
                           "tokens_per_s": tokens3 / dt3}
    report["decode_speedup_vs_ref"] = (
        report["saturated"]["tokens_per_s"]
        / report["reference"]["tokens_per_s"])
    print(f"saturation OFF: {tokens3} tokens in {dt3:.2f}s "
          f"({tokens3 / dt3:.1f} tok/s) -> saturated is "
          f"{report['decode_speedup_vs_ref']:.2f}x")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
