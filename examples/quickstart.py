"""Quickstart: saturate a kernel with ACC Saturator-on-TPU and inspect
everything the paper's pipeline produces.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (KernelProgram, SaturatorConfig, c, run_reference,
                        saturate_all_modes, v)

# --- 1. Write the body of a parallel loop in the kernel DSL -----------------
# (this is Listing 1 of the paper: the matmul kernel under OpenACC)
p = KernelProgram("matmul_tile")
a = p.array_in("a")
b = p.array_in("b")
cm = p.array_in("cmat")
p.array_out("r")
for s in ("alpha", "beta", "i", "j", "ax"):
    p.scalar(s)
p.let("tmp", c(0.0))
with p.for_("l", 0, v("ax")):
    p.let("tmp", v("tmp") + a[v("i"), v("l")] * b[v("l"), v("j")])
p.store("r", v("alpha") * v("tmp") + v("beta") * cm[v("i"), v("j")],
        v("i"), v("j"))

# --- 2. Saturate under all four paper configurations -------------------------
kernels = saturate_all_modes(p)
print("mode       cost  ops  loads  fma   (paper Fig. 2 columns)")
for mode, sk in kernels.items():
    st = sk.kernel.stats
    print(f"{mode:9s} {sk.extraction.dag_cost:6.0f} {st.n_ops:4d} "
          f"{st.n_loads:5d} {st.n_fma:4d}")

# --- 3. The ACCSAT-generated JAX code (temp vars + bulk load, Listing 3) -----
print("\n--- generated code (accsat) ---")
print(kernels["accsat"].source)

# --- 4. Execute and validate against the reference interpreter ---------------
rng = np.random.default_rng(0)
A, B, C = (rng.normal(size=(4, 5)), rng.normal(size=(5, 6)),
           rng.normal(size=(4, 6)))
inputs = dict(a=A, b=B, cmat=C, r=np.zeros((4, 6)), alpha=1.5, beta=0.5,
              i=2, j=3, ax=5)
ref = run_reference(p, inputs)
out = kernels["accsat"](jnp.asarray(A), jnp.asarray(B), jnp.asarray(C),
                        jnp.zeros((4, 6)), 1.5, 0.5, 2, 3, 5)
assert np.allclose(np.asarray(out[0]), ref["r"])
print("matches reference interpreter ✓")
