"""Bring-your-own-kernel: three ways to use the saturator.

1. The kernel DSL → saturated JAX + Pallas TPU kernel (bulk load).
2. The jaxpr bridge: automatically saturate an existing jnp function.
3. Inspect the e-graph pipeline phases directly.

Run:  PYTHONPATH=src python examples/saturate_custom_kernel.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (KernelProgram, SaturatorConfig, make_tile_op,
                        rsqrt, rmean, saturate_jax_fn, silu)

# --- 1. tile program → Pallas kernel ------------------------------------------
p = KernelProgram("fused_norm_gate")
x = p.array_in("x")
z = p.array_in("z")
g = p.array_in("g")
p.array_out("o")
eps = p.scalar("eps")
xg = x.load() * silu(z.load())
p.store("o", xg * rsqrt(rmean(xg * xg) + eps) * g.load())

op = make_tile_op(p)
print("--- Pallas kernel body (bulk-loaded VMEM reads first) ---")
print(op.source)

rng = np.random.default_rng(0)
X = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
Z = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
G = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
out_pallas = op.apply(X, Z, G, eps=1e-6)      # interpret-mode on CPU
out_jnp = op.jax_ref(X, Z, G, eps=1e-6)       # saturated generated JAX
assert np.allclose(np.asarray(out_pallas), np.asarray(out_jnp), atol=1e-5)
print("pallas == saturated jnp ✓")

# --- 2. automatic bridging of an existing jnp function -------------------------
def my_fn(a, b):
    t = a * b + a * b          # redundant on purpose
    return t * jax.lax.logistic(t) + a * b

bk = saturate_jax_fn(my_fn, (X, Z), name="my_fn")
print(f"\njaxpr bridge: {bk.n_eqns} eqns -> "
      f"{bk.sk.kernel.stats.n_ops} ops (CSE found the shared a*b)")
assert np.allclose(np.asarray(bk(X, Z)), np.asarray(my_fn(X, Z)),
                   atol=1e-5)
print("bridged function matches original ✓")

# --- 3. phase-by-phase inspection ----------------------------------------------
sk = bk.sk
print(f"\npipeline report: {sk.report()}")
