"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with the full production stack — saturated kernels, fused AdamW, sharded
data pipeline, async checkpointing, and a mid-run simulated node failure
with elastic recovery.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(CPU: ~100M params; pass --tiny for a quick smoke run.)
"""
import argparse
import time

from repro.launch.train import build_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    # minitron-family reduced config: ~100M params at smoke scale ×4 width
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.launch import train as T

    steps = 30 if args.tiny else args.steps
    batch, seq = (4, 64) if args.tiny else (8, 256)

    trainer = T.build_trainer(
        "minitron-4b", smoke=True, steps=steps, batch=batch, seq=seq,
        ckpt_dir="/tmp/repro_example_ckpt", lr=1e-3,
        inject={steps // 2: ("node_loss", 1)})   # fail mid-run, recover
    if not args.tiny:
        # scale the smoke config up to ~100M params
        cfg = dataclasses.replace(
            get_smoke_config("minitron_4b"), n_layers=8, d_model=512,
            n_heads=8, n_kv_heads=4, d_ff=1536, vocab=32_000, head_dim=64)
        from repro.models import get_model
        import jax
        from repro.optim import init_opt_state, OptConfig
        model = get_model(cfg)
        print(f"params: {cfg.param_count()/1e6:.1f}M")

    t0 = time.time()
    out = trainer.run()
    losses = out["losses"]
    print(f"steps={out['final_step']}  loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}  recoveries={out['recoveries']}  "
          f"wall={time.time()-t0:.0f}s")
    assert losses[-1] < losses[0]
    print("loss decreased across a simulated node failure ✓")


if __name__ == "__main__":
    main()
