"""Qwen2-VL-2B text backbone [arXiv:2409.12191; hf]. M-RoPE:
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, head_dim=128.
Vision patch frontend is a STUB (input_specs provides patch embeddings /
3-axis position ids); dynamic resolution reduces to the position ids."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151_936, head_dim=128,
        norm="rmsnorm", act="swiglu", rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24), tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
        norm="rmsnorm", act="swiglu", mrope_sections=(2, 3, 3),
        tie_embeddings=True, remat=False, loss_chunk=32)
