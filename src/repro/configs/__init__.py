"""Assigned architectures (exact public configs) + reduced smoke variants.

``get_config(arch)`` returns the full config; ``get_smoke_config(arch)``
returns a tiny same-family variant for CPU smoke tests. ``SHAPES`` defines
the assigned input-shape set; ``cells()`` enumerates the 40 (arch × shape)
dry-run cells with applicability flags.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models import ModelConfig

ARCHS = [
    "minitron_4b", "mistral_nemo_12b", "mistral_large_123b", "granite_8b",
    "mamba2_1p3b", "qwen2_vl_2b", "dbrx_132b", "arctic_480b",
    "whisper_small", "zamba2_2p7b",
]

# canonical ids as assigned (dashes/dots)
ARCH_IDS = {
    "minitron-4b": "minitron_4b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "mistral-large-123b": "mistral_large_123b",
    "granite-8b": "granite_8b",
    "mamba2-1.3b": "mamba2_1p3b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "dbrx-132b": "dbrx_132b",
    "arctic-480b": "arctic_480b",
    "whisper-small": "whisper_small",
    "zamba2-2.7b": "zamba2_2p7b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _module(arch: str):
    arch = ARCH_IDS.get(arch, arch)
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is (arch, shape) a runnable cell? Returns (ok, reason_if_not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k decode is quadratic "
                       "in compute/KV; skipped per assignment "
                       "(run for SSM/hybrid only)")
    return True, ""


def cells() -> List[Tuple[str, str, bool, str]]:
    """All 40 (arch, shape, applicable, reason) cells."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, spec in SHAPES.items():
            ok, why = applicable(cfg, spec)
            out.append((arch, sname, ok, why))
    return out
