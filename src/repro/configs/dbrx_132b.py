"""DBRX-132B [hf:databricks/dbrx-base; unverified]. Fine-grained MoE:
40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, 16 experts top-4."""
from repro.models import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=10752, vocab=100_352, head_dim=128,
        norm="rmsnorm", act="swiglu", rope_theta=500_000.0,
        moe=MoEConfig(n_experts=16, top_k=4, capacity_factor=1.25))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
        norm="rmsnorm", act="swiglu",
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.5),
        remat=False, loss_chunk=32)
