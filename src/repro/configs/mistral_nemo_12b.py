"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128
(q_dim 4096 < d_model — explicit head_dim), 128k context."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131_072, head_dim=128,
        norm="rmsnorm", act="swiglu", rope_theta=1_000_000.0,
        max_seq=131_072)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-smoke", family="dense", n_layers=2,
        d_model=96, n_heads=4, n_kv_heads=2, d_ff=192, vocab=512,
        head_dim=16,  # head_dim*heads != d_model, like the real config
        norm="rmsnorm", act="swiglu", remat=False, loss_chunk=32)
