"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base; hf].
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual MLP (dense-MoE hybrid)."""
from repro.models import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32_000, head_dim=128,
        norm="rmsnorm", act="swiglu", rope_theta=10_000.0,
        moe=MoEConfig(n_experts=128, top_k=2, capacity_factor=1.25,
                      residual_ffn_dim=4864))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab=512, head_dim=16,
        norm="rmsnorm", act="swiglu",
        moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.5,
                      residual_ffn_dim=96),
        remat=False, loss_chunk=32)
