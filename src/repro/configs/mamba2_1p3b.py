"""Mamba2-1.3B [arXiv:2405.21060; unverified]. Attention-free SSD:
48L d_model=2048 vocab=50280, ssm_state=128, headdim=64, expand=2."""
from repro.models import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=50_280, head_dim=0,
        norm="rmsnorm",
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      chunk=128),
        tie_embeddings=True, sub_quadratic=True, max_seq=1_048_576)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=512, head_dim=0,
        norm="rmsnorm",
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      chunk=16),
        tie_embeddings=True, sub_quadratic=True, remat=False,
        loss_chunk=32)
