"""Granite-8B-Code [arXiv:2405.04324; hf]. Llama-arch:
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152, head_dim=128."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense", n_layers=36, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=49_152, head_dim=128,
        norm="rmsnorm", act="swiglu", rope_theta=10_000_000.0,
        tie_embeddings=True)  # granite-code ties embeddings


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
        norm="rmsnorm", act="swiglu", tie_embeddings=True, remat=False,
        loss_chunk=32)
