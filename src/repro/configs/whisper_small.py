"""Whisper-small [arXiv:2212.04356; unverified]. Enc-dec, conv frontend
STUB (precomputed frame embeddings): 12L enc + 12L dec, d_model=768,
12H MHA (kv=12), d_ff=3072, vocab=51865, head_dim=64, LayerNorm+GELU."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51_865, head_dim=64,
        norm="layernorm", act="gelu", n_enc_layers=12, max_seq=32_768)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke", family="encdec", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        head_dim=16, norm="layernorm", act="gelu", n_enc_layers=2,
        max_seq=256, remat=False, loss_chunk=32)
