"""Mistral-Large-123B [hf:mistralai/Mistral-Large-Instruct-2407; unverified].
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768, head_dim=128."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family="dense", n_layers=88,
        d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672, vocab=32_768,
        head_dim=128, norm="rmsnorm", act="swiglu",
        rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-smoke", family="dense", n_layers=3,
        d_model=96, n_heads=6, n_kv_heads=2, d_ff=192, vocab=512,
        head_dim=16, norm="rmsnorm", act="swiglu", remat=False,
        loss_chunk=32)
