"""Minitron-4B: width-pruned Nemotron [arXiv:2407.14679; hf].
32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000, head_dim=128."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", family="dense", n_layers=32, d_model=3072,
        n_heads=24, n_kv_heads=8, d_ff=9216, vocab=256_000, head_dim=128,
        norm="rmsnorm", act="swiglu", rope_theta=10_000.0,
        loss_chunk=256)  # 256k vocab: small seq chunks for the xent scan


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
        norm="rmsnorm", act="swiglu", remat=False, loss_chunk=32)
