"""Zamba2-2.7B [arXiv:2411.15242; hf]. Hybrid: Mamba2 backbone with a
SHARED attention+MLP block applied every 6 SSD blocks (param tying):
54L d_model=2560, shared attn 32H (kv=32, MHA) d_ff=10240, ssm_state=64."""
from repro.models import ModelConfig, MoEConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32_000, head_dim=80,
        norm="rmsnorm", act="swiglu",
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                      chunk=128),
        shared_attn_every=6, tie_embeddings=True, sub_quadratic=True,
        max_seq=1_048_576)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, head_dim=16,
        norm="rmsnorm", act="swiglu",
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      chunk=16),
        shared_attn_every=2, tie_embeddings=True, sub_quadratic=True,
        remat=False, loss_chunk=32)
