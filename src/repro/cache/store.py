"""On-disk content-addressed store for saturation results.

Layout (all names content-derived, see :mod:`repro.cache.keys`)::

    <root>/<kernel>/<warm_key[:24]>/<exact_key[:24]>.json

One JSON file per (program, shapes, config) — the committed extraction
choice, schedule order, and predicted cost. A lookup first tries the
exact file (→ ``"hit"``: replay, no search); otherwise any sibling in
the same warm directory is the same kernel under the same rules/config
with different shapes (→ ``"warm"``: seed the searches from it).

Robustness contract (exercised by ``tests/test_saturation_cache.py``):

* writes go to a temp file in the same directory and land via
  ``os.replace`` — atomic on POSIX, so concurrent writers can't clobber
  each other or expose torn entries;
* corrupt / truncated / version-mismatched entries are *ignored* (and
  counted in telemetry), never trusted — the caller falls back to the
  cold path;
* the full keys are embedded in each entry and re-validated on load, so
  a truncated-digest filename collision degrades to a miss;
* every entry carries a sha256 ``digest`` over its semantic fields
  (choice, schedule, costs) that is re-verified on load, so corruption
  that stays valid JSON still degrades to a miss, never a wrong replay.

Trust model: entries are replayed into generated code, so the cache
root must be private to the user. A root this process creates is made
``0700``; a pre-existing root is refused (cache silently off, counted
in telemetry) unless it is a real directory owned by the current uid
with no group/other write bits — so a world-writable location another
local user pre-created can never feed us entries. Entry *contents* are
additionally validated structurally at graft time (see
:mod:`repro.cache.serialize`).
"""
from __future__ import annotations

import errno
import hashlib
import json
import os
import stat
import time
import uuid
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

from repro.core.telemetry import telemetry
from repro.runtime import chaos

from .keys import EXTRACTOR_VERSION, FORMAT_VERSION, CacheKey
from .serialize import CacheInvalid

_DIGEST_CHARS = 24

# The fields an entry's integrity digest seals — everything that feeds
# replay. Keys/versions are validated separately; cold_report and
# created_unix are informational.
_SEALED_FIELDS = ("choice", "schedule", "predicted", "dag_cost",
                  "tree_cost")


def default_cache_dir() -> Path:
    """User-private default cache location:
    ``$XDG_CACHE_HOME/repro/sat_cache`` (or ``~/.cache/repro/sat_cache``)
    — never a shared world-writable directory like ``/tmp``, where any
    local user could pre-create the path and plant entries."""
    base = os.environ.get("XDG_CACHE_HOME")
    root = Path(base) if base else Path.home() / ".cache"
    return root / "repro" / "sat_cache"


def entry_digest(doc: Dict[str, Any]) -> str:
    """sha256 over the canonical JSON of the entry's sealed fields."""
    payload = json.dumps([doc.get(k) for k in _SEALED_FIELDS],
                         sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()


class SaturationCache:
    def __init__(self, root):
        self.root = Path(root)
        self._usable: Optional[bool] = None

    # -- root trust ----------------------------------------------------------
    def _root_usable(self) -> bool:
        """Create-or-verify the cache root. A root we create is 0700;
        a pre-existing one must be a non-symlink directory owned by the
        current uid with no group/other write permission. Anything else
        disables the cache for this instance (recorded once)."""
        if self._usable is not None:
            return self._usable
        try:
            os.makedirs(self.root, mode=0o700, exist_ok=True)
            st = os.stat(self.root, follow_symlinks=False)
            if not stat.S_ISDIR(st.st_mode):
                raise OSError(f"{self.root} is not a directory")
            if hasattr(os, "getuid") and st.st_uid != os.getuid():
                raise OSError(f"{self.root} is owned by uid {st.st_uid}, "
                              f"not {os.getuid()}")
            if st.st_mode & (stat.S_IWGRP | stat.S_IWOTH):
                raise OSError(f"{self.root} is group/other-writable "
                              f"(mode {stat.S_IMODE(st.st_mode):o})")
        except OSError as e:
            telemetry().record_invalid(
                "<root>", f"untrusted cache root, cache disabled: {e}")
            self._usable = False
            return False
        self._usable = True
        return True

    # -- paths --------------------------------------------------------------
    def _warm_dir(self, key: CacheKey) -> Path:
        return self.root / key.kernel / key.warm_key[:_DIGEST_CHARS]

    def _entry_path(self, key: CacheKey) -> Path:
        return self._warm_dir(key) / \
            f"{key.exact_key[:_DIGEST_CHARS]}.json"

    # -- load/validate -------------------------------------------------------
    def _load(self, path: Path, key: CacheKey, *, exact: bool
              ) -> Dict[str, Any]:
        try:
            # chaos site: a failing cache volume (EIO) exercises exactly
            # this handler — the production degrade-to-miss path
            chaos.maybe_raise_os("cache_read_io", errno.EIO,
                                 f"read {path.name}")
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CacheInvalid(f"unreadable entry {path.name}: {e}") from e
        if chaos.chaos_point("cache_corrupt"):
            # tamper a sealed field post-parse: the digest check below
            # must reject it (corruption that stays valid JSON)
            doc = dict(doc)
            doc["dag_cost"] = float(doc.get("dag_cost") or 0.0) + 1.0
        if not isinstance(doc, dict):
            raise CacheInvalid(f"entry {path.name} is not an object")
        if doc.get("format") != FORMAT_VERSION:
            raise CacheInvalid(f"format {doc.get('format')!r} != "
                               f"{FORMAT_VERSION}")
        if doc.get("extractor_version") != EXTRACTOR_VERSION:
            raise CacheInvalid(
                f"extractor version {doc.get('extractor_version')!r} != "
                f"{EXTRACTOR_VERSION}")
        dk = doc.get("key", {})
        if dk.get("warm") != key.warm_key:
            raise CacheInvalid("warm-key mismatch (stale rules/config "
                               "or digest collision)")
        if exact and dk.get("exact") != key.exact_key:
            raise CacheInvalid("exact-key mismatch")
        if "choice" not in doc:
            raise CacheInvalid("entry has no choice")
        if doc.get("digest") != entry_digest(doc):
            raise CacheInvalid("content digest mismatch (corrupt or "
                               "tampered entry)")
        return doc

    def lookup(self, key: CacheKey
               ) -> Tuple[Optional[Dict[str, Any]], str]:
        """Returns ``(entry, status)`` with status in
        ``{"hit", "warm", "miss"}``; entry is None on a miss."""
        if not self._root_usable():
            return None, "miss"
        exact = self._entry_path(key)
        if exact.is_file():
            try:
                return self._load(exact, key, exact=True), "hit"
            except CacheInvalid as e:
                telemetry().record_invalid(key.kernel, str(e))
        warm_dir = self._warm_dir(key)
        if warm_dir.is_dir():
            for path in sorted(warm_dir.glob("*.json")):
                if path == exact:
                    continue
                try:
                    return self._load(path, key, exact=False), "warm"
                except CacheInvalid as e:
                    telemetry().record_invalid(key.kernel, str(e))
        return None, "miss"

    # -- store ---------------------------------------------------------------
    def put(self, key: CacheKey, entry: Dict[str, Any]) -> bool:
        """Atomically persist ``entry``; False on filesystem trouble
        (caching is best-effort, never fatal). The entry is stamped with
        its content digest so ``_load`` can detect corruption that stays
        valid JSON."""
        if not self._root_usable():
            return False
        path = self._entry_path(key)
        tmp = path.with_name(
            f".{path.stem}.{os.getpid()}.{uuid.uuid4().hex[:8]}.tmp")
        try:
            entry = dict(entry)
            entry["digest"] = entry_digest(entry)
            path.parent.mkdir(parents=True, exist_ok=True)
            # chaos site: ENOSPC from the atomic-write path exercises
            # the cache-disabled-with-telemetry degrade below
            chaos.maybe_raise_os("cache_write_io", errno.ENOSPC,
                                 f"write {path.name}")
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(entry, f, sort_keys=True, separators=(",", ":"))
            os.replace(tmp, path)   # atomic: readers see old or new, whole
        except (OSError, TypeError, ValueError) as e:
            try:
                tmp.unlink(missing_ok=True)
            except OSError:
                pass
            if isinstance(e, OSError):
                # ENOSPC / EIO / read-only fs: a filesystem that cannot
                # take writes won't heal mid-process — disable this
                # cache instance (matching the untrusted-root behavior)
                # instead of paying a failed write per build, and say so
                telemetry().record_invalid(
                    key.kernel, f"cache write failed, cache disabled "
                    f"for this process: {e}")
                self._usable = False
            return False
        telemetry().record_store(key.kernel)
        return True

    def stats(self) -> Dict[str, int]:
        entries = 0
        kernels = set()
        if self.root.is_dir():
            for p in self.root.rglob("*.json"):
                entries += 1
                kernels.add(p.parts[len(self.root.parts)])
        return {"entries": entries, "kernels": len(kernels)}


def make_entry(key: CacheKey, *, choice_doc: Dict[str, Any],
               schedule_doc: Optional[Dict[str, Any]],
               predicted: Optional[Dict[str, Any]],
               dag_cost: float, report: Dict[str, Any]
               ) -> Dict[str, Any]:
    """Assemble one versioned on-disk entry."""
    return {
        "format": FORMAT_VERSION,
        "extractor_version": EXTRACTOR_VERSION,
        "key": {"warm": key.warm_key, "exact": key.exact_key,
                "components": dict(key.components)},
        "choice": choice_doc,
        "schedule": schedule_doc,
        "predicted": predicted,
        "dag_cost": dag_cost,
        "cold_report": report,
        "created_unix": time.time(),
    }
