"""On-disk content-addressed store for saturation results.

Layout (all names content-derived, see :mod:`repro.cache.keys`)::

    <root>/<kernel>/<warm_key[:24]>/<exact_key[:24]>.json

One JSON file per (program, shapes, config) — the committed extraction
choice, schedule order, and predicted cost. A lookup first tries the
exact file (→ ``"hit"``: replay, no search); otherwise any sibling in
the same warm directory is the same kernel under the same rules/config
with different shapes (→ ``"warm"``: seed the searches from it).

Robustness contract (exercised by ``tests/test_saturation_cache.py``):

* writes go to a temp file in the same directory and land via
  ``os.replace`` — atomic on POSIX, so concurrent writers can't clobber
  each other or expose torn entries;
* corrupt / truncated / version-mismatched entries are *ignored* (and
  counted in telemetry), never trusted — the caller falls back to the
  cold path;
* the full keys are embedded in each entry and re-validated on load, so
  a truncated-digest filename collision degrades to a miss.
"""
from __future__ import annotations

import json
import os
import time
import uuid
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

from repro.core.telemetry import telemetry

from .keys import EXTRACTOR_VERSION, FORMAT_VERSION, CacheKey
from .serialize import CacheInvalid

_DIGEST_CHARS = 24


class SaturationCache:
    def __init__(self, root):
        self.root = Path(root)

    # -- paths --------------------------------------------------------------
    def _warm_dir(self, key: CacheKey) -> Path:
        return self.root / key.kernel / key.warm_key[:_DIGEST_CHARS]

    def _entry_path(self, key: CacheKey) -> Path:
        return self._warm_dir(key) / \
            f"{key.exact_key[:_DIGEST_CHARS]}.json"

    # -- load/validate -------------------------------------------------------
    def _load(self, path: Path, key: CacheKey, *, exact: bool
              ) -> Dict[str, Any]:
        try:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CacheInvalid(f"unreadable entry {path.name}: {e}") from e
        if not isinstance(doc, dict):
            raise CacheInvalid(f"entry {path.name} is not an object")
        if doc.get("format") != FORMAT_VERSION:
            raise CacheInvalid(f"format {doc.get('format')!r} != "
                               f"{FORMAT_VERSION}")
        if doc.get("extractor_version") != EXTRACTOR_VERSION:
            raise CacheInvalid(
                f"extractor version {doc.get('extractor_version')!r} != "
                f"{EXTRACTOR_VERSION}")
        dk = doc.get("key", {})
        if dk.get("warm") != key.warm_key:
            raise CacheInvalid("warm-key mismatch (stale rules/config "
                               "or digest collision)")
        if exact and dk.get("exact") != key.exact_key:
            raise CacheInvalid("exact-key mismatch")
        if "choice" not in doc:
            raise CacheInvalid("entry has no choice")
        return doc

    def lookup(self, key: CacheKey
               ) -> Tuple[Optional[Dict[str, Any]], str]:
        """Returns ``(entry, status)`` with status in
        ``{"hit", "warm", "miss"}``; entry is None on a miss."""
        exact = self._entry_path(key)
        if exact.is_file():
            try:
                return self._load(exact, key, exact=True), "hit"
            except CacheInvalid as e:
                telemetry().record_invalid(key.kernel, str(e))
        warm_dir = self._warm_dir(key)
        if warm_dir.is_dir():
            for path in sorted(warm_dir.glob("*.json")):
                if path == exact:
                    continue
                try:
                    return self._load(path, key, exact=False), "warm"
                except CacheInvalid as e:
                    telemetry().record_invalid(key.kernel, str(e))
        return None, "miss"

    # -- store ---------------------------------------------------------------
    def put(self, key: CacheKey, entry: Dict[str, Any]) -> bool:
        """Atomically persist ``entry``; False on filesystem trouble
        (caching is best-effort, never fatal)."""
        path = self._entry_path(key)
        tmp = path.with_name(
            f".{path.stem}.{os.getpid()}.{uuid.uuid4().hex[:8]}.tmp")
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(entry, f, sort_keys=True, separators=(",", ":"))
            os.replace(tmp, path)   # atomic: readers see old or new, whole
        except OSError:
            try:
                tmp.unlink(missing_ok=True)
            except OSError:
                pass
            return False
        telemetry().record_store(key.kernel)
        return True

    def stats(self) -> Dict[str, int]:
        entries = 0
        kernels = set()
        if self.root.is_dir():
            for p in self.root.rglob("*.json"):
                entries += 1
                kernels.add(p.parts[len(self.root.parts)])
        return {"entries": entries, "kernels": len(kernels)}


def make_entry(key: CacheKey, *, choice_doc: Dict[str, Any],
               schedule_doc: Optional[Dict[str, Any]],
               predicted: Optional[Dict[str, Any]],
               dag_cost: float, report: Dict[str, Any]
               ) -> Dict[str, Any]:
    """Assemble one versioned on-disk entry."""
    return {
        "format": FORMAT_VERSION,
        "extractor_version": EXTRACTOR_VERSION,
        "key": {"warm": key.warm_key, "exact": key.exact_key,
                "components": dict(key.components)},
        "choice": choice_doc,
        "schedule": schedule_doc,
        "predicted": predicted,
        "dag_cost": dag_cost,
        "cold_report": report,
        "created_unix": time.time(),
    }
