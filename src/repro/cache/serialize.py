"""Structural (de)serialization of cached saturation results.

E-class ids are *process-local*: they depend on insertion and
set-iteration order, so a cache entry must never store a cid. Instead
the committed extraction choice is serialized as a flat, topologically
ordered node list — ``[op, [child_indices...], payload]`` — where every
child reference is an index into the same list. Schedule orders are
serialized per region as unit keys that survive the same translation:
``["load"|"compute", node_index]``, ``["store", store_order]``,
``["loop", loop_id]`` (store orders and loop ids are assigned by the
deterministic SSA build, so they are stable across processes).

Deserialization *grafts* the cached term DAG back into a fresh SSA
e-graph: each node is re-added bottom-up (``EGraph.add`` hash-conses,
so nodes that already exist resolve to their canonical class), and each
reconstructed root is unioned with the corresponding SSA root. The
union is sound because the cache key pins the exact program and rule
set — the cached term was proven equal to the root by a previous
saturation of the *same* e-graph (the eqsat-dialect "non-destructive
reuse of e-graph state" idea). This is what lets an exact hit skip
``run_rules`` entirely, not just the extraction search.

Anything unexpected raises :class:`CacheInvalid`; callers treat it as a
miss and fall back to the cold path — a corrupt entry can cost time,
never correctness.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.extract import choice_nodes
from repro.core.ir import ENode


class CacheInvalid(ValueError):
    """Entry cannot be used (corrupt, stale, or structurally wrong)."""


# -- payload encoding --------------------------------------------------------
# Payloads are typed: 0, 0.0 and False are distinct constants (the
# type-aware ENode hash), so the JSON encoding carries an explicit tag.
def _enc_payload(p: Any) -> Any:
    if p is None:
        return ["none"]
    if isinstance(p, bool):
        return ["bool", p]
    if isinstance(p, int):
        return ["int", p]
    if isinstance(p, float):
        return ["float", p.hex()]   # exact round trip, incl. inf/-0.0
    if isinstance(p, str):
        return ["str", p]
    if isinstance(p, tuple):
        return ["tuple", [_enc_payload(x) for x in p]]
    raise CacheInvalid(f"unsupported payload type {type(p).__name__}")


def _dec_payload(doc: Any) -> Any:
    try:
        tag = doc[0]
        if tag == "none":
            return None
        if tag == "bool":
            return bool(doc[1])
        if tag == "int":
            return int(doc[1])
        if tag == "float":
            return float.fromhex(doc[1])
        if tag == "str":
            return str(doc[1])
        if tag == "tuple":
            return tuple(_dec_payload(x) for x in doc[1])
    except (TypeError, ValueError, IndexError, KeyError) as e:
        raise CacheInvalid(f"bad payload {doc!r}: {e}") from e
    raise CacheInvalid(f"unknown payload tag {doc!r}")


# -- choice <-> flat node list ----------------------------------------------
def choice_to_doc(eg, choice: Dict[int, ENode], roots: Sequence[int]
                  ) -> Tuple[Dict[str, Any], Dict[int, int]]:
    """Serialize the chosen DAG reachable from ``roots``.

    Returns ``(doc, index_of)`` where ``index_of`` maps canonical cid →
    node index (the schedule serializer reuses it).
    """
    nodes: List[Any] = []
    index_of: Dict[int, int] = {}

    def visit(cid: int) -> int:
        cid = eg.find(cid)
        if cid in index_of:
            return index_of[cid]
        n = choice.get(cid)
        if n is None:
            raise CacheInvalid(f"choice has no node for class {cid}")
        ch = [visit(c) for c in n.children]   # acyclic by extraction
        idx = len(nodes)
        nodes.append([n.op, ch, _enc_payload(n.payload)])
        index_of[cid] = idx
        return idx

    root_idx = [visit(r) for r in roots]
    return {"nodes": nodes, "roots": root_idx}, index_of


def graft_choice(eg, doc: Dict[str, Any], ssa_roots: Sequence[int]
                 ) -> Tuple[Dict[int, ENode], Tuple[int, ...]]:
    """Rebuild a serialized choice inside ``eg`` (see module docstring).

    ``eg`` may be the fresh SSA e-graph (exact-hit replay: no
    saturation ran) or the saturated one (warm-start seeding) — either
    way missing nodes are added and the reconstructed roots are unioned
    with ``ssa_roots``. Returns the canonical ``(choice, roots)``.

    Validation is ordered so an invalid entry mutates ``eg`` as little
    as possible: node structure and payloads are checked before any
    ``add`` (a ``var`` payload is emitted *verbatim* into exec'd kernel
    source by codegen, so it must name a variable the e-graph already
    knows — a cache entry can never introduce new program text), and
    the choice must cover its own reconstructed roots acyclically
    *before* the root unions merge any classes. Added-but-unused nodes
    land in fresh unreachable classes; no equivalence is created until
    the entry has fully validated.
    """
    try:
        nodes_doc = list(doc["nodes"])
        root_idx = list(doc["roots"])
    except (TypeError, KeyError) as e:
        raise CacheInvalid(f"malformed choice doc: {e}") from e

    # pass 1: decode + validate structurally, no e-graph mutation
    allowed_vars = {n.payload for n in eg.hashcons if n.op == "var"}
    decoded: List[Tuple[str, List[int], Any]] = []
    for i, entry in enumerate(nodes_doc):
        try:
            op, ch_idx, payload = entry
            ch_idx = list(ch_idx)
        except (TypeError, ValueError) as e:
            raise CacheInvalid(f"malformed node {entry!r}") from e
        if not isinstance(op, str):
            raise CacheInvalid(f"bad op {op!r}")
        for j in ch_idx:
            if not isinstance(j, int) or isinstance(j, bool) \
                    or not 0 <= j < i:
                raise CacheInvalid(f"bad child index in {entry!r}")
        p = _dec_payload(payload)
        if op == "var" and p not in allowed_vars:
            raise CacheInvalid(f"var payload {p!r} is not a variable of "
                               "this kernel (refusing to emit it)")
        decoded.append((op, ch_idx, p))

    # pass 2: graft (EGraph.add hash-conses; no unions yet)
    cids: List[int] = []
    for op, ch_idx, p in decoded:
        children = tuple(eg.find(cids[j]) for j in ch_idx)
        cids.append(eg.add(ENode(op, children, p)))

    # pass 3: the choice must stand on its own roots before we union
    # anything — a failure here leaves roots/equivalences untouched
    ssa_roots = [eg.find(r) for r in ssa_roots]
    try:
        rec_roots = [eg.find(cids[i]) for i in root_idx]
    except (IndexError, TypeError) as e:
        raise CacheInvalid(f"bad root index: {e}") from e
    if len(rec_roots) != len(ssa_roots):
        raise CacheInvalid(f"entry has {len(rec_roots)} roots, "
                           f"kernel has {len(ssa_roots)}")

    def _canonical_choice() -> Dict[int, ENode]:
        out: Dict[int, ENode] = {}
        for i, (op, ch_idx, p) in enumerate(decoded):
            children = tuple(eg.find(cids[j]) for j in ch_idx)
            out.setdefault(eg.find(cids[i]),
                           eg.canonicalize(ENode(op, children, p)))
        return out

    if choice_nodes(eg, _canonical_choice(), rec_roots) is None:
        raise CacheInvalid("reconstructed choice does not cover its own "
                           "roots acyclically")

    changed = False
    for a, b in zip(rec_roots, ssa_roots):
        if eg.find(a) != eg.find(b):
            eg.union(a, b)
            changed = True
    if changed:
        eg.rebuild()

    choice = _canonical_choice()
    roots = tuple(eg.find(r) for r in ssa_roots)
    if choice_nodes(eg, choice, roots) is None:
        raise CacheInvalid("reconstructed choice does not cover the "
                           "kernel roots acyclically")
    return choice, roots


def index_to_cid(eg, doc: Dict[str, Any], cids_hint: Optional[List[int]]
                 = None) -> List[int]:
    """Canonical cid of every serialized node, post-graft. Re-walks the
    doc (cheap) so callers don't have to thread the graft's internals."""
    cids: List[int] = []
    for op, ch_idx, payload in doc["nodes"]:
        children = tuple(eg.find(cids[j]) for j in ch_idx)
        node = eg.canonicalize(ENode(op, children, _dec_payload(payload)))
        cid = eg.hashcons.get(node)
        if cid is None:
            raise CacheInvalid(f"grafted node vanished: {node!r}")
        cids.append(eg.find(cid))
    return cids


# -- schedule orders <-> unit keys ------------------------------------------
def schedule_to_doc(sr, eg, index_of: Dict[int, int]
                    ) -> Optional[Dict[str, Any]]:
    """Serialize a ScheduleResult's per-region orders, or None when a
    unit's class is outside the serialized choice (late-demanded
    classes resolved by the greedy fallback — rare; the entry then
    caches the choice but not the order)."""
    orders: Dict[str, Any] = {}
    for path, rs in sr.regions.items():
        keys: List[Any] = []
        for u in rs.ordered_units():
            if u.kind in ("load", "compute"):
                idx = index_of.get(eg.find(u.cid))
                if idx is None:
                    return None
                keys.append([u.kind, idx])
            elif u.kind == "store":
                keys.append(["store", int(u.item.order)])
            else:
                keys.append(["loop", int(u.item.loop_id)])
        orders[",".join(map(str, path))] = keys
    return {"mode": sr.mode, "orders": orders,
            "predicted_ns": sr.predicted_ns,
            "predicted_by_mode": dict(sr.predicted_by_mode)}


def orders_from_doc(doc: Dict[str, Any], node_cids: List[int]
                    ) -> Dict[Tuple[int, ...], List[Tuple[str, Any]]]:
    """Translate serialized orders back to the unit-key form
    ``compute_schedule(fixed_orders=...)`` consumes: node indices become
    canonical cids, store/loop keys pass through."""
    out: Dict[Tuple[int, ...], List[Tuple[str, Any]]] = {}
    try:
        for path_s, keys in doc["orders"].items():
            path = tuple(int(x) for x in path_s.split(",")) if path_s \
                else ()
            units = []
            for kind, ref in keys:
                if kind in ("load", "compute"):
                    units.append((kind, node_cids[int(ref)]))
                elif kind in ("store", "loop"):
                    units.append((kind, int(ref)))
                else:
                    raise CacheInvalid(f"unknown unit kind {kind!r}")
            out[path] = units
    except (TypeError, ValueError, KeyError, IndexError) as e:
        raise CacheInvalid(f"malformed schedule doc: {e}") from e
    return out
