"""Persistent, content-addressed saturation cache (PR 6).

Equality saturation pays off only when its cost is amortized: a serving
process should pay beam-search cost once per kernel shape — across the
fleet and across boots — not once per process. This package persists
the *committed result* of ``saturate_program`` (extraction choice,
schedule order, predicted cost) keyed by content fingerprints of the
program, rule set, search configuration, and operand shapes:

* exact hit  → the choice is grafted back into a fresh SSA e-graph and
  the kernel re-emitted with the cached statement order: **no
  saturation, no beam search, no schedule search**, bit-identical
  sources to the cold path;
* warm hit (same kernel, different shapes) → the cached choice seeds
  the beam and the cached order seeds the schedule search;
* anything invalid → cold path (correctness never depends on an entry).

Enable per-config (``SaturatorConfig(cache_dir=...)``), process-wide
for the tile-op hot path (``repro.kernels.ops.set_saturation_cache``),
or via the ``REPRO_SAT_CACHE`` environment variable. Telemetry lands in
``repro.core.telemetry``.
"""
from .keys import (EXTRACTOR_VERSION, FORMAT_VERSION, CacheKey,
                   cache_key_for, config_fingerprint, program_fingerprint,
                   rules_fingerprint, shapes_fingerprint)
from .serialize import (CacheInvalid, choice_to_doc, graft_choice,
                        orders_from_doc, schedule_to_doc)
from .store import (SaturationCache, default_cache_dir, entry_digest,
                    make_entry)

__all__ = [
    "EXTRACTOR_VERSION", "FORMAT_VERSION", "CacheKey", "CacheInvalid",
    "SaturationCache", "cache_key_for", "choice_to_doc",
    "config_fingerprint", "default_cache_dir", "entry_digest",
    "graft_choice", "make_entry", "orders_from_doc",
    "program_fingerprint", "rules_fingerprint", "schedule_to_doc",
    "shapes_fingerprint",
]
