"""Deterministic cache keys for the persistent saturation cache.

Every component of a key is derived from *content*, never from Python
object identity or set/dict iteration order (the PR 3 ``ENode.__hash__``
lesson: ``id()``-dependent hashing made e-class ids differ across
processes, which is exactly what a cross-process cache must not depend
on). Keys are sha256 digests over canonical JSON:

* :func:`program_fingerprint` — the kernel's structure: statements
  (nested-tuple term reprs are deterministic), array names/roles and
  scalar names **in declaration order** (the emitted signature depends
  on it). Shapes and dtypes are deliberately *excluded* — they go into
  the exact key only, so a shape change is a near-miss (warm start),
  not a different kernel.
* :func:`rules_fingerprint` — names + lhs/rhs pattern reprs of the
  exact rule list the config would run. Editing any rule changes the
  digest and invalidates stale entries instead of silently reusing
  them.
* :func:`config_fingerprint` / :func:`shapes_fingerprint` — the search
  configuration (budgets, strategy, schedule mode, device-profile id)
  and the per-array geometry. Wall-clock safety limits are excluded:
  results are determined by the deterministic evaluation budgets.

The composite :class:`CacheKey` carries a ``warm_key`` (kernel + rules
+ extractor + search config — same kernel, any shapes) and an
``exact_key`` (warm + shapes/dtypes): an exact hit replays the
committed choice, a warm hit seeds the searches.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Optional

# Bump when extraction/scheduling *semantics* change in a way the rules
# fingerprint cannot see (e.g. a new beam neighborhood, a changed
# objective): stale entries are then ignored, never reused.
EXTRACTOR_VERSION = 1

# On-disk entry format; bump on incompatible serialization changes.
# v2: entries carry a mandatory content digest over the sealed fields.
FORMAT_VERSION = 2


def _digest(obj: Any) -> str:
    payload = json.dumps(obj, sort_keys=True, separators=(",", ":"),
                         default=repr)
    return hashlib.sha256(payload.encode()).hexdigest()


def _stmt_doc(stmt) -> Any:
    from repro.core.dsl import Assign, ArrayRef, For, If
    if isinstance(stmt, Assign):
        tgt = stmt.target
        if isinstance(tgt, ArrayRef):
            target = ["store", tgt.name, [repr(i) for i in tgt.indices]]
        else:
            target = ["let", str(tgt)]
        return ["assign", target, repr(stmt.expr)]
    if isinstance(stmt, If):
        return ["if", repr(stmt.cond),
                [_stmt_doc(s) for s in stmt.then],
                [_stmt_doc(s) for s in stmt.orelse]]
    if isinstance(stmt, For):
        return ["for", stmt.var, repr(stmt.start), repr(stmt.stop),
                [_stmt_doc(s) for s in stmt.body]]
    raise TypeError(f"unknown statement {type(stmt).__name__}")


def program_fingerprint(prog) -> str:
    """Structure-only digest of a :class:`KernelProgram` (no shapes)."""
    doc = {
        "name": prog.name,
        "arrays": [[spec.name, spec.role] for spec in prog.arrays.values()],
        "scalars": list(prog.scalars),
        "body": [_stmt_doc(s) for s in prog.body],
    }
    return _digest(doc)


def shapes_fingerprint(prog) -> str:
    """Digest of the declared operand geometry + dtypes (exact key only)."""
    doc = {
        "dtype": prog.dtype,
        "arrays": [[spec.name,
                    list(spec.shape) if spec.shape is not None else None,
                    spec.dtype]
                   for spec in prog.arrays.values()],
    }
    return _digest(doc)


def rules_fingerprint(config) -> str:
    """Digest of the exact rule list the config runs (names + patterns)."""
    if not config.use_sat:
        return _digest({"rules": []})
    doc = {"rules": [[r.name, repr(r.lhs), repr(r.rhs)]
                     for r in config.rules()]}
    return _digest(doc)


def device_profile_id(config) -> Optional[str]:
    """Stable identifier of the configured device profile, or None for
    the analytic models. When the profile resolves, the id is
    ``<name>@<digest of its fitted parameters>`` — re-fitting a profile
    under the same file name then changes the key, so entries tuned for
    stale calibration are not silently replayed. An unresolvable spec
    (e.g. the profile file is gone) falls back to the name string."""
    prof = config.device_profile
    if prof is None:
        return None
    name = getattr(prof, "name", None)
    name = str(name if name is not None else prof)
    try:
        from repro.analysis.calibrate import CalibrationError, load_profile
        params = load_profile(prof).params.to_dict()
    except (CalibrationError, OSError, ValueError, TypeError):
        return name
    return f"{name}@{_digest(params)[:16]}"


def config_fingerprint(config) -> str:
    """Digest of everything besides the program/rules that shapes the
    committed result: mode, search strategy + deterministic budgets,
    schedule mode, cost model, device profile — and, for non-default
    emission backends, the versioned emitter id (``name@v{n}``, see
    ``repro.core.emit.emitter_cache_id``) so cached replays never mix
    emitters. Default emitters (None/"jax"/"pallas") contribute no key
    at all: fingerprints of pre-PR-8 configs stay byte-identical and no
    existing cache entry invalidates. Wall-clock time limits are
    excluded (safety nets, machine-dependent)."""
    doc = {
        "mode": config.mode,
        "cost_model": config.cost_model,
        "search": config.search,
        "beam_width": config.beam_width,
        "beam_expansions": config.beam_expansions,
        "beam_coordinated": config.beam_coordinated,
        "hillclimb_evals": config.hillclimb_evals,
        "local_search": config.local_search,
        "iter_limit": config.iter_limit,
        "node_limit": config.node_limit,
        "schedule": config.schedule_mode,
        "device_profile": device_profile_id(config),
    }
    from repro.core.emit import emitter_cache_id
    em = emitter_cache_id(getattr(config, "emitter", None))
    if em is not None:
        doc["emitter"] = em
    return _digest(doc)


@dataclasses.dataclass(frozen=True)
class CacheKey:
    kernel: str          # sanitized program name (directory component)
    warm_key: str        # same kernel+rules+config, any shapes
    exact_key: str       # warm + shapes/dtypes
    components: Dict[str, Any] = dataclasses.field(default_factory=dict,
                                                   compare=False)


def _sanitize(name: str) -> str:
    return "".join(ch if ch.isalnum() or ch in "-_" else "_"
                   for ch in name) or "kernel"


def cache_key_for(prog, config) -> CacheKey:
    """The composite key of one ``saturate_program(prog, config)`` call."""
    prog_fp = program_fingerprint(prog)
    rules_fp = rules_fingerprint(config)
    cfg_fp = config_fingerprint(config)
    shapes_fp = shapes_fingerprint(prog)
    warm = _digest({"program": prog_fp, "rules": rules_fp,
                    "config": cfg_fp,
                    "extractor_version": EXTRACTOR_VERSION})
    exact = _digest({"warm": warm, "shapes": shapes_fp})
    return CacheKey(
        kernel=_sanitize(prog.name), warm_key=warm, exact_key=exact,
        components={
            "program": prog_fp, "rules": rules_fp, "config": cfg_fp,
            "shapes": shapes_fp, "extractor_version": EXTRACTOR_VERSION,
            "device_profile": device_profile_id(config),
            "schedule": config.schedule_mode, "mode": config.mode,
        })
