"""Step functions + abstract input specs shared by train/serve/dry-run."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import ModelConfig, get_model
from repro.optim import OptConfig, apply_updates


def make_train_step(model, opt_cfg: OptConfig, accum_steps: int = 1,
                    accum_dtype=None, grad_shardings=None):
    """Train step with optional gradient accumulation: the global batch is
    split into ``accum_steps`` microbatches scanned sequentially, so saved
    activations scale with the microbatch (the standard way to fit
    256×4096-token steps in HBM). ``accum_dtype`` controls the gradient
    carry: f32 default; bf16 for 100B+ models halves both the carry HBM
    and the per-microbatch cross-data reduction wire (profiled at 22 TB/
    step in f32 on mistral-large — EXPERIMENTS.md §Perf It.8)."""
    if accum_steps <= 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            new_params, new_state = apply_updates(params, grads, opt_state,
                                                  opt_cfg)
            return new_params, new_state, loss
        return train_step

    def split_micro(batch):
        def re(path, a):
            key = path[-1].key if hasattr(path[-1], "key") else ""
            if key == "positions":        # (3, B, S) -> (k, 3, B/k, S)
                k3, B, S = a.shape[0], a.shape[1], a.shape[2]
                return a.reshape(k3, accum_steps, B // accum_steps, S) \
                    .swapaxes(0, 1)
            B = a.shape[0]
            assert B % accum_steps == 0, \
                f"batch {B} not divisible by accum {accum_steps}"
            return a.reshape((accum_steps, B // accum_steps) + a.shape[1:])
        return jax.tree_util.tree_map_with_path(re, batch)

    acc_dt = accum_dtype or jnp.float32

    def _pin(gi):
        # pin each microbatch's gradients to the carry sharding at the
        # point of production: without this the partitioner materializes
        # full f32 wgrads and re-gathers them per micro per layer
        # (profiled: 22 TB/step on mistral-large — EXPERIMENTS.md It.8/9)
        if grad_shardings is None:
            return gi
        return jax.tree.map(jax.lax.with_sharding_constraint, gi,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        micro = split_micro(batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

        def body(carry, mb):
            loss_sum, g = carry
            li, gi = jax.value_and_grad(model.loss)(params, mb)
            gi = _pin(gi)
            g = jax.tree.map(lambda a, b: a + b.astype(acc_dt), g, gi)
            return (loss_sum + li, g), None

        (loss_sum, g), _ = jax.lax.scan(body, (jnp.float32(0.0), g0), micro)
        grads = jax.tree.map(lambda a: a.astype(jnp.float32) / accum_steps,
                             g)
        new_params, new_state = apply_updates(params, grads, opt_state,
                                              opt_cfg)
        return new_params, new_state, loss_sum / accum_steps
    return train_step


def default_accum_steps(cfg: ModelConfig, shape: ShapeSpec,
                        dp: int = 16, tp: int = 1,
                        budget_bytes: float = 4e9) -> int:
    """Microbatch count so saved activations (≈ 8·L·tokens_dev·d bytes:
    bf16 carry + attention lse + mlp residual factor) fit the budget.
    With sequence-parallel residuals (seq_shard) the saved carry is
    already sharded tp-ways, so far fewer microbatches are needed —
    keeping FSDP re-gathers per step low."""
    if shape.kind != "train":
        return 1
    tokens_dev = shape.global_batch * shape.seq_len / dp
    layers = cfg.n_layers + cfg.n_enc_layers
    est = 8.0 * layers * tokens_dev * cfg.d_model
    if cfg.seq_shard:
        est /= tp
    k = 1
    max_k = max(shape.global_batch // dp, 1)
    while k < max_k and est / k > budget_bytes:
        k *= 2
    return min(k, max_k)


def make_grad_step(model):
    def grad_step(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)
    return grad_step


def make_prefill_step(model, cfg: ModelConfig):
    if cfg.family == "encdec":
        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"], batch["frames"])
    else:
        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"])
    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, token):
        return model.decode_step(params, cache, token)
    return serve_step


def default_opt_config(cfg: ModelConfig, total_steps: int = 10_000
                       ) -> OptConfig:
    """int8 Adam moments for ≥100B-param archs (HBM fit; DESIGN.md §5)."""
    moment = "int8" if cfg.param_count() > 100e9 else "f32"
    return OptConfig(moment_dtype=moment, total_steps=total_steps)


def batch_spec_struct(cfg: ModelConfig, shape: ShapeSpec
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract train/prefill batch: ShapeDtypeStruct stand-ins only."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    batch: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
    }
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.float32)
    if cfg.family == "vlm":
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
    return batch


def decode_input_struct(model, cfg: ModelConfig, shape: ShapeSpec):
    """(cache, token) stand-ins for a decode step at full cache length."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return cache, token


def params_struct(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def opt_struct(params_sds, opt_cfg: OptConfig):
    from repro.optim import init_opt_state
    return jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_sds)
