"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the `pod` axis
carries only data parallelism (gradient all-reduce over DCI), keeping all
TP collectives inside a pod's ICI domain.

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax use).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)}; "
            f"run under launch/dryrun.py (XLA_FLAGS host device count) "
            f"or on a real pod slice")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over available devices for tests."""
    need = data * model
    devices = jax.devices()
    assert len(devices) >= need
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[:need])
