# Launchers: mesh construction, multi-pod dry-run, training and serving
# drivers. dryrun.py must be executed as a module entry (it sets XLA_FLAGS
# before importing jax).
