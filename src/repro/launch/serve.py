"""Serving driver: batched prefill + decode with continuous batching.

A minimal production-shaped server loop:
  * requests arrive with prompts of different lengths;
  * scheduler packs up to ``max_batch`` active sequences;
  * prefill runs per-admission, decode advances the whole batch one token
    per tick via the jitted serve_step (the same function the decode
    dry-run cells lower);
  * finished sequences free their slot (continuous batching).

CPU-scale entry:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import SaturatorConfig
from repro.core.telemetry import telemetry
from repro.kernels import ops
from repro.models import get_model

from repro.cache import default_cache_dir

# Default persistent saturation-cache location for the serving CLI: the
# decode hot path pays beam-search cost once per kernel shape across
# boots, not once per process (disable with --no-cache). User-private
# ($XDG_CACHE_HOME/repro/sat_cache) — cached entries are replayed into
# generated code, so the directory must not be writable by other users.
DEFAULT_CACHE_DIR = str(default_cache_dir())


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,)
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, arch: str, *, smoke: bool = True, max_batch: int = 4,
                 max_seq: int = 128, seed: int = 0,
                 cache_dir: Optional[str] = None,
                 verify: Optional[str] = None):
        # every saturated tile op the model layers dispatch through
        # repro.kernels.ops is built (or replayed) via this cache
        if cache_dir is not None:
            ops.set_saturation_cache(cache_dir)
        if verify is not None:
            ops.set_saturation_verify(verify)
        arch = ARCH_IDS.get(arch, arch)
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.model = get_model(self.cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.max_seq = max_seq
        self._decode = jax.jit(self.model.decode_step)
        # metrics are mutated from every serving thread — concurrent
        # generate() calls are supported, so counter updates take this
        # lock (prevents lost increments / torn read-modify-write)
        self._metrics_lock = threading.Lock()
        self.metrics = {"prefills": 0, "decode_ticks": 0, "tokens": 0}

    def _bump(self, key: str, n: int = 1):
        with self._metrics_lock:
            self.metrics[key] += n

    def _prefill_batch(self, prompts: np.ndarray):
        tokens = jnp.asarray(prompts, jnp.int32)
        if self.cfg.family == "encdec":
            frames = jnp.zeros((tokens.shape[0], tokens.shape[1],
                                self.cfg.d_model), jnp.float32)
            logits, cache = self.model.prefill(self.params, tokens, frames)
        else:
            logits, cache = self.model.prefill(self.params, tokens)
        self._bump("prefills")
        return logits, cache

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve a list of requests with continuous batching (greedy)."""
        pending = list(requests)
        results: Dict[int, List[int]] = {}
        while pending:
            batch = pending[:self.max_batch]
            pending = pending[self.max_batch:]
            plen = max(len(r.prompt) for r in batch)
            prompts = np.stack([
                np.pad(r.prompt, (plen - len(r.prompt), 0)) for r in batch])
            logits, cache = self._prefill_batch(prompts)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            steps = max(r.max_new for r in batch)
            for t in range(steps - 1):
                for i, r in enumerate(batch):
                    if len(r.out) < r.max_new:
                        r.out.append(int(tok[i, 0]))
                logits, cache = self._decode(self.params, cache, tok)
                tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
                self._bump("decode_ticks")
                self._bump("tokens", len(batch))
            for i, r in enumerate(batch):
                if len(r.out) < r.max_new:
                    r.out.append(int(tok[i, 0]))
                r.done = True
                results[r.rid] = r.out
        snap = telemetry().snapshot()
        with self._metrics_lock:
            # snapshot() is already internally consistent; the lock only
            # orders the dict swap against concurrent counter bumps.
            # snap["guard"] carries the PR-10 robustness counters
            # (ladder levels, degradations, breaker events, chaos fires).
            self.metrics["saturation"] = snap
        return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE_DIR,
                    help="persistent saturation cache directory")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the on-disk saturation cache")
    ap.add_argument("--verify", default=None,
                    choices=["off", "cheap", "full"],
                    help="static verification level for every kernel "
                         "build (default: REPRO_VERIFY, else off)")
    args = ap.parse_args(argv)

    # one documented front door for the cache/verify side-channels:
    # explicit arg > CLI flag > env var (REPRO_SAT_CACHE / REPRO_VERIFY)
    sat = SaturatorConfig.from_env(flags=args)
    srv = Server(args.arch, smoke=args.smoke,
                 cache_dir=sat.cache_dir or None, verify=sat.verify)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, srv.cfg.vocab,
                                        size=args.prompt_len
                                        - (i % 3)).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = srv.generate(reqs)
    dt = time.time() - t0
    sat = srv.metrics.get("saturation", {})
    print(f"arch={args.arch} served {len(out)} requests, "
          f"{srv.metrics['tokens']} tokens in {dt:.1f}s "
          f"({srv.metrics['prefills']} prefills, "
          f"{srv.metrics['decode_ticks']} ticks)")
    print(f"  saturation cache: hits={sat.get('cache_hits', 0)} "
          f"warm={sat.get('cache_warm_starts', 0)} "
          f"misses={sat.get('cache_misses', 0)} "
          f"hit_rate={sat.get('cache_hit_rate', 0.0):.2f}")
    guard = sat.get("guard", {})
    print(f"  guard: levels={guard.get('ladder_levels', {})} "
          f"degradations={sum(guard.get('degradations', {}).values())} "
          f"breaker={guard.get('breaker_events', {})} "
          f"runtime_fallbacks="
          f"{sum(guard.get('runtime_fallbacks', {}).values())}")
    for rid in sorted(out):
        print(f"  req{rid}: {out[rid]}")
    return out


if __name__ == "__main__":
    main()
