"""Training driver: elastic fault-tolerant loop over any assigned arch.

CPU-scale entry (smoke/examples):
  PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --smoke \\
      --steps 50 --batch 8 --seq 128

Production posture: the same loop drives the 16×16 / 2×16×16 meshes via
--mesh single|multi (requires a real pod or the dry-run device flag); the
jitted step carries explicit shardings from repro.parallel, checkpointing
is async+atomic, failures are recovered elastically, and the gradient
all-reduce can be compressed (--compress bf16|int8_ef).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import default_cache_dir
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import SaturatorConfig
from repro.core.telemetry import telemetry
from repro.data import DataConfig, ShardedTokenPipeline
from repro.kernels import ops
from repro.models import get_model
from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.parallel.compression import Compressor
from repro.runtime import ElasticTrainer, FailureInjector, TrainLoopConfig


def build_trainer(arch: str, *, smoke: bool, steps: int, batch: int,
                  seq: int, ckpt_dir: str, compress: str = "none",
                  inject: Optional[dict] = None, lr: float = 3e-4,
                  num_shards: int = 1, seed: int = 0,
                  cache_dir: Optional[str] = None,
                  verify: Optional[str] = None) -> ElasticTrainer:
    # persist saturation results (norm/optimizer tile ops) across runs:
    # a restarted or elastically-recovered job replays committed kernels
    # instead of re-searching
    if cache_dir is not None:
        ops.set_saturation_cache(cache_dir)
    if verify is not None:
        ops.set_saturation_verify(verify)
    arch = ARCH_IDS.get(arch, arch)
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = get_model(cfg)
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                        total_steps=steps)
    comp = Compressor(compress)

    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    opt_state = init_opt_state(params, opt_cfg)
    comp_state = comp.init_state(params) if compress == "int8_ef" else None

    def build_step(n_shards: int):
        pipe = ShardedTokenPipeline(DataConfig(
            vocab=cfg.vocab, seq_len=seq, global_batch=batch,
            seed=seed, shard_id=0, num_shards=1))

        @jax.jit
        def step(params, opt_state, batch_np):
            b = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if cfg.family == "encdec":
                b["frames"] = jax.random.normal(
                    jax.random.PRNGKey(0),
                    (b["tokens"].shape[0], b["tokens"].shape[1],
                     cfg.d_model), jnp.float32)
            loss, grads = jax.value_and_grad(model.loss)(params, b)
            # DP gradient exchange with optional compression (on one
            # process this is the identity wire format; wire-byte savings
            # are accounted in the roofline)
            if compress != "none":
                g_c, _ = comp.compress(grads, comp_state)
                grads = comp.decompress(g_c)
                grads = jax.tree.map(lambda g, p: g.astype(jnp.float32),
                                     grads, params)
            new_p, new_s = apply_updates(params, grads, opt_state, opt_cfg)
            return new_p, new_s, loss

        def step_np(params, opt_state, batch_np):
            return step(params, opt_state, batch_np)

        return step_np, pipe

    loop_cfg = TrainLoopConfig(total_steps=steps, ckpt_every=max(steps // 4,
                                                                 1),
                               ckpt_dir=ckpt_dir)
    return ElasticTrainer(loop_cfg, build_step, params, opt_state,
                          num_shards=num_shards,
                          injector=FailureInjector(inject))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8", "int8_ef"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--cache-dir", default=str(default_cache_dir()),
                    help="persistent saturation cache directory "
                         "(user-private by default)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the on-disk saturation cache")
    ap.add_argument("--verify", default=None,
                    choices=["off", "cheap", "full"],
                    help="static verification level for every kernel "
                         "build (default: REPRO_VERIFY, else off)")
    args = ap.parse_args(argv)

    inject = {args.inject_failure_at: ("node_loss", 1)} \
        if args.inject_failure_at else None
    # one documented front door for the cache/verify side-channels:
    # explicit arg > CLI flag > env var (REPRO_SAT_CACHE / REPRO_VERIFY)
    sat = SaturatorConfig.from_env(flags=args)
    trainer = build_trainer(args.arch, smoke=args.smoke, steps=args.steps,
                            batch=args.batch, seq=args.seq,
                            ckpt_dir=args.ckpt_dir, lr=args.lr,
                            compress=args.compress, inject=inject,
                            cache_dir=sat.cache_dir or None,
                            verify=sat.verify)
    t0 = time.time()
    out = trainer.run()
    losses = out["losses"]
    print(f"arch={args.arch} steps={out['final_step']} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"recoveries={out['recoveries']} wall={time.time()-t0:.1f}s")
    sat = telemetry().snapshot()
    print(f"  saturation cache: hits={sat['cache_hits']} "
          f"warm={sat['cache_warm_starts']} misses={sat['cache_misses']} "
          f"bridge_fallbacks={sum(sat['bridge_fallbacks'].values())}")
    ver = sat["verify"]
    print(f"  verify: runs={ver['runs']} errors={ver['errors']} "
          f"rules_checked={ver['rules_checked']} "
          f"schedules_certified={ver['schedules_certified']} "
          f"findings_by_pass={ver['findings_by_pass']}")
    guard = sat["guard"]
    print(f"  guard: levels={guard['ladder_levels']} "
          f"degradations={sum(guard['degradations'].values())} "
          f"breaker={guard['breaker_events']} "
          f"runtime_fallbacks={sum(guard['runtime_fallbacks'].values())} "
          f"recoveries={guard['elastic_recoveries']}")
    assert losses[-1] < losses[0], "training did not reduce loss"
    return out


if __name__ == "__main__":
    main()
