import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this
  * builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  * constructs abstract inputs (ShapeDtypeStruct — no allocation),
  * jits the right step (train_step / prefill / serve_step) with explicit
    in_shardings from repro.parallel.sharding,
  * ``.lower().compile()``s it,
  * prints memory_analysis() / cost_analysis() and derives the three-term
    roofline (repro.roofline), writing JSON to experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch minitron-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, ARCHS, SHAPES, applicable, get_config)
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as S
from repro.models import get_model
from repro.parallel import (batch_specs, cache_specs, ctx, opt_state_specs,
                            param_specs, to_named)
from repro.roofline.report import model_flops_for, roofline_from_compiled

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, overrides: dict = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    tp = mesh.shape.get("model", 1)
    if shape.kind == "train":
        # train cells shard the residual stream along S (Megatron SP):
        # saved activations divide by tp, so accumulation stays small
        cfg = dataclasses.replace(cfg, seq_shard=True)
    if shape.kind == "decode" and cfg.param_count() > 100e9:
        # 100B+ decode carries a TB-scale global KV cache: store it f8
        cfg = dataclasses.replace(cfg, kv_cache_dtype="f8")
    accum = S.default_accum_steps(cfg, shape, dp=dp, tp=tp)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = get_model(cfg)
    with ctx.activate(mesh):
        params_sds = S.params_struct(model)
        # FSDP policy: for training, shard params/moments over data when
        # the TP-sharded copy would not fit comfortably (>12B params);
        # with gradient accumulation FSDP re-gathers per microbatch, so
        # small models are cheaper replicated. Inference: 30B threshold.
        fsdp = (cfg.param_count() > 6e9) if shape.kind == "train" else None
        pspecs = param_specs(cfg, params_sds, mesh, fsdp=fsdp)
        psh = to_named(pspecs, mesh)

        # output shardings are pinned everywhere: leaving them to the
        # partitioner let the returned KV caches come back badly sharded
        # (mistral-large decode held a 22 GiB replicated cache output and
        # donation silently failed on the layout mismatch)
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(mesh, P())
        if shape.kind == "train":
            opt_cfg = S.default_opt_config(cfg)
            opt_sds = S.opt_struct(params_sds, opt_cfg)
            ospecs = opt_state_specs(cfg, opt_sds, pspecs, mesh)
            osh = to_named(ospecs, mesh)
            batch_sds = S.batch_spec_struct(cfg, shape)
            bsh = to_named(batch_specs(cfg, batch_sds, mesh), mesh)
            accum_dt = jnp.bfloat16 if cfg.param_count() > 100e9 else None
            step_fn = S.make_train_step(model, opt_cfg,
                                        accum_steps=accum,
                                        accum_dtype=accum_dt,
                                        grad_shardings=psh if accum > 1
                                        else None)
            jitted = jax.jit(step_fn, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, rep),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            batch_sds = S.batch_spec_struct(cfg, shape)
            bsh = to_named(batch_specs(cfg, batch_sds, mesh), mesh)
            step_fn = S.make_prefill_step(model, cfg)
            out_sds = jax.eval_shape(step_fn, params_sds, batch_sds)
            cache_osh = to_named(cache_specs(cfg, out_sds[1], mesh), mesh)
            jitted = jax.jit(step_fn, in_shardings=(psh, bsh),
                             out_shardings=(rep, cache_osh))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            cache_sds, token_sds = S.decode_input_struct(model, cfg, shape)
            csh = to_named(cache_specs(cfg, cache_sds, mesh), mesh)
            tsh = to_named(batch_specs(cfg, {"tokens": token_sds}, mesh),
                           mesh)["tokens"]
            step_fn = S.make_serve_step(model)
            jitted = jax.jit(step_fn, in_shardings=(psh, csh, tsh),
                             out_shardings=(rep, csh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, token_sds)

        compiled = lowered.compile()

    n_dev = mesh.size
    terms = roofline_from_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        n_devices=n_dev, model_flops_global=model_flops_for(cfg, shape))
    mem = compiled.memory_analysis()
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "compile_s": round(time.time() - t0, 1),
        "accum_steps": accum, "seq_shard": cfg.seq_shard,
        "memory_analysis": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        } if mem else None,
        "roofline": terms.to_dict(),
    }
    if verbose:
        ma = result["memory_analysis"]
        per_dev_gb = terms.bytes_per_device / 2**30
        print(f"[{arch} × {shape_name} × {mesh_name}] compiled in "
              f"{result['compile_s']}s")
        print(f"  memory_analysis: args={ma['argument_bytes']/2**30:.2f}GiB "
              f"temp={ma['temp_bytes']/2**30:.2f}GiB "
              f"out={ma['output_bytes']/2**30:.2f}GiB "
              f"alias={ma['alias_bytes']/2**30:.2f}GiB "
              f"-> {per_dev_gb:.2f}GiB/device "
              f"({'FITS' if terms.fits_hbm else 'OVER'} 16GiB)")
        print(f"  cost_analysis(xla): flops={terms.xla_flops:.3e} "
              f"bytes={terms.xla_bytes:.3e} (scan bodies counted once)")
        print(f"  roofline/device: compute={terms.compute_s*1e3:.2f}ms "
              f"memory={terms.memory_s*1e3:.2f}ms "
              f"collective={terms.collective_s*1e3:.2f}ms "
              f"dominant={terms.dominant} "
              f"frac={terms.roofline_frac:.3f}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="run only the 2x16x16 mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="run only the 16x16 mesh")
    ap.add_argument("--out", type=str, default=str(OUT_DIR))
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    if args.single_pod:
        meshes = [False]

    archs = ARCHS if (args.all or not args.arch) else \
        [ARCH_IDS.get(args.arch, args.arch)]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape_name}_{'mp' if mp else 'sp'}"
                path = out_dir / f"{tag}.json"
                if path.exists():
                    print(f"[{tag}] cached -> {path}")
                    continue
                try:
                    res = run_cell(arch, shape_name, mp)
                except Exception as e:
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "mp" if mp else "sp",
                           "status": "error", "error": str(e)[-2000:]}
                    failures.append(tag)
                path.write_text(json.dumps(res, indent=1))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
