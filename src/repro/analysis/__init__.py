"""Unified operation-statistics engine (roofline-calibrated costs).

Merges the HLO roofline analyzer and the e-graph extraction cost models
into one subsystem: per-node FLOP/byte/pass statistics
(:mod:`.opstats`), a hardware latency model derived from the chip peaks
(:mod:`.latency`), the extraction objective (:mod:`.cost_model`), and
the HLO bridge (:mod:`.hlo`).
"""
from .opstats import (DTYPE_BYTES, TILE_ELEMS, TILE_SHAPE, ArrayInfo,
                      OpStats, dtype_byte_width, node_stats, op_pass_class,
                      store_stats)
from .latency import LatencyModel, ScheduleEvent
from .cost_model import RooflineCostModel
from .hlo import latency_from_hlo, stats_from_hlo, stats_from_report
from .calibrate import (DEFAULT_PARAMS, SPEARMAN_FLOOR, CalibrationError,
                        CalibrationParams, DeviceProfile, KernelFeatures,
                        check_profile, evaluate_params, fit_params,
                        fit_profile, kernel_features, load_profile, mape_pct,
                        predict_ns, schedule_paired_pct, spearman)

__all__ = [
    "OpStats", "node_stats", "op_pass_class", "store_stats",
    "TILE_ELEMS", "TILE_SHAPE", "DTYPE_BYTES",
    "ArrayInfo", "dtype_byte_width",
    "LatencyModel", "ScheduleEvent", "RooflineCostModel",
    "latency_from_hlo", "stats_from_hlo", "stats_from_report",
    "DEFAULT_PARAMS", "SPEARMAN_FLOOR",
    "CalibrationError", "CalibrationParams", "DeviceProfile",
    "KernelFeatures", "check_profile", "evaluate_params", "fit_params",
    "fit_profile", "kernel_features", "load_profile", "mape_pct",
    "predict_ns", "schedule_paired_pct", "spearman",
]
