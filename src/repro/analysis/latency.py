"""Hardware latency model: OpStats → predicted nanoseconds on a chip.

Classic roofline with three ceilings derived from the
:class:`repro.core.hardware.ChipSpec` peaks:

  compute  = VPU passes × tile cycles / clock  +  MXU FLOPs / peak
  memory   = HBM bytes / (HBM bandwidth × efficiency)
  latency  = base + max(compute, memory) + slack × min(compute, memory)

The ``overlap_slack`` term models imperfect compute/memory overlap (DMA
issue, semaphore waits). It is deliberately small — the roofline maximum
still dominates — but it makes the objective strictly monotone in both
axes, so extraction always prefers "less computation, less memory access"
even for terms pinned against one roof (the paper's §V-B motivation:
ties under a flat weight table are exactly where extraction quality is
lost).

The model is *calibratable*: :meth:`LatencyModel.from_profile` loads a
fitted :class:`repro.analysis.calibrate.DeviceProfile` whose measured
parameters replace the analytic guesses — per-bound overlap slack
(compute-bound and memory-bound kernels hide traffic differently), an
HBM-efficiency factor (achieved vs peak bandwidth), a constant
per-instance launch overhead ``base_ns``, and per-op-class VPU pass
coefficients (``pass_coeffs``, applied at node-pricing time by
:class:`repro.analysis.cost_model.RooflineCostModel` so the aggregate
``vpu_passes`` arriving here is already coefficient-weighted). With the
default values the formula reduces exactly to the uncalibrated model.

Schedule awareness (PR 5)
-------------------------
Statement order matters on real machines: a load issued far ahead of its
first consumer hides its HBM transfer behind the intervening compute,
one issued right before it stalls. Two layers model this:

* :meth:`LatencyModel.schedule_ns` prices an explicit issue *order* — a
  sequence of :class:`ScheduleEvent` — with a position-dependent overlap
  term (per-load exposed transfer = ``max(0, mem − eff × gap)`` where
  ``gap`` is the issue time between the load and its first consumer)
  plus a VMEM live-range pressure penalty when the peak live working
  set exceeds the budget. :mod:`repro.core.schedule` minimizes this
  objective when searching over legal topological orders.
* when a fitted ``overlap_efficiency`` is present (schedule-aware device
  profiles), the *aggregate* :meth:`latency_ns` replaces the scalar
  ``overlap_slack`` coupling with the best-schedule bound
  ``memory − min(memory, eff × compute)`` — the extraction beam then
  optimizes the same objective the downstream scheduler realizes. With
  ``overlap_efficiency=None`` (the default, and every pre-PR-5 profile)
  the formula reduces exactly to the PR-4 model.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Mapping, Optional, Sequence

from .opstats import OpStats, TILE_ELEMS, dtype_byte_width

if TYPE_CHECKING:
    from repro.core.hardware import ChipSpec


def _default_chip():
    # deferred: repro.core.__init__ imports this package, so hardware must
    # not be pulled in at module load time
    from repro.core.hardware import DEFAULT_CHIP
    return DEFAULT_CHIP


@dataclasses.dataclass(frozen=True)
class ScheduleEvent:
    """One issue slot of an explicit kernel schedule.

    ``issue_ns`` is how long the slot occupies the issue pipeline
    (compute: its VPU/MXU time; load/store: the calibrated per-access
    dispatch cost). ``mem_ns`` is the asynchronous HBM transfer the slot
    starts (0 for compute). ``first_use``/``last_use`` index the event
    list: the transfer must complete before ``first_use`` issues, and
    ``bytes_live`` stays resident in VMEM through ``last_use``.
    ``first_use=-1`` means no later consumer (the transfer drains
    against everything issued afterwards — how stores behave).
    """
    kind: str                    # "load" | "compute" | "store"
    issue_ns: float = 0.0
    mem_ns: float = 0.0
    bytes_live: float = 0.0
    first_use: int = -1
    last_use: int = -1


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    chip: Optional["ChipSpec"] = None   # None -> DEFAULT_CHIP
    tile_elems: int = TILE_ELEMS
    overlap_slack: float = 0.05
    # Matrix-unit dtype: the MXU peak scales with operand width (f32 runs
    # at half the bf16 rate, 8-bit at double it). None keeps the legacy
    # bf16-peak pricing for callers that never declared a dtype.
    mxu_dtype: Optional[str] = None
    # -- calibrated parameters (defaults == the analytic model) ------------
    # Per-bound overlap slack: measured kernels hide the minor axis
    # differently depending on which roof binds; ``None`` falls back to
    # the shared ``overlap_slack``.
    overlap_slack_compute: Optional[float] = None
    overlap_slack_memory: Optional[float] = None
    # Achieved/peak HBM bandwidth ratio (memory roof divisor).
    hbm_efficiency: float = 1.0
    # Constant per-instance overhead (kernel launch / interpret dispatch).
    base_ns: float = 0.0
    # Per-op-class VPU pass multipliers fitted by calibration. NOT applied
    # here (OpStats only carries aggregate passes) — RooflineCostModel
    # scales each node's passes by its class coefficient at pricing time.
    pass_coeffs: Optional[Mapping[str, float]] = None
    # -- schedule-aware parameters (PR 5) ----------------------------------
    # Fraction of the issue time between a load and its first consumer
    # that actually hides the load's HBM transfer. ``None`` keeps the
    # PR-4 aggregate formula (scalar per-bound slack); a fitted value
    # switches ``latency_ns`` to the best-schedule bound and is what
    # ``schedule_ns`` scales its per-load overlap windows by.
    overlap_efficiency: Optional[float] = None
    # ns of penalty per byte of VMEM working set beyond the budget,
    # expressed as a multiplier on the spill traffic's HBM time.
    vmem_pressure_coeff: float = 0.0
    # Name of the device profile these parameters came from (reporting).
    profile_name: Optional[str] = None

    def __post_init__(self):
        if self.chip is None:
            object.__setattr__(self, "chip", _default_chip())

    @classmethod
    def from_profile(cls, profile, *, chip: Optional["ChipSpec"] = None,
                     mxu_dtype: Optional[str] = None) -> "LatencyModel":
        """Calibrated model from a :class:`DeviceProfile` (or a path /
        bare profile name resolved via ``calibrate.load_profile``).

        ``chip=None`` resolves the profile's stored ``model_chip`` — the
        ChipSpec its coefficients were fitted against — so a profile
        fitted on non-default constants is never silently re-priced with
        the default ones.
        """
        from .calibrate import chip_by_name, load_profile  # deferred cycle
        prof = load_profile(profile)
        if chip is None:
            chip = chip_by_name(prof.model_chip)
        p = prof.params
        return cls(chip=chip, tile_elems=prof.tile_elems,
                   overlap_slack=p.overlap_slack_compute,
                   overlap_slack_compute=p.overlap_slack_compute,
                   overlap_slack_memory=p.overlap_slack_memory,
                   hbm_efficiency=p.hbm_efficiency, base_ns=p.base_ns,
                   pass_coeffs=dict(p.vpu_pass_coeffs),
                   overlap_efficiency=p.overlap_efficiency,
                   vmem_pressure_coeff=p.vmem_pressure_coeff,
                   mxu_dtype=mxu_dtype, profile_name=prof.name)

    @property
    def slack_compute(self) -> float:
        """Overlap slack applied when the compute roof binds."""
        s = self.overlap_slack_compute
        return self.overlap_slack if s is None else s

    @property
    def slack_memory(self) -> float:
        """Overlap slack applied when the memory roof binds."""
        s = self.overlap_slack_memory
        return self.overlap_slack if s is None else s

    def mxu_peak_flops(self) -> float:
        peak = self.chip.peak_flops_bf16
        if self.mxu_dtype is None:
            return peak
        width = dtype_byte_width(self.mxu_dtype)
        if width >= 4:
            return peak / 2.0
        if width == 1:
            return peak * 2.0
        return peak

    def compute_ns(self, stats: OpStats) -> float:
        vpu_s = stats.vpu_passes * self.tile_elems / self.chip.vpu_elems_per_s
        mxu_s = stats.mxu_flops / self.mxu_peak_flops()
        return (vpu_s + mxu_s) * 1e9

    def memory_ns(self, stats: OpStats) -> float:
        return stats.total_bytes / (self.chip.hbm_bw
                                    * self.hbm_efficiency) * 1e9

    def latency_ns(self, stats: OpStats) -> float:
        c = self.compute_ns(stats)
        m = self.memory_ns(stats)
        if self.overlap_efficiency is not None:
            # best-schedule bound: the downstream scheduler can hide at
            # most eff × compute of the memory traffic behind compute
            # issue slots; the exposed remainder couples via the fitted
            # per-bound slack exactly as in the PR-4 formula (eff=0
            # reduces to it bit-for-bit)
            m = m - min(m, self.overlap_efficiency * c)
        slack = self.slack_compute if c >= m else self.slack_memory
        return self.base_ns + max(c, m) + slack * min(c, m)

    # -- schedule-aware objective (PR 5) ------------------------------------
    def vmem_budget_bytes(self) -> int:
        """Working-set budget for the pressure term: a quarter of the
        chip's VMEM, matching ``pick_row_block``'s headroom for compiler
        temporaries."""
        return int(self.chip.vmem_bytes) // 4

    def schedule_ns(self, events: Sequence[ScheduleEvent], *,
                    vmem_budget_bytes: Optional[int] = None
                    ) -> Dict[str, float]:
        """Price an explicit issue order (position-dependent roofline).

        The issue pipeline executes ``events`` in order; each load/store
        starts an asynchronous HBM transfer at issue time. A transfer is
        hidden by ``overlap_efficiency`` × the issue time between it and
        its first consumer (end of schedule for consumer-less stores);
        the un-hidden remainder is exposed stall time. Loads hold
        ``bytes_live`` of VMEM from issue through ``last_use``; the peak
        live set beyond the budget is charged as spill traffic scaled by
        ``vmem_pressure_coeff``.

        Returns a breakdown dict; ``latency_ns`` is the objective
        :mod:`repro.core.schedule` minimizes.
        """
        eff = (self.overlap_efficiency
               if self.overlap_efficiency is not None else 1.0)
        budget = (self.vmem_budget_bytes() if vmem_budget_bytes is None
                  else vmem_budget_bytes)
        n = len(events)
        cum = [0.0] * (n + 1)   # issue time elapsed before slot i
        for i, ev in enumerate(events):
            cum[i + 1] = cum[i] + ev.issue_ns
        exposed = 0.0
        peak_live = live = 0.0
        # bytes whose live range ends after slot i (swept in order)
        drops = [0.0] * (n + 1)
        for i, ev in enumerate(events):
            if ev.mem_ns > 0.0:
                end = ev.first_use if ev.first_use >= 0 else n
                gap = max(0.0, cum[end] - cum[i + 1])
                exposed += max(0.0, ev.mem_ns - eff * gap)
            if ev.bytes_live > 0.0:
                live += ev.bytes_live
                last = ev.last_use if ev.last_use >= 0 else n - 1
                drops[min(last, n - 1) + 1] += ev.bytes_live
            peak_live = max(peak_live, live)
            live -= drops[i + 1]
        spill = max(0.0, peak_live - budget)
        pressure = (self.vmem_pressure_coeff * spill
                    / (self.chip.hbm_bw * self.hbm_efficiency) * 1e9)
        compute = cum[n]
        return {
            "latency_ns": self.base_ns + compute + exposed + pressure,
            "issue_ns": compute,
            "exposed_mem_ns": exposed,
            "peak_live_bytes": peak_live,
            "pressure_ns": pressure,
        }

    def bound(self, stats: OpStats) -> str:
        return "compute" if self.compute_ns(stats) >= self.memory_ns(stats) \
            else "memory"

    def arithmetic_intensity(self, stats: OpStats) -> float:
        return stats.total_flops / stats.total_bytes if stats.total_bytes \
            else float("inf")

    def throughput_gbps(self, stats: OpStats) -> float:
        """Achieved HBM GB/s if the term runs at predicted latency."""
        lat = self.latency_ns(stats)
        return stats.total_bytes / lat if lat > 0 else 0.0

    def report(self, stats: OpStats) -> Dict[str, float]:
        return {
            "flops": stats.total_flops,
            "vpu_passes": stats.vpu_passes,
            "bytes_read": stats.bytes_read,
            "bytes_written": stats.bytes_written,
            "compute_ns": self.compute_ns(stats),
            "memory_ns": self.memory_ns(stats),
            "latency_ns": self.latency_ns(stats),
            "bound": self.bound(stats),
            "arithmetic_intensity": self.arithmetic_intensity(stats),
            "n_ops": stats.n_ops,
            "profile": self.profile_name,
        }
