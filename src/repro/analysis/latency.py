"""Hardware latency model: OpStats → predicted nanoseconds on a chip.

Classic roofline with three ceilings derived from the
:class:`repro.core.hardware.ChipSpec` peaks:

  compute  = VPU passes × tile cycles / clock  +  MXU FLOPs / peak
  memory   = HBM bytes / HBM bandwidth
  latency  = max(compute, memory) + slack × min(compute, memory)

The ``overlap_slack`` term models imperfect compute/memory overlap (DMA
issue, semaphore waits). It is deliberately small — the roofline maximum
still dominates — but it makes the objective strictly monotone in both
axes, so extraction always prefers "less computation, less memory access"
even for terms pinned against one roof (the paper's §V-B motivation:
ties under a flat weight table are exactly where extraction quality is
lost).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Optional

from .opstats import OpStats, TILE_ELEMS, dtype_byte_width

if TYPE_CHECKING:
    from repro.core.hardware import ChipSpec


def _default_chip():
    # deferred: repro.core.__init__ imports this package, so hardware must
    # not be pulled in at module load time
    from repro.core.hardware import DEFAULT_CHIP
    return DEFAULT_CHIP


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    chip: Optional["ChipSpec"] = None   # None -> DEFAULT_CHIP
    tile_elems: int = TILE_ELEMS
    overlap_slack: float = 0.05
    # Matrix-unit dtype: the MXU peak scales with operand width (f32 runs
    # at half the bf16 rate, 8-bit at double it). None keeps the legacy
    # bf16-peak pricing for callers that never declared a dtype.
    mxu_dtype: Optional[str] = None

    def __post_init__(self):
        if self.chip is None:
            object.__setattr__(self, "chip", _default_chip())

    def mxu_peak_flops(self) -> float:
        peak = self.chip.peak_flops_bf16
        if self.mxu_dtype is None:
            return peak
        width = dtype_byte_width(self.mxu_dtype)
        if width >= 4:
            return peak / 2.0
        if width == 1:
            return peak * 2.0
        return peak

    def compute_ns(self, stats: OpStats) -> float:
        vpu_s = stats.vpu_passes * self.tile_elems / self.chip.vpu_elems_per_s
        mxu_s = stats.mxu_flops / self.mxu_peak_flops()
        return (vpu_s + mxu_s) * 1e9

    def memory_ns(self, stats: OpStats) -> float:
        return stats.total_bytes / self.chip.hbm_bw * 1e9

    def latency_ns(self, stats: OpStats) -> float:
        c = self.compute_ns(stats)
        m = self.memory_ns(stats)
        return max(c, m) + self.overlap_slack * min(c, m)

    def bound(self, stats: OpStats) -> str:
        return "compute" if self.compute_ns(stats) >= self.memory_ns(stats) \
            else "memory"

    def arithmetic_intensity(self, stats: OpStats) -> float:
        return stats.total_flops / stats.total_bytes if stats.total_bytes \
            else float("inf")

    def throughput_gbps(self, stats: OpStats) -> float:
        """Achieved HBM GB/s if the term runs at predicted latency."""
        lat = self.latency_ns(stats)
        return stats.total_bytes / lat if lat > 0 else 0.0

    def report(self, stats: OpStats) -> Dict[str, float]:
        return {
            "flops": stats.total_flops,
            "vpu_passes": stats.vpu_passes,
            "bytes_read": stats.bytes_read,
            "bytes_written": stats.bytes_written,
            "compute_ns": self.compute_ns(stats),
            "memory_ns": self.memory_ns(stats),
            "latency_ns": self.latency_ns(stats),
            "bound": self.bound(stats),
            "arithmetic_intensity": self.arithmetic_intensity(stats),
            "n_ops": stats.n_ops,
        }
