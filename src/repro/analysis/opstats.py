"""Operation statistics: the unified per-node accounting engine.

One table classifies every IR operator (shared with the paper-weight
adapters in :mod:`repro.core.cost`), and :func:`node_stats` prices a node
in hardware terms — FLOPs, HBM byte traffic, and VPU tile passes — under
the saturator's tile execution model: every e-graph term is the body of
one tile program, so a `load` moves one tile HBM→VMEM and an elementwise
op is one (or more) full-tile VPU passes.

These statistics are the shared currency between the e-graph extractor
(:class:`repro.analysis.cost_model.RooflineCostModel`) and the HLO
roofline walk (:mod:`repro.analysis.hlo`): both sides reduce to an
:class:`OpStats`, and :mod:`repro.analysis.latency` turns either into a
predicted latency against the chip peaks.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid a load-time cycle: repro.core.cost imports us
    from repro.core.ir import ENode

# ---------------------------------------------------------------------------
# Operator classification — the single source of truth. The paper cost
# model (repro.core.cost) derives its 0/1/10/100 weight classes from these
# same sets, so the two layers can never drift apart.
# ---------------------------------------------------------------------------
FREE_OPS = frozenset({"const", "tuple"})
INPUT_OPS = frozenset({"var", "array"})          # paper weight 1
PHI_OPS = frozenset({"phi", "phi_loop"})         # paper weight 1
MEMORY_OPS = frozenset({"load"})                 # paper weight 100
CALL_OPS = frozenset({"call"})                   # paper weight 100
SERIAL_ARITH = frozenset({"div", "mod"})         # paper weight 100
TRANSCENDENTALS = frozenset({"exp", "log", "tanh", "sigmoid", "pow"})
ROOTLIKE = frozenset({"sqrt", "rsqrt", "recip"})
SIGN_OPS = frozenset({"neg"})                    # folds into FMA operands
REDUCTIONS = frozenset({"rsum", "rmean", "rmax"})

# Default tile geometry: one (8, 128) f32 vreg tile per term instance.
TILE_ELEMS = 8 * 128
DTYPE_BYTES = 4

# VPU multi-pass issue counts (v5e timing; same rationale as TPUCostModel:
# transcendentals are 4-8 pass pipelined polynomial sequences, true divide
# ~10 passes, cross-lane reductions a short log-tree).
_PASSES = {
    "transcendental": 8.0,
    "rootlike": 4.0,
    "serial": 10.0,
    "call": 20.0,
    "reduction": 4.0,
    "simple": 1.0,
    "sign": 0.0,     # folds into the consumer's FMA operand slot
    "leaf": 0.0,
}

# FLOPs per element (mirrors repro.core.cost.count_flops so roofline and
# histogram accounting agree).
_FLOPS_PER_ELEM = {
    "add": 1, "sub": 1, "mul": 1, "div": 1, "neg": 1, "min": 1, "max": 1,
    "square": 1, "recip": 1, "mod": 1, "fma": 2,
    "exp": 8, "log": 8, "sqrt": 8, "rsqrt": 8, "tanh": 8, "sigmoid": 8,
    "pow": 8,
    "rsum": 1, "rmean": 1, "rmax": 1,
}


@dataclasses.dataclass(frozen=True)
class OpStats:
    """Additive hardware statistics for a node, term, or whole program."""
    flops: float = 0.0            # elementwise (VPU) floating-point ops
    mxu_flops: float = 0.0        # matrix-unit FLOPs (HLO dots/convs)
    bytes_read: float = 0.0
    bytes_written: float = 0.0
    vpu_passes: float = 0.0       # full-tile vector issue slots
    n_ops: int = 0                # executed instructions (non-leaf nodes)

    @property
    def total_flops(self) -> float:
        return self.flops + self.mxu_flops

    @property
    def total_bytes(self) -> float:
        return self.bytes_read + self.bytes_written

    def __add__(self, other: "OpStats") -> "OpStats":
        return OpStats(
            flops=self.flops + other.flops,
            mxu_flops=self.mxu_flops + other.mxu_flops,
            bytes_read=self.bytes_read + other.bytes_read,
            bytes_written=self.bytes_written + other.bytes_written,
            vpu_passes=self.vpu_passes + other.vpu_passes,
            n_ops=self.n_ops + other.n_ops)

    def scaled(self, k: float) -> "OpStats":
        return OpStats(flops=self.flops * k, mxu_flops=self.mxu_flops * k,
                       bytes_read=self.bytes_read * k,
                       bytes_written=self.bytes_written * k,
                       vpu_passes=self.vpu_passes * k,
                       n_ops=int(self.n_ops * k))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def op_pass_class(op: str) -> str:
    """Pass-count class of an operator (also keys the paper adapters)."""
    if op in FREE_OPS or op in INPUT_OPS or op in PHI_OPS:
        return "leaf"
    if op in SIGN_OPS:
        return "sign"
    if op in TRANSCENDENTALS:
        return "transcendental"
    if op in ROOTLIKE:
        return "rootlike"
    if op in SERIAL_ARITH:
        return "serial"
    if op in CALL_OPS:
        return "call"
    if op in REDUCTIONS:
        return "reduction"
    if op in MEMORY_OPS:
        return "leaf"   # no VPU pass; priced on the memory axis
    return "simple"     # arith, cmp, select, structural tile ops


def node_stats(node: ENode, *, tile_elems: int = TILE_ELEMS,
               dtype_bytes: int = DTYPE_BYTES) -> OpStats:
    """Hardware statistics of one e-node under tile semantics."""
    op = node.op
    tile_bytes = float(tile_elems * dtype_bytes)
    counted = op not in FREE_OPS and op not in INPUT_OPS
    if op in MEMORY_OPS:
        return OpStats(bytes_read=tile_bytes, n_ops=1)
    passes = _PASSES[op_pass_class(op)]
    flops = _FLOPS_PER_ELEM.get(op, 0) * float(tile_elems)
    return OpStats(flops=flops, vpu_passes=passes, n_ops=1 if counted else 0)


def store_stats(n_stores: int, *, tile_elems: int = TILE_ELEMS,
                dtype_bytes: int = DTYPE_BYTES) -> OpStats:
    """Write traffic of a term's root stores (constant across extraction
    choices — reported, never part of the minimized objective)."""
    return OpStats(bytes_written=float(n_stores * tile_elems * dtype_bytes))
