"""Operation statistics: the unified per-node accounting engine.

One table classifies every IR operator (shared with the paper-weight
adapters in :mod:`repro.core.cost`), and :func:`node_stats` prices a node
in hardware terms — FLOPs, HBM byte traffic, and VPU tile passes — under
the saturator's tile execution model: every e-graph term is the body of
one tile program, so a `load` moves one tile HBM→VMEM and an elementwise
op is one (or more) full-tile VPU passes.

These statistics are the shared currency between the e-graph extractor
(:class:`repro.analysis.cost_model.RooflineCostModel`) and the HLO
roofline walk (:mod:`repro.analysis.hlo`): both sides reduce to an
:class:`OpStats`, and :mod:`repro.analysis.latency` turns either into a
predicted latency against the chip peaks.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence, Tuple

if TYPE_CHECKING:  # avoid a load-time cycle: repro.core.cost imports us
    from repro.core.ir import ENode

# ---------------------------------------------------------------------------
# Operator classification — the single source of truth. The paper cost
# model (repro.core.cost) derives its 0/1/10/100 weight classes from these
# same sets, so the two layers can never drift apart.
# ---------------------------------------------------------------------------
FREE_OPS = frozenset({"const", "tuple"})
INPUT_OPS = frozenset({"var", "array"})          # paper weight 1
PHI_OPS = frozenset({"phi", "phi_loop"})         # paper weight 1
MEMORY_OPS = frozenset({"load"})                 # paper weight 100
CALL_OPS = frozenset({"call"})                   # paper weight 100
SERIAL_ARITH = frozenset({"div", "mod"})         # paper weight 100
TRANSCENDENTALS = frozenset({"exp", "log", "tanh", "sigmoid", "pow"})
ROOTLIKE = frozenset({"sqrt", "rsqrt", "recip"})
SIGN_OPS = frozenset({"neg"})                    # folds into FMA operands
REDUCTIONS = frozenset({"rsum", "rmean", "rmax"})

# Default tile geometry: one (8, 128) f32 vreg tile per term instance.
TILE_SHAPE = (8, 128)
TILE_ELEMS = TILE_SHAPE[0] * TILE_SHAPE[1]
DTYPE_BYTES = 4

# HBM byte width per element for the dtypes the saturator prices. bf16/f16
# tiles move half the bytes of f32, f8 a quarter — the memory roof scales
# with the stored width, not the compute width.
DTYPE_BYTE_WIDTH = {
    "f64": 8, "i64": 8,
    "f32": 4, "tf32": 4, "i32": 4,
    "bf16": 2, "f16": 2, "i16": 2,
    "f8": 1, "f8_e4m3": 1, "f8_e5m2": 1, "i8": 1, "bool": 1,
}


def dtype_byte_width(dtype: str) -> int:
    """HBM bytes per element of ``dtype`` (raises on unknown names so a
    typo'd declaration fails loudly instead of silently pricing as f32)."""
    try:
        return DTYPE_BYTE_WIDTH[dtype]
    except KeyError:
        raise ValueError(
            f"unknown dtype {dtype!r}; known: {sorted(DTYPE_BYTE_WIDTH)}")


@dataclasses.dataclass(frozen=True)
class ArrayInfo:
    """Declared (shape, dtype) of one kernel array — the SSA array table
    entry the analysis layer prices loads/stores with.

    ``shape=None`` means unknown extent (price a full tile, the pre-shape
    behavior). A dimension may be ``None`` for a symbolic/runtime extent;
    any symbolic dimension left after indexing also falls back to a full
    tile. Known extents are capped at the tile size: one term instance
    never moves more than one tile per load, but a broadcast scalar or row
    moves only its true operand extent.
    """
    shape: Optional[Tuple[Optional[int], ...]] = None
    dtype: str = "f32"

    @property
    def byte_width(self) -> int:
        return dtype_byte_width(self.dtype)

    def index(self, n_idx: int) -> "ArrayInfo":
        """Info of the operand left after ``n_idx`` leading indices."""
        if self.shape is None or n_idx <= 0:
            return self
        return ArrayInfo(shape=self.shape[n_idx:], dtype=self.dtype)

    def elems(self, tile_elems: int = TILE_ELEMS) -> int:
        """Per-tile-instance element extent of this operand."""
        if self.shape is None:
            return tile_elems
        n = 1
        for d in self.shape:
            if d is None:       # symbolic dimension: unknown extent
                return tile_elems
            n *= int(d)
        return min(n, tile_elems)

    def bytes(self, tile_elems: int = TILE_ELEMS) -> float:
        return float(self.elems(tile_elems) * self.byte_width)

# VPU multi-pass issue counts (v5e timing; same rationale as TPUCostModel:
# transcendentals are 4-8 pass pipelined polynomial sequences, true divide
# ~10 passes, cross-lane reductions a short log-tree).
_PASSES = {
    "transcendental": 8.0,
    "rootlike": 4.0,
    "serial": 10.0,
    "call": 20.0,
    "reduction": 4.0,
    "simple": 1.0,
    "sign": 0.0,     # folds into the consumer's FMA operand slot
    "leaf": 0.0,
}

# FLOPs per element (mirrors repro.core.cost.count_flops so roofline and
# histogram accounting agree).
_FLOPS_PER_ELEM = {
    "add": 1, "sub": 1, "mul": 1, "div": 1, "neg": 1, "min": 1, "max": 1,
    "square": 1, "recip": 1, "mod": 1, "fma": 2,
    "exp": 8, "log": 8, "sqrt": 8, "rsqrt": 8, "tanh": 8, "sigmoid": 8,
    "pow": 8,
    "rsum": 1, "rmean": 1, "rmax": 1,
}


@dataclasses.dataclass(frozen=True)
class OpStats:
    """Additive hardware statistics for a node, term, or whole program."""
    flops: float = 0.0            # elementwise (VPU) floating-point ops
    mxu_flops: float = 0.0        # matrix-unit FLOPs (HLO dots/convs)
    bytes_read: float = 0.0
    bytes_written: float = 0.0
    vpu_passes: float = 0.0       # full-tile vector issue slots
    n_ops: int = 0                # executed instructions (non-leaf nodes)

    @property
    def total_flops(self) -> float:
        return self.flops + self.mxu_flops

    @property
    def total_bytes(self) -> float:
        return self.bytes_read + self.bytes_written

    def __add__(self, other: "OpStats") -> "OpStats":
        return OpStats(
            flops=self.flops + other.flops,
            mxu_flops=self.mxu_flops + other.mxu_flops,
            bytes_read=self.bytes_read + other.bytes_read,
            bytes_written=self.bytes_written + other.bytes_written,
            vpu_passes=self.vpu_passes + other.vpu_passes,
            n_ops=self.n_ops + other.n_ops)

    def scaled(self, k: float) -> "OpStats":
        return OpStats(flops=self.flops * k, mxu_flops=self.mxu_flops * k,
                       bytes_read=self.bytes_read * k,
                       bytes_written=self.bytes_written * k,
                       vpu_passes=self.vpu_passes * k,
                       n_ops=int(self.n_ops * k))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def op_pass_class(op: str) -> str:
    """Pass-count class of an operator (also keys the paper adapters)."""
    if op in FREE_OPS or op in INPUT_OPS or op in PHI_OPS:
        return "leaf"
    if op in SIGN_OPS:
        return "sign"
    if op in TRANSCENDENTALS:
        return "transcendental"
    if op in ROOTLIKE:
        return "rootlike"
    if op in SERIAL_ARITH:
        return "serial"
    if op in CALL_OPS:
        return "call"
    if op in REDUCTIONS:
        return "reduction"
    if op in MEMORY_OPS:
        return "leaf"   # no VPU pass; priced on the memory axis
    return "simple"     # arith, cmp, select, structural tile ops


def node_stats(node: ENode, *, tile_elems: int = TILE_ELEMS,
               dtype_bytes: int = DTYPE_BYTES,
               info: Optional[ArrayInfo] = None) -> OpStats:
    """Hardware statistics of one e-node under tile semantics.

    ``info`` — when the caller resolved the loaded operand's
    :class:`ArrayInfo` (shape after indexing + dtype), a load is priced at
    its true operand extent and byte width: a broadcast scalar costs one
    element, a broadcast row one row, a bf16 tile half an f32 tile.
    Without it, loads keep the full-f32-tile default.
    """
    op = node.op
    counted = op not in FREE_OPS and op not in INPUT_OPS
    if op in MEMORY_OPS:
        if info is not None:
            return OpStats(bytes_read=info.bytes(tile_elems), n_ops=1)
        return OpStats(bytes_read=float(tile_elems * dtype_bytes), n_ops=1)
    passes = _PASSES[op_pass_class(op)]
    flops = _FLOPS_PER_ELEM.get(op, 0) * float(tile_elems)
    return OpStats(flops=flops, vpu_passes=passes, n_ops=1 if counted else 0)


def store_stats(n_stores: int, *, tile_elems: int = TILE_ELEMS,
                dtype_bytes: int = DTYPE_BYTES,
                infos: Optional[Sequence[Optional[ArrayInfo]]] = None
                ) -> OpStats:
    """Write traffic of a term's root stores (constant across extraction
    choices — reported, never part of the minimized objective).

    With ``infos`` (one entry per store, ``None`` = unknown) each store is
    priced at its target operand's true extent and byte width instead of a
    full f32 tile; ``n_stores`` is then ignored in favor of the list.
    """
    if infos is not None:
        total = 0.0
        for inf in infos:
            if inf is None:
                total += float(tile_elems * dtype_bytes)
            else:
                total += inf.bytes(tile_elems)
        return OpStats(bytes_written=total)
    return OpStats(bytes_written=float(n_stores * tile_elems * dtype_bytes))
