"""HLO side of the unified analysis subsystem.

Bridges the trip-count-aware HLO walk
(:mod:`repro.roofline.hlo_analysis`) into the same :class:`OpStats` /
:class:`LatencyModel` currency the e-graph extractor prices terms with,
so predicted-vs-measured throughput can be tracked in one unit system
from a single tile body all the way up to a compiled training step.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.hardware import DEFAULT_CHIP, ChipSpec
from repro.roofline.hlo_analysis import HLOReport, analyze
from .latency import LatencyModel
from .opstats import OpStats


def stats_from_report(rep: HLOReport) -> OpStats:
    """Collapse an HLO walk into OpStats (traffic model counts reads and
    writes together, so it all lands in ``bytes_read``)."""
    return OpStats(mxu_flops=rep.dot_flops, bytes_read=rep.hbm_bytes)


def stats_from_hlo(text: str, n_devices: int = 1) -> OpStats:
    return stats_from_report(analyze(text, n_devices=n_devices))


def latency_from_hlo(text: str, *, chip: ChipSpec = DEFAULT_CHIP,
                     n_devices: int = 1) -> Dict[str, Any]:
    """Three-term roofline of an HLO module in the unified ns units."""
    rep = analyze(text, n_devices=n_devices)
    stats = stats_from_report(rep)
    lm = LatencyModel(chip)
    out = lm.report(stats)
    out["collective_ns"] = (rep.collective_wire_bytes
                            / chip.ici_bw_per_link * 1e9)
    out["latency_ns"] = max(out["latency_ns"], out["collective_ns"])
    if out["collective_ns"] >= max(out["compute_ns"], out["memory_ns"]):
        out["bound"] = "collective"
    out["trip_counts"] = list(rep.trip_counts)
    return out
