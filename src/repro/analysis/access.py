"""Symbolic grid/block access analysis — affine footprints of BlockSpecs.

The one layer of the stack `repro.verify` could not see before PR 9 is
the *grid*: every `pl.pallas_call` carries index-map lambdas that decide
which block of which operand each grid instance touches, and until now
those lambdas were trusted by eye. This module gives them a semantics
the verifier can reason about:

* :class:`Sym` — a symbolic integer over named grid axes. Index maps
  are *probed* with one ``Sym`` per grid axis; ordinary arithmetic
  (``+ - *`` and ``// %`` by constants) propagates an exact **affine
  form** ``sum(c_k * g_k) + b``, while anything non-affine (``bh // H``,
  ``(bh % H) // group`` — the flash-attention GQA maps) degrades to an
  opaque-but-evaluable closure. Either way every map can be *evaluated*
  at concrete grid coordinates; affine maps can additionally be bounded
  and proven injective without enumeration.
* :class:`BlockAccess` / :class:`GridModel` — the declarative model of
  one ``pallas_call``: grid extents, per-operand block shapes, buffer
  shapes (post-padding), index maps, element byte widths, and the VMEM
  buffer multiplicity (2 for double-buffered async staging).

Footprints use Pallas *blocked* indexing semantics: an index map returns
block coordinates, so instance ``g`` touches elements
``[idx_k(g) * bs_k, (idx_k(g) + 1) * bs_k)`` along dim ``k`` — always
aligned to the block lattice. That alignment is load-bearing: two block
footprints either coincide exactly or are disjoint, which turns
coverage/race certification into set arithmetic over block-index tuples
(see :mod:`repro.verify.grid_check` for the checks themselves).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# Exhaustive-evaluation ceiling: grids up to this many instances are
# certified by full enumeration when the affine fast path does not
# apply; beyond it the checker samples the grid-box corners and
# downgrades its verdict to a warning (documented in
# docs/verification.md as the not-provable fallback).
ENUM_LIMIT = 1 << 16


class Sym:
    """Symbolic integer over grid axes with affine tracking.

    ``affine`` is ``(coeffs, const)`` — one integer coefficient per grid
    axis plus a constant — or ``None`` when an operation left the exact
    affine lattice (the value is still evaluable through ``ev``).
    """

    __slots__ = ("n_axes", "affine", "_ev")

    def __init__(self, n_axes: int,
                 affine: Optional[Tuple[Tuple[int, ...], int]],
                 ev: Callable[[Sequence[int]], int]):
        self.n_axes = n_axes
        self.affine = affine
        self._ev = ev

    # -- constructors -------------------------------------------------------
    @classmethod
    def axis(cls, n_axes: int, k: int) -> "Sym":
        coeffs = tuple(1 if i == k else 0 for i in range(n_axes))
        return cls(n_axes, (coeffs, 0), lambda env, _k=k: env[_k])

    @classmethod
    def const(cls, n_axes: int, v: int) -> "Sym":
        v = int(v)
        return cls(n_axes, ((0,) * n_axes, v), lambda env, _v=v: _v)

    def ev(self, env: Sequence[int]) -> int:
        return int(self._ev(env))

    def _coerce(self, other) -> Optional["Sym"]:
        if isinstance(other, Sym):
            return other
        if isinstance(other, int):
            return Sym.const(self.n_axes, other)
        return None

    # -- arithmetic ---------------------------------------------------------
    def __add__(self, other):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        aff = None
        if self.affine is not None and o.affine is not None:
            (ca, ba), (cb, bb) = self.affine, o.affine
            aff = (tuple(x + y for x, y in zip(ca, cb)), ba + bb)
        return Sym(self.n_axes, aff,
                   lambda env, s=self, t=o: s.ev(env) + t.ev(env))

    __radd__ = __add__

    def __neg__(self):
        aff = None
        if self.affine is not None:
            c, b = self.affine
            aff = (tuple(-x for x in c), -b)
        return Sym(self.n_axes, aff, lambda env, s=self: -s.ev(env))

    def __sub__(self, other):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        return self + (-o)

    def __rsub__(self, other):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        return o + (-self)

    def __mul__(self, other):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        aff = None
        for a, b in ((self, o), (o, self)):
            if a.affine is not None and not any(a.affine[0]):
                k = a.affine[1]
                if b.affine is not None:
                    c, bb = b.affine
                    aff = (tuple(k * x for x in c), k * bb)
                break
        return Sym(self.n_axes, aff,
                   lambda env, s=self, t=o: s.ev(env) * t.ev(env))

    __rmul__ = __mul__

    def __floordiv__(self, other):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        aff = None
        if o.affine is not None and not any(o.affine[0]):
            d = o.affine[1]
            if d != 0 and self.affine is not None:
                c, b = self.affine
                # d | every coefficient: a*g ≡ 0 (mod d) for integer g,
                # so floor((a*g + b)/d) = (a/d)*g + floor(b/d) exactly
                if all(x % d == 0 for x in c):
                    aff = (tuple(x // d for x in c), b // d)
        return Sym(self.n_axes, aff,
                   lambda env, s=self, t=o: s.ev(env) // t.ev(env))

    def __rfloordiv__(self, other):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        return o // self

    def __mod__(self, other):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        aff = None
        if o.affine is not None and not any(o.affine[0]):
            d = o.affine[1]
            if d != 0 and self.affine is not None:
                c, b = self.affine
                if all(x % d == 0 for x in c):
                    aff = ((0,) * self.n_axes, b % d)
        return Sym(self.n_axes, aff,
                   lambda env, s=self, t=o: s.ev(env) % t.ev(env))

    def __rmod__(self, other):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        return o % self

    # an index map that *branches* on a symbolic coordinate is outside
    # the model; raising here makes the probe fail cleanly so the
    # summary degrades to concrete per-instance evaluation
    def __bool__(self):
        raise TypeError("symbolic grid coordinate has no truth value")

    def __repr__(self):
        if self.affine is None:
            return "Sym(<non-affine>)"
        c, b = self.affine
        terms = [f"{x}*g{i}" for i, x in enumerate(c) if x]
        terms.append(str(b))
        return f"Sym({' + '.join(terms)})"


@dataclasses.dataclass
class IndexMapSummary:
    """One index map, probed: per-output-dim symbolic forms (or opaque)."""
    n_axes: int
    dims: Optional[List[Sym]]       # None: probe failed — call fn directly
    fn: Callable

    @property
    def opaque(self) -> bool:
        return self.dims is None

    @property
    def fully_affine(self) -> bool:
        return (self.dims is not None
                and all(d.affine is not None for d in self.dims))


def summarize_index_map(fn: Callable, n_axes: int) -> IndexMapSummary:
    """Probe ``fn`` with one :class:`Sym` per grid axis."""
    try:
        out = fn(*[Sym.axis(n_axes, k) for k in range(n_axes)])
    except Exception:
        return IndexMapSummary(n_axes, None, fn)
    if not isinstance(out, tuple):
        out = (out,)
    dims: List[Sym] = []
    for o in out:
        if isinstance(o, Sym):
            dims.append(o)
        elif isinstance(o, int):
            dims.append(Sym.const(n_axes, o))
        else:
            return IndexMapSummary(n_axes, None, fn)
    return IndexMapSummary(n_axes, dims, fn)


def eval_index(summary: IndexMapSummary,
               env: Sequence[int]) -> Tuple[int, ...]:
    """Block coordinates of one grid instance."""
    if summary.dims is None:
        out = summary.fn(*env)
        if not isinstance(out, tuple):
            out = (out,)
        return tuple(int(x) for x in out)
    return tuple(d.ev(env) for d in summary.dims)


def affine_bounds(sym: Sym, grid: Sequence[int]) -> Tuple[int, int]:
    """Inclusive (min, max) of an affine form over the grid box — the
    extremum of an affine function over a box sits at a corner, picked
    per-axis by coefficient sign."""
    assert sym.affine is not None
    coeffs, const = sym.affine
    lo = hi = const
    for c, g in zip(coeffs, grid):
        if c >= 0:
            hi += c * (g - 1)
        else:
            lo += c * (g - 1)
    return lo, hi


# ---------------------------------------------------------------------------
# The declarative pallas_call model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockAccess:
    """One operand of a ``pallas_call``: which block of which buffer
    each grid instance reads or writes.

    ``array_shape`` is the shape of the buffer actually passed to the
    call — i.e. *after* any host-side padding (``_ceil_to``), so the
    pad region is modeled explicitly as in-bounds. ``buffers`` is the
    VMEM copy count (2 when the pipelined emitter stages the operand
    through a double-buffer scratch in addition to its block window).
    """
    array: str
    mode: str                       # "read" | "write"
    block_shape: Tuple[int, ...]
    array_shape: Tuple[int, ...]
    index_map: Callable
    dtype_bytes: int = 4
    buffers: int = 1

    def __post_init__(self):
        if self.mode not in ("read", "write"):
            raise ValueError(f"mode must be read|write, got {self.mode!r}")
        if len(self.block_shape) != len(self.array_shape):
            raise ValueError(
                f"{self.array}: block rank {len(self.block_shape)} != "
                f"array rank {len(self.array_shape)}")

    @property
    def block_elems(self) -> int:
        return math.prod(self.block_shape)

    @property
    def vmem_bytes(self) -> int:
        return self.block_elems * self.dtype_bytes * self.buffers

    def n_blocks(self) -> Tuple[int, ...]:
        """Block-lattice extents (ceil per dim — a ragged final block is
        masked by Pallas and counts as one block)."""
        return tuple(-(-a // b) for a, b in
                     zip(self.array_shape, self.block_shape))


@dataclasses.dataclass(frozen=True)
class GridModel:
    """Everything :func:`repro.verify.grid_check.check_grid` needs to
    certify one kernel launch configuration."""
    name: str
    grid: Tuple[int, ...]
    reads: Tuple[BlockAccess, ...]
    writes: Tuple[BlockAccess, ...]
    scratch_bytes: int = 0

    def __post_init__(self):
        if not self.grid or any(g <= 0 for g in self.grid):
            raise ValueError(f"{self.name}: grid {self.grid} must be "
                             "non-empty with positive extents")

    @property
    def n_instances(self) -> int:
        return math.prod(self.grid)

    def instances(self):
        """All grid coordinate tuples (row-major)."""
        return itertools.product(*[range(g) for g in self.grid])

    @property
    def vmem_bytes(self) -> int:
        """Exact VMEM working set: every operand's block window times
        its buffer multiplicity, plus declared scratch."""
        return (sum(a.vmem_bytes for a in self.reads + self.writes)
                + self.scratch_bytes)
