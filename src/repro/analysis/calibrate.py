"""Calibration: fit the roofline latency model to measured kernel times.

The analytic :class:`repro.analysis.latency.LatencyModel` guesses its
constants (``overlap_slack=0.05``, the per-class VPU pass counts), so
nothing guarantees the extraction objective ranks e-nodes the way the
machine does. This module closes the predicted-vs-measured loop:

* :func:`kernel_features` reduces a saturated kernel to the calibration
  feature vector — per-op-class VPU pass counts, MXU FLOPs, and
  shape/dtype-aware HBM bytes (loads + root stores), the same
  :class:`~repro.analysis.opstats.OpStats` accounting extraction uses.
* :func:`fit_params` fits the free parameters of the latency formula —
  per-class pass coefficients, HBM efficiency, per-bound overlap slack,
  and a constant per-instance overhead — to measured times
  (``benchmarks/measure.py``) by deterministic coordinate descent on
  mean squared *log* error (scale-free, so µs-scale interpret-mode
  timings fit as well as ns-scale compiled ones).
* :class:`DeviceProfile` persists a fit (parameters + the measurements
  and per-kernel predictions it was fitted on) as versioned JSON under
  ``experiments/device_profiles/<name>.json``;
  ``LatencyModel.from_profile(...)`` loads it back, and
  ``RooflineCostModel(profile=...)`` / ``SaturatorConfig(
  device_profile=...)`` thread it through beam extraction so the search
  minimizes the calibrated objective instead of the guessed one.
* :func:`evaluate_params` / :func:`check_profile` score a parameter set
  against measurements (MAPE + Spearman rank correlation of the
  predicted ordering) — the ``bench-regression`` CI gate recomputes both
  from the committed profiles and fails when the calibrated model's rank
  correlation drops below the floor or its stored baseline, or when it
  stops beating the uncalibrated defaults on MAPE.

The fitted model stays the same formula the extractor optimizes::

    compute = Σ_class passes·coeff_class × tile/vpu_rate + mxu/peak
    memory  = bytes / (hbm_bw × hbm_efficiency)
    latency = base + max(compute, memory) + slack_bound × min(...)

with ``slack_bound`` chosen by the binding roof (compute-bound and
memory-bound kernels overlap their minor axis differently — the
per-bound split is fitted, not guessed).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from .latency import LatencyModel
from .opstats import _PASSES, TILE_ELEMS, op_pass_class

SCHEMA_VERSION = 1
SPEARMAN_FLOOR = 0.8          # acceptance floor for a committed profile
PASS_CLASSES = tuple(sorted(k for k, v in _PASSES.items() if v > 0))

# Calibration-only pseudo-class: serial per-load dispatch cost in
# VPU-pass-equivalents. The analytic model prices loads purely on the
# memory axis (bytes/bandwidth); measurement shows some devices — most
# visibly the CPU interpret path — charge a per-*instruction* cost for a
# load that bytes-linear pricing cannot express (a broadcast-row load
# moves 1/8 the bytes of a tile load but costs the same dispatch). The
# default coefficient is 0.0, so uncalibrated predictions are unchanged.
MEM_DISPATCH_CLASS = "memory_dispatch"
_DEFAULT_COEFFS = {MEM_DISPATCH_CLASS: 0.0}


class CalibrationError(ValueError):
    """Unusable profile/measurement data (schema drift, bad fit input)."""


def schedule_paired_pct(entry: Mapping) -> Optional[float]:
    """The gated cost-vs-bulk statistic of one ``schedule_medians``
    entry: the paired per-rep median when the measurement recorded it,
    else the raw median delta; None when bulk/cost are missing. Single
    owner — the bench-regression gate and the rendered latency table
    must report the same number."""
    p = entry.get("cost_vs_bulk_paired_pct")
    if p is not None:
        return float(p)
    bulk, cost = entry.get("bulk"), entry.get("cost")
    if not bulk or cost is None:
        return None
    return 100.0 * (float(cost) - float(bulk)) / float(bulk)


# ---------------------------------------------------------------------------
# Feature extraction
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KernelFeatures:
    """Per-tile-instance hardware features of one extracted kernel.

    The PR-5 schedule features describe the *emitted statement order*:
    ``sched_loads`` carries, per load, ``(bytes, gap_passes,
    gap_loads)`` — the load's HBM bytes and the unweighted VPU passes /
    load-dispatch slots issued between it and its first consumer under
    the generated schedule — and ``peak_live_bytes`` the schedule's peak
    VMEM working set. ``None``/0 (every pre-PR-5 measurement) keeps the
    position-independent formula.
    """
    kernel: str
    class_passes: Mapping[str, float]   # op-class -> total VPU passes
    mxu_flops: float = 0.0
    hbm_bytes: float = 0.0              # loads + root stores, dtype-aware
    flops: float = 0.0                  # reporting only
    sched_loads: Optional[Tuple[Tuple[float, float, float], ...]] = None
    peak_live_bytes: float = 0.0
    sched_mode: Optional[str] = None    # provenance: bulk|source|cost
    # PR-8 trip-count features: per-loop (trip_count, body_units) from
    # repro.core.schedule.loop_profile. None/() (every earlier
    # measurement) keeps the once-through formula bit-identical.
    loop_trips: Optional[Tuple[Tuple[float, float], ...]] = None

    @property
    def vpu_passes(self) -> float:
        return sum(self.class_passes.values())

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["class_passes"] = dict(self.class_passes)
        if self.sched_loads is not None:
            d["sched_loads"] = [list(t) for t in self.sched_loads]
        if self.loop_trips is not None:
            d["loop_trips"] = [list(t) for t in self.loop_trips]
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "KernelFeatures":
        sl = d.get("sched_loads")
        lt = d.get("loop_trips")
        return cls(kernel=d["kernel"],
                   class_passes={k: float(v)
                                 for k, v in d["class_passes"].items()},
                   mxu_flops=float(d.get("mxu_flops", 0.0)),
                   hbm_bytes=float(d.get("hbm_bytes", 0.0)),
                   flops=float(d.get("flops", 0.0)),
                   sched_loads=(None if sl is None else
                                tuple(tuple(float(x) for x in t)
                                      for t in sl)),
                   peak_live_bytes=float(d.get("peak_live_bytes", 0.0)),
                   sched_mode=d.get("sched_mode"),
                   loop_trips=(None if lt is None else
                               tuple(tuple(float(x) for x in t)
                                     for t in lt)))


def kernel_features(sk, schedule=None,
                    scalars: Optional[Mapping[str, float]] = None
                    ) -> KernelFeatures:
    """Calibration features of a pipeline result (``SaturatedKernel``).

    Prices the *extracted* choice — the exact nodes the beam committed
    to — with the same shape/dtype-aware model extraction used, plus the
    root stores' write traffic, so fitted coefficients talk about the
    code that actually ran. ``schedule`` (a
    :class:`repro.core.schedule.ScheduleResult`) additionally records
    the emitted order's per-load overlap windows and peak VMEM live
    set, enabling the position-dependent fit. ``scalars`` (runtime
    scalar bindings, e.g. ``cg_like``'s ``nnz``) lets
    :func:`repro.core.schedule.loop_profile` resolve scalar-bounded
    trip counts for the trip-count-aware term.
    """
    from repro.core.extract import choice_nodes  # deferred: core imports us
    from .cost_model import RooflineCostModel
    from .opstats import store_stats

    ssa = sk.ssa
    eg = ssa.egraph
    ex = sk.extraction
    cm = RooflineCostModel(dtype=getattr(ssa.prog, "dtype", None) or "f32",
                           egraph=eg)
    nodes = choice_nodes(eg, ex.choice, ex.roots)
    if nodes is None:
        raise CalibrationError(
            f"kernel {ssa.prog.name!r}: extraction choice is not a valid "
            "acyclic selection")
    stats = cm.choice_stats(nodes)
    n_stores = sk.kernel.stats.n_stores
    infos = ssa.store_infos()
    stats = stats + store_stats(
        n_stores, infos=infos if len(infos) == n_stores else None)
    classes: Dict[str, float] = {}
    for n in nodes:
        if n.op == "load":
            # one dispatch-equivalent per load instruction (fitted
            # coefficient, 0 in the analytic model) — loads only, to
            # stay consistent with the extraction-side objective where
            # store traffic is a constant outside the minimized term
            classes[MEM_DISPATCH_CLASS] = \
                classes.get(MEM_DISPATCH_CLASS, 0.0) + 1.0
            continue
        kls = op_pass_class(n.op)
        p = _PASSES[kls]
        if p > 0:
            classes[kls] = classes.get(kls, 0.0) + p
    sched_loads = peak_live = mode = None
    if schedule is not None:
        sched_loads = tuple(schedule.load_windows())
        peak_live = schedule.peak_live_bytes
        mode = schedule.mode
    from repro.core.schedule import loop_profile
    trips = loop_profile(ssa, scalars=dict(scalars) if scalars else None)
    return KernelFeatures(kernel=ssa.prog.name, class_passes=classes,
                          mxu_flops=stats.mxu_flops,
                          hbm_bytes=stats.total_bytes,
                          flops=stats.total_flops,
                          sched_loads=sched_loads,
                          peak_live_bytes=peak_live or 0.0,
                          sched_mode=mode,
                          loop_trips=trips or None)


# ---------------------------------------------------------------------------
# Parameters + the calibrated latency formula over features
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CalibrationParams:
    overlap_slack_compute: float = 0.05
    overlap_slack_memory: float = 0.05
    hbm_efficiency: float = 1.0
    base_ns: float = 0.0
    vpu_pass_coeffs: Mapping[str, float] = dataclasses.field(
        default_factory=dict)   # missing: 1.0 (0.0 for memory_dispatch)
    # -- schedule-aware terms (PR 5; None/0 == the PR-4 formula) -----------
    # Fraction of the issue time between a load and its first consumer
    # that hides the load's transfer (fitted against schedule features).
    overlap_efficiency: Optional[float] = None
    # Spill-traffic multiplier on VMEM working set beyond the budget.
    vmem_pressure_coeff: float = 0.0
    # -- trip-count term (PR 8; 0.0 == the once-through formula) -----------
    # Per-(extra-iteration × body-unit) cost in VPU-pass-equivalents:
    # loop bodies are priced once by class_passes, so a loop running T
    # times adds (T-1) × body_units × coeff extra passes. Identifiable
    # only from measurements whose features carry loop_trips (cg_like).
    trip_count_coeff: float = 0.0

    def coeff(self, kls: str) -> float:
        d = self.vpu_pass_coeffs.get(kls)
        if d is None:
            return _DEFAULT_COEFFS.get(kls, 1.0)
        return float(d)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["vpu_pass_coeffs"] = dict(self.vpu_pass_coeffs)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "CalibrationParams":
        eff = d.get("overlap_efficiency")
        return cls(overlap_slack_compute=float(d["overlap_slack_compute"]),
                   overlap_slack_memory=float(d["overlap_slack_memory"]),
                   hbm_efficiency=float(d["hbm_efficiency"]),
                   base_ns=float(d["base_ns"]),
                   vpu_pass_coeffs={k: float(v) for k, v in
                                    d.get("vpu_pass_coeffs", {}).items()},
                   overlap_efficiency=None if eff is None else float(eff),
                   vmem_pressure_coeff=float(
                       d.get("vmem_pressure_coeff", 0.0)),
                   trip_count_coeff=float(d.get("trip_count_coeff", 0.0)))


DEFAULT_PARAMS = CalibrationParams()


def _chip():
    from repro.core.hardware import DEFAULT_CHIP
    return DEFAULT_CHIP


def chip_by_name(name: str):
    """Resolve a stored ``model_chip`` name back to its ChipSpec, so a
    profile's coefficients are always combined with the constants they
    were fitted against (unknown names fail loudly, never fall back)."""
    from repro.core import hardware
    for v in vars(hardware).values():
        if isinstance(v, hardware.ChipSpec) and v.name == name:
            return v
    known = sorted(v.name for v in vars(hardware).values()
                   if isinstance(v, hardware.ChipSpec))
    raise CalibrationError(
        f"profile references unknown model_chip {name!r}; known: {known}")


def predict_ns(feat: KernelFeatures, params: CalibrationParams,
               chip=None, tile_elems: int = TILE_ELEMS) -> float:
    """Latency of one kernel under ``params`` — the same formula
    :class:`LatencyModel` computes once a profile is loaded (kept in
    lock-step by ``tests/test_calibration.py``).

    With a fitted ``overlap_efficiency`` the memory axis is reduced by
    the schedule's hidden transfer time before the roofline max: the
    per-load windows in ``feat.sched_loads`` when the measurement
    recorded them (position-dependent — each load hides at most
    ``eff × gap``), else the aggregate best-schedule bound
    ``min(memory, eff × compute)``. ``overlap_efficiency=None`` (all
    PR-4 profiles) is bit-identical to the PR-4 formula.
    """
    chip = chip if chip is not None else _chip()
    per_pass_ns = tile_elems / chip.vpu_elems_per_s * 1e9
    compute = sum(p * params.coeff(k)
                  for k, p in feat.class_passes.items()) * per_pass_ns
    compute += feat.mxu_flops / chip.peak_flops_bf16 * 1e9
    if params.trip_count_coeff and feat.loop_trips:
        extra = sum(max(t - 1.0, 0.0) * units
                    for t, units in feat.loop_trips)
        compute += params.trip_count_coeff * extra * per_pass_ns
    bw = chip.hbm_bw * params.hbm_efficiency
    memory = feat.hbm_bytes / bw * 1e9
    if params.overlap_efficiency is not None:
        eff = params.overlap_efficiency
        if feat.sched_loads:
            # gap windows are recorded as unweighted pass counts; price
            # them with the fitted coefficients (the "simple" class as
            # the stand-in for the window's compute mix) so the overlap
            # term lives on the same scale as the fitted memory axis
            dispatch = params.coeff(MEM_DISPATCH_CLASS)
            gap_coeff = params.coeff("simple")
            hidden = 0.0
            for nbytes, gap_passes, gap_loads in feat.sched_loads:
                m_i = nbytes / bw * 1e9
                gap_ns = (gap_passes * gap_coeff
                          + gap_loads * dispatch) * per_pass_ns
                hidden += min(m_i, eff * gap_ns)
            hidden = min(hidden, memory)
        else:
            hidden = min(memory, eff * compute)
        memory -= hidden
    pressure = 0.0
    if params.vmem_pressure_coeff and feat.peak_live_bytes:
        spill = max(0.0, feat.peak_live_bytes - chip.vmem_bytes / 4)
        pressure = params.vmem_pressure_coeff * spill / bw * 1e9
    slack = (params.overlap_slack_compute if compute >= memory
             else params.overlap_slack_memory)
    return (params.base_ns + max(compute, memory)
            + slack * min(compute, memory) + pressure)


# ---------------------------------------------------------------------------
# Fit quality metrics
# ---------------------------------------------------------------------------
def _ranks(xs: Sequence[float]) -> List[float]:
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        avg = (i + j) / 2.0 + 1.0
        for k in range(i, j + 1):
            ranks[order[k]] = avg
        i = j + 1
    return ranks


def spearman(xs: Sequence[float], ys: Sequence[float]) -> float:
    """Spearman rank correlation (tie-averaged); 0.0 on degenerate input."""
    if len(xs) != len(ys) or len(xs) < 2:
        return 0.0
    rx, ry = _ranks(xs), _ranks(ys)
    mx = sum(rx) / len(rx)
    my = sum(ry) / len(ry)
    num = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    dx = math.sqrt(sum((a - mx) ** 2 for a in rx))
    dy = math.sqrt(sum((b - my) ** 2 for b in ry))
    if dx == 0.0 or dy == 0.0:
        return 0.0
    return num / (dx * dy)


def mape_pct(pred: Sequence[float], meas: Sequence[float]) -> float:
    """Mean absolute percentage error of predictions vs measurements."""
    if not meas:
        return float("inf")
    return 100.0 * sum(abs(p - m) / m for p, m in zip(pred, meas)) \
        / len(meas)


def evaluate_params(feats: Sequence[KernelFeatures],
                    measured_ns: Sequence[float],
                    params: CalibrationParams, chip=None,
                    tile_elems: int = TILE_ELEMS) -> dict:
    preds = [predict_ns(f, params, chip=chip, tile_elems=tile_elems)
             for f in feats]
    return {
        "predicted_ns": preds,
        "mape_pct": mape_pct(preds, measured_ns),
        "spearman": spearman(preds, list(measured_ns)),
    }


# ---------------------------------------------------------------------------
# Fitting: deterministic coordinate descent on mean squared log error
# ---------------------------------------------------------------------------
def _msle(feats, measured, params, chip, tile_elems) -> float:
    loss = 0.0
    for f, m in zip(feats, measured):
        p = predict_ns(f, params, chip=chip, tile_elems=tile_elems)
        loss += (math.log(max(p, 1e-12)) - math.log(m)) ** 2
    return loss / len(measured)


def fit_params(feats: Sequence[KernelFeatures],
               measured_ns: Sequence[float], *, chip=None,
               tile_elems: int = TILE_ELEMS, max_rounds: int = 80,
               fit_base: bool = True,
               ) -> Tuple[CalibrationParams, float, int]:
    """Fit calibration parameters to measured per-instance times.

    Coordinate descent from several deterministic starts (memory-led,
    compute-led, and no-overlap/sum-like — the loss surface has local
    minima where one roof absorbs everything): each round sweeps every
    free parameter with a multiplicative line search (slacks with an
    additive one, clipped to [0, 2]) and keeps the best value; a start
    converges when a full round improves mean squared log error by
    < 1e-15 per candidate. Fully deterministic — no RNG, no wall
    clock — so a re-fit on the same measurements is bit-identical.

    Returns ``(params, final_loss, rounds_used)`` of the best start.
    """
    if len(feats) != len(measured_ns) or not feats:
        raise CalibrationError(
            f"need matching non-empty features/measurements, got "
            f"{len(feats)}/{len(measured_ns)}")
    if any(m <= 0 for m in measured_ns):
        raise CalibrationError("measured times must be positive")
    chip = chip if chip is not None else _chip()
    classes = sorted({k for f in feats for k in f.class_passes})
    # schedule-aware terms are only identifiable when the measurements
    # recorded per-load overlap windows; starting at eff=0 makes the
    # schedule-aware fit begin exactly at the PR-4 formula, so added
    # freedom can only lower the loss
    has_sched = any(f.sched_loads for f in feats)
    over_budget = any(f.peak_live_bytes > chip.vmem_bytes / 4
                      for f in feats)
    # trip counts are only identifiable when some measured kernel has a
    # loop that actually iterates (trips > 1); otherwise flat at 0
    has_trips = any(t > 1.0 for f in feats
                    for t, _ in (f.loop_trips or ()))

    # scale-matched starts: uncalibrated predictions are ns-scale while
    # interpret-mode measurements are µs/ms-scale; starting coefficients
    # at the median measured/predicted ratio keeps the line search short
    base0 = [predict_ns(f, DEFAULT_PARAMS, chip=chip,
                        tile_elems=tile_elems) for f in feats]
    ratios = sorted(m / max(p, 1e-12) for m, p in zip(measured_ns, base0))
    scale = max(ratios[len(ratios) // 2], 1e-12)
    mn = min(measured_ns)
    med = sorted(measured_ns)[len(measured_ns) // 2]

    def start(hbm_mul: float, coeff_mul: float, slack: float
              ) -> CalibrationParams:
        return CalibrationParams(
            overlap_slack_compute=slack, overlap_slack_memory=slack,
            hbm_efficiency=hbm_mul / scale, base_ns=0.0,
            vpu_pass_coeffs={k: scale * coeff_mul for k in classes},
            overlap_efficiency=0.0 if has_sched else None)

    starts = (
        start(1.0, 1.0, 0.05),       # balanced (the analytic prior)
        start(1.0, 1.0, 1.0),        # no-overlap: latency ~ compute+memory
        start(100.0, 1.0, 0.05),     # compute-led: memory roof negligible
        start(0.01, 1.0, 0.05),      # memory-led: compute roof negligible
    )

    def loss_of(p: CalibrationParams) -> float:
        return _msle(feats, measured_ns, p, chip, tile_elems)

    def descend(params: CalibrationParams, mul_steps, slack_steps,
                rounds0: int = 0) -> Tuple[CalibrationParams, float, int]:
        best = loss_of(params)
        rounds = rounds0
        for rounds in range(rounds0 + 1, rounds0 + max_rounds + 1):
            improved = False

            def try_param(make) -> None:
                nonlocal params, best, improved
                for cand in make():
                    lo = loss_of(cand)
                    if lo < best - 1e-15:
                        params, best = cand, lo
                        improved = True

            for kls in classes:
                try_param(lambda kls=kls: (
                    dataclasses.replace(params, vpu_pass_coeffs={
                        **params.vpu_pass_coeffs,
                        kls: params.vpu_pass_coeffs[kls] * s})
                    for s in mul_steps))
            try_param(lambda: (
                dataclasses.replace(params,
                                    hbm_efficiency=params.hbm_efficiency
                                    * s) for s in mul_steps))
            if fit_base:
                try_param(lambda: (
                    dataclasses.replace(params, base_ns=b)
                    for b in ([0.0, med * 0.01, med * 0.1, mn * 0.5,
                               mn * 0.8, mn * 0.95]
                              + [params.base_ns * s for s in mul_steps
                                 if params.base_ns > 0])))
            for field in ("overlap_slack_compute", "overlap_slack_memory"):
                try_param(lambda field=field: (
                    dataclasses.replace(params, **{
                        field: min(max(getattr(params, field) + d, 0.0),
                                   2.0)})
                    for d in slack_steps))
            if has_sched:
                try_param(lambda: (
                    dataclasses.replace(params, overlap_efficiency=min(
                        max((params.overlap_efficiency or 0.0) + d, 0.0),
                        1.0))
                    for d in slack_steps))
            if over_budget:
                # only identifiable when some kernel's peak live set
                # exceeds the budget; otherwise the term is flat at 0
                try_param(lambda: (
                    dataclasses.replace(params, vmem_pressure_coeff=max(
                        params.vmem_pressure_coeff + d, 0.0))
                    for d in slack_steps))
            if has_trips:
                # multiplicative when already non-zero, seeded from the
                # fitted "simple" pass coefficient otherwise (the body's
                # per-iteration cost should start on the compute scale)
                try_param(lambda: (
                    dataclasses.replace(params, trip_count_coeff=tc)
                    for tc in ([params.trip_count_coeff * s
                                for s in mul_steps]
                               if params.trip_count_coeff > 0 else
                               [0.0, 0.1 * scale, scale, 10.0 * scale])))
            if not improved:
                break
        return params, best, rounds

    # coarse sweep from every start, then a fine polish of each result
    # (the coarse grid's ~5% resolution caps how close it can land)
    coarse_mul = (0.125, 0.25, 0.5, 0.8, 0.95, 1.05, 1.25, 2.0, 4.0, 8.0)
    coarse_slack = (-0.5, -0.2, -0.05, -0.01, -0.002, 0.002, 0.01, 0.05,
                    0.2, 0.5)
    fine_mul = (0.98, 0.99, 0.995, 1.005, 1.01, 1.02)
    fine_slack = (-0.01, -0.003, -0.001, 0.001, 0.003, 0.01)
    results = []
    for s in starts:
        p, _, r = descend(s, coarse_mul, coarse_slack)
        results.append(descend(p, fine_mul, fine_slack, rounds0=r))
    return min(results, key=lambda r: r[1])


# ---------------------------------------------------------------------------
# Device profiles: versioned, persisted fits
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DeviceProfile:
    """A persisted calibration: fitted parameters + the evidence.

    ``fit`` embeds the measurements the parameters were fitted on, so
    the CI gate can re-score the *current* model code against them
    deterministically — no re-timing on the CI runner needed.
    """
    name: str                      # file stem, e.g. "cpu_pallas_interpret"
    chip: str                      # measured device (jax backend name)
    measured_kind: str             # e.g. pallas_interpret / jax_cpu_grid
    params: CalibrationParams
    model_chip: str = "tpu_v5e"    # ChipSpec the analytic features used
    tile_elems: int = TILE_ELEMS
    schema_version: int = SCHEMA_VERSION
    fit: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["params"] = self.params.to_dict()
        return json.dumps(d, indent=1, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str, name: Optional[str] = None
                  ) -> "DeviceProfile":
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise CalibrationError(f"device profile is not valid JSON: {e}")
        ver = d.get("schema_version")
        if ver != SCHEMA_VERSION:
            raise CalibrationError(
                f"device profile schema_version {ver!r} != supported "
                f"{SCHEMA_VERSION}; re-fit it with "
                "`python benchmarks/measure.py --fit` and commit the result")
        missing = [k for k in ("chip", "measured_kind", "params")
                   if k not in d]
        if missing:
            raise CalibrationError(f"device profile missing keys {missing}")
        return cls(name=name or d.get("name", "profile"), chip=d["chip"],
                   measured_kind=d["measured_kind"],
                   params=CalibrationParams.from_dict(d["params"]),
                   model_chip=d.get("model_chip", "tpu_v5e"),
                   tile_elems=int(d.get("tile_elems", TILE_ELEMS)),
                   schema_version=ver, fit=d.get("fit", {}))

    def save(self, path: Union[str, pathlib.Path]) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    # -- stored evidence -----------------------------------------------------
    def stored_features(self) -> List[KernelFeatures]:
        return [KernelFeatures.from_dict(r["features"])
                for r in self.fit.get("kernels", [])]

    def stored_measurements(self) -> List[float]:
        return [float(r["measured_ns"]) for r in self.fit.get("kernels", [])]

    def latency_model(self, chip=None,
                      mxu_dtype: Optional[str] = None) -> LatencyModel:
        return LatencyModel.from_profile(self, chip=chip,
                                         mxu_dtype=mxu_dtype)


def fit_profile(feats: Sequence[KernelFeatures],
                measured_ns: Sequence[float], *, name: str, chip: str,
                measured_kind: str, model_chip=None,
                tile_elems: int = TILE_ELEMS, **fit_kw) -> DeviceProfile:
    """Fit and package a :class:`DeviceProfile` with full fit evidence."""
    spec = model_chip if model_chip is not None else _chip()
    params, loss, rounds = fit_params(feats, measured_ns, chip=spec,
                                      tile_elems=tile_elems, **fit_kw)
    cal = evaluate_params(feats, measured_ns, params, chip=spec,
                          tile_elems=tile_elems)
    uncal = evaluate_params(feats, measured_ns, DEFAULT_PARAMS, chip=spec,
                            tile_elems=tile_elems)
    rows = [{"kernel": f.kernel, "measured_ns": m,
             "predicted_ns": cp, "uncalibrated_ns": up,
             "features": f.to_dict()}
            for f, m, cp, up in zip(feats, measured_ns,
                                    cal["predicted_ns"],
                                    uncal["predicted_ns"])]
    return DeviceProfile(
        name=name, chip=chip, measured_kind=measured_kind, params=params,
        model_chip=getattr(spec, "name", str(spec)), tile_elems=tile_elems,
        fit={"loss_msle": loss, "rounds": rounds,
             "mape_pct": cal["mape_pct"], "spearman": cal["spearman"],
             "uncalibrated_mape_pct": uncal["mape_pct"],
             "uncalibrated_spearman": uncal["spearman"],
             "kernels": rows})


# ---------------------------------------------------------------------------
# Profile discovery / loading
# ---------------------------------------------------------------------------
def profile_dir() -> pathlib.Path:
    """Where committed device profiles live (override with
    ``REPRO_PROFILE_DIR`` for out-of-tree checkouts)."""
    env = os.environ.get("REPRO_PROFILE_DIR")
    if env:
        return pathlib.Path(env)
    return (pathlib.Path(__file__).resolve().parents[3]
            / "experiments" / "device_profiles")


def load_profile(spec: Union["DeviceProfile", str, pathlib.Path]
                 ) -> DeviceProfile:
    """Resolve a profile: an instance passes through; a path loads it; a
    bare name resolves against :func:`profile_dir`."""
    if isinstance(spec, DeviceProfile):
        return spec
    path = pathlib.Path(spec)
    if not path.suffix:
        path = profile_dir() / f"{path.name}.json"
    if not path.exists():
        known = sorted(p.stem for p in profile_dir().glob("*.json")) \
            if profile_dir().exists() else []
        raise CalibrationError(
            f"no device profile at {path}; known profiles: {known or 'none'}"
            " (generate one with `python benchmarks/measure.py --fit`)")
    return DeviceProfile.from_json(path.read_text(), name=path.stem)


def check_profile(profile: Union[DeviceProfile, str, pathlib.Path],
                  spearman_floor: float = SPEARMAN_FLOOR,
                  degrade_tol: float = 1e-9) -> List[str]:
    """Re-score a committed profile against its stored measurements with
    the *current* model code. Returns human-readable failures when

    * calibrated Spearman rank correlation < ``spearman_floor``,
    * calibrated rank correlation degraded vs the value stored at fit
      time (the committed baseline), or
    * calibrated MAPE is not strictly better than the uncalibrated
      defaults.

    Empty list = the calibrated objective still ranks kernels at least
    as faithfully as when the profile was fitted.
    """
    prof = load_profile(profile)
    feats = prof.stored_features()
    meas = prof.stored_measurements()
    if len(feats) < 2:
        return [f"profile {prof.name}: fewer than 2 stored kernels — "
                "cannot assess ranking quality"]
    chip = chip_by_name(prof.model_chip)
    cal = evaluate_params(feats, meas, prof.params, chip=chip,
                          tile_elems=prof.tile_elems)
    uncal = evaluate_params(feats, meas, DEFAULT_PARAMS, chip=chip,
                            tile_elems=prof.tile_elems)
    fails: List[str] = []
    if cal["spearman"] < spearman_floor:
        fails.append(
            f"profile {prof.name}: calibrated Spearman {cal['spearman']:.3f}"
            f" < floor {spearman_floor}")
    stored = prof.fit.get("spearman")
    if stored is not None and cal["spearman"] < stored - degrade_tol:
        fails.append(
            f"profile {prof.name}: calibrated Spearman degraded "
            f"{stored:.3f} -> {cal['spearman']:.3f} vs committed baseline "
            "(model code drifted; re-fit with "
            "`python benchmarks/measure.py --fit` if intentional)")
    if not cal["mape_pct"] < uncal["mape_pct"]:
        fails.append(
            f"profile {prof.name}: calibrated MAPE {cal['mape_pct']:.1f}% "
            f"not better than uncalibrated {uncal['mape_pct']:.1f}%")
    return fails
