"""Roofline-calibrated extraction cost model.

The extractor minimizes *predicted latency* of the whole selected term,
not a sum of abstract per-op weights. Two hooks drive it:

* ``node_cost`` — additive surrogate (compute_ns + memory_ns of one
  node). Used by the bottom-up tree fixed point to seed a valid
  selection; since ``max(Σc, Σm) + s·min ≤ Σ(c+m)``, the surrogate upper-
  bounds the true objective, so seeding with it is sound.
* ``aggregate_cost`` — the real objective: roofline latency of the summed
  statistics of all chosen nodes (shared e-classes counted once). The
  DAG evaluator, beam search, and hill-climb polish in
  :mod:`repro.core.extract` call this when present.

Costs are shape/dtype-aware when the model is *bound to an e-graph*
(``bind_egraph`` — done automatically by ``extract_dag``): a ``load``
node resolves its array operand's :class:`ArrayInfo` through the e-class
analysis, so a broadcast scalar is priced at one element, a row at one
row, and bf16/f8 arrays at half/quarter f32 HBM bytes. Unbound models
keep the full-f32-tile pricing.

Models may additionally be *calibrated*: constructing with
``profile=<DeviceProfile | name | path>`` (see
:mod:`repro.analysis.calibrate`) swaps in a measured
:class:`LatencyModel` — fitted per-bound overlap slack, HBM efficiency,
launch overhead — and scales every node's VPU passes by its op-class's
fitted coefficient, so beam/hill-climb extraction minimizes the
calibrated objective rather than the analytic guess.

Duck-typed against :class:`repro.core.cost.CostModel` (same ``node_cost``
signature) so every existing call site keeps working.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Iterable, Optional

from .latency import LatencyModel, _default_chip
from .opstats import (TILE_ELEMS, ArrayInfo, OpStats, dtype_byte_width,
                      node_stats, op_pass_class)

if TYPE_CHECKING:
    from repro.core.egraph import EGraph
    from repro.core.hardware import ChipSpec
    from repro.core.ir import ENode


class RooflineCostModel:
    """Extraction objective = roofline-predicted latency (ns)."""

    name = "roofline"

    def __init__(self, chip: Optional["ChipSpec"] = None, *,
                 tile_elems: int = TILE_ELEMS,
                 dtype: Optional[str] = None,
                 dtype_bytes: Optional[int] = None,
                 latency: Optional[LatencyModel] = None,
                 profile=None,
                 egraph: Optional["EGraph"] = None):
        self.chip = chip if chip is not None else _default_chip()
        self.tile_elems = tile_elems
        self.dtype = dtype or "f32"
        self.dtype_bytes = (dtype_bytes if dtype_bytes is not None
                            else dtype_byte_width(self.dtype))
        # the MXU roof scales with the kernel's operand width (only
        # matters for terms carrying mxu_flops, i.e. the HLO bridge —
        # e-graph tile terms are pure VPU); an explicit `latency`
        # override keeps whatever the caller configured, and a device
        # profile swaps in the calibrated model fitted to measured times
        if latency is not None:
            self.latency = latency
        elif profile is not None:
            self.latency = LatencyModel.from_profile(
                profile, chip=chip, mxu_dtype=self.dtype)
            # one tile size / chip for both axes: node pricing
            # (bytes/flops) must use the same tile_elems the calibrated
            # compute roof uses, and chip=None resolves to the
            # profile's fitted model_chip — or the objective mixes units
            self.tile_elems = self.latency.tile_elems
            self.chip = self.latency.chip
        else:
            self.latency = LatencyModel(self.chip, tile_elems=tile_elems,
                                        mxu_dtype=self.dtype)
        # fitted per-op-class VPU pass multipliers (calibration); applied
        # at node-pricing time so every aggregate downstream — beam
        # Evaluator fast path included — sees coefficient-weighted passes
        self._pass_coeffs = dict(self.latency.pass_coeffs or {})
        self._node_cache: Dict["ENode", OpStats] = {}
        self._eg: Optional["EGraph"] = None
        self._eg_version: Optional[int] = None
        if egraph is not None:
            self.bind_egraph(egraph)

    # -- e-graph binding (shape/dtype resolution) -----------------------------
    def bind_egraph(self, eg: Optional["EGraph"]) -> "RooflineCostModel":
        """Attach the e-graph whose array table prices load operands.

        Cached node statistics depend on the bound graph's analysis
        data, so the cache is cleared when the graph changes — or when
        the same graph's array table was re-declared since the last
        bind (tracked via ``EGraph.ainfo_version``).
        """
        version = getattr(eg, "ainfo_version", None)
        if eg is not self._eg or version != self._eg_version:
            self._eg = eg
            self._eg_version = version
            self._node_cache.clear()
        return self

    def _load_info(self, node: "ENode") -> Optional[ArrayInfo]:
        """ArrayInfo of the operand a ``load`` node actually moves."""
        if self._eg is None:
            return None
        return self._eg.load_operand_info(node)

    # -- per-node statistics --------------------------------------------------
    def node_stats(self, node: ENode) -> OpStats:
        st = self._node_cache.get(node)
        if st is None:
            info = self._load_info(node) if node.op == "load" else None
            if info is not None:
                # declared array: honor its dtype always, its extent when
                # a shape was declared (ArrayInfo falls back to a full
                # tile for unknown/symbolic shapes)
                st = node_stats(node, tile_elems=self.tile_elems,
                                dtype_bytes=info.byte_width, info=info)
            else:
                st = node_stats(node, tile_elems=self.tile_elems,
                                dtype_bytes=self.dtype_bytes)
            if self._pass_coeffs:
                if st.vpu_passes:
                    k = self._pass_coeffs.get(op_pass_class(node.op), 1.0)
                    if k != 1.0:
                        st = dataclasses.replace(
                            st, vpu_passes=st.vpu_passes * k)
                elif node.op == "load":
                    # calibrated per-load dispatch cost (serial issue
                    # slot, not bandwidth) — 0 in the analytic model
                    k = self._pass_coeffs.get("memory_dispatch", 0.0)
                    if k:
                        st = dataclasses.replace(st, vpu_passes=k)
            self._node_cache[node] = st
        return st

    def choice_stats(self, nodes: Iterable[ENode]) -> OpStats:
        # hot path for beam search: accumulate into floats and build ONE
        # OpStats instead of allocating a dataclass per node
        flops = mxu = br = bw = passes = 0.0
        n_ops = 0
        cache = self._node_cache
        for n in nodes:
            st = cache.get(n)
            if st is None:
                st = self.node_stats(n)
            flops += st.flops
            mxu += st.mxu_flops
            br += st.bytes_read
            bw += st.bytes_written
            passes += st.vpu_passes
            n_ops += st.n_ops
        return OpStats(flops=flops, mxu_flops=mxu, bytes_read=br,
                       bytes_written=bw, vpu_passes=passes, n_ops=n_ops)

    # -- extraction hooks -----------------------------------------------------
    def node_cost(self, node: ENode) -> float:
        st = self.node_stats(node)
        return self.latency.compute_ns(st) + self.latency.memory_ns(st)

    def aggregate_cost(self, nodes: Iterable[ENode]) -> float:
        return self.latency.latency_ns(self.choice_stats(nodes))

    # -- reporting ------------------------------------------------------------
    def report(self, nodes: Iterable[ENode]) -> Dict[str, float]:
        return self.latency.report(self.choice_stats(nodes))
