"""Roofline-calibrated extraction cost model.

The extractor minimizes *predicted latency* of the whole selected term,
not a sum of abstract per-op weights. Two hooks drive it:

* ``node_cost`` — additive surrogate (compute_ns + memory_ns of one
  node). Used by the bottom-up tree fixed point to seed a valid
  selection; since ``max(Σc, Σm) + s·min ≤ Σ(c+m)``, the surrogate upper-
  bounds the true objective, so seeding with it is sound.
* ``aggregate_cost`` — the real objective: roofline latency of the summed
  statistics of all chosen nodes (shared e-classes counted once). The
  DAG evaluator and hill-climbing local search in
  :mod:`repro.core.extract` call this when present.

Duck-typed against :class:`repro.core.cost.CostModel` (same ``node_cost``
signature) so every existing call site keeps working.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Iterable, Optional

from .latency import LatencyModel, _default_chip
from .opstats import DTYPE_BYTES, TILE_ELEMS, OpStats, node_stats

if TYPE_CHECKING:
    from repro.core.hardware import ChipSpec
    from repro.core.ir import ENode


class RooflineCostModel:
    """Extraction objective = roofline-predicted latency (ns)."""

    name = "roofline"

    def __init__(self, chip: Optional["ChipSpec"] = None, *,
                 tile_elems: int = TILE_ELEMS,
                 dtype_bytes: int = DTYPE_BYTES,
                 latency: Optional[LatencyModel] = None):
        self.chip = chip if chip is not None else _default_chip()
        self.tile_elems = tile_elems
        self.dtype_bytes = dtype_bytes
        self.latency = latency or LatencyModel(self.chip,
                                               tile_elems=tile_elems)
        self._node_cache: Dict["ENode", OpStats] = {}

    # -- per-node statistics --------------------------------------------------
    def node_stats(self, node: ENode) -> OpStats:
        st = self._node_cache.get(node)
        if st is None:
            st = node_stats(node, tile_elems=self.tile_elems,
                            dtype_bytes=self.dtype_bytes)
            self._node_cache[node] = st
        return st

    def choice_stats(self, nodes: Iterable[ENode]) -> OpStats:
        total = OpStats()
        for n in nodes:
            total = total + self.node_stats(n)
        return total

    # -- extraction hooks -----------------------------------------------------
    def node_cost(self, node: ENode) -> float:
        st = self.node_stats(node)
        return self.latency.compute_ns(st) + self.latency.memory_ns(st)

    def aggregate_cost(self, nodes: Iterable[ENode]) -> float:
        return self.latency.latency_ns(self.choice_stats(nodes))

    # -- reporting ------------------------------------------------------------
    def report(self, nodes: Iterable[ENode]) -> Dict[str, float]:
        return self.latency.report(self.choice_stats(nodes))
