"""Automatic saturation of arbitrary elementwise JAX functions.

The paper wraps the C-compiler invocation and rewrites kernels with no
user intervention. The JAX analogue stages a function to a jaxpr,
converts the supported elementwise subset to a tile program, saturates
it, and returns a drop-in replacement function — the framework applies
this to user code via :func:`saturate_jax_fn` and to its own layers.

Unsupported primitives raise :class:`BridgeUnsupported`; callers fall
back to the original function (never a silent behavior change).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dsl import Expr, KernelProgram
from .pipeline import SaturatedKernel, SaturatorConfig, saturate_program
from .telemetry import telemetry


class BridgeUnsupported(ValueError):
    """Raised when a jaxpr cannot be bridged. ``primitive`` names the
    offending primitive (or a pseudo-primitive like ``"array literal"``)
    so fallbacks can be counted per coverage gap, not just swallowed."""

    def __init__(self, msg: str, primitive: str = ""):
        super().__init__(msg)
        self.primitive = primitive or msg


# primitive name -> DSL op (unary)
_UNARY = {
    "neg": "neg", "exp": "exp", "log": "log", "tanh": "tanh",
    "logistic": "sigmoid", "sqrt": "sqrt", "rsqrt": "rsqrt", "abs": "abs",
    "floor": "floor",
}
_BINARY = {
    "add": "add", "sub": "sub", "mul": "mul", "div": "div",
    "max": "max", "min": "min", "pow": "pow", "rem": "mod",
    "lt": "lt", "le": "le", "gt": "gt", "ge": "ge", "eq": "eq", "ne": "ne",
}
_PASSTHROUGH = ("convert_element_type", "stop_gradient", "copy")


@dataclasses.dataclass
class BridgedKernel:
    fn: Callable
    sk: SaturatedKernel
    n_eqns: int
    n_consts: int

    def __call__(self, *args):
        return self.fn(*args)


def _to_term(prim_name: str, in_terms: List[tuple], eqn) -> tuple:
    if prim_name in _UNARY:
        return (_UNARY[prim_name], in_terms[0])
    if prim_name in _BINARY:
        return (_BINARY[prim_name], in_terms[0], in_terms[1])
    if prim_name == "integer_pow":
        y = eqn.params["y"]
        if y == 2:
            return ("square", in_terms[0])
        if y == -1:
            return ("recip", in_terms[0])
        if y == 3:
            return ("mul", in_terms[0], ("square", in_terms[0]))
        return ("pow", in_terms[0], ("const", float(y)))
    if prim_name == "select_n":
        if len(in_terms) != 3:
            raise BridgeUnsupported("select_n with >2 cases",
                                    primitive="select_n")
        # lax.select_n(pred, on_false, on_true)
        return ("select", in_terms[0], in_terms[2], in_terms[1])
    if prim_name in _PASSTHROUGH:
        return in_terms[0]
    if prim_name == "broadcast_in_dim":
        return in_terms[0]  # value-preserving under tile broadcasting
    raise BridgeUnsupported(f"primitive {prim_name!r} not bridgeable",
                            primitive=prim_name)


def saturate_jax_fn(fn: Callable, example_args: Sequence[Any],
                    config: Optional[SaturatorConfig] = None,
                    name: str = "bridged") -> BridgedKernel:
    """Stage ``fn`` and return a saturated drop-in replacement.

    ``fn`` must be elementwise over same-shaped array args (broadcast
    scalars allowed) with a single array (or tuple) output.
    """
    cfg = config or SaturatorConfig()
    closed = jax.make_jaxpr(fn)(*example_args)
    jaxpr = closed.jaxpr

    prog = KernelProgram(name)
    terms: Dict[Any, tuple] = {}
    for k, invar in enumerate(jaxpr.invars):
        aval = invar.aval
        if getattr(aval, "ndim", 0) == 0:
            terms[invar] = prog.scalar(f"s{k}").t
        else:
            terms[invar] = prog.array_in(f"a{k}").load().t
    for k, (cvar, cval) in enumerate(zip(jaxpr.constvars, closed.consts)):
        arr = np.asarray(cval)
        if arr.ndim == 0:
            terms[cvar] = ("const", arr.item())
        else:
            raise BridgeUnsupported("non-scalar closure constants",
                                    primitive="closure constant")

    from jax.extend.core import Literal

    def term_of(atom) -> tuple:
        if isinstance(atom, Literal):
            val = np.asarray(atom.val)
            if val.ndim != 0:
                raise BridgeUnsupported("array literal",
                                        primitive="array literal")
            return ("const", val.item())
        return terms[atom]

    for eqn in jaxpr.eqns:
        if len(eqn.outvars) != 1:
            raise BridgeUnsupported(
                f"multi-output prim {eqn.primitive.name}",
                primitive=eqn.primitive.name)
        in_terms = [term_of(a) for a in eqn.invars]
        terms[eqn.outvars[0]] = _to_term(eqn.primitive.name, in_terms, eqn)

    out_names = []
    for k, outvar in enumerate(jaxpr.outvars):
        oname = f"o{k}"
        prog.array_out(oname)
        prog.store(oname, Expr(term_of(outvar)))
        out_names.append(oname)

    sk = saturate_program(prog, cfg)

    kernel_in = sk.kernel.in_arrays
    kernel_scalars = sk.kernel.scalars
    n_outs = len(jaxpr.outvars)

    def wrapped(*args):
        if len(args) != len(jaxpr.invars):
            raise TypeError(f"expected {len(jaxpr.invars)} args")
        arrays: Dict[str, Any] = {}
        scalars: Dict[str, Any] = {}
        tile = None
        for k, (a, invar) in enumerate(zip(args, jaxpr.invars)):
            if getattr(invar.aval, "ndim", 0) == 0:
                scalars[f"s{k}"] = a
            else:
                arrays[f"a{k}"] = a
                tile = a
        call_args = []
        for nm in kernel_in:
            if nm in arrays:
                call_args.append(arrays[nm])
            else:  # out buffer
                call_args.append(jnp.zeros(tile.shape, tile.dtype))
        call_args += [scalars[s] for s in kernel_scalars]
        out = sk.kernel.fn(*call_args)
        return out[0] if n_outs == 1 else tuple(out)

    return BridgedKernel(fn=wrapped, sk=sk, n_eqns=len(jaxpr.eqns),
                         n_consts=len(closed.consts))


def maybe_saturate(fn: Callable, example_args: Sequence[Any],
                   config: Optional[SaturatorConfig] = None,
                   name: str = "bridged") -> Tuple[Callable, Optional[BridgedKernel]]:
    """Best-effort bridge: returns (replacement_or_original, info).

    A fallback is never silent: the unsupported primitive is counted in
    :mod:`repro.core.telemetry` (surfaced by saturation_stats and the
    launch drivers' metrics) so bridge coverage gaps stay visible.
    """
    try:
        bk = saturate_jax_fn(fn, example_args, config, name)
        return bk.fn, bk
    except BridgeUnsupported as e:
        telemetry().record_bridge_fallback(e.primitive, name)
        return fn, None
