"""Schedule-aware statement ordering for generated kernels (PR 5).

The paper's closing claim is that *computational reordering* — the order
in which loads, compute, and stores are issued — matters as much as what
is computed. Until this module, the only ordering decision the
reproduction made was the all-or-nothing bulk load in
:mod:`repro.core.codegen`, which front-loads every tile read sorted by
array name — a fixed convention, not an optimization.

This module makes statement order a first-class, cost-driven choice:

* :func:`compute_schedule` builds the **dependence DAG** of the
  extracted choice per codegen region — one :class:`SchedUnit` per load,
  compute temp, store effect, and (atomic) loop — with data edges,
  array-version (store→load) edges, and WAR anti-dependences (a load of
  a version must issue before the store/loop that overwrites it: the
  Pallas path reuses refs in place, so this is a real hazard, and it is
  merely conservative for the functional JAX path);
* three named orders span the schedule space:

  - ``"source"`` — loads at their use sites (the paper's un-optimized
    input; today's ``bulk=False``),
  - ``"bulk"``   — every load front-loaded in the legacy
    ``(array, static index)`` order, reproducing today's emitted
    sources bit-for-bit,
  - ``"cost"``   — a deterministic first-improvement insertion search
    over legal topological orders, seeded with both named orders and
    scored by :meth:`repro.analysis.latency.LatencyModel.schedule_ns`
    (position-dependent load→compute overlap + VMEM live-range
    pressure). The search only ever accepts strict improvements from
    the ``bulk`` seed, so ``predicted(cost) <= predicted(bulk)``
    structurally;

* :class:`ScheduleResult` carries the per-region orders (consumed by
  ``JaxCodeGenerator``/the Pallas generators), the schedule-feature vector
  for calibration (per-load overlap windows, peak live bytes), and the
  predicted latency of each named order;
* :func:`random_topological_order` / :func:`is_legal_order` support the
  property-based legality fuzz in ``tests/test_schedule.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.runtime import chaos
from repro.runtime.guard import BudgetExceeded, guard_tick

from repro.analysis.latency import LatencyModel, ScheduleEvent
from repro.analysis.opstats import _PASSES, op_pass_class

from .ir import ENode
from .ssa import LoopRegion, Region, SSAResult, StoreEffect

SCHEDULE_MODES = ("source", "bulk", "cost")

# Evaluation budget of the cost search (scored candidate orders across
# all regions of one kernel) — deterministic, machine-independent.
DEFAULT_MOVE_BUDGET = 4000


def legacy_bulk_key(node_of: Callable[[int], ENode], cid: int):
    """The bulk-load flush order of one load: ``(array name, static
    index representation)``. This is the single owner of the convention
    — ``CodeGenerator._flush_loads`` sorts by it, and the ``"bulk"``
    order here reproduces it — so emitted load order always comes from
    the schedule subsystem, never from an ad-hoc ``sorted()`` call."""
    n = node_of(cid)
    arr = node_of(n.children[0])
    idx_repr = tuple(repr(node_of(c)) for c in n.children[1:])
    return (str(arr.payload), idx_repr)


@dataclasses.dataclass
class SchedUnit:
    """One schedulable statement of a region."""
    uid: int
    kind: str                      # "load" | "compute" | "store" | "loop"
    cid: Optional[int] = None      # load/compute: canonical e-class id
    item: Any = None               # store: StoreEffect; loop: LoopRegion
    deps: Set[int] = dataclasses.field(default_factory=set)
    # deps in first-encounter (expression) order — what the legacy
    # use-site emission follows; the "source" order replays it
    dep_seq: List[int] = dataclasses.field(default_factory=list)

    def add_dep(self, uid: int):
        if uid not in self.deps:
            self.deps.add(uid)
            self.dep_seq.append(uid)
    # -- pricing (calibrated units when a profile drives the model) -------
    issue_ns: float = 0.0          # issue-pipeline occupancy
    mem_ns: float = 0.0            # async HBM transfer started at issue
    bytes_live: float = 0.0        # VMEM residency (loads)
    # -- raw features for calibration (unweighted, hardware-neutral) ------
    raw_passes: float = 0.0        # unweighted VPU passes (compute)
    key: Any = None                # deterministic tiebreak / bulk rank


@dataclasses.dataclass
class RegionSchedule:
    path: Tuple[int, ...]
    units: List[SchedUnit]
    order: List[int]               # uids in emission order
    report: Dict[str, float] = dataclasses.field(default_factory=dict)

    def ordered_units(self) -> List[SchedUnit]:
        by_uid = {u.uid: u for u in self.units}
        return [by_uid[uid] for uid in self.order]


@dataclasses.dataclass
class ScheduleResult:
    mode: str
    regions: Dict[Tuple[int, ...], RegionSchedule]
    predicted_ns: float            # whole-kernel schedule objective
    # predicted objective of every named order (same units) — the
    # benchmarks' cost<=bulk<=source leg reads these
    predicted_by_mode: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    moves_scored: int = 0          # cost-search telemetry

    @property
    def peak_live_bytes(self) -> float:
        return max((rs.report.get("peak_live_bytes", 0.0)
                    for rs in self.regions.values()), default=0.0)

    def load_windows(self) -> List[Tuple[float, float, float]]:
        """Per-load ``(bytes, gap_passes, gap_loads)`` calibration
        features: the load's HBM bytes and the unweighted compute
        passes / load slots issued between it and its first consumer
        under this schedule (deterministic region order)."""
        out: List[Tuple[float, float, float]] = []
        for path in sorted(self.regions):
            rs = self.regions[path]
            ordered = rs.ordered_units()
            pos = {u.uid: i for i, u in enumerate(ordered)}
            for i, u in enumerate(ordered):
                if u.kind != "load":
                    continue
                first = min((pos[v.uid] for v in ordered
                             if u.uid in v.deps), default=len(ordered))
                gap_passes = gap_loads = 0.0
                for v in ordered[i + 1:first]:
                    if v.kind == "load":
                        gap_loads += 1.0
                    else:
                        gap_passes += v.raw_passes
                out.append((u.bytes_live, gap_passes, gap_loads))
        return out


def is_legal_order(units: Sequence[SchedUnit], order: Sequence[int]) -> bool:
    """True iff ``order`` is a permutation of the units' uids that never
    places a unit before one of its dependences."""
    if sorted(order) != sorted(u.uid for u in units):
        return False
    pos = {uid: i for i, uid in enumerate(order)}
    for u in units:
        for d in u.deps:
            if pos[d] >= pos[u.uid]:
                return False
    return True


def random_topological_order(units: Sequence[SchedUnit], rng
                             ) -> List[int]:
    """A uniformly-seeded random legal topological order (Kahn's
    algorithm with an rng-chosen ready pick) — the fuzz driver for the
    schedule-legality property tests."""
    pending = {u.uid: set(u.deps) for u in units}
    out: List[int] = []
    while pending:
        ready = sorted(uid for uid, deps in pending.items() if not deps)
        if not ready:
            raise ValueError("dependence cycle in schedule units")
        pick = ready[int(rng.integers(len(ready)))]
        out.append(pick)
        del pending[pick]
        for deps in pending.values():
            deps.discard(pick)
    return out


# ---------------------------------------------------------------------------
# DAG construction
# ---------------------------------------------------------------------------
class _Builder:
    def __init__(self, ssa: SSAResult, choice: Dict[int, ENode],
                 cost_model):
        self.ssa = ssa
        self.eg = ssa.egraph
        self.choice = choice
        self.cm = cost_model
        self.lat: LatencyModel = cost_model.latency
        # region (loop-id path) of every cid in the chosen dag
        self.cid_region: Dict[int, Tuple[int, ...]] = {}
        self.var_region: Dict[str, Tuple[int, ...]] = {}
        self.sym_region: Dict[str, Tuple[int, ...]] = {}
        self._store_infos = dict(zip(
            [id(it) for it in self._stores(ssa.region)],
            ssa.store_infos()))
        self._uid = 0

    def _stores(self, region: Region) -> List[StoreEffect]:
        out: List[StoreEffect] = []
        for item in region.items:
            if isinstance(item, StoreEffect):
                out.append(item)
            else:
                out.extend(self._stores(item.body))
        return out

    def node(self, cid: int) -> ENode:
        cid = self.eg.find(cid)
        n = self.choice.get(cid)
        if n is None:
            # same fallback as CodeGenerator.node: classes demanded late
            # (pred/index added after extraction) get a greedy local pick
            from .extract import extract_dag
            res = extract_dag(self.eg, (cid,), local_search=False)
            for k, v in res.choice.items():
                self.choice.setdefault(k, v)
            n = self.choice[cid]
        return n

    # -- region assignment (mirrors codegen._collect_load_regions, over
    #    every cid of the chosen dag, not just loads) ----------------------
    def assign_regions(self):
        def index_regions(region: Region, path: Tuple[int, ...]):
            for item in region.items:
                if isinstance(item, LoopRegion):
                    inner = path + (item.loop_id,)
                    self.var_region[f"%L{item.loop_id}:{item.var}"] = inner
                    for carry in item.carries:
                        self.var_region[f"%L{item.loop_id}:{carry.name}"] \
                            = inner
                    for ac in item.array_carries:
                        self.sym_region[ac.version_body] = inner
                        self.sym_region[ac.version_post] = path
                    index_regions(item.body, inner)
                else:
                    self.sym_region[item.version_out] = path
        index_regions(self.ssa.region, ())

        def join(a, b):
            return a if len(a) >= len(b) else b

        memo = self.cid_region

        def walk(cid: int) -> Tuple[int, ...]:
            cid = self.eg.find(cid)
            if cid in memo:
                return memo[cid]
            memo[cid] = ()   # provisional (acyclic by extraction)
            n = self.node(cid)
            r: Tuple[int, ...] = ()
            if n.op == "var" and isinstance(n.payload, str):
                r = self.var_region.get(n.payload, ())
            elif n.op == "array":
                r = self.sym_region.get(n.payload, ())
            for ch in n.children:
                r = join(r, walk(ch))
            memo[cid] = r
            return r

        for root in self.ssa.roots():
            walk(root)

    # -- cone walks --------------------------------------------------------
    def cone(self, roots: Sequence[int]) -> Tuple[List[int], List[str]]:
        """All cids reachable through the chosen dag from ``roots`` plus
        every array-version symbol they read, in deterministic
        depth-first (expression) visit order."""
        cids: List[int] = []
        seen: Set[int] = set()
        syms: List[str] = []
        seen_syms: Set[str] = set()

        def walk(cid: int):
            cid = self.eg.find(cid)
            if cid in seen:
                return
            seen.add(cid)
            n = self.node(cid)
            if n.op == "array" and n.payload not in seen_syms:
                seen_syms.add(n.payload)
                syms.append(n.payload)
            for ch in n.children:
                walk(ch)
            cids.append(cid)

        for r in roots:
            walk(r)
        return cids, syms

    def loop_roots(self, loop: LoopRegion) -> List[int]:
        out = [loop.start_cid, loop.stop_cid]
        for carry in loop.carries:
            out.extend([carry.init_cid, carry.next_cid])

        def body(region: Region):
            for item in region.items:
                if isinstance(item, StoreEffect):
                    out.append(item.value_cid)
                    out.extend(item.index_cids)
                    if item.pred_cid is not None:
                        out.append(item.pred_cid)
                else:
                    out.extend(self.loop_roots(item))
        body(loop.body)
        return out

    # -- pricing -----------------------------------------------------------
    def _per_pass_ns(self) -> float:
        return self.lat.tile_elems / self.lat.chip.vpu_elems_per_s * 1e9

    def _dispatch_ns(self) -> float:
        coeffs = self.lat.pass_coeffs or {}
        return float(coeffs.get("memory_dispatch", 0.0)) \
            * self._per_pass_ns()

    def make_unit(self, kind: str, *, cid=None, item=None) -> SchedUnit:
        u = SchedUnit(uid=self._uid, kind=kind, cid=cid, item=item)
        self._uid += 1
        if kind == "load":
            st = self.cm.node_stats(self.node(cid))
            u.issue_ns = self.lat.compute_ns(st)  # calibrated dispatch
            u.mem_ns = self.lat.memory_ns(st)
            u.bytes_live = st.bytes_read
            u.key = legacy_bulk_key(self.node, cid)
        elif kind == "compute":
            n = self.node(cid)
            st = self.cm.node_stats(n)
            u.issue_ns = self.lat.compute_ns(st)
            u.raw_passes = _PASSES.get(op_pass_class(n.op), 0.0)
            u.key = repr(n)
        elif kind == "store":
            info = self._store_infos.get(id(item))
            nbytes = (info.bytes(self.lat.tile_elems) if info is not None
                      else float(self.lat.tile_elems * 4))
            u.issue_ns = self._dispatch_ns()
            u.mem_ns = nbytes / (self.lat.chip.hbm_bw
                                 * self.lat.hbm_efficiency) * 1e9
            u.key = ("store", item.order)
        return u


# units that never emit a line of their own: leaves are named inline,
# phi_loop/loop placeholders are bound by the loop emission machinery
_NON_UNIT_OPS = frozenset({"const", "var", "array", "phi_loop"})


def _build_regions(b: _Builder) -> Dict[Tuple[int, ...], List[SchedUnit]]:
    b.assign_regions()
    regions: Dict[Tuple[int, ...], List[SchedUnit]] = {}
    cid_unit: Dict[int, SchedUnit] = {}
    # version symbol -> defining unit (store or loop)
    sym_def: Dict[str, SchedUnit] = {}
    # version symbol -> load units reading it (WAR anti-dependences)
    sym_readers: Dict[str, List[SchedUnit]] = {}

    loop_units: Dict[int, SchedUnit] = {}

    def units_for(region: Region, path: Tuple[int, ...]):
        units: List[SchedUnit] = []
        # 1 unit per load/compute cid homed here (deterministic walk
        # order: discovery from the region's roots in program order)
        seen: Set[int] = set()

        def discover(cid: int):
            cid = b.eg.find(cid)
            if cid in seen:
                return
            seen.add(cid)
            n = b.node(cid)
            for ch in n.children:
                discover(ch)
            if b.cid_region.get(cid) != path or n.op in _NON_UNIT_OPS:
                return
            if cid in cid_unit:
                return  # already homed (shared with an earlier region)
            kind = "load" if n.op == "load" else "compute"
            u = b.make_unit(kind, cid=cid)
            cid_unit[cid] = u
            units.append(u)
            if kind == "load":
                arr = b.node(n.children[0])
                if arr.op == "array":
                    sym_readers.setdefault(arr.payload, []).append(u)

        item_units: List[Tuple[Any, SchedUnit]] = []
        for item in region.items:
            if isinstance(item, StoreEffect):
                discover(item.value_cid)
                for i in item.index_cids:
                    discover(i)
                if item.pred_cid is not None:
                    discover(item.pred_cid)
                u = b.make_unit("store", item=item)
                sym_def[item.version_out] = u
            else:
                for r in b.loop_roots(item):
                    # only the cids homed at THIS path become units here;
                    # deeper ones are discovered by the body's own pass
                    discover(r)
                u = b.make_unit("loop", item=item)
                loop_units[item.loop_id] = u
                for ac in item.array_carries:
                    sym_def[ac.version_post] = u
                    sym_def[ac.version_body] = u
            units.append(u)
            item_units.append((item, u))

        # -- edges ---------------------------------------------------------
        def dep_of_cid(cid: int) -> Optional[SchedUnit]:
            return cid_unit.get(b.eg.find(cid))

        def expr_deps(u: SchedUnit, cid: int, visiting: Set[int]):
            """deps of a unit on the cone of ``cid`` (stop at units),
            registered in expression (first-encounter) order."""
            cid = b.eg.find(cid)
            if cid in visiting:
                return
            visiting.add(cid)
            d = dep_of_cid(cid)
            if d is not None and d.uid != u.uid:
                u.add_dep(d.uid)
                return
            n = b.node(cid)
            if n.op == "array":
                s = sym_def.get(n.payload)
                if s is not None and s.uid != u.uid:
                    u.add_dep(s.uid)
                return
            if n.op == "phi_loop":
                # post-loop value: defined by the loop's emission, not
                # by its (init, next) children — next lives in the body
                lu = loop_units.get(n.payload[0])
                if lu is not None and lu.uid != u.uid:
                    u.add_dep(lu.uid)
                expr_deps(u, n.children[0], visiting)  # init value
                return
            for ch in n.children:
                expr_deps(u, ch, visiting)

        for u in units:
            if u.cid is not None:
                for ch in b.node(u.cid).children:
                    expr_deps(u, ch, set())
        for item, u in item_units:
            if isinstance(item, StoreEffect):
                expr_deps(u, item.value_cid, set())
                for i in item.index_cids:
                    expr_deps(u, i, set())
                if item.pred_cid is not None:
                    expr_deps(u, item.pred_cid, set())
                s = sym_def.get(item.version_in)
                if s is not None and s.uid != u.uid:
                    u.add_dep(s.uid)   # store chain (RAW + store-store)
                # WAR: loads of the overwritten version issue first
                # (the Pallas path rebinds the same ref in place)
                for rd in sym_readers.get(item.version_in, []):
                    if rd.uid != u.uid:
                        u.add_dep(rd.uid)
            else:
                cids, syms = b.cone(b.loop_roots(item))
                for cid in cids:
                    d = dep_of_cid(cid)
                    if d is not None and d.uid != u.uid:
                        u.add_dep(d.uid)
                for sym in syms:
                    s = sym_def.get(sym)
                    if s is not None and s.uid != u.uid:
                        u.add_dep(s.uid)
                    # the loop reads this version: later stores that
                    # overwrite it must wait for the whole loop (WAR)
                    sym_readers.setdefault(sym, []).append(u)
                for ac in item.array_carries:
                    s = sym_def.get(ac.version_init)
                    if s is not None and s.uid != u.uid:
                        u.add_dep(s.uid)
                    for rd in sym_readers.get(ac.version_init, []):
                        if rd.uid != u.uid:
                            u.add_dep(rd.uid)

        # edges may only point inside this region's unit set
        uids = {u.uid for u in units}
        for u in units:
            u.deps &= uids
            u.dep_seq = [d for d in u.dep_seq if d in uids]
        regions[path] = units
        for item in region.items:
            if isinstance(item, LoopRegion):
                units_for(item.body, path + (item.loop_id,))

    units_for(b.ssa.region, ())
    return regions


# ---------------------------------------------------------------------------
# Named orders
# ---------------------------------------------------------------------------
def _source_order(units: List[SchedUnit]) -> List[int]:
    """Loads/compute at their use sites: emit each store/loop after a
    depth-first emission of its not-yet-emitted dependences in
    expression order — the legacy ``bulk=False`` emission shape."""
    by_uid = {u.uid: u for u in units}
    emitted: Set[int] = set()
    out: List[int] = []

    def emit(uid: int):
        if uid in emitted:
            return
        emitted.add(uid)
        for d in by_uid[uid].dep_seq:
            emit(d)
        out.append(uid)

    for u in units:
        if u.kind in ("store", "loop"):
            emit(u.uid)
    for u in units:              # consumer-less stragglers, if any
        emit(u.uid)
    return out


def _bulk_order(units: List[SchedUnit]) -> List[int]:
    """The legacy bulk-load emission order: at the top of the region —
    and again after every store/loop — flush every load whose
    dependences are all emitted, in ``legacy_bulk_key`` order; compute
    still sits at its use sites."""
    by_uid = {u.uid: u for u in units}
    emitted: Set[int] = set()
    out: List[int] = []

    def emit(uid: int):
        if uid in emitted:
            return
        emitted.add(uid)
        for d in by_uid[uid].dep_seq:
            emit(d)
        out.append(uid)

    def ready(u: SchedUnit) -> bool:
        """A load is flushable when nothing blocking (store/loop) sits
        under it — pure compute/load deps are emitted with it, exactly
        like the legacy ``_deps_ready`` recursion."""
        seen: Set[int] = set()

        def ok(uid: int) -> bool:
            if uid in emitted or uid in seen:
                return True
            seen.add(uid)
            d = by_uid[uid]
            if d.kind in ("store", "loop"):
                return False
            return all(ok(x) for x in d.deps)
        return ok(u.uid)

    def flush():
        pend = [u for u in units if u.kind == "load"
                and u.uid not in emitted and ready(u)]
        for u in sorted(pend, key=lambda u: u.key):
            emit(u.uid)

    flush()
    for u in units:
        if u.kind in ("store", "loop"):
            emit(u.uid)
            flush()
    for u in units:
        emit(u.uid)
    return out


# ---------------------------------------------------------------------------
# Objective + cost-driven search
# ---------------------------------------------------------------------------
def _events_of(units: List[SchedUnit], order: List[int]
               ) -> List[ScheduleEvent]:
    by_uid = {u.uid: u for u in units}
    pos = {uid: i for i, uid in enumerate(order)}
    consumers: Dict[int, List[int]] = {uid: [] for uid in order}
    for u in units:
        for d in u.deps:
            consumers[d].append(pos[u.uid])
    events: List[ScheduleEvent] = []
    for uid in order:
        u = by_uid[uid]
        cons = consumers[uid]
        events.append(ScheduleEvent(
            kind=u.kind if u.kind in ("load", "store") else "compute",
            issue_ns=u.issue_ns, mem_ns=u.mem_ns,
            bytes_live=u.bytes_live,
            first_use=min(cons) if cons else -1,
            last_use=max(cons) if cons else -1))
    return events


def _region_ns(lat: LatencyModel, units: List[SchedUnit],
               order: List[int], vmem_budget: Optional[int]
               ) -> Dict[str, float]:
    return lat.schedule_ns(_events_of(units, order),
                           vmem_budget_bytes=vmem_budget)


class _Budget:
    __slots__ = ("remaining",)

    def __init__(self, n: int):
        self.remaining = n

    def take(self) -> bool:
        if self.remaining <= 0:
            return False
        self.remaining -= 1
        return True


def _cost_order(lat: LatencyModel, units: List[SchedUnit],
                seeds: List[List[int]], vmem_budget: Optional[int],
                budget: _Budget) -> Tuple[List[int], int]:
    """Deterministic first-improvement insertion search: repeatedly try
    moving one unit to every other legal position, accepting strict
    improvements, from each seed; return the best order found. Because
    the seeds themselves are candidates, the result is never worse than
    any seed."""
    by_uid = {u.uid: u for u in units}
    scored = 0
    # chaos site: a stalled cost search surfaces as the deadline trip
    # the guard's wall-clock safety net would report, deterministically
    if chaos.chaos_point("slow_stage"):
        raise BudgetExceeded("deadline", "injected slow-stage stall in "
                             "the cost schedule search")

    def objective(order: List[int]) -> float:
        nonlocal scored
        scored += 1
        # guard hook: one deterministic tick per scored order
        guard_tick("schedule")
        return _region_ns(lat, units, order, vmem_budget)["latency_ns"]

    dependents = {u.uid: {v.uid for v in units if u.uid in v.deps}
                  for u in units}
    best_order, best = None, float("inf")
    for seed in seeds:
        cur = list(seed)
        cur_ns = objective(cur)
        improved = True
        while improved and budget.remaining > 0:
            improved = False
            for i in range(len(cur)):
                uid = cur[i]
                u = by_uid[uid]
                # legal final positions for u in the list with u removed:
                # strictly after every dep, strictly before every
                # dependent (indices adjusted for the removal)
                lo, hi = 0, len(cur) - 1
                for j, w in enumerate(cur):
                    if w == uid:
                        continue
                    adj = j if j < i else j - 1
                    if w in u.deps:
                        lo = max(lo, adj + 1)
                    if w in dependents[uid]:
                        hi = min(hi, adj)
                for f in range(lo, hi + 1):
                    if f == i:        # re-inserting at i is the identity
                        continue
                    if not budget.take():
                        break
                    cand = list(cur)
                    cand.pop(i)
                    cand.insert(f, uid)
                    ns = objective(cand)
                    if ns < cur_ns - 1e-9:
                        cur, cur_ns = cand, ns
                        improved = True
                        break
                if improved or budget.remaining <= 0:
                    break
        if cur_ns < best - 1e-12:
            best_order, best = cur, cur_ns
    return (best_order if best_order is not None else list(seeds[0]),
            scored)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------
def unit_key(eg, u: SchedUnit) -> Tuple[str, Any]:
    """Process-portable identity of one unit: loads/compute by canonical
    e-class, stores by their SSA store order, loops by loop id. The
    persistent saturation cache serializes region orders as these keys
    (with cids further translated to structural node indices)."""
    if u.kind in ("load", "compute"):
        return (u.kind, eg.find(u.cid))
    if u.kind == "store":
        return ("store", u.item.order)
    return ("loop", u.item.loop_id)


def _order_from_keys(eg, units: List[SchedUnit],
                     keys: Optional[Sequence[Tuple[str, Any]]]
                     ) -> List[int]:
    """Translate a unit-key order back to uids; raises ValueError when a
    key is missing/unknown or the order is illegal."""
    if keys is None:
        raise ValueError("no cached order for this region")
    key_uid = {}
    for u in units:
        key_uid[unit_key(eg, u)] = u.uid
    order: List[int] = []
    for kind, ref in keys:
        k = (kind, eg.find(ref)) if kind in ("load", "compute") \
            else (kind, ref)
        uid = key_uid.get(k)
        if uid is None:
            raise ValueError(f"cached order names unknown unit {k!r}")
        order.append(uid)
    if not is_legal_order(units, order):
        raise ValueError("cached order is not a legal topological order")
    return order


def compute_schedule(ssa: SSAResult, choice: Dict[int, ENode], *,
                     mode: str = "cost", cost_model=None,
                     vmem_budget_bytes: Optional[int] = None,
                     move_budget: int = DEFAULT_MOVE_BUDGET,
                     fixed_orders: Optional[Dict[Tuple[int, ...],
                                                 Sequence]] = None,
                     seed_orders: Optional[Dict[Tuple[int, ...],
                                                Sequence]] = None
                     ) -> ScheduleResult:
    """Build the dependence DAG of the extracted ``choice`` and order it
    under ``mode`` (``"source" | "bulk" | "cost"``).

    ``cost_model`` prices the units (defaults to the analytic
    :class:`repro.analysis.RooflineCostModel` bound to the SSA e-graph;
    pass the pipeline's calibrated model so scheduling optimizes the
    same objective as extraction). Loops are scheduled recursively and
    priced as atomic units of their body's one-trip latency.

    ``fixed_orders`` replays a persisted schedule: a ``{region path:
    [unit keys]}`` map (see :func:`unit_key`) that becomes the emitted
    order verbatim — **no cost search runs** (the exact-cache-hit
    path). Every region must be present and legal or ValueError is
    raised (callers fall back to a cold search). ``seed_orders`` has
    the same shape but only *seeds* the cost search (warm start);
    unmappable/illegal seeds are ignored.
    """
    if mode not in SCHEDULE_MODES:
        raise ValueError(
            f"schedule mode must be one of {SCHEDULE_MODES}, got {mode!r}")
    if cost_model is None:
        from repro.analysis import RooflineCostModel
        cost_model = RooflineCostModel(
            dtype=getattr(ssa.prog, "dtype", None) or "f32",
            egraph=ssa.egraph)
    b = _Builder(ssa, choice, cost_model)
    lat = b.lat
    region_units = _build_regions(b)
    budget = _Budget(move_budget)
    regions: Dict[Tuple[int, ...], RegionSchedule] = {}
    moves = 0
    # deepest regions first: loop units in parent regions are priced by
    # their (already scheduled) body latency
    body_ns: Dict[Tuple[int, ...], float] = {}
    mode_ns: Dict[str, Dict[Tuple[int, ...], float]] = {
        m: {} for m in SCHEDULE_MODES}
    for path in sorted(region_units, key=len, reverse=True):
        units = region_units[path]
        for u in units:
            if u.kind == "loop":
                inner = path + (u.item.loop_id,)
                # marginal one-trip time of the body (base_ns is a
                # per-kernel constant, not per-loop)
                u.issue_ns = max(0.0, body_ns.get(inner, 0.0)
                                 - lat.base_ns)
        orders = {"source": _source_order(units),
                  "bulk": _bulk_order(units)}
        reports = {m: _region_ns(lat, units, o, vmem_budget_bytes)
                   for m, o in orders.items()}
        if fixed_orders is not None:
            # replay a persisted order verbatim — no search
            fixed = _order_from_keys(b.eg, units, fixed_orders.get(path))
            orders[mode] = fixed
            if mode != "cost":
                orders["cost"] = orders["bulk"]   # placeholder pricing
            reports[mode] = _region_ns(lat, units, fixed,
                                       vmem_budget_bytes)
            if "cost" not in reports:
                reports["cost"] = _region_ns(lat, units, orders["cost"],
                                             vmem_budget_bytes)
        else:
            seeds = [orders["bulk"], orders["source"]]
            if seed_orders is not None and path in seed_orders:
                try:
                    seeds.insert(0, _order_from_keys(
                        b.eg, units, seed_orders[path]))
                except ValueError:
                    pass   # a stale seed is just not a seed
            cost_o, scored = _cost_order(lat, units, seeds,
                                         vmem_budget_bytes, budget)
            moves += scored
            orders["cost"] = cost_o
            reports["cost"] = _region_ns(lat, units, cost_o,
                                         vmem_budget_bytes)
        for m in SCHEDULE_MODES:
            mode_ns[m][path] = reports[m]["latency_ns"]
        chosen = orders[mode]
        regions[path] = RegionSchedule(path=path, units=units,
                                       order=chosen,
                                       report=reports[mode])
        body_ns[path] = reports[mode]["latency_ns"]
    top = regions.get((), None)
    predicted = top.report["latency_ns"] if top is not None else 0.0
    # whole-kernel per-mode totals: the top region's objective, with
    # loop bodies folded in through their unit pricing under ``mode``
    by_mode = {m: mode_ns[m].get((), 0.0) for m in SCHEDULE_MODES}
    return ScheduleResult(mode=mode, regions=regions,
                          predicted_ns=predicted,
                          predicted_by_mode=by_mode,
                          moves_scored=moves)


def loop_profile(ssa: SSAResult, scalars: Optional[Dict[str, float]] = None
                 ) -> Tuple[Tuple[float, float], ...]:
    """Static per-loop ``(trip_count, body_units)`` calibration features.

    Walks the SSA region tree and, for every loop, resolves the trip
    count from the e-graph's constant-folding analysis — falling back to
    ``scalars`` for runtime-scalar bounds (``cg_like``'s ``nnz`` is a
    scalar the measurement harness *does* know at measure time).
    ``body_units`` is the loop body's per-iteration statement count
    (store effects + scalar/array carry updates — a deterministic
    dispatch-equivalent regressor; the fitted coefficient absorbs the
    per-statement cost scale); nested loops
    multiply the enclosing trip counts in. Unresolvable bounds record a
    trip count of 0.0, which prices as the old once-through formula
    (the extra term contributes nothing)."""
    eg = ssa.egraph
    scalars = scalars or {}

    def resolve(cid: int) -> Optional[float]:
        ec = eg.classes.get(eg.find(cid))
        if ec is None:
            return None
        if ec.data is not None:
            return float(ec.data)
        for n in ec.nodes:
            if n.op == "var" and n.payload in scalars:
                return float(scalars[n.payload])
        return None

    def body_units(loop: LoopRegion) -> float:
        stores = sum(1 for item in loop.body.items
                     if not isinstance(item, LoopRegion))
        return float(stores + len(loop.carries)
                     + len(loop.array_carries))

    out: List[Tuple[float, float]] = []

    def walk(region, outer_trips: float) -> None:
        for item in region.items:
            if not isinstance(item, LoopRegion):
                continue
            start = resolve(item.start_cid)
            stop = resolve(item.stop_cid)
            trips = (max(stop - start, 0.0)
                     if start is not None and stop is not None else 0.0)
            out.append((trips * outer_trips, body_units(item)))
            walk(item.body, outer_trips * max(trips, 1.0))

    walk(ssa.region, 1.0)
    return tuple(out)
