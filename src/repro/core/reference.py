"""Reference interpreter for the kernel DSL — the correctness oracle.

Executes a :class:`KernelProgram` imperatively with numpy, completely
independent of the e-graph/SSA/codegen path, so tests can check that
saturated kernels preserve semantics (paper's reproducibility requirement,
§IV).
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

from .dsl import ArrayRef, Assign, For, If, KernelProgram
from .ir import EVAL_FNS


def _eval(t: tuple, env: Dict[str, Any], arrays: Dict[str, np.ndarray],
          calls: Dict[str, Any]):
    op = t[0]
    if op == "const":
        return t[1]
    if op == "var":
        return env[t[1]]
    if op == "aload":
        arr = arrays[t[1]]
        idx = tuple(int(_eval(i, env, arrays, calls)) for i in t[2:])
        return arr[idx] if idx else arr
    if op == "call":
        args = [_eval(a, env, arrays, calls) for a in t[2:]]
        return calls[t[1]](*args)
    args = [_eval(a, env, arrays, calls) for a in t[1:]]
    if op == "select":
        c, a, b = args
        return np.where(c, a, b)
    return EVAL_FNS[op](*args)


def _run_block(stmts, env, arrays, calls):
    for st in stmts:
        if isinstance(st, Assign):
            val = _eval(st.expr, env, arrays, calls)
            if isinstance(st.target, str):
                env[st.target] = val
            else:
                ref: ArrayRef = st.target
                idx = tuple(int(_eval(i, env, arrays, calls))
                            for i in ref.indices)
                if idx:
                    arrays[ref.name] = arrays[ref.name].copy()
                    arrays[ref.name][idx] = val
                else:
                    arrays[ref.name] = np.broadcast_to(
                        np.asarray(val, dtype=arrays[ref.name].dtype),
                        arrays[ref.name].shape).copy()
        elif isinstance(st, If):
            cond = _eval(st.cond, env, arrays, calls)
            if np.ndim(cond) == 0:
                _run_block(st.then if cond else st.orelse, env, arrays, calls)
            else:
                raise ValueError("reference interpreter requires scalar "
                                 "if-conditions; use select() for tiles")
        elif isinstance(st, For):
            start = int(_eval(st.start, env, arrays, calls))
            stop = int(_eval(st.stop, env, arrays, calls))
            for i in range(start, stop):
                env[st.var] = i
                _run_block(st.body, env, arrays, calls)
        else:
            raise TypeError(st)


def run_reference(prog: KernelProgram, inputs: Dict[str, Any],
                  calls: Dict[str, Any] | None = None) -> Dict[str, np.ndarray]:
    """Run ``prog`` on numpy inputs; returns the out/inout arrays."""
    env: Dict[str, Any] = {}
    arrays: Dict[str, np.ndarray] = {}
    for name, spec in prog.arrays.items():
        if name not in inputs:
            raise KeyError(f"missing array input {name!r} (out arrays need "
                           f"a zero-initialized buffer, like a C kernel)")
        arrays[name] = np.array(inputs[name], dtype=np.float64, copy=True)
    for s in prog.scalars:
        env[s] = inputs[s]
    _run_block(prog.body, env, arrays, calls or {})
    return {a.name: arrays[a.name] for a in prog.arrays.values()
            if a.role in ("out", "inout")}
