"""TPU hardware constants used by cost models, roofline, and VMEM sizing.

Target: TPU v5e (the assignment's roofline constants). A100 numbers are
kept for the paper-comparison ablation in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s (matrix unit)
    hbm_bw: float               # bytes/s
    ici_bw_per_link: float      # bytes/s per link
    hbm_bytes: int              # HBM capacity
    vmem_bytes: int             # usable VMEM per core (conservative)
    ici_links: int = 4          # 2D torus: 4 links/chip
    # Vector-unit issue model (used by the unified analysis subsystem to
    # price elementwise tile passes): `vpu_lanes` elements retire per
    # cycle at `clock_hz`.
    vpu_lanes: int = 8 * 128    # v5e VPU: (8, 128) vregs
    clock_hz: float = 0.94e9

    @property
    def vpu_elems_per_s(self) -> float:
        """Elementwise lanes/sec — the vector-issue roofline ceiling."""
        return self.vpu_lanes * self.clock_hz


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,     # assignment constant
    hbm_bw=819e9,               # assignment constant
    ici_bw_per_link=50e9,       # assignment constant (~50 GB/s/link)
    hbm_bytes=16 * 1024**3,
    vmem_bytes=64 * 1024**2,    # keep kernels well under the 128 MiB VMEM
)

# For the paper's own A100-PCIE-40GB evaluation (Fig. 2/4), used by the
# ablation benchmark to relate our cost-model deltas to the paper's GPU.
A100_PCIE_40GB = ChipSpec(
    name="a100_pcie_40gb",
    peak_flops_bf16=312e12,
    hbm_bw=1555e9,
    ici_bw_per_link=64e9,       # NVLink3 per-direction aggregate/ring share
    hbm_bytes=40 * 1024**3,
    vmem_bytes=192 * 1024,      # SMEM+L1 per SM — for commentary only
    vpu_lanes=108 * 64,         # 108 SMs × 64 FP32 lanes
    clock_hz=1.41e9,
)

DEFAULT_CHIP = TPU_V5E

# Mesh axis conventions used across the framework.
AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"
