"""Code generation from extracted e-graphs (paper §VI).

Reproduces both halves of the paper's generator:

* **Temporary-variable insertion** (§VI-A): every selected e-node becomes a
  ``_v{n}`` SSA temp placed immediately before its first use (innermost
  scope covering all uses), so shared subexpressions are computed once.
* **Bulk load** (§VI-B): with ``bulk=True`` every memory load is relocated
  to the *first point where its dependencies are resolved* — the top of the
  innermost legal region, re-flushed after each store/loop that defines a
  new array version — and loads of the same array are sorted by their
  static index representation. Memory pressure is front-loaded exactly as
  in the paper's Listing 3.

Since PR 5 statement order is owned by :mod:`repro.core.schedule`: the
``schedule`` parameter picks ``"source"`` (loads at use sites, the old
``bulk=False``), ``"bulk"`` (the paper's rule — same bit-identical
sources as before, with the flush order coming from
``schedule.legacy_bulk_key`` instead of an ad-hoc sort), or ``"cost"``
(a cost-driven legal topological order minimizing the schedule-aware
latency objective; emission then follows the explicit per-region order).

The emitted artifact is Python/JAX source (``jnp``/``lax``), exec'd into a
callable; the Pallas emitter in :mod:`repro.core.pallasgen` reuses this
module's scheduler.
"""
from __future__ import annotations

import dataclasses
import sys
import textwrap
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple, Union

from repro.runtime import chaos

from .egraph import EGraph
from .extract import ExtractionResult
from .ir import ENode
from .schedule import (SCHEDULE_MODES, ScheduleResult, compute_schedule,
                       legacy_bulk_key)
from .ssa import ArrayCarry, Carry, LoopRegion, Region, SSAResult, StoreEffect

sys.setrecursionlimit(100_000)


def _sanitize(sym: str) -> str:
    return (sym.replace("@", "_v_").replace(":", "_").replace("%", "p_")
            .replace(".", "_"))


@dataclasses.dataclass
class GenStats:
    n_temps: int = 0
    n_loads: int = 0
    n_stores: int = 0
    n_fma: int = 0
    n_ops: int = 0
    instruction_mix: Dict[str, int] = dataclasses.field(default_factory=dict)
    # per-region: how many loads were emitted before the first compute op
    loads_before_compute: int = 0
    dag_cost: float = 0.0


@dataclasses.dataclass
class GeneratedKernel:
    name: str
    source: str
    fn: Callable
    in_arrays: List[str]
    scalars: List[str]
    out_arrays: List[str]
    stats: GenStats
    bulk: bool
    schedule_mode: str = "bulk"            # source | bulk | cost
    schedule: Optional[ScheduleResult] = None  # set for explicit orders

    def __call__(self, *args, **kw):
        return self.fn(*args, **kw)


_PRELUDE = """\
import jax
import jax.numpy as jnp
from jax import lax

def _rothalf(x):
    h = x.shape[-1] // 2
    return jnp.concatenate([-x[..., h:], x[..., :h]], axis=-1)
"""

_UNARY_FMT = {
    "neg": "(-{0})",
    "exp": "jnp.exp({0})",
    "log": "jnp.log({0})",
    "sqrt": "jnp.sqrt({0})",
    "rsqrt": "lax.rsqrt({0})",
    "tanh": "jnp.tanh({0})",
    "abs": "jnp.abs({0})",
    "sigmoid": "lax.logistic({0})",
    "recip": "(1.0 / {0})",
    "floor": "jnp.floor({0})",
    "square": "({0} * {0})",
    "toint": "{0}.astype(jnp.int32)",
    "rsum": "jnp.sum({0}, axis=-1, keepdims=True)",
    "rmean": "jnp.mean({0}, axis=-1, keepdims=True)",
    "rmax": "jnp.max({0}, axis=-1, keepdims=True)",
    "rothalf": "_rothalf({0})",
}
_BIN_FMT = {
    "add": "({0} + {1})", "sub": "({0} - {1})", "mul": "({0} * {1})",
    "div": "({0} / {1})", "mod": "({0} % {1})", "pow": "({0} ** {1})",
    "min": "jnp.minimum({0}, {1})", "max": "jnp.maximum({0}, {1})",
    "lt": "({0} < {1})", "le": "({0} <= {1})", "gt": "({0} > {1})",
    "ge": "({0} >= {1})", "eq": "({0} == {1})", "ne": "({0} != {1})",
}
_TERN_FMT = {
    "fma": "({0} + {1} * {2})",  # XLA:TPU emits a fused multiply-add
    "select": "jnp.where({0}, {1}, {2})",
    "phi": "jnp.where({0}, {1}, {2})",
}


class _Scope:
    """Stack of name bindings; inner scopes see outer bindings.

    ``forced`` bindings (loop vars, carries, post-loop values) are always
    visible; ``memo`` bindings implement temp reuse and are consulted only
    when the generator runs with ``reuse_temps=True`` (CSE on). Disabling
    them reproduces the 'original' code with fully re-expanded expressions.
    """

    def __init__(self):
        self.stack: List[Dict[int, str]] = [{}]
        self.forced: List[Dict[int, str]] = [{}]
        self.syms: List[Dict[str, str]] = [{}]  # array-version symbol -> name

    def push(self):
        self.stack.append({})
        self.forced.append({})
        self.syms.append({})

    def pop(self):
        self.stack.pop()
        self.forced.pop()
        self.syms.pop()

    def get(self, cid: int, memo: bool = True) -> Optional[str]:
        for frame in reversed(self.forced):
            if cid in frame:
                return frame[cid]
        if memo:
            for frame in reversed(self.stack):
                if cid in frame:
                    return frame[cid]
        return None

    def bind(self, cid: int, name: str):
        self.stack[-1][cid] = name

    def bind_forced(self, cid: int, name: str):
        self.forced[-1][cid] = name

    def get_sym(self, sym: str) -> Optional[str]:
        for frame in reversed(self.syms):
            if sym in frame:
                return frame[sym]
        return None

    def bind_sym(self, sym: str, name: str):
        self.syms[-1][sym] = name


class JaxCodeGenerator:
    """The ``"jax"`` emitter: saturated Python/JAX source, exec'd into a
    callable. Known as ``CodeGenerator`` before the PR-8 emitter
    registry (:mod:`repro.core.emit`); that name remains as a deprecated
    alias."""

    def __init__(self, ssa: SSAResult, extraction: ExtractionResult, *,
                 bulk: bool = True, fn_name: Optional[str] = None,
                 extra_fns: Optional[Dict[str, Callable]] = None,
                 reuse_temps: bool = True,
                 schedule: Optional[Union[str, ScheduleResult]] = None,
                 sched_cost_model=None):
        self.ssa = ssa
        self.eg: EGraph = ssa.egraph
        self.choice: Dict[int, ENode] = dict(extraction.choice)
        # ``schedule`` overrides the legacy bulk flag: a mode name picks a
        # named order ("bulk" stays bit-identical to bulk=True, "source"
        # to bulk=False, "cost" searches); a ScheduleResult is emitted
        # verbatim (the legality-fuzz tests inject arbitrary legal orders
        # this way). ``sched_cost_model`` prices the cost search — pass
        # the extraction's (possibly calibrated) roofline model so both
        # optimize the same objective.
        if isinstance(schedule, ScheduleResult):
            self.schedule_mode = schedule.mode
            self._explicit: Optional[ScheduleResult] = schedule
        else:
            if schedule is not None and schedule not in SCHEDULE_MODES:
                raise ValueError(f"schedule must be one of "
                                 f"{SCHEDULE_MODES}, got {schedule!r}")
            self.schedule_mode = schedule if schedule is not None else \
                ("bulk" if bulk else "source")
            self._explicit = None
        self.bulk = self.schedule_mode == "bulk"
        self._sched_cm = sched_cost_model
        # reuse_temps: True = CSE on (memoize every e-class); False/"lets"
        # = only programmer-named `let` values are reused, reproducing the
        # original source's temporaries (the paper's un-optimized input)
        self.reuse_temps = reuse_temps
        self._let_set = {ssa.egraph.find(c) for c in ssa.let_cids}
        self.fn_name = fn_name or _sanitize(ssa.prog.name)
        self.extra_fns = extra_fns or {}
        self.scope = _Scope()
        self.tmp = 0
        self.stats = GenStats(dag_cost=extraction.dag_cost)
        self._load_regions: Dict[int, Tuple[int, ...]] = {}
        self._region_first_compute: Dict[Tuple[int, ...], bool] = {}

    def _resolve_schedule(self) -> Optional[ScheduleResult]:
        """The explicit per-region order to emit, or None for the legacy
        source/bulk paths (which stay bit-identical to pre-PR-5)."""
        if self._explicit is None and self.schedule_mode == "cost":
            cm = self._sched_cm if hasattr(self._sched_cm, "latency") \
                else None   # flat models can't price a schedule
            if cm is not None and hasattr(cm, "bind_egraph"):
                cm.bind_egraph(self.eg)
            self._explicit = compute_schedule(
                self.ssa, self.choice, mode="cost", cost_model=cm)
        return self._explicit

    # -- choice helpers -----------------------------------------------------
    def node(self, cid: int) -> ENode:
        cid = self.eg.find(cid)
        n = self.choice.get(cid)
        if n is None:
            # node outside extraction (e.g. demanded pred/index added late):
            # fall back to a fresh greedy extraction for it
            from .extract import extract_dag
            res = extract_dag(self.eg, (cid,), local_search=False)
            for k, v in res.choice.items():
                self.choice.setdefault(k, v)
            n = self.choice[cid]
        return n

    def _fresh(self) -> str:
        self.tmp += 1
        return f"_v{self.tmp}"

    # -- load-region analysis (bulk mode) ---------------------------------------
    def _collect_load_regions(self):
        """min legal region (loop-id path) for every load in the chosen dag."""
        memo: Dict[int, Tuple[int, ...]] = {}
        var_region: Dict[str, Tuple[int, ...]] = {}
        sym_region: Dict[str, Tuple[int, ...]] = {}

        def index_regions(region: Region, path: Tuple[int, ...]):
            for item in region.items:
                if isinstance(item, LoopRegion):
                    inner = path + (item.loop_id,)
                    var_region[f"%L{item.loop_id}:{item.var}"] = inner
                    for carry in item.carries:
                        var_region[f"%L{item.loop_id}:{carry.name}"] = inner
                    for ac in item.array_carries:
                        sym_region[ac.version_body] = inner
                        sym_region[ac.version_post] = path
                    index_regions(item.body, inner)
                else:
                    sym_region[item.version_out] = path
        index_regions(self.ssa.region, ())

        def join(a: Tuple[int, ...], b: Tuple[int, ...]) -> Tuple[int, ...]:
            return a if len(a) >= len(b) else b

        def walk(cid: int) -> Tuple[int, ...]:
            cid = self.eg.find(cid)
            if cid in memo:
                return memo[cid]
            memo[cid] = ()  # provisional (acyclic by extraction)
            n = self.node(cid)
            r: Tuple[int, ...] = ()
            if n.op == "var" and isinstance(n.payload, str):
                r = var_region.get(n.payload, ())
            elif n.op == "array":
                r = sym_region.get(n.payload, ())
            for ch in n.children:
                r = join(r, walk(ch))
            memo[cid] = r
            if n.op == "load":
                self._load_regions[cid] = r
            return r

        for root in self.ssa.roots():
            walk(root)

    # -- expression emission ---------------------------------------------------------
    def _const_repr(self, val) -> str:
        if isinstance(val, bool):
            return "True" if val else "False"
        return repr(val)

    def emit_value(self, cid: int, lines: List[str], indent: str) -> str:
        cid = self.eg.find(cid)
        memo_ok = (self.reuse_temps is True
                   or (self.reuse_temps in (False, "lets")
                       and cid in self._let_set))
        bound = self.scope.get(cid, memo=memo_ok)
        if bound is not None:
            return bound
        n = self.node(cid)
        op = n.op
        if op == "const":
            return self._const_repr(n.payload)
        if op == "var":
            if isinstance(n.payload, str) and n.payload.startswith("%"):
                raise RuntimeError(f"unbound placeholder {n.payload}")
            return n.payload  # function parameter
        if op == "array":
            name = self.scope.get_sym(n.payload)
            if name is None:
                raise RuntimeError(f"unbound array version {n.payload}")
            return name
        kid_names = [self.emit_value(ch, lines, indent) for ch in n.children]
        name = self._fresh()
        self.stats.n_temps += 1
        self.stats.instruction_mix[op] = \
            self.stats.instruction_mix.get(op, 0) + 1
        if op == "load":
            self.stats.n_loads += 1
            arr = kid_names[0]
            if len(kid_names) == 1:
                expr = arr  # whole-tile load
            else:
                expr = f"{arr}[{', '.join(kid_names[1:])}]"
        elif op == "call":
            self.stats.n_ops += 1
            expr = f"_calls[{n.payload!r}]({', '.join(kid_names)})"
        elif op in _UNARY_FMT:
            self.stats.n_ops += 1
            expr = _UNARY_FMT[op].format(*kid_names)
        elif op in _BIN_FMT:
            self.stats.n_ops += 1
            expr = _BIN_FMT[op].format(*kid_names)
        elif op in _TERN_FMT:
            self.stats.n_ops += 1
            if op == "fma":
                self.stats.n_fma += 1
            expr = _TERN_FMT[op].format(*kid_names)
        else:
            raise NotImplementedError(f"codegen for op {op!r}")
        lines.append(f"{indent}{name} = {expr}")
        self.scope.bind(cid, name)
        return name

    # -- bulk-load flushing ---------------------------------------------------------
    def _deps_ready(self, cid: int, visiting: Optional[Set[int]] = None) -> bool:
        cid = self.eg.find(cid)
        if self.scope.get(cid) is not None:
            return True
        visiting = visiting or set()
        if cid in visiting:
            return False
        visiting.add(cid)
        n = self.node(cid)
        if n.op == "var" and isinstance(n.payload, str) and \
                n.payload.startswith("%"):
            return False
        if n.op == "array":
            return self.scope.get_sym(n.payload) is not None
        return all(self._deps_ready(c, visiting) for c in n.children)

    def _load_sort_key(self, cid: int):
        # the flush order is owned by the schedule subsystem (its "bulk"
        # order reproduces this exact key), never an ad-hoc sort here
        return legacy_bulk_key(self.node, cid)

    def _flush_loads(self, path: Tuple[int, ...], pending: List[int],
                     lines: List[str], indent: str):
        """Emit every pending load whose dependencies are resolved, in
        the schedule subsystem's bulk order — the paper's bulk-load
        rule."""
        ready = [c for c in pending if self._deps_ready(c)]
        for cid in sorted(ready, key=self._load_sort_key):
            self.emit_value(cid, lines, indent)
            if not self._region_first_compute.get(path, False):
                # index math emitted alongside counts as address
                # calculation (paper Listing 3: "Addr calculation + 123
                # loads"), not as the region's first compute
                self.stats.loads_before_compute += 1
            pending.remove(cid)

    # -- region emission ---------------------------------------------------------------
    def emit_region(self, region: Region, path: Tuple[int, ...],
                    lines: List[str], indent: str):
        sched = self._explicit.regions.get(path) \
            if self._explicit is not None else None
        if sched is not None:
            self._emit_scheduled(sched, path, lines, indent)
            return
        pending = [cid for cid, r in self._load_regions.items()
                   if r == path and self.scope.get(cid) is None] \
            if self.bulk else []
        if self.bulk:
            self._flush_loads(path, pending, lines, indent)
        for item in region.items:
            if isinstance(item, StoreEffect):
                self._emit_store(item, lines, indent)
            else:
                self._emit_loop(item, path, lines, indent)
            self._region_first_compute[path] = True
            if self.bulk:
                self._flush_loads(path, pending, lines, indent)

    def _emit_scheduled(self, sched, path: Tuple[int, ...],
                        lines: List[str], indent: str):
        """Emit one region following an explicit schedule order. Each
        unit is emitted at its scheduled slot; ``emit_value`` pulls any
        non-unit leaves (consts, bound vars) inline, and a unit already
        bound by an earlier recursion is a no-op."""
        for u in sched.ordered_units():
            if u.kind in ("load", "compute"):
                self.emit_value(u.cid, lines, indent)
                if u.kind == "load" and \
                        not self._region_first_compute.get(path, False):
                    self.stats.loads_before_compute += 1
                else:
                    self._region_first_compute[path] = True
            elif u.kind == "store":
                self._emit_store(u.item, lines, indent)
                self._region_first_compute[path] = True
            else:
                self._emit_loop(u.item, path, lines, indent)
                self._region_first_compute[path] = True

    def _emit_store(self, eff: StoreEffect, lines: List[str], indent: str):
        val = self.emit_value(eff.value_cid, lines, indent)
        idx = [self.emit_value(i, lines, indent) for i in eff.index_cids]
        src = self.scope.get_sym(eff.version_in)
        if src is None:
            raise RuntimeError(f"array version {eff.version_in} unbound")
        dst = _sanitize(eff.version_out)
        if eff.pred_cid is not None:
            pred = self.emit_value(eff.pred_cid, lines, indent)
            if idx:
                old = f"{src}[{', '.join(idx)}]"
            else:
                old = src
            val_expr = f"jnp.where({pred}, {val}, {old})"
        else:
            val_expr = val
        if idx:
            lines.append(f"{indent}{dst} = {src}.at[{', '.join(idx)}]"
                         f".set({val_expr})")
        else:
            if eff.pred_cid is None:
                lines.append(f"{indent}{dst} = {val_expr}")
            else:
                lines.append(f"{indent}{dst} = {val_expr}")
        self.scope.bind_sym(eff.version_out, dst)
        self.stats.n_stores += 1

    def _emit_loop(self, loop: LoopRegion, path: Tuple[int, ...],
                   lines: List[str], indent: str):
        start = self.emit_value(loop.start_cid, lines, indent)
        stop = self.emit_value(loop.stop_cid, lines, indent)
        inits = [self.emit_value(c.init_cid, lines, indent)
                 for c in loop.carries]
        arr_inits = []
        for ac in loop.array_carries:
            name = self.scope.get_sym(ac.version_init)
            if name is None:
                raise RuntimeError(f"loop-carried array {ac.version_init} "
                                   f"unbound")
            arr_inits.append(name)
        fn = f"_loop{loop.loop_id}"
        carry_names = [f"c_{_sanitize(c.name)}{loop.loop_id}"
                       for c in loop.carries]
        arr_names = [f"a_{_sanitize(ac.name)}{loop.loop_id}"
                     for ac in loop.array_carries]
        all_names = carry_names + arr_names
        ivar = f"i{loop.loop_id}"
        lines.append(f"{indent}def {fn}({ivar}, _carry):")
        inner = indent + "    "
        if all_names:
            lines.append(f"{inner}{', '.join(all_names)}"
                         f"{',' if len(all_names) == 1 else ''} = _carry")
        self.scope.push()
        self.scope.bind_forced(self.eg.find(loop.var_cid), ivar)
        for c, nm in zip(loop.carries, carry_names):
            self.scope.bind_forced(self.eg.find(c.placeholder_cid), nm)
        for ac, nm in zip(loop.array_carries, arr_names):
            self.scope.bind_sym(ac.version_body, nm)
        body_lines: List[str] = []
        self.emit_region(loop.body, path + (loop.loop_id,), body_lines, inner)
        nexts = [self.emit_value(c.next_cid, body_lines, inner)
                 for c in loop.carries]
        arr_nexts = []
        for ac in loop.array_carries:
            nm = self.scope.get_sym(ac.version_next)
            arr_nexts.append(nm if nm is not None else
                             self.scope.get_sym(ac.version_body))
        self.scope.pop()
        lines.extend(body_lines if body_lines else [f"{inner}pass"])
        rets = nexts + arr_nexts
        lines.append(f"{inner}return ({', '.join(rets)}"
                     f"{',' if len(rets) == 1 else ''})")
        init_tuple = ", ".join(inits + arr_inits)
        trailing = "," if len(inits) + len(arr_inits) == 1 else ""
        res = f"_res{loop.loop_id}"
        lines.append(f"{indent}{res} = lax.fori_loop({start}, {stop}, {fn}, "
                     f"({init_tuple}{trailing}))")
        # bind post-loop values
        for k, c in enumerate(loop.carries):
            nm = f"post_{_sanitize(c.name)}{loop.loop_id}"
            lines.append(f"{indent}{nm} = {res}[{k}]")
            self.scope.bind_forced(self.eg.find(c.post_cid), nm)
        for k, ac in enumerate(loop.array_carries):
            nm = f"post_{_sanitize(ac.name)}{loop.loop_id}"
            lines.append(f"{indent}{nm} = {res}[{len(loop.carries) + k}]")
            self.scope.bind_sym(ac.version_post, nm)

    # -- top level ------------------------------------------------------------------------
    def generate(self) -> GeneratedKernel:
        prog = self.ssa.prog
        in_arrays = [a.name for a in prog.arrays.values()]
        out_arrays = [a.name for a in prog.arrays.values()
                      if a.role in ("out", "inout")]
        scalars = list(prog.scalars)
        params = in_arrays + scalars
        lines: List[str] = []
        indent = "    "
        # bind array inputs (version @0 and @undef both map to the argument)
        for a in prog.arrays.values():
            self.scope.bind_sym(f"{a.name}@0", a.name)
            self.scope.bind_sym(f"{a.name}@undef", a.name)
        sched = self._resolve_schedule()
        if sched is None and self.bulk:
            self._collect_load_regions()
        self.emit_region(self.ssa.region, (), lines, indent)
        rets = []
        for name in out_arrays:
            ver = self.ssa.final_versions.get(name, f"{name}@0")
            nm = self.scope.get_sym(ver)
            rets.append(nm if nm is not None else name)
        body = "\n".join(lines) if lines else "    pass"
        src = (f"{_PRELUDE}\n"
               f"def {self.fn_name}({', '.join(params)}):\n"
               f"{body}\n"
               f"    return ({', '.join(rets)}{',' if len(rets) == 1 else ''})\n")
        glb: Dict[str, Any] = {"_calls": self.extra_fns}
        chaos.maybe_raise("exec_fail", self.ssa.prog.name,
                          "generated JAX source")
        exec(compile(src, f"<saturated:{self.fn_name}>", "exec"), glb)
        return GeneratedKernel(
            name=self.fn_name, source=src, fn=glb[self.fn_name],
            in_arrays=in_arrays, scalars=scalars, out_arrays=out_arrays,
            stats=self.stats, bulk=self.bulk,
            schedule_mode=self.schedule_mode, schedule=sched)


class CodeGenerator(JaxCodeGenerator):
    """Deprecated alias of :class:`JaxCodeGenerator`.

    Use ``repro.core.emit.get_emitter("jax")`` (or ``JaxCodeGenerator``
    directly) instead; this name is kept so pre-PR-8 imports keep
    working."""

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro.core.codegen.CodeGenerator is deprecated; use "
            "repro.core.emit.get_emitter('jax') or JaxCodeGenerator",
            DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)


def generate_jax(ssa: SSAResult, extraction: ExtractionResult, *,
                 bulk: bool = True, fn_name: Optional[str] = None,
                 extra_fns: Optional[Dict[str, Callable]] = None,
                 schedule: Optional[Union[str, ScheduleResult]] = None,
                 sched_cost_model=None) -> GeneratedKernel:
    return JaxCodeGenerator(ssa, extraction, bulk=bulk, fn_name=fn_name,
                            extra_fns=extra_fns, schedule=schedule,
                            sched_cost_model=sched_cost_model).generate()
