"""Rewriting rules — exactly the paper's Table I plus constant folding.

  FMA1       A + B*C      -> FMA(A, B, C)
  FMA2       A - B*C      -> FMA(A, -B, C)
  FMA3       B*C - A      -> FMA(-A, B, C)
  COMM-ADD   A + B        -> B + A
  COMM-MUL   A * B        -> B * A
  ASSOC-ADD1 A + (B + C)  -> (A + B) + C
  ASSOC-ADD2 (A + B) + C  -> A + (B + C)
  ASSOC-MUL1 A * (B * C)  -> (A * B) * C
  ASSOC-MUL2 (A * B) * C  -> A * (B * C)

Constant folding is an e-class analysis in :mod:`repro.core.egraph`.

``EXTENDED_RULES`` adds the rewrites the paper names but disables for
e-graph-size reasons (§V-A: subtraction, division, ...); they are off by
default here too and exercised in tests/ablations.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

from repro.runtime import chaos
from repro.runtime.guard import BudgetExceeded, guard_tick

from .egraph import EGraph, P, V, Pattern, PatVar


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    lhs: object  # PatTerm
    rhs: object  # PatTerm
    # True = the equality holds only when no operand/intermediate is
    # non-finite or denormal (IEEE-754 edge cases break it: reassociation
    # changes which partial sum overflows; a/b -> a*(1/b) overflows the
    # reciprocal of a denormal divisor). repro.verify.rules_check gates
    # its adversarial tier on this flag — finite-math rules report a
    # documented info note instead of an unsound-rule error.
    finite_math: bool = False


A, B, C = V("a"), V("b"), V("c")

# The paper's minimum rule set (Table I).
FMA_RULES: List[Rule] = [
    Rule("FMA1", P("add", A, P("mul", B, C)), P("fma", A, B, C)),
    Rule("FMA2", P("sub", A, P("mul", B, C)), P("fma", A, P("neg", B), C)),
    Rule("FMA3", P("sub", P("mul", B, C), A), P("fma", P("neg", A), B, C)),
]

# Reassociation is finite-math only: (1e308 + 1e308) - 1e308 overflows
# to inf in one association and stays 1e308 in the other. Commutativity
# is exact (IEEE add/mul are commutative even for NaN payload-free math).
REORDER_RULES: List[Rule] = [
    Rule("COMM-ADD", P("add", A, B), P("add", B, A)),
    Rule("COMM-MUL", P("mul", A, B), P("mul", B, A)),
    Rule("ASSOC-ADD1", P("add", A, P("add", B, C)),
         P("add", P("add", A, B), C), finite_math=True),
    Rule("ASSOC-ADD2", P("add", P("add", A, B), C),
         P("add", A, P("add", B, C)), finite_math=True),
    Rule("ASSOC-MUL1", P("mul", A, P("mul", B, C)),
         P("mul", P("mul", A, B), C), finite_math=True),
    Rule("ASSOC-MUL2", P("mul", P("mul", A, B), C),
         P("mul", A, P("mul", B, C)), finite_math=True),
]

PAPER_RULES: List[Rule] = FMA_RULES + REORDER_RULES

# Rewrites the paper mentions but restricts (§V-A last paragraph). Sound,
# used only when SaturatorConfig.extended_rules=True.
EXTENDED_RULES: List[Rule] = [
    Rule("SUB-AS-ADDNEG", P("sub", A, B), P("add", A, P("neg", B))),
    Rule("ADDNEG-AS-SUB", P("add", A, P("neg", B)), P("sub", A, B)),
    Rule("NEG-NEG", P("neg", P("neg", A)), A),
    # a/b <-> a*(1/b) is finite-math only: recip of a denormal divisor
    # (1e-310) overflows to inf, so 1e-310/1e-310 = 1 but
    # 1e-310 * recip(1e-310) = inf (likewise 0*recip(inf) = nan vs 0).
    Rule("DIV-AS-RECIP", P("div", A, B), P("mul", A, P("recip", B)),
         finite_math=True),
    Rule("RECIP-AS-DIV", P("mul", A, P("recip", B)), P("div", A, B),
         finite_math=True),
    Rule("SQUARE", P("mul", A, A), P("square", A)),
    Rule("UNSQUARE", P("square", A), P("mul", A, A)),
    Rule("FMA-UNFOLD", P("fma", A, B, C), P("add", A, P("mul", B, C))),
]

# TPU-targeted additions (beyond-paper; see DESIGN.md §2): strength
# reductions that matter on the VPU where transcendentals/divides are
# multi-pass ops. All are exact-value rewrites (no fastmath approximations).
TPU_RULES: List[Rule] = [
    Rule("RSQRT", P("recip", P("sqrt", A)), P("rsqrt", A)),
    Rule("RSQRT-DIV", P("div", A, P("sqrt", B)), P("mul", A, P("rsqrt", B))),
    Rule("DIV-CONST-NOP", P("div", A, A), P("div", A, A)),  # placeholder keeps table aligned
]


@dataclasses.dataclass
class SaturationReport:
    iterations: int = 0
    n_nodes: int = 0
    n_classes: int = 0
    n_unions: int = 0
    saturated: bool = False
    stop_reason: str = ""
    wall_s: float = 0.0
    per_rule_matches: dict = dataclasses.field(default_factory=dict)


def run_rules(eg: EGraph, rules: List[Rule], *,
              iter_limit: int = 10,
              node_limit: int = 10_000,
              time_limit_s: float = 10.0) -> SaturationReport:
    """egg-style batched saturation under the paper's §VII limits."""
    rep = SaturationReport()
    t0 = time.perf_counter()
    for it in range(iter_limit):
        rep.iterations = it + 1
        # guard hook: one tick per saturation iteration, carrying the
        # graph size so the node/class ceilings (safety nets above the
        # paper's node_limit) are enforced even if a rule loops
        guard_tick("saturation", nodes=eg.num_nodes(),
                   classes=eg.num_classes())
        chaos.maybe_raise("rule_raise", detail="rule application")
        if chaos.chaos_point("egraph_budget"):
            raise BudgetExceeded("egraph_budget",
                                 "injected e-graph exhaustion")
        matches: List[Tuple[Rule, int, dict]] = []
        for rule in rules:
            found = eg.ematch(rule.lhs)
            if found:
                rep.per_rule_matches[rule.name] = (
                    rep.per_rule_matches.get(rule.name, 0) + len(found))
            for cid, sub in found:
                matches.append((rule, cid, sub))
            if time.perf_counter() - t0 > time_limit_s:
                rep.stop_reason = "time_limit"
                break
        if rep.stop_reason:
            break
        before_unions = eg.n_unions
        before_nodes = eg.num_nodes()
        for rule, cid, sub in matches:
            new_id = eg.instantiate(rule.rhs, sub)
            eg.union(cid, new_id)
            if eg.num_nodes() > node_limit:
                rep.stop_reason = "node_limit"
                break
        eg.rebuild()
        if rep.stop_reason:
            break
        if eg.n_unions == before_unions and eg.num_nodes() == before_nodes:
            rep.saturated = True
            rep.stop_reason = "saturated"
            break
        if time.perf_counter() - t0 > time_limit_s:
            rep.stop_reason = "time_limit"
            break
    else:
        rep.stop_reason = rep.stop_reason or "iter_limit"
    rep.n_nodes = eg.num_nodes()
    rep.n_classes = eg.num_classes()
    rep.n_unions = eg.n_unions
    rep.wall_s = time.perf_counter() - t0
    return rep
