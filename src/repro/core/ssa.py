"""SSA + φ construction from the kernel DSL into an e-graph (paper §IV-A).

Phases, mirroring the paper:
  1. conditional φ nodes represent ``if`` (value-merge / predication) and
     ``for`` (loop-carried φ with an abstract condition);
  2. every variable/array assignment gets an ID (an e-class);
  3. every load refers to the latest ID along its data flow
     (store→load forwarding when the index e-classes match exactly —
     sound even under aliasing, conservative otherwise via array
     versioning);
  4. assignments/φ and their expressions share an e-class.

The result keeps the *structure* (store order, loop nests) out of the
e-graph — exactly how the paper preserves directives and loop structure —
while the pure expressions become fully rewritable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple, Union

from repro.analysis.opstats import ArrayInfo

from .dsl import ArrayRef, Assign, For, If, KernelProgram
from .egraph import EGraph
from .ir import ENode


@dataclasses.dataclass
class StoreEffect:
    array: str
    version_in: str            # array-version symbol read-modified
    version_out: str           # version defined by this store
    index_cids: Tuple[int, ...]  # () = whole tile
    value_cid: int
    order: int
    pred_cid: Optional[int] = None  # predication condition (store under if)


@dataclasses.dataclass
class Carry:
    name: str
    placeholder_cid: int  # value at top of each iteration
    init_cid: int
    next_cid: int = -1
    post_cid: int = -1    # value after the loop (phi_loop node)


@dataclasses.dataclass
class ArrayCarry:
    name: str
    version_init: str     # version entering the loop
    version_body: str     # symbolic version at top of each iteration
    version_next: str = ""  # version at end of body
    version_post: str = ""  # version after the loop


@dataclasses.dataclass
class LoopRegion:
    loop_id: int
    var: str
    var_cid: int
    start_cid: int
    stop_cid: int
    carries: List[Carry]
    array_carries: List[ArrayCarry]
    body: "Region"
    order: int


@dataclasses.dataclass
class Region:
    items: List[Union[StoreEffect, LoopRegion]] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class SSAResult:
    prog: KernelProgram
    egraph: EGraph
    region: Region
    # final array version symbol per array (what the kernel outputs)
    final_versions: Dict[str, str]
    # array version symbol -> how codegen binds it
    #   ('input', name) | ('store', StoreEffect) | ('loop', loop_id, name)
    version_origin: Dict[str, tuple]
    n_loads: int = 0
    n_stores: int = 0
    # e-classes the programmer named with `let` — the 'original code'
    # temporaries (baseline codegen reuses exactly these, §VIII)
    let_cids: Set[int] = dataclasses.field(default_factory=set)
    # SSA array table: array name -> declared (shape, dtype). Mirrors
    # egraph.array_info; the analysis layer prices loads/stores with it.
    array_info: Dict[str, ArrayInfo] = dataclasses.field(default_factory=dict)

    def store_infos(self) -> List[Optional[ArrayInfo]]:
        """Per-store operand info (array info after indexing), in program
        order — what each root store actually writes to HBM. Indexing
        semantics mirror loads: uniform (constant) indices slice the
        operand, varying indices keep a full per-lane tile."""
        out: List[Optional[ArrayInfo]] = []

        def walk(region: Region):
            for item in region.items:
                if isinstance(item, StoreEffect):
                    info = self.array_info.get(item.array)
                    out.append(self.egraph.operand_info(info,
                                                        item.index_cids))
                else:
                    walk(item.body)
        walk(self.region)
        return out

    def roots(self) -> List[int]:
        """Every e-class the codegen will need (extraction roots)."""
        out: List[int] = []

        def walk(region: Region):
            for item in region.items:
                if isinstance(item, StoreEffect):
                    out.append(item.value_cid)
                    out.extend(item.index_cids)
                    if item.pred_cid is not None:
                        out.append(item.pred_cid)
                else:
                    out.extend([item.start_cid, item.stop_cid])
                    for cparr in item.carries:
                        out.extend([cparr.init_cid, cparr.next_cid])
                    walk(item.body)
        walk(self.region)
        return out


class _ScopeError(ValueError):
    pass


class SSABuilder:
    def __init__(self, prog: KernelProgram, egraph: Optional[EGraph] = None):
        self.prog = prog
        self.eg = egraph or EGraph()
        self.env: Dict[str, int] = {}
        # array name -> current version symbol
        self.versions: Dict[str, str] = {}
        self.version_origin: Dict[str, tuple] = {}
        # array name -> (index_cids_key, value_cid): store->load forwarding
        self.forward: Dict[str, Tuple[Tuple[int, ...], int]] = {}
        self._ver_counter: Dict[str, int] = {}
        self._loop_counter = 0
        self._order = 0
        self.n_loads = 0
        self.n_stores = 0
        self.let_cids: Set[int] = set()

    # -- helpers ------------------------------------------------------------
    def _new_version(self, array: str, tag: str = "") -> str:
        k = self._ver_counter.get(array, 0) + 1
        self._ver_counter[array] = k
        return f"{array}@{tag or k}"

    def _array_sym(self, version: str) -> int:
        return self.eg.add(ENode("array", (), version))

    def build(self) -> SSAResult:
        array_info: Dict[str, ArrayInfo] = {}
        for name, spec in self.prog.arrays.items():
            # record the declared (shape, dtype) in the array table and
            # register it with the e-graph's operand analysis up front,
            # before any load/store of the array is added
            info = ArrayInfo(shape=getattr(spec, "shape", None),
                             dtype=getattr(spec, "dtype", "f32"))
            array_info[name] = info
            self.eg.set_array_info(name, info)
            if spec.role in ("in", "inout"):
                ver = f"{name}@0"
                self.versions[name] = ver
                self.version_origin[ver] = ("input", name)
        for s in self.prog.scalars:
            self.env[s] = self.eg.add(ENode("var", (), s))
        region = Region()
        self._eval_block(self.prog.body, region, pred=None)
        return SSAResult(
            prog=self.prog, egraph=self.eg, region=region,
            final_versions=dict(self.versions),
            version_origin=dict(self.version_origin),
            n_loads=self.n_loads, n_stores=self.n_stores,
            let_cids=set(self.let_cids), array_info=array_info)

    # -- expression -> e-class ------------------------------------------------
    def eval_expr(self, t: tuple) -> int:
        op = t[0]
        if op == "const":
            return self.eg.add(ENode("const", (), t[1]))
        if op == "var":
            cid = self.env.get(t[1])
            if cid is None:
                raise _ScopeError(f"undefined variable {t[1]!r}")
            return cid
        if op == "aload":
            name = t[1]
            idx = tuple(self.eval_expr(i) for i in t[2:])
            return self._load(name, idx)
        if op == "call":
            fn = t[1]
            kids = tuple(self.eval_expr(a) for a in t[2:])
            return self.eg.add(ENode("call", kids, fn))
        kids = tuple(self.eval_expr(a) for a in t[1:])
        return self.eg.add(ENode(op, kids, None))

    def _load(self, name: str, idx: Tuple[int, ...]) -> int:
        if name not in self.versions:
            if name in self.prog.arrays:  # 'out' array read before write
                raise _ScopeError(f"array {name!r} read before any store")
            raise _ScopeError(f"unknown array {name!r}")
        fwd = self.forward.get(name)
        idx = tuple(self.eg.find(i) for i in idx)
        if fwd is not None and tuple(self.eg.find(i) for i in fwd[0]) == idx:
            return fwd[1]  # store->load forwarding (latest ID, §IV-A)
        self.n_loads += 1
        arr = self._array_sym(self.versions[name])
        return self.eg.add(ENode("load", (arr,) + idx, None))

    # -- statements --------------------------------------------------------------
    def _eval_block(self, stmts: List[Any], region: Region,
                    pred: Optional[int]) -> None:
        for st in stmts:
            self._order += 1
            if isinstance(st, Assign):
                self._eval_assign(st, region, pred)
            elif isinstance(st, If):
                self._eval_if(st, region, pred)
            elif isinstance(st, For):
                if pred is not None:
                    raise _ScopeError("for-loop under if is not supported; "
                                      "hoist the loop or predicate its body")
                self._eval_for(st, region)
            else:
                raise TypeError(f"unknown statement {st!r}")

    def _eval_assign(self, st: Assign, region: Region,
                     pred: Optional[int]) -> None:
        val = self.eval_expr(st.expr)
        if isinstance(st.target, str):
            if pred is not None and st.target in self.env:
                val = self.eg.add(ENode("phi",
                                        (pred, val, self.env[st.target])))
            self.env[st.target] = val
            self.let_cids.add(val)
            return
        # array store
        ref = st.target
        idx = tuple(self.eval_expr(i) for i in ref.indices)
        ver_in = self.versions.get(ref.name)
        if ver_in is None:  # first write to an 'out' array
            ver_in = f"{ref.name}@undef"
            self.version_origin[ver_in] = ("undef", ref.name)
        ver_out = self._new_version(ref.name)
        eff = StoreEffect(array=ref.name, version_in=ver_in,
                          version_out=ver_out, index_cids=idx,
                          value_cid=val, order=self._order, pred_cid=pred)
        self.versions[ref.name] = ver_out
        self.version_origin[ver_out] = ("store", eff)
        self.forward[ref.name] = (idx, val) if pred is None else None
        if self.forward[ref.name] is None:
            del self.forward[ref.name]
        region.items.append(eff)
        self.n_stores += 1

    def _eval_if(self, st: If, region: Region, pred: Optional[int]) -> None:
        cond = self.eval_expr(st.cond)
        if pred is not None:
            cond = self.eg.add(ENode("mul", (pred, cond)))  # logical and
        saved_env = dict(self.env)
        self._eval_block(st.then, region, pred=cond)
        then_env = self.env
        if st.orelse:
            self.env = dict(saved_env)
            notc = self.eg.add(ENode("sub", (
                self.eg.add(ENode("const", (), 1)), cond)))
            self._eval_block(st.orelse, region, pred=notc)
            # merge: names changed in either branch get phi(cond, then, else)
            merged = dict(saved_env)
            for name in set(then_env) | set(self.env):
                tval = then_env.get(name, saved_env.get(name))
                eval_ = self.env.get(name, saved_env.get(name))
                if tval is None or eval_ is None:
                    continue  # defined in only one branch and not before
                if tval == eval_:
                    merged[name] = tval
                else:
                    merged[name] = self.eg.add(ENode("phi", (cond, tval, eval_)))
            self.env = merged
        # (no else): _eval_assign already φ-merged against prior values

    def _collect_writes(self, stmts: List[Any],
                        scalars: Set[str], arrays: Set[str]) -> None:
        for st in stmts:
            if isinstance(st, Assign):
                if isinstance(st.target, str):
                    scalars.add(st.target)
                else:
                    arrays.add(st.target.name)
            elif isinstance(st, If):
                self._collect_writes(st.then, scalars, arrays)
                self._collect_writes(st.orelse, scalars, arrays)
            elif isinstance(st, For):
                scalars.add(st.var)
                self._collect_writes(st.body, scalars, arrays)

    def _eval_for(self, st: For, region: Region) -> None:
        loop_id = self._loop_counter
        self._loop_counter += 1
        start = self.eval_expr(st.start)
        stop = self.eval_expr(st.stop)
        wr_scalars: Set[str] = set()
        wr_arrays: Set[str] = set()
        self._collect_writes(st.body, wr_scalars, wr_arrays)
        wr_scalars.discard(st.var)

        # loop variable placeholder
        var_cid = self.eg.add(ENode("var", (), f"%L{loop_id}:{st.var}"))
        saved_env = dict(self.env)
        self.env[st.var] = var_cid

        # scalar carries: only names live before the loop are carried out
        carries: List[Carry] = []
        for name in sorted(wr_scalars):
            if name in saved_env:
                ph = self.eg.add(ENode("var", (), f"%L{loop_id}:{name}"))
                carries.append(Carry(name=name, placeholder_cid=ph,
                                     init_cid=saved_env[name]))
                self.env[name] = ph

        # array carries: any array stored inside the loop
        arr_carries: List[ArrayCarry] = []
        saved_versions = dict(self.versions)
        for name in sorted(wr_arrays):
            ver_init = self.versions.get(name, f"{name}@undef")
            if ver_init.endswith("@undef"):
                self.version_origin[ver_init] = ("undef", name)
            ver_body = f"{name}@L{loop_id}"
            arr_carries.append(ArrayCarry(name=name, version_init=ver_init,
                                          version_body=ver_body))
            self.versions[name] = ver_body
            self.version_origin[ver_body] = ("loop", loop_id, name)
            self.forward.pop(name, None)  # no forwarding across iterations

        body_region = Region()
        self._eval_block(st.body, body_region, pred=None)

        for carry in carries:
            carry.next_cid = self.env[carry.name]
            post = self.eg.add(ENode("phi_loop",
                                     (carry.init_cid, carry.next_cid),
                                     (loop_id, carry.name)))
            carry.post_cid = post
        for ac in arr_carries:
            ac.version_next = self.versions[ac.name]
            ac.version_post = f"{ac.name}@postL{loop_id}"
            self.version_origin[ac.version_post] = ("loop_post", loop_id,
                                                    ac.name)

        # restore env: loop var and body-locals go out of scope;
        # carried names bind to their phi_loop value
        self.env = saved_env
        for carry in carries:
            self.env[carry.name] = carry.post_cid
        for name in wr_arrays:
            self.versions[name] = next(a.version_post for a in arr_carries
                                       if a.name == name)
            self.forward.pop(name, None)

        region.items.append(LoopRegion(
            loop_id=loop_id, var=st.var, var_cid=var_cid,
            start_cid=start, stop_cid=stop, carries=carries,
            array_carries=arr_carries, body=body_region, order=self._order))


def build_ssa(prog: KernelProgram, egraph: Optional[EGraph] = None) -> SSAResult:
    return SSABuilder(prog, egraph).build()
