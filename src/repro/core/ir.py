"""Term IR for the saturator (paper §IV).

An :class:`ENode` is one operator application whose children are *e-class
ids* (ints). Leaf nodes carry a payload instead of children:

  op='const'  payload=float/int/bool  — literal (cost 0, paper §V-B)
  op='var'    payload=str             — SSA input variable (cost 1)
  op='load'   children=(array_class, *index_classes)  — memory read (cost 100)
  op='phi'    children=(cond, then, else)             — conditional phi (§IV-A)
  op='phi_loop' payload=loop_id children=(init, next) — loop-carried phi
  op='call'   payload=fn_name children=args           — function call (cost 100)
  op='array'  payload=str             — array symbol (for load/store roots)

Interior arithmetic ops use the canonical names below.  ``fma(a, b, c)``
denotes ``a + b * c`` exactly as the paper's FMA1 rule (Table I).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

# Canonical operator vocabulary ------------------------------------------------
# Binary arithmetic
BINOPS = ("add", "sub", "mul", "div", "mod", "min", "max", "pow")
# Unary
UNOPS = ("neg", "exp", "log", "sqrt", "rsqrt", "tanh", "abs", "sigmoid",
         "recip", "floor", "square", "toint")
# Tile reductions (last axis, keepdims=True) + structural tile ops.
# Scalars are fixed points of the reductions, so constant folding is sound.
REDOPS = ("rsum", "rmean", "rmax")
STRUCTOPS = ("rothalf",)
# Ternary
TERNOPS = ("fma", "select")
# Comparisons (produce booleans consumed by select/phi)
CMPOPS = ("lt", "le", "gt", "ge", "eq", "ne")
# Structural
LEAF_OPS = ("const", "var", "array")
MEM_OPS = ("load",)
CTRL_OPS = ("phi", "phi_loop", "call", "tuple")

ALL_OPS = (BINOPS + UNOPS + TERNOPS + CMPOPS + LEAF_OPS + MEM_OPS
           + CTRL_OPS + REDOPS + STRUCTOPS)

COMMUTATIVE = frozenset({"add", "mul", "min", "max", "eq", "ne"})


@dataclasses.dataclass(frozen=True, eq=False)
class ENode:
    """Immutable, hash-consable operator application.

    Equality/hash are *type-aware* on the payload: ``0``, ``0.0`` and
    ``False`` compare equal in Python but are distinct constants (an int
    loop bound must not alias a float accumulator init), so the payload
    type participates in the hash-cons key.
    """
    op: str
    children: Tuple[int, ...] = ()
    payload: Any = None

    def _key(self):
        return (self.op, self.children, type(self.payload).__name__,
                self.payload)

    def __eq__(self, other):
        if not isinstance(other, ENode):
            return NotImplemented
        return self._key() == other._key()

    def __hash__(self):
        # hash(None) is id-based in CPython <= 3.11, i.e. different per
        # process under ASLR — which would reorder e-node sets (and with
        # them rule-match/union order, e-class numbering, and extraction
        # tie-breaks) from run to run even under a fixed PYTHONHASHSEED.
        # Substitute a stable sentinel so e-graph construction is
        # reproducible; equality semantics are unchanged.
        payload = self.payload
        if payload is None:
            payload = "\0none"
        return hash((self.op, self.children,
                     type(self.payload).__name__, payload))

    def map_children(self, f: Callable[[int], int]) -> "ENode":
        if not self.children:
            return self
        return ENode(self.op, tuple(f(c) for c in self.children), self.payload)

    def is_leaf(self) -> bool:
        return not self.children

    def __repr__(self) -> str:  # compact, used in debug dumps
        if self.op == "const":
            return f"#{self.payload}"
        if self.op in ("var", "array"):
            return f"{self.payload}"
        inner = ",".join(map(str, self.children))
        tag = f"[{self.payload}]" if self.payload is not None else ""
        return f"{self.op}{tag}({inner})"


def const(v) -> ENode:
    return ENode("const", (), v)


def var(name: str) -> ENode:
    return ENode("var", (), name)


# Numeric evaluation of operators (used by constant folding and by the
# reference interpreter in tests). Works on python scalars and numpy/jnp
# arrays alike.
def _sigmoid(x):
    import numpy as np
    return 1.0 / (1.0 + np.exp(-x))


EVAL_FNS: Dict[str, Callable] = {}


def _register_eval():
    import numpy as np
    EVAL_FNS.update({
        "add": lambda a, b: a + b,
        "sub": lambda a, b: a - b,
        "mul": lambda a, b: a * b,
        "div": lambda a, b: a / b,
        "mod": lambda a, b: a % b,
        "min": lambda a, b: np.minimum(a, b),
        "max": lambda a, b: np.maximum(a, b),
        "pow": lambda a, b: a ** b,
        "neg": lambda a: -a,
        "exp": np.exp,
        "log": np.log,
        "sqrt": np.sqrt,
        "rsqrt": lambda a: 1.0 / np.sqrt(a),
        "tanh": np.tanh,
        "abs": np.abs,
        "sigmoid": _sigmoid,
        "recip": lambda a: 1.0 / a,
        "floor": np.floor,
        "square": lambda a: a * a,
        "fma": lambda a, b, c: a + b * c,
        "select": lambda c, t, f: np.where(c, t, f),
        "lt": lambda a, b: a < b,
        "le": lambda a, b: a <= b,
        "gt": lambda a, b: a > b,
        "ge": lambda a, b: a >= b,
        "eq": lambda a, b: a == b,
        "ne": lambda a, b: a != b,
        # reductions: identity on scalars, last-axis keepdims on arrays
        "rsum": lambda a: (np.sum(a, axis=-1, keepdims=True)
                           if getattr(a, "ndim", 0) else a),
        "rmean": lambda a: (np.mean(a, axis=-1, keepdims=True)
                            if getattr(a, "ndim", 0) else a),
        "rmax": lambda a: (np.max(a, axis=-1, keepdims=True)
                           if getattr(a, "ndim", 0) else a),
        "toint": lambda a: (a.astype(np.int64) if getattr(a, "ndim", 0)
                            else int(a)),
        "rothalf": lambda a: (np.concatenate(
            [-a[..., a.shape[-1] // 2:], a[..., :a.shape[-1] // 2]], axis=-1)
            if getattr(a, "ndim", 0) else a),
    })


_register_eval()


def try_const_eval(op: str, child_values: Tuple[Optional[Any], ...],
                   payload: Any = None) -> Optional[Any]:
    """Fold ``op`` over known-constant children; None if not foldable.

    Mirrors the paper's 'constant folding of arithmetic operations with
    integer and floating-point numbers' (§V-A).
    """
    if op == "const":
        return payload
    # rsum / rothalf of a constant-filled tile depend on the tile width, so
    # folding them to the scalar would be unsound under tile semantics.
    if op in ("rsum", "rothalf"):
        return None
    if any(v is None for v in child_values):
        return None
    fn = EVAL_FNS.get(op)
    if fn is None:
        return None
    try:
        import numpy as np
        with np.errstate(all="ignore"):
            out = fn(*child_values)
        # Only fold clean finite scalars — keep e-graph payloads hashable.
        if isinstance(out, (bool,)):
            return out
        out_f = float(out)
        if out_f != out_f or out_f in (float("inf"), float("-inf")):
            return None
        # preserve int-ness when exact
        if (isinstance(out, (int,)) or
                (out_f.is_integer() and all(isinstance(v, (int, bool))
                                            for v in child_values)
                 and op not in ("div", "rsqrt", "recip", "exp", "log",
                                "sqrt", "tanh", "sigmoid"))):
            return int(out_f)
        return out_f
    except Exception:
        return None
