"""PR-8 emitter registry: one front door for every code generator.

Before this module the two emission paths were a subclass fork
(``CodeGenerator`` for JAX source, ``PallasGenerator`` for Pallas kernel
bodies) that callers imported directly; adding the pipelined Pallas
backend would have meant a third ad-hoc class name in every call site.
Instead, emitters are now named:

====================  =============================  ==================
name                  generator                      produces
====================  =============================  ==================
``jax``               :class:`JaxCodeGenerator`      ``GeneratedKernel``
``pallas``            :class:`SyncPallasGenerator`   ``PallasKernel``
``pallas_pipelined``  :class:`PipelinedPallasGenerator`  ``PallasKernel``
====================  =============================  ==================

``get_emitter(name)`` returns a small :class:`Emitter` facade; its
``emit(ssa, extraction, **options)`` classmethod builds the generator
and runs it, and ``info`` carries the registry metadata — including the
``version`` that enters the cache key for non-default emitters (see
:func:`emitter_cache_id` and ``repro.cache.keys.config_fingerprint``).

The pre-registry class names (``CodeGenerator``, ``PallasGenerator``)
remain importable as deprecated aliases; the CI deprecation lint keeps
the repo's own code off them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

EMITTER_NAMES: Tuple[str, ...] = ("jax", "pallas", "pallas_pipelined")

# Bump an emitter's version whenever its emitted source for a fixed
# (choice, schedule) changes: non-default emitters carry name@version in
# the cache config fingerprint, so cached replays never mix emitters.
_VERSIONS: Dict[str, int] = {"jax": 1, "pallas": 1, "pallas_pipelined": 1}

# Emitters whose cache entries predate the registry: their fingerprints
# must stay byte-identical, so they contribute *no* emitter key (None).
_DEFAULT_EMITTERS = (None, "jax", "pallas")


@dataclasses.dataclass(frozen=True)
class EmitterInfo:
    name: str      # registry name
    version: int   # cache-key version (see _VERSIONS)
    target: str    # "jax" (GeneratedKernel) or "pallas" (PallasKernel)


class Emitter:
    """Facade over one generator class.

    ``emit`` accepts the common generator options (``bulk``,
    ``fn_name``, ``reuse_temps``, ``schedule``, ``sched_cost_model`` and,
    for the jax target, ``extra_fns``) and returns the generator's
    product — a ``GeneratedKernel`` or ``PallasKernel``.
    """

    info: EmitterInfo

    # resolved lazily: the generator modules import this one's clients
    @property
    def generator_cls(self):
        raise NotImplementedError

    def emit(self, ssa, extraction, **options):
        gen = self.generator_cls(ssa, extraction, **options)
        if self.info.target == "pallas":
            return gen.generate_pallas()
        return gen.generate()


class _JaxEmitter(Emitter):
    info = EmitterInfo("jax", _VERSIONS["jax"], "jax")

    @property
    def generator_cls(self):
        from .codegen import JaxCodeGenerator
        return JaxCodeGenerator


class _PallasEmitter(Emitter):
    info = EmitterInfo("pallas", _VERSIONS["pallas"], "pallas")

    @property
    def generator_cls(self):
        from .pallasgen import SyncPallasGenerator
        return SyncPallasGenerator


class _PipelinedPallasEmitter(Emitter):
    info = EmitterInfo("pallas_pipelined", _VERSIONS["pallas_pipelined"],
                       "pallas")

    @property
    def generator_cls(self):
        from .pallasgen import PipelinedPallasGenerator
        return PipelinedPallasGenerator


_REGISTRY: Dict[str, Emitter] = {
    "jax": _JaxEmitter(),
    "pallas": _PallasEmitter(),
    "pallas_pipelined": _PipelinedPallasEmitter(),
}


def get_emitter(name: str) -> Emitter:
    """The registered emitter, by name (``EMITTER_NAMES``)."""
    em = _REGISTRY.get(name)
    if em is None:
        raise ValueError(f"unknown emitter {name!r}; "
                         f"expected one of {EMITTER_NAMES}")
    return em


def emitter_cache_id(name: Optional[str]) -> Optional[str]:
    """The ``name@v{version}`` token a config fingerprint carries for a
    non-default emitter, or None for the pre-registry defaults (whose
    cached entries must keep their byte-identical keys)."""
    if name in _DEFAULT_EMITTERS:
        return None
    if name not in _VERSIONS:
        raise ValueError(f"unknown emitter {name!r}; "
                         f"expected one of {EMITTER_NAMES}")
    return f"{name}@v{_VERSIONS[name]}"
