"""CSE-aware extraction of optimal terms from an e-graph (paper §IV-B).

The paper extracts the minimum total cost selection where common e-classes
are counted ONCE (CSE folded into extraction) using an ILP solver (CBC).
No ILP solver ships in this environment, so we reproduce the objective
with:

  1. a bottom-up fixed point over *tree* cost (classic egg extractor) —
     gives a valid acyclic selection fast;
  2. true *DAG* cost evaluation (shared classes counted once);
  3. hill-climbing local search over per-class node choices against the
     true DAG objective, with acyclicity checking — our ILP stand-in.

The default objective is *roofline-predicted latency*
(:class:`repro.analysis.RooflineCostModel`): a cost model may expose
``aggregate_cost(nodes)`` and the DAG evaluator then scores a selection
by that non-additive objective (here ``max(compute, memory)`` over the
summed statistics of the chosen nodes) instead of a per-node weight sum —
extraction picks terms that realize less computation AND less memory
traffic simultaneously, not just fewer abstract ops. Flat-weight models
(:class:`repro.core.cost.CostModel`) still work unchanged.

`extract_exact` brute-forces tiny graphs and is used by tests to verify
the local search reaches the optimum where enumeration is feasible.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import (Any, Dict, Iterable, List, Optional, Sequence, Set,
                    Tuple)

from repro.analysis import RooflineCostModel

from .cost import CostModel
from .egraph import EGraph
from .ir import ENode

INF = float("inf")


@dataclasses.dataclass
class ExtractionResult:
    choice: Dict[int, ENode]           # canonical cid -> chosen e-node
    roots: Tuple[int, ...]             # canonical root cids
    dag_cost: float
    tree_cost: float
    wall_s: float = 0.0
    improved_by_search: float = 0.0    # dag-cost reduction from local search
    predicted: Optional[Dict[str, Any]] = None  # roofline stats of choice

    def term(self, eg: EGraph, root: Optional[int] = None):
        from .egraph import extract_to_term
        root = self.roots[0] if root is None else eg.find(root)
        return extract_to_term(self.choice, eg, root)


# -- step 1: bottom-up tree-cost fixed point ------------------------------------
def _tree_costs(eg: EGraph, cm: CostModel):
    best_cost: Dict[int, float] = {}
    best_node: Dict[int, ENode] = {}
    classes = eg.eclasses()
    changed = True
    while changed:
        changed = False
        for cid, ec in classes.items():
            for node in ec.nodes:
                node = eg.canonicalize(node)
                cost = cm.node_cost(node)
                ok = True
                for ch in node.children:
                    ch_cost = best_cost.get(eg.find(ch))
                    if ch_cost is None:
                        ok = False
                        break
                    cost += ch_cost
                if ok and cost < best_cost.get(cid, INF):
                    best_cost[cid] = cost
                    best_node[cid] = node
                    changed = True
    return best_cost, best_node


# -- DAG cost of a choice map ------------------------------------------------------
def choice_nodes(eg: EGraph, choice: Dict[int, ENode],
                 roots: Sequence[int]) -> Optional[List[ENode]]:
    """Chosen nodes over classes reachable from roots, each class once.

    Returns None on a cyclic or incomplete selection.
    """
    nodes: List[ENode] = []
    state: Dict[int, int] = {}  # 0=on stack, 1=done
    stack: List[Tuple[int, bool]] = [(eg.find(r), False) for r in roots]
    while stack:
        cid, processed = stack.pop()
        cid = eg.find(cid)
        if processed:
            state[cid] = 1
            continue
        st = state.get(cid)
        if st == 1:
            continue
        if st == 0:
            return None  # cycle
        node = choice.get(cid)
        if node is None:
            return None
        state[cid] = 0
        stack.append((cid, True))
        nodes.append(node)
        for ch in node.children:
            ch = eg.find(ch)
            if state.get(ch) is None:
                stack.append((ch, False))
            elif state.get(ch) == 0:
                return None
    return nodes


def dag_cost_of(eg: EGraph, cm: CostModel, choice: Dict[int, ENode],
                roots: Sequence[int]) -> float:
    """Cost of a selection with shared classes counted once.

    Models exposing ``aggregate_cost`` (the roofline objective) score the
    whole node multiset at once; flat models sum per-node weights.
    Returns inf on a cyclic selection.
    """
    nodes = choice_nodes(eg, choice, roots)
    if nodes is None:
        return INF
    aggregate = getattr(cm, "aggregate_cost", None)
    if aggregate is not None:
        return aggregate(nodes)
    return sum(cm.node_cost(n) for n in nodes)


def reachable(eg: EGraph, choice: Dict[int, ENode],
              roots: Sequence[int]) -> Set[int]:
    seen: Set[int] = set()
    stack = [eg.find(r) for r in roots]
    while stack:
        cid = stack.pop()
        if cid in seen:
            continue
        seen.add(cid)
        node = choice.get(cid)
        if node is None:
            continue
        for ch in node.children:
            ch = eg.find(ch)
            if ch not in seen:
                stack.append(ch)
    return seen


# -- step 3: local search on the DAG objective -------------------------------------
def _local_search(eg: EGraph, cm: CostModel, choice: Dict[int, ENode],
                  roots: Sequence[int], deadline: float) -> Tuple[Dict[int, ENode], float]:
    best = dict(choice)
    best_cost = dag_cost_of(eg, cm, best, roots)
    improved = True
    while improved and time.perf_counter() < deadline:
        improved = False
        for cid in list(reachable(eg, best, roots)):
            ec = eg.classes.get(eg.find(cid))
            if ec is None:
                continue
            nodes = [eg.canonicalize(n) for n in ec.nodes]
            if len(nodes) <= 1:
                continue
            current = best[eg.find(cid)]
            for cand in nodes:
                if cand == current:
                    continue
                trial = dict(best)
                trial[eg.find(cid)] = cand
                c = dag_cost_of(eg, cm, trial, roots)
                if c < best_cost - 1e-9:
                    best, best_cost = trial, c
                    improved = True
                    break
            if time.perf_counter() > deadline:
                break
    return best, best_cost


def extract_dag(eg: EGraph, roots, cost_model: Optional[CostModel] = None,
                *, time_limit_s: float = 5.0,
                local_search: bool = True) -> ExtractionResult:
    """Extract a minimum-DAG-cost selection covering ``roots``.

    Defaults to the roofline-calibrated cost model: the objective is the
    predicted latency of the extracted term against the chip's compute
    and memory roofs, not a flat op-weight sum.
    """
    t0 = time.perf_counter()
    cm = cost_model if cost_model is not None else RooflineCostModel()
    if isinstance(roots, int):
        roots = (roots,)
    roots = tuple(eg.find(r) for r in roots)
    tree_cost, tree_choice = _tree_costs(eg, cm)
    for r in roots:
        if r not in tree_choice:
            raise ValueError(f"no extractable term for e-class {r}")
    base_cost = dag_cost_of(eg, cm, tree_choice, roots)
    choice, cost = tree_choice, base_cost
    if local_search:
        deadline = t0 + time_limit_s
        seeds = [tree_choice]
        if getattr(cm, "aggregate_cost", None) is not None \
                and not isinstance(cm, CostModel):
            # Multi-start for the non-additive roofline objective: the
            # flat-weight extractor's refined solution is an independent
            # restart, so the roofline pick can never be worse than what
            # the paper model would have chosen (hill climbing from a
            # seed only improves the aggregate objective).
            flat_cm = CostModel()
            _, flat_choice = _tree_costs(eg, flat_cm)
            if all(r in flat_choice for r in roots):
                # cap seed refinement at a third of the remaining budget —
                # the flat objective is only a restart heuristic; most of
                # the deadline belongs to the true (roofline) objective
                now = time.perf_counter()
                refine_deadline = now + max(deadline - now, 0.0) / 3.0
                refined, _ = _local_search(eg, flat_cm, flat_choice,
                                           roots, refine_deadline)
                seeds.append(refined)
        for seed in seeds:
            ch, c = _local_search(eg, cm, seed, roots, deadline)
            if c < cost:
                choice, cost = ch, c
    live = reachable(eg, choice, roots)
    choice = {cid: n for cid, n in choice.items() if cid in live}
    predicted = None
    reporter = getattr(cm, "report", None)
    if reporter is not None:
        nodes = choice_nodes(eg, choice, roots)
        if nodes is not None:
            predicted = reporter(nodes)
    return ExtractionResult(
        choice=choice, roots=roots, dag_cost=cost,
        tree_cost=sum(tree_cost[r] for r in roots),
        wall_s=time.perf_counter() - t0,
        improved_by_search=base_cost - cost,
        predicted=predicted)


# -- brute force for tests -----------------------------------------------------------
def extract_exact(eg: EGraph, roots, cost_model: Optional[CostModel] = None,
                  max_combos: int = 200_000) -> ExtractionResult:
    """Enumerate all acyclic selections (tiny graphs only)."""
    cm = cost_model if cost_model is not None else RooflineCostModel()
    if isinstance(roots, int):
        roots = (roots,)
    roots = tuple(eg.find(r) for r in roots)
    classes = eg.eclasses()
    cids = sorted(classes.keys())
    node_lists = [[eg.canonicalize(n) for n in classes[c].nodes] for c in cids]
    n_combos = 1
    for nl in node_lists:
        n_combos *= len(nl)
        if n_combos > max_combos:
            raise ValueError(f"too many combos (> {max_combos})")
    best_choice, best_cost = None, INF
    for combo in itertools.product(*node_lists):
        choice = dict(zip(cids, combo))
        c = dag_cost_of(eg, cm, choice, roots)
        if c < best_cost:
            best_choice, best_cost = choice, c
    assert best_choice is not None
    live = reachable(eg, best_choice, roots)
    best_choice = {c: n for c, n in best_choice.items() if c in live}
    return ExtractionResult(choice=best_choice, roots=roots,
                            dag_cost=best_cost, tree_cost=best_cost)
