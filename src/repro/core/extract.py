"""CSE-aware extraction of optimal terms from an e-graph (paper §IV-B).

The paper extracts the minimum total cost selection where common e-classes
are counted ONCE (CSE folded into extraction) using an ILP solver (CBC).
No ILP solver ships in this environment, so we reproduce the objective
with a staged global search:

  1. a bottom-up fixed point over *tree* cost (classic egg extractor) —
     gives a valid acyclic selection fast;
  2. true *DAG* cost evaluation (shared classes counted once);
  3. width-configurable **beam search** over per-class node choices
     against the true DAG objective (:mod:`repro.core.beam`) — the main
     ILP stand-in; the beam retains equal-cost siblings, so it crosses
     objective plateaus that first-improvement hill climbing cannot;
  4. the PR-2 hill climb, demoted to a **polish pass** over the beam's
     winner and the original seeds (so the result is provably never
     worse than the old extractor given the same budget).

The default objective is *roofline-predicted latency*
(:class:`repro.analysis.RooflineCostModel`): a cost model may expose
``aggregate_cost(nodes)`` and the DAG evaluator then scores a selection
by that non-additive objective (here ``max(compute, memory)`` over the
summed statistics of the chosen nodes) instead of a per-node weight sum —
extraction picks terms that realize less computation AND less memory
traffic simultaneously, not just fewer abstract ops. Cost models exposing
``bind_egraph`` are bound to the graph before searching, which is how the
roofline model resolves per-array (shape, dtype) declarations and prices
broadcast scalars/rows and bf16/f8 tiles at their true HBM traffic.
Flat-weight models (:class:`repro.core.cost.CostModel`) work unchanged.

`extract_exact` brute-forces tiny graphs: tests use it to verify the
search reaches the optimum where enumeration is feasible, and
:func:`optimality_gap` reports the beam-vs-exact gap on such graphs.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import (Any, Dict, List, Optional, Sequence, Set, Tuple)

from repro.analysis import RooflineCostModel

from .beam import BeamStats, EvalBudget, Evaluator, beam_search
from .cost import CostModel
from .egraph import EGraph
from .ir import ENode

INF = float("inf")

SEARCH_STRATEGIES = ("beam", "hillclimb", "none")


@dataclasses.dataclass
class ExtractionResult:
    choice: Dict[int, ENode]           # canonical cid -> chosen e-node
    roots: Tuple[int, ...]             # canonical root cids
    dag_cost: float
    tree_cost: float
    wall_s: float = 0.0
    improved_by_search: float = 0.0    # dag-cost reduction from local search
    predicted: Optional[Dict[str, Any]] = None  # roofline stats of choice
    search: str = "none"               # strategy that produced the choice
    beam_cost: float = INF             # beam stage best (pre-polish)
    beam_stats: Optional[BeamStats] = None

    def term(self, eg: EGraph, root: Optional[int] = None):
        from .egraph import extract_to_term
        root = self.roots[0] if root is None else eg.find(root)
        return extract_to_term(self.choice, eg, root)


# -- step 1: bottom-up tree-cost fixed point ------------------------------------
def _tree_costs(eg: EGraph, cm: CostModel):
    best_cost: Dict[int, float] = {}
    best_node: Dict[int, ENode] = {}
    classes = eg.eclasses()
    changed = True
    while changed:
        changed = False
        for cid, ec in classes.items():
            for node in ec.nodes:
                node = eg.canonicalize(node)
                cost = cm.node_cost(node)
                ok = True
                for ch in node.children:
                    ch_cost = best_cost.get(eg.find(ch))
                    if ch_cost is None:
                        ok = False
                        break
                    cost += ch_cost
                if ok and cost < best_cost.get(cid, INF):
                    best_cost[cid] = cost
                    best_node[cid] = node
                    changed = True
    return best_cost, best_node


# -- DAG cost of a choice map ------------------------------------------------------
def choice_nodes(eg: EGraph, choice: Dict[int, ENode],
                 roots: Sequence[int]) -> Optional[List[ENode]]:
    """Chosen nodes over classes reachable from roots, each class once.

    Returns None on a cyclic or incomplete selection.
    """
    nodes: List[ENode] = []
    state: Dict[int, int] = {}  # 0=on stack, 1=done
    stack: List[Tuple[int, bool]] = [(eg.find(r), False) for r in roots]
    while stack:
        cid, processed = stack.pop()
        cid = eg.find(cid)
        if processed:
            state[cid] = 1
            continue
        st = state.get(cid)
        if st == 1:
            continue
        if st == 0:
            return None  # cycle
        node = choice.get(cid)
        if node is None:
            return None
        state[cid] = 0
        stack.append((cid, True))
        nodes.append(node)
        for ch in node.children:
            ch = eg.find(ch)
            if state.get(ch) is None:
                stack.append((ch, False))
            elif state.get(ch) == 0:
                return None
    return nodes


def dag_cost_of(eg: EGraph, cm: CostModel, choice: Dict[int, ENode],
                roots: Sequence[int]) -> float:
    """Cost of a selection with shared classes counted once.

    Models exposing ``aggregate_cost`` (the roofline objective) score the
    whole node multiset at once; flat models sum per-node weights.
    Returns inf on a cyclic selection.
    """
    nodes = choice_nodes(eg, choice, roots)
    if nodes is None:
        return INF
    aggregate = getattr(cm, "aggregate_cost", None)
    if aggregate is not None:
        return aggregate(nodes)
    return sum(cm.node_cost(n) for n in nodes)


def reachable(eg: EGraph, choice: Dict[int, ENode],
              roots: Sequence[int]) -> Set[int]:
    seen: Set[int] = set()
    stack = [eg.find(r) for r in roots]
    while stack:
        cid = stack.pop()
        if cid in seen:
            continue
        seen.add(cid)
        node = choice.get(cid)
        if node is None:
            continue
        for ch in node.children:
            ch = eg.find(ch)
            if ch not in seen:
                stack.append(ch)
    return seen


# -- unextractable-root diagnostics -------------------------------------------------
def _unextractable_message(eg: EGraph, root: int,
                           extractable: Set[int]) -> str:
    """Explain *why* a root has no extractable term: list its e-nodes and
    walk the blocking dependency cycle through unextractable classes."""
    ec = eg.classes.get(eg.find(root))
    nodes = sorted((eg.canonicalize(n) for n in ec.nodes), key=repr) \
        if ec is not None else []
    lines = [f"no extractable term for e-class {eg.find(root)}"]
    if not nodes:
        lines.append("  the class contains no e-nodes")
        return "\n".join(lines)
    lines.append("  available e-nodes (every one depends on an "
                 "unextractable child):")
    for n in nodes:
        blocked = [eg.find(c) for c in n.children
                   if eg.find(c) not in extractable]
        lines.append(f"    {n!r}  blocked by e-class(es) "
                     f"{sorted(set(blocked))}")
    # Every unextractable class has, in each of its nodes, at least one
    # unextractable child — so following first-blocked-child links from
    # the root must revisit a class: that revisit is the blocking cycle.
    path: List[int] = []
    seen_at: Dict[int, int] = {}
    cur = eg.find(root)
    while cur not in seen_at:
        seen_at[cur] = len(path)
        path.append(cur)
        ecur = eg.classes.get(cur)
        nxt = None
        for n in sorted((eg.canonicalize(m) for m in ecur.nodes), key=repr):
            for c in n.children:
                if eg.find(c) not in extractable:
                    nxt = eg.find(c)
                    break
            if nxt is not None:
                break
        if nxt is None:       # defensive: shouldn't happen by construction
            break
        cur = nxt
    if cur in seen_at:
        cycle = path[seen_at[cur]:] + [cur]
        lines.append("  blocking cycle: "
                     + " -> ".join(str(c) for c in cycle))
    return "\n".join(lines)


# -- local search on the DAG objective (polish pass) --------------------------------
def _local_search(eg: EGraph, cm: CostModel, choice: Dict[int, ENode],
                  roots: Sequence[int], deadline: float,
                  evaluator: Optional[Evaluator] = None,
                  budget: Optional[EvalBudget] = None
                  ) -> Tuple[Dict[int, ENode], float]:
    """First-improvement hill climb (the PR-2 extractor). Demoted to the
    polish pass after beam search; trials mutate in place and revert, so
    a swap costs one DAG walk, not a full choice-map copy. ``budget``
    caps the number of scored swaps — the deterministic stop; the
    wall-clock deadline is only a safety net."""
    ev = evaluator if evaluator is not None else Evaluator(eg, cm)
    best = dict(choice)
    get = best.get
    best_cost = ev.cost(get, roots)
    improved = True
    while improved and time.perf_counter() < deadline:
        improved = False
        for cid in list(reachable(eg, best, roots)):
            cid = eg.find(cid)
            cands = ev.candidates(cid)
            if len(cands) <= 1:
                continue
            current = best.get(cid)
            if current is None:
                continue
            for cand in cands:
                if cand == current:
                    continue
                if budget is not None and not budget.take():
                    return best, best_cost
                best[cid] = cand
                c = ev.cost(get, roots)
                if c < best_cost - 1e-9:
                    best_cost = c
                    current = cand
                    improved = True
                    break
                best[cid] = current
            if time.perf_counter() > deadline:
                break
    return best, best_cost


def _collect_seeds(eg: EGraph, cm, tree_choice: Dict[int, ENode],
                   roots: Sequence[int], deadline: float,
                   budget: EvalBudget) -> List[Dict[int, ENode]]:
    """Restart seeds: the objective's own tree fixed point plus, for
    non-additive models, the flat-weight extractor's refined solution —
    so the search can never end worse than what the paper's flat model
    would have chosen (refinement only improves the true objective).
    The refinement draws on its own deterministic ``budget``; the flat
    objective is only a restart heuristic."""
    seeds = [tree_choice]
    if getattr(cm, "aggregate_cost", None) is not None \
            and not isinstance(cm, CostModel):
        flat_cm = CostModel()
        _, flat_choice = _tree_costs(eg, flat_cm)
        if all(eg.find(r) in flat_choice for r in roots):
            refined, _ = _local_search(eg, flat_cm, flat_choice,
                                       roots, deadline, budget=budget)
            seeds.append(refined)
    return seeds


def extract_dag(eg: EGraph, roots, cost_model: Optional[CostModel] = None,
                *, time_limit_s: float = 5.0, local_search: bool = True,
                search: str = "beam", beam_width: int = 8,
                beam_expansions: int = 10_000,
                hillclimb_evals: int = 100_000,
                coordinated: bool = True,
                seed_choices: Optional[Sequence[Dict[int, ENode]]] = None
                ) -> ExtractionResult:
    """Extract a minimum-DAG-cost selection covering ``roots``.

    Defaults to the roofline-calibrated cost model: the objective is the
    predicted latency of the extracted term against the chip's compute
    and memory roofs, not a flat op-weight sum. Models exposing
    ``bind_egraph`` are bound to ``eg`` first so per-array (shape, dtype)
    declarations price loads at their true operand extent.

    ``search`` picks the global strategy. ``"hillclimb"`` is the PR-2
    multi-start hill climb. ``"beam"`` (default) does strictly more
    work in a fixed order: the same seed refinement and seed polish as
    ``"hillclimb"`` first, then :func:`repro.core.beam.beam_search`, then
    a polish of the beam winner — so a beam extraction is never worse
    than a hill-climb extraction of the same graph. ``"none"`` (or
    ``local_search=False``) returns the tree fixed point unrefined.

    ``coordinated`` (default on) extends the beam's neighborhood with
    2-class moves along chosen-DAG edges — a load and its consumer can
    change together, escaping plateaus where either single swap is
    strictly worse (ROADMAP's multi-class-move item).

    ``seed_choices`` prepends extra restart seeds (partial choices are
    completed over the tree fixed point) — the persistent saturation
    cache warm-starts the beam this way, so a near-miss entry can only
    speed the search up, never worsen the committed result.

    Every pass stops on a deterministic evaluation budget
    (``beam_expansions`` for the beam, ``hillclimb_evals`` for the
    hill-climb passes), never on the wall clock unless the generous
    ``time_limit_s`` safety net binds — results are machine-independent
    for a fixed e-graph and ``PYTHONHASHSEED``.
    """
    t0 = time.perf_counter()
    cm = cost_model if cost_model is not None else RooflineCostModel()
    binder = getattr(cm, "bind_egraph", None)
    if binder is not None:
        binder(eg)
    if search not in SEARCH_STRATEGIES:
        raise ValueError(f"search must be one of {SEARCH_STRATEGIES}, "
                         f"got {search!r}")
    if not local_search:
        search = "none"
    if isinstance(roots, int):
        roots = (roots,)
    roots = tuple(eg.find(r) for r in roots)
    tree_cost, tree_choice = _tree_costs(eg, cm)
    for r in roots:
        if r not in tree_choice:
            raise ValueError(
                _unextractable_message(eg, r, set(tree_choice)))
    base_cost = dag_cost_of(eg, cm, tree_choice, roots)
    choice, cost = tree_choice, base_cost
    beam_cost = INF
    beam_stats = None
    if search != "none":
        deadline = t0 + time_limit_s
        evaluator = Evaluator(eg, cm)
        seeds = _collect_seeds(eg, cm, tree_choice, roots, deadline,
                               EvalBudget(max(hillclimb_evals // 4, 1000)))
        if seed_choices:
            # cache warm starts go first; completed over the tree fixed
            # point so every class keeps a pick
            seeds = [{**tree_choice,
                      **{eg.find(c): eg.canonicalize(n)
                         for c, n in sc.items()}}
                     for sc in seed_choices] + seeds
        # stage 1 — identical in both modes: polish every restart seed
        # (this IS the PR-2 extractor; in beam mode it doubles as the
        # floor the beam must beat)
        seed_budget = EvalBudget(hillclimb_evals)
        for seed in seeds:
            ch, c = _local_search(eg, cm, seed, roots, deadline,
                                  evaluator=evaluator, budget=seed_budget)
            if c < cost:
                choice, cost = ch, c
        if search == "beam":
            # stage 2 — strictly additional work: beam over the seeds,
            # then polish the beam winner with its own budget, so the
            # final pick can only improve on the hill-climb result
            beam_stats = BeamStats()
            beam_choice, beam_cost = beam_search(
                eg, cm, seeds, roots, width=beam_width,
                deadline=deadline, max_expansions=beam_expansions,
                coordinated=coordinated,
                evaluator=evaluator, stats=beam_stats)
            if beam_cost < INF:
                ch, c = _local_search(
                    eg, cm, beam_choice, roots, deadline,
                    evaluator=evaluator,
                    budget=EvalBudget(max(hillclimb_evals // 2, 1000)))
                if c < cost:
                    choice, cost = ch, c
    live = reachable(eg, choice, roots)
    choice = {cid: n for cid, n in choice.items() if cid in live}
    predicted = None
    reporter = getattr(cm, "report", None)
    if reporter is not None:
        nodes = choice_nodes(eg, choice, roots)
        if nodes is not None:
            predicted = reporter(nodes)
    return ExtractionResult(
        choice=choice, roots=roots, dag_cost=cost,
        tree_cost=sum(tree_cost[r] for r in roots),
        wall_s=time.perf_counter() - t0,
        improved_by_search=base_cost - cost,
        predicted=predicted, search=search,
        beam_cost=beam_cost, beam_stats=beam_stats)


# -- brute force for tests -----------------------------------------------------------
def extract_exact(eg: EGraph, roots, cost_model: Optional[CostModel] = None,
                  max_combos: int = 200_000) -> ExtractionResult:
    """Enumerate all acyclic selections (tiny graphs only)."""
    cm = cost_model if cost_model is not None else RooflineCostModel()
    binder = getattr(cm, "bind_egraph", None)
    if binder is not None:
        binder(eg)
    if isinstance(roots, int):
        roots = (roots,)
    roots = tuple(eg.find(r) for r in roots)
    classes = eg.eclasses()
    cids = sorted(classes.keys())
    node_lists = [[eg.canonicalize(n) for n in classes[c].nodes] for c in cids]
    n_combos = 1
    for nl in node_lists:
        n_combos *= len(nl)
        if n_combos > max_combos:
            raise ValueError(f"too many combos (> {max_combos})")
    best_choice, best_cost = None, INF
    for combo in itertools.product(*node_lists):
        choice = dict(zip(cids, combo))
        c = dag_cost_of(eg, cm, choice, roots)
        if c < best_cost:
            best_choice, best_cost = choice, c
    assert best_choice is not None
    live = reachable(eg, best_choice, roots)
    best_choice = {c: n for c, n in best_choice.items() if c in live}
    return ExtractionResult(choice=best_choice, roots=roots,
                            dag_cost=best_cost, tree_cost=best_cost,
                            search="exact")


def optimality_gap(eg: EGraph, result: ExtractionResult,
                   cost_model: Optional[CostModel] = None, *,
                   max_classes: int = 12,
                   max_combos: int = 200_000) -> Optional[float]:
    """Relative gap of ``result`` vs the brute-force oracle, or None when
    the graph is too large to enumerate.

    ``0.0`` means the search matched the global optimum. Used by the
    benchmark layer to measure how far the beam is from ILP-quality
    extraction wherever the oracle is feasible.
    """
    if eg.num_classes() > max_classes:
        return None
    try:
        exact = extract_exact(eg, result.roots, cost_model,
                              max_combos=max_combos)
    except ValueError:
        return None
    if exact.dag_cost <= 0:
        return 0.0 if result.dag_cost <= exact.dag_cost + 1e-9 else INF
    return max(0.0, (result.dag_cost - exact.dag_cost) / exact.dag_cost)
