"""Cost models (paper §V-B) — thin adapters over the unified analysis
subsystem.

The operator classification lives in :mod:`repro.analysis.opstats`; the
classes here map those classes onto the paper's abstract weights, so the
flat-weight models and the roofline-calibrated extraction objective
(:class:`repro.analysis.RooflineCostModel`) can never disagree about
what an operator *is* — only about what it costs.

The paper's model: constants cost 0, each input variable or phi costs 1,
every computational operation costs 10 except division and modular
arithmetic, and each memory access, division, modular arithmetic, or
function call costs 100.

``TPUCostModel`` is the beyond-paper variant tuned from TPU v5e
instruction timing: transcendentals are mid-cost (VPU multi-pass), fma
equals one op (MXU/VPU native), loads keep the paper's 10x-over-compute
ratio (HBM→VMEM).
"""
from __future__ import annotations

from typing import Dict

from repro.analysis.opstats import (CALL_OPS, FREE_OPS, INPUT_OPS,
                                    MEMORY_OPS, PHI_OPS, ROOTLIKE,
                                    SERIAL_ARITH, TRANSCENDENTALS)

from .ir import ENode

_EXPENSIVE_OPS = MEMORY_OPS | CALL_OPS | SERIAL_ARITH


class CostModel:
    """Paper cost model. Cost of one e-node, excluding children."""

    name = "paper"
    CONST = 0.0
    VAR = 1.0
    PHI = 1.0
    OP = 10.0
    EXPENSIVE = 100.0  # memory access, div, mod, call

    def node_cost(self, node: ENode) -> float:
        op = node.op
        if op in FREE_OPS:
            return self.CONST
        if op in INPUT_OPS:
            return self.VAR
        if op in PHI_OPS:
            return self.PHI
        if op in _EXPENSIVE_OPS:
            return self.EXPENSIVE
        return self.OP


class TPUCostModel(CostModel):
    """TPU v5e-tuned costs (beyond-paper, DESIGN.md §2).

    Rationale: VPU issues one 8x128 vector op/cycle; exp/log/tanh/rsqrt are
    ~4-8 pass pipelined sequences; true divide is ~10 passes; an HBM load at
    819 GB/s against 197 TFLOP/s bf16 compute gives ~240 flops/float of
    headroom -> keep memory at the paper's 10:1 over plain ops but price
    transcendentals between the two.
    """

    name = "tpu_v5e"
    TRANSCENDENTAL = 40.0

    def node_cost(self, node: ENode) -> float:
        op = node.op
        if op in TRANSCENDENTALS:
            return self.TRANSCENDENTAL
        if op in ROOTLIKE:
            return self.TRANSCENDENTAL / 2
        if op == "neg":
            # sign flips fold into FMA operands on the VPU/MXU — free.
            # This is what makes FMA2/FMA3 (paper Table I) strictly win
            # over sub+mul under the TPU model (they tie under the paper's).
            return 0.0
        return super().node_cost(node)


def instruction_mix(node_choice: Dict[int, ENode]) -> Dict[str, int]:
    """Instruction histogram of an extraction choice (Table IV analog)."""
    mix: Dict[str, int] = {}
    for node in node_choice.values():
        mix[node.op] = mix.get(node.op, 0) + 1
    return mix


def count_ops(node_choice: Dict[int, ENode]) -> int:
    """Executed 'instructions': everything but consts/vars/arrays/tuples."""
    skip = ("const", "var", "array", "tuple")
    return sum(1 for n in node_choice.values() if n.op not in skip)


def count_flops(node_choice: Dict[int, ENode]) -> int:
    """Arithmetic op count with fma=2 (for roofline-style accounting)."""
    flops = 0
    for n in node_choice.values():
        if n.op == "fma":
            flops += 2
        elif n.op in ("add", "sub", "mul", "div", "neg", "min", "max",
                      "square", "recip"):
            flops += 1
        elif n.op in ("exp", "log", "sqrt", "rsqrt", "tanh", "sigmoid",
                      "pow"):
            flops += 8  # polynomial-expansion estimate
    return flops
