"""End-to-end saturator pipeline (paper Fig. 1) with the four evaluated
configurations:

  =========  ====  ============  =========
  mode       CSE   saturation    bulk load
  =========  ====  ============  =========
  baseline    no        no           no      (original code, §VIII)
  cse         yes       no           no
  cse_sat     yes    Table I        no
  cse_bulk    yes       no          yes
  accsat      yes    Table I       yes      (default, = ACCSAT)
  =========  ====  ============  =========

`saturate_program` runs: DSL → SSA+φ → e-graph → equality saturation →
CSE-aware extraction → codegen (temp vars + bulk load) → callable JAX
kernel. Limits default to the paper's §VII values.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from repro.analysis import RooflineCostModel

from .codegen import CodeGenerator, GeneratedKernel
from .cost import CostModel, TPUCostModel
from .dsl import KernelProgram
from .egraph import EGraph
from .extract import SEARCH_STRATEGIES, ExtractionResult, extract_dag
from .rules import (EXTENDED_RULES, PAPER_RULES, TPU_RULES, Rule,
                    SaturationReport, run_rules)
from .ssa import SSAResult, build_ssa

MODES = ("baseline", "cse", "cse_sat", "cse_bulk", "accsat")
COST_MODELS = ("paper", "tpu_v5e", "roofline")
SEARCHES = SEARCH_STRATEGIES  # single source of truth: repro.core.extract


@dataclasses.dataclass
class SaturatorConfig:
    mode: str = "accsat"
    # paper §VII limits: 10k e-nodes, 10 iters, 10 s saturation, 30 s extract
    iter_limit: int = 10
    node_limit: int = 10_000
    time_limit_s: float = 10.0
    extract_time_limit_s: float = 30.0
    # 'roofline' minimizes predicted latency (repro.analysis); 'paper' and
    # 'tpu_v5e' are the flat-weight models kept for ablation comparisons.
    cost_model: str = "roofline"
    extended_rules: bool = False   # §V-A restricted set (off, as in paper)
    tpu_rules: bool = False        # beyond-paper strength reduction
    local_search: bool = True      # DAG-cost refinement (ILP stand-in)
    # global extraction strategy: beam search (default, hill climb kept as
    # the polish pass) or 'hillclimb' (the PR-2 extractor, for ablations);
    # beam_expansions / hillclimb_evals are the deterministic search
    # budgets (scored swaps) — wall clocks are only safety nets
    search: str = "beam"
    beam_width: int = 8
    beam_expansions: int = 10_000
    hillclimb_evals: int = 100_000
    # Calibrated objective: a DeviceProfile instance, a path, or a bare
    # profile name under experiments/device_profiles/ (see
    # repro.analysis.calibrate). None keeps the analytic roofline
    # constants — the default, so committed baselines stay in analytic
    # units. Only meaningful with cost_model="roofline".
    device_profile: Optional[Any] = None
    # Statement order of the generated kernel (repro.core.schedule):
    # "source" = loads at use sites, "bulk" = the paper's bulk load
    # (bit-identical to the pre-PR-5 emitter), "cost" = cost-driven
    # legal topological order minimizing the schedule-aware latency
    # objective. None keeps the mode's historical default (bulk for
    # accsat/cse_bulk, source otherwise), so baselines never drift.
    schedule: Optional[str] = None
    # Coordinated multi-class beam moves (load + consumers swapped
    # together) — escapes plateaus the 1-swap neighborhood cannot leave.
    beam_coordinated: bool = True

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode}")
        if self.cost_model not in COST_MODELS:
            raise ValueError(f"cost_model must be one of {COST_MODELS}, "
                             f"got {self.cost_model}")
        if self.search not in SEARCHES:
            raise ValueError(f"search must be one of {SEARCHES}, "
                             f"got {self.search}")
        from .schedule import SCHEDULE_MODES
        if self.schedule is not None and \
                self.schedule not in SCHEDULE_MODES:
            raise ValueError(f"schedule must be one of {SCHEDULE_MODES}, "
                             f"got {self.schedule}")

    @property
    def schedule_mode(self) -> str:
        """The effective statement order (explicit ``schedule`` wins,
        else the mode's historical bulk/source behavior)."""
        if self.schedule is not None:
            return self.schedule
        return "bulk" if self.use_bulk else "source"

    @property
    def use_sat(self) -> bool:
        return self.mode in ("cse_sat", "accsat")

    @property
    def use_bulk(self) -> bool:
        return self.mode in ("cse_bulk", "accsat")

    @property
    def use_cse(self) -> bool:
        return self.mode != "baseline"

    def rules(self) -> list:
        rules = list(PAPER_RULES)
        if self.extended_rules:
            rules += EXTENDED_RULES
        if self.tpu_rules:
            rules += [r for r in TPU_RULES if "NOP" not in r.name]
        return rules

    def make_cost_model(self, prog: Optional[KernelProgram] = None
                        ) -> CostModel:
        if self.cost_model == "roofline":
            # thread the kernel's declared dtype through the roofline
            # objective (per-array shapes/dtypes resolve later, when
            # extract_dag binds the model to the e-graph); a device
            # profile makes the beam minimize the calibrated objective
            dtype = getattr(prog, "dtype", None) or "f32"
            return RooflineCostModel(dtype=dtype,
                                     profile=self.device_profile)
        return TPUCostModel() if self.cost_model == "tpu_v5e" else CostModel()

    def make_schedule_cost_model(self, prog: Optional[KernelProgram] = None):
        """Model pricing the cost-driven schedule search. The roofline
        objective (calibrated or not) is shared with extraction; flat
        extraction models can't price a schedule, so a configured
        ``device_profile`` still drives scheduling through a calibrated
        roofline model (extraction stays flat — the committed choice is
        unchanged, only the statement order is optimized), and None
        falls back to the analytic roofline."""
        if self.cost_model == "roofline":
            return self.make_cost_model(prog)
        if self.device_profile is not None:
            dtype = getattr(prog, "dtype", None) or "f32"
            return RooflineCostModel(dtype=dtype,
                                     profile=self.device_profile)
        return None


@dataclasses.dataclass
class SaturatedKernel:
    """Everything the pipeline produced for one kernel."""
    kernel: GeneratedKernel
    ssa: SSAResult
    extraction: ExtractionResult
    saturation: Optional[SaturationReport]
    config: SaturatorConfig
    ssa_wall_s: float = 0.0
    codegen_wall_s: float = 0.0

    @property
    def fn(self) -> Callable:
        return self.kernel.fn

    @property
    def source(self) -> str:
        return self.kernel.source

    def __call__(self, *a, **k):
        return self.kernel.fn(*a, **k)

    def report(self) -> Dict[str, Any]:
        s = self.kernel.stats
        pred = self.extraction.predicted or {}
        bs = self.extraction.beam_stats
        return {
            "mode": self.config.mode,
            "cost_model": self.config.cost_model,
            "search": self.extraction.search,
            "beam_width": self.config.beam_width,
            "beam_cost": self.extraction.beam_cost,
            "beam_generations": bs.generations if bs else 0,
            "beam_expanded": bs.expanded if bs else 0,
            "dag_cost": self.extraction.dag_cost,
            "tree_cost": self.extraction.tree_cost,
            "predicted_flops": pred.get("flops", 0.0),
            "predicted_bytes": (pred.get("bytes_read", 0.0)
                                + pred.get("bytes_written", 0.0)),
            "predicted_latency_ns": pred.get("latency_ns", 0.0),
            "predicted_bound": pred.get("bound", "n/a"),
            "device_profile": pred.get("profile"),
            "n_temps": s.n_temps,
            "n_loads": s.n_loads,
            "n_stores": s.n_stores,
            "n_fma": s.n_fma,
            "n_ops": s.n_ops,
            "loads_before_compute": s.loads_before_compute,
            "schedule": self.kernel.schedule_mode,
            "schedule_predicted_ns": (
                self.kernel.schedule.predicted_ns
                if self.kernel.schedule is not None else None),
            "sat_iterations": self.saturation.iterations
            if self.saturation else 0,
            "sat_nodes": self.saturation.n_nodes if self.saturation else 0,
            "sat_stop": self.saturation.stop_reason
            if self.saturation else "disabled",
            "ssa_ms": self.ssa_wall_s * 1e3,
            "sat_s": self.saturation.wall_s if self.saturation else 0.0,
            "extract_s": self.extraction.wall_s,
            "codegen_ms": self.codegen_wall_s * 1e3,
        }


def predict_choice(ssa: SSAResult, choice, roots, n_stores: int,
                   profile=None):
    """Roofline prediction of an extraction choice in the pipeline's
    reporting units: shape/dtype-aware load pricing bound to the SSA
    e-graph, plus the root stores' write traffic (per-store operand info
    when the SSA store count matches codegen's). Shared with
    ``benchmarks/saturation_stats.py`` so beam-vs-hillclimb deltas are
    always computed in these exact units. ``profile`` reports in a
    calibrated device profile's units instead of the analytic ones."""
    store_infos = ssa.store_infos()
    return ssa.egraph.choice_stats(
        choice, roots, n_stores=n_stores,
        store_infos=store_infos if len(store_infos) == n_stores else None,
        cost_model=RooflineCostModel(
            dtype=getattr(ssa.prog, "dtype", "f32"), egraph=ssa.egraph,
            profile=profile))


def saturate_program(prog: KernelProgram,
                     config: Optional[SaturatorConfig] = None,
                     extra_fns: Optional[Dict[str, Callable]] = None
                     ) -> SaturatedKernel:
    cfg = config or SaturatorConfig()
    t0 = time.perf_counter()
    ssa = build_ssa(prog)
    ssa_wall = time.perf_counter() - t0
    sat_report = None
    if cfg.use_sat:
        sat_report = run_rules(ssa.egraph, cfg.rules(),
                               iter_limit=cfg.iter_limit,
                               node_limit=cfg.node_limit,
                               time_limit_s=cfg.time_limit_s)
    roots = ssa.roots()
    cm = cfg.make_cost_model(prog)
    extraction = extract_dag(
        ssa.egraph, tuple(roots) if roots else (),
        cost_model=cm,
        time_limit_s=cfg.extract_time_limit_s,
        local_search=cfg.local_search and cfg.use_cse,
        search=cfg.search, beam_width=cfg.beam_width,
        beam_expansions=cfg.beam_expansions,
        hillclimb_evals=cfg.hillclimb_evals,
        coordinated=cfg.beam_coordinated)
    t1 = time.perf_counter()
    # the cost scheduler prices statement orders with the same (possibly
    # calibrated) model extraction minimized — one objective end to end
    gen = CodeGenerator(ssa, extraction, bulk=cfg.use_bulk,
                        extra_fns=extra_fns,
                        reuse_temps=cfg.use_cse,
                        schedule=cfg.schedule,
                        sched_cost_model=cfg.make_schedule_cost_model(prog)
                        ).generate()
    codegen_wall = time.perf_counter() - t1
    # Roofline prediction of the chosen term including root-store write
    # traffic (known only post-codegen), regardless of which cost model
    # drove extraction — ablations compare in the same units. Stores are
    # priced per target operand (shape after indexing, declared dtype).
    # A configured device profile reports in its calibrated units.
    predicted = predict_choice(ssa, extraction.choice, extraction.roots,
                               gen.stats.n_stores,
                               profile=cfg.device_profile
                               if cfg.cost_model == "roofline" else None)
    if predicted is not None:
        extraction.predicted = predicted
    return SaturatedKernel(kernel=gen, ssa=ssa, extraction=extraction,
                           saturation=sat_report, config=cfg,
                           ssa_wall_s=ssa_wall, codegen_wall_s=codegen_wall)


def saturate_all_modes(prog: KernelProgram, base: Optional[SaturatorConfig]
                       = None, extra_fns=None) -> Dict[str, SaturatedKernel]:
    """All four paper configurations + baseline, for ablation benchmarks."""
    base = base or SaturatorConfig()
    out = {}
    for mode in MODES:
        cfg = dataclasses.replace(base, mode=mode)
        out[mode] = saturate_program(prog, cfg, extra_fns=extra_fns)
    return out
