"""End-to-end saturator pipeline (paper Fig. 1) with the four evaluated
configurations:

  =========  ====  ============  =========
  mode       CSE   saturation    bulk load
  =========  ====  ============  =========
  baseline    no        no           no      (original code, §VIII)
  cse         yes       no           no
  cse_sat     yes    Table I        no
  cse_bulk    yes       no          yes
  accsat      yes    Table I       yes      (default, = ACCSAT)
  =========  ====  ============  =========

`saturate_program` runs: DSL → SSA+φ → e-graph → equality saturation →
CSE-aware extraction → codegen (temp vars + bulk load) → callable JAX
kernel. Limits default to the paper's §VII values.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Any, Callable, Dict, Optional

from repro.analysis import RooflineCostModel
from repro.runtime import chaos
from repro.runtime.guard import GuardConfig, breaker_for, run_ladder

from .codegen import JaxCodeGenerator, GeneratedKernel, GenStats
from .cost import CostModel, TPUCostModel
from .dsl import KernelProgram
from .egraph import EGraph
from .emit import EMITTER_NAMES
from .extract import SEARCH_STRATEGIES, ExtractionResult, extract_dag
from .rules import (EXTENDED_RULES, PAPER_RULES, TPU_RULES, Rule,
                    SaturationReport, run_rules)
from .schedule import compute_schedule
from .ssa import SSAResult, build_ssa
from .telemetry import telemetry

# Environment switch for the persistent saturation cache: a directory
# path enables it for every SaturatorConfig that doesn't set its own
# cache_dir (the launch drivers use this to make serving/training warm
# across processes).
CACHE_ENV_VAR = "REPRO_SAT_CACHE"
# Environment switch for static verification: a repro.verify level name
# ("off" | "cheap" | "full") picked up by SaturatorConfig.from_env().
VERIFY_ENV_VAR = "REPRO_VERIFY"

MODES = ("baseline", "cse", "cse_sat", "cse_bulk", "accsat")
COST_MODELS = ("paper", "tpu_v5e", "roofline")
SEARCHES = SEARCH_STRATEGIES  # single source of truth: repro.core.extract

_UNSET = object()   # "caller did not pass this" sentinel (from_env)


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Saturation + extraction search budgets (paper §VII limits).

    ``iter_limit``/``node_limit``/``time_limit_s`` bound equality
    saturation (10 iters, 10k e-nodes, 10 s); ``extract_time_limit_s``
    bounds extraction (30 s). ``search`` picks the global extraction
    strategy — beam search (default, hill climb kept as the polish pass)
    or ``"hillclimb"`` (the PR-2 extractor, for ablations);
    ``beam_expansions``/``hillclimb_evals`` are the deterministic search
    budgets (scored swaps) — wall clocks are only safety nets.
    ``beam_coordinated`` enables multi-class beam moves (load +
    consumers swapped together), escaping plateaus the 1-swap
    neighborhood cannot leave. ``local_search`` is the DAG-cost
    refinement pass (ILP stand-in)."""
    iter_limit: int = 10
    node_limit: int = 10_000
    time_limit_s: float = 10.0
    extract_time_limit_s: float = 30.0
    local_search: bool = True
    search: str = "beam"
    beam_width: int = 8
    beam_expansions: int = 10_000
    hillclimb_evals: int = 100_000
    beam_coordinated: bool = True


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """Statement order + emission backend of the generated kernel.

    ``schedule`` (repro.core.schedule): "source" = loads at use sites,
    "bulk" = the paper's bulk load (bit-identical to the pre-PR-5
    emitter), "cost" = cost-driven legal topological order minimizing
    the schedule-aware latency objective. None keeps the mode's
    historical default (bulk for accsat/cse_bulk, source otherwise), so
    baselines never drift.

    ``device_profile``: a calibrated DeviceProfile instance, a path, or
    a bare profile name under experiments/device_profiles/ (see
    repro.analysis.calibrate). None keeps the analytic roofline
    constants. Only meaningful with cost_model="roofline" for
    extraction; always prices the cost schedule search.

    ``emitter`` (repro.core.emit): registry name of the emission
    backend. None keeps the context's default ("jax" in the pipeline,
    "pallas" in make_tile_op); "pallas_pipelined" emits double-buffered
    async copies. Non-default emitters enter the cache fingerprint as
    ``name@v{version}`` so cached replays never mix emitters."""
    schedule: Optional[str] = None
    device_profile: Optional[Any] = None
    emitter: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Persistent saturation cache (repro.cache).

    ``cache_dir``: a directory path (or SaturationCache instance)
    enabling on-disk reuse of committed extraction choices + schedule
    orders across processes. None falls back to the REPRO_SAT_CACHE
    environment variable (unset = off); False disables the cache even
    when that variable is set (the resolved form of ``--no-cache``).
    An exact hit skips saturation, beam search, and schedule search
    and re-emits a bit-identical kernel; a near-miss (same kernel,
    other shapes) seeds the searches when ``cache_warm_start`` is on."""
    cache_dir: Optional[Any] = None
    cache_warm_start: bool = True


@dataclasses.dataclass(frozen=True)
class VerifyConfig:
    """Static verification (repro.verify): "off" adds zero overhead,
    "cheap" audits the e-graph + certifies the attached schedule +
    lints the emitted source on every build (cold and cached replay),
    "full" additionally certifies reconstructed legacy orders and
    differentially re-validates the active rule set."""
    verify: str = "off"


_GROUP_FIELDS = {
    "search_cfg": SearchConfig,
    "schedule_cfg": ScheduleConfig,
    "cache_cfg": CacheConfig,
    "verify_cfg": VerifyConfig,
}
# legacy flat kwarg -> owning sub-config field ("emitter" is post-split,
# so it is a first-class keyword, not a deprecated one)
_LEGACY_TO_GROUP = {
    f.name: g for g, cls in _GROUP_FIELDS.items()
    for f in dataclasses.fields(cls) if f.name != "emitter"
}


@dataclasses.dataclass(init=False)
class SaturatorConfig:
    """Pipeline configuration, grouped since PR 8.

    Four evergreen fields stay flat (``mode``, ``cost_model``,
    ``extended_rules``, ``tpu_rules``); everything else lives in the
    :class:`SearchConfig` / :class:`ScheduleConfig` / :class:`CacheConfig`
    / :class:`VerifyConfig` sub-configs (``search_cfg`` etc.). The old
    flat keyword arguments still construct (forwarded into their group
    with a ``DeprecationWarning``) and every flat *read* keeps working
    through read-only properties, so pre-PR-8 call sites and cache
    fingerprints are unchanged.

    ``cost_model``: 'roofline' minimizes predicted latency
    (repro.analysis); 'paper' and 'tpu_v5e' are the flat-weight models
    kept for ablation comparisons. ``extended_rules`` is the §V-A
    restricted set (off, as in the paper); ``tpu_rules`` adds the
    beyond-paper strength-reduction set."""
    mode: str = "accsat"
    cost_model: str = "roofline"
    extended_rules: bool = False
    tpu_rules: bool = False
    search_cfg: SearchConfig = dataclasses.field(
        default_factory=SearchConfig)
    schedule_cfg: ScheduleConfig = dataclasses.field(
        default_factory=ScheduleConfig)
    cache_cfg: CacheConfig = dataclasses.field(default_factory=CacheConfig)
    verify_cfg: VerifyConfig = dataclasses.field(default_factory=VerifyConfig)
    # guarded-runtime policy (repro.runtime.guard, PR 10): hard ceilings,
    # degradation-ladder/breaker knobs, optional chaos plan. Deliberately
    # outside the cache fingerprint (keys.py lists components explicitly)
    # and outside the legacy flat-kwarg shim (like "emitter", it is
    # post-split — pass the group).
    guard_cfg: GuardConfig = dataclasses.field(default_factory=GuardConfig)

    def __init__(self, mode: str = "accsat", cost_model: str = "roofline",
                 extended_rules: bool = False, tpu_rules: bool = False,
                 search_cfg: Optional[SearchConfig] = None,
                 schedule_cfg: Optional[ScheduleConfig] = None,
                 cache_cfg: Optional[CacheConfig] = None,
                 verify_cfg: Optional[VerifyConfig] = None,
                 guard_cfg: Optional[GuardConfig] = None,
                 emitter: Any = _UNSET, **legacy: Any):
        self.mode = mode
        self.cost_model = cost_model
        self.extended_rules = extended_rules
        self.tpu_rules = tpu_rules
        groups: Dict[str, Any] = {
            "search_cfg": search_cfg or SearchConfig(),
            "schedule_cfg": schedule_cfg or ScheduleConfig(),
            "cache_cfg": cache_cfg or CacheConfig(),
            "verify_cfg": verify_cfg or VerifyConfig(),
        }
        unknown = sorted(k for k in legacy if k not in _LEGACY_TO_GROUP)
        if unknown:
            raise TypeError(f"SaturatorConfig got unexpected keyword "
                            f"argument(s) {unknown}")
        if legacy:
            owners = sorted({_LEGACY_TO_GROUP[k] for k in legacy})
            warnings.warn(
                f"flat SaturatorConfig kwarg(s) {sorted(legacy)} are "
                f"deprecated; pass the grouped {'/'.join(owners)} "
                f"sub-config(s) instead", DeprecationWarning, stacklevel=2)
            for k, v in legacy.items():
                g = _LEGACY_TO_GROUP[k]
                groups[g] = dataclasses.replace(groups[g], **{k: v})
        if emitter is not _UNSET:
            groups["schedule_cfg"] = dataclasses.replace(
                groups["schedule_cfg"], emitter=emitter)
        self.search_cfg = groups["search_cfg"]
        self.schedule_cfg = groups["schedule_cfg"]
        self.cache_cfg = groups["cache_cfg"]
        self.verify_cfg = groups["verify_cfg"]
        self.guard_cfg = guard_cfg or GuardConfig()
        self.__post_init__()

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode}")
        if self.cost_model not in COST_MODELS:
            raise ValueError(f"cost_model must be one of {COST_MODELS}, "
                             f"got {self.cost_model}")
        if self.search not in SEARCHES:
            raise ValueError(f"search must be one of {SEARCHES}, "
                             f"got {self.search}")
        from .schedule import SCHEDULE_MODES
        if self.schedule is not None and \
                self.schedule not in SCHEDULE_MODES:
            raise ValueError(f"schedule must be one of {SCHEDULE_MODES}, "
                             f"got {self.schedule}")
        if self.emitter is not None and self.emitter not in EMITTER_NAMES:
            raise ValueError(f"emitter must be one of {EMITTER_NAMES}, "
                             f"got {self.emitter}")
        from repro.verify import VERIFY_LEVELS
        if self.verify not in VERIFY_LEVELS:
            raise ValueError(f"verify must be one of {VERIFY_LEVELS}, "
                             f"got {self.verify}")

    # -- resolved side-channels (one documented front door) --------------
    @classmethod
    def from_env(cls, *, cache_dir: Any = _UNSET, verify: Any = _UNSET,
                 flags: Any = None, env: Optional[Dict[str, str]] = None,
                 **kwargs: Any) -> "SaturatorConfig":
        """Build a config with the cache/verify side-channels resolved.

        Precedence, per setting: **explicit keyword argument > CLI flag
        > environment variable > default**. ``flags`` is an
        ``argparse.Namespace`` (or mapping) that may carry ``cache_dir``,
        ``no_cache`` and ``verify`` — the launch drivers
        (``repro.launch.serve`` / ``repro.launch.train``) pass their
        parsed args here verbatim. Environment variables consulted:
        ``REPRO_SAT_CACHE`` (cache directory) and ``REPRO_VERIFY``
        (verification level); ``env`` overrides ``os.environ`` for
        tests. The resolved values land in ``cache_cfg``/``verify_cfg``
        (``--no-cache`` resolves to ``cache_dir=False``, which disables
        the cache even when ``REPRO_SAT_CACHE`` is set); remaining
        ``kwargs`` pass through to the constructor."""
        env_map = os.environ if env is None else env
        if flags is None:
            fl: Dict[str, Any] = {}
        elif isinstance(flags, dict):
            fl = dict(flags)
        else:
            fl = vars(flags)
        if cache_dir is _UNSET:
            if fl.get("no_cache"):
                cache_dir = False
            elif fl.get("cache_dir") is not None:
                cache_dir = fl["cache_dir"]
            else:
                cache_dir = env_map.get(CACHE_ENV_VAR) or None
        if verify is _UNSET:
            if fl.get("verify") is not None:
                verify = fl["verify"]
            else:
                verify = env_map.get(VERIFY_ENV_VAR) or "off"
        cache_cfg = dataclasses.replace(
            kwargs.pop("cache_cfg", None) or CacheConfig(),
            cache_dir=cache_dir)
        verify_cfg = dataclasses.replace(
            kwargs.pop("verify_cfg", None) or VerifyConfig(),
            verify=verify)
        return cls(cache_cfg=cache_cfg, verify_cfg=verify_cfg, **kwargs)

    # -- flat read-only views (pre-PR-8 call sites + cache fingerprints) --
    @property
    def iter_limit(self) -> int:
        return self.search_cfg.iter_limit

    @property
    def node_limit(self) -> int:
        return self.search_cfg.node_limit

    @property
    def time_limit_s(self) -> float:
        return self.search_cfg.time_limit_s

    @property
    def extract_time_limit_s(self) -> float:
        return self.search_cfg.extract_time_limit_s

    @property
    def local_search(self) -> bool:
        return self.search_cfg.local_search

    @property
    def search(self) -> str:
        return self.search_cfg.search

    @property
    def beam_width(self) -> int:
        return self.search_cfg.beam_width

    @property
    def beam_expansions(self) -> int:
        return self.search_cfg.beam_expansions

    @property
    def hillclimb_evals(self) -> int:
        return self.search_cfg.hillclimb_evals

    @property
    def beam_coordinated(self) -> bool:
        return self.search_cfg.beam_coordinated

    @property
    def schedule(self) -> Optional[str]:
        return self.schedule_cfg.schedule

    @property
    def device_profile(self) -> Optional[Any]:
        return self.schedule_cfg.device_profile

    @property
    def emitter(self) -> Optional[str]:
        return self.schedule_cfg.emitter

    @property
    def cache_dir(self) -> Optional[Any]:
        return self.cache_cfg.cache_dir

    @property
    def cache_warm_start(self) -> bool:
        return self.cache_cfg.cache_warm_start

    @property
    def verify(self) -> str:
        return self.verify_cfg.verify

    @property
    def schedule_mode(self) -> str:
        """The effective statement order (explicit ``schedule`` wins,
        else the mode's historical bulk/source behavior)."""
        if self.schedule is not None:
            return self.schedule
        return "bulk" if self.use_bulk else "source"

    @property
    def use_sat(self) -> bool:
        return self.mode in ("cse_sat", "accsat")

    @property
    def use_bulk(self) -> bool:
        return self.mode in ("cse_bulk", "accsat")

    @property
    def use_cse(self) -> bool:
        return self.mode != "baseline"

    def rules(self) -> list:
        rules = list(PAPER_RULES)
        if self.extended_rules:
            rules += EXTENDED_RULES
        if self.tpu_rules:
            rules += [r for r in TPU_RULES if "NOP" not in r.name]
        return rules

    def make_cost_model(self, prog: Optional[KernelProgram] = None
                        ) -> CostModel:
        if self.cost_model == "roofline":
            # thread the kernel's declared dtype through the roofline
            # objective (per-array shapes/dtypes resolve later, when
            # extract_dag binds the model to the e-graph); a device
            # profile makes the beam minimize the calibrated objective
            dtype = getattr(prog, "dtype", None) or "f32"
            return RooflineCostModel(dtype=dtype,
                                     profile=self.device_profile)
        return TPUCostModel() if self.cost_model == "tpu_v5e" else CostModel()

    def make_schedule_cost_model(self, prog: Optional[KernelProgram] = None):
        """Model pricing the cost-driven schedule search. The roofline
        objective (calibrated or not) is shared with extraction; flat
        extraction models can't price a schedule, so a configured
        ``device_profile`` still drives scheduling through a calibrated
        roofline model (extraction stays flat — the committed choice is
        unchanged, only the statement order is optimized), and None
        falls back to the analytic roofline."""
        if self.cost_model == "roofline":
            return self.make_cost_model(prog)
        if self.device_profile is not None:
            dtype = getattr(prog, "dtype", None) or "f32"
            return RooflineCostModel(dtype=dtype,
                                     profile=self.device_profile)
        return None


@dataclasses.dataclass
class SaturatedKernel:
    """Everything the pipeline produced for one kernel."""
    kernel: GeneratedKernel
    ssa: SSAResult
    extraction: ExtractionResult
    saturation: Optional[SaturationReport]
    config: SaturatorConfig
    ssa_wall_s: float = 0.0
    codegen_wall_s: float = 0.0
    # persistent-cache outcome for this build: "off" (no cache), "miss"
    # (cold search, result stored), "warm" (searches seeded from a
    # near-miss entry), "hit" (replayed with no search at all)
    cache_status: str = "off"
    # static-verification report (repro.verify) when config.verify != "off"
    verify_report: Optional[Any] = None
    # degradation-ladder rung this build landed on (repro.runtime.guard):
    # "hit" | "warm" | "cold" | "cheap" | "ref"
    ladder_level: str = "cold"

    @property
    def fn(self) -> Callable:
        return self.kernel.fn

    @property
    def source(self) -> str:
        return self.kernel.source

    def __call__(self, *a, **k):
        return self.kernel.fn(*a, **k)

    def report(self) -> Dict[str, Any]:
        s = self.kernel.stats
        pred = self.extraction.predicted or {}
        bs = self.extraction.beam_stats
        return {
            "mode": self.config.mode,
            "cost_model": self.config.cost_model,
            "search": self.extraction.search,
            "beam_width": self.config.beam_width,
            "beam_cost": self.extraction.beam_cost,
            "beam_generations": bs.generations if bs else 0,
            "beam_expanded": bs.expanded if bs else 0,
            "dag_cost": self.extraction.dag_cost,
            "tree_cost": self.extraction.tree_cost,
            "predicted_flops": pred.get("flops", 0.0),
            "predicted_bytes": (pred.get("bytes_read", 0.0)
                                + pred.get("bytes_written", 0.0)),
            "predicted_latency_ns": pred.get("latency_ns", 0.0),
            "predicted_bound": pred.get("bound", "n/a"),
            "device_profile": pred.get("profile"),
            "n_temps": s.n_temps,
            "n_loads": s.n_loads,
            "n_stores": s.n_stores,
            "n_fma": s.n_fma,
            "n_ops": s.n_ops,
            "loads_before_compute": s.loads_before_compute,
            "schedule": self.kernel.schedule_mode,
            "schedule_predicted_ns": (
                self.kernel.schedule.predicted_ns
                if self.kernel.schedule is not None else None),
            "cache": self.cache_status,
            "ladder": self.ladder_level,
            "sat_iterations": self.saturation.iterations
            if self.saturation else 0,
            "sat_nodes": self.saturation.n_nodes if self.saturation else 0,
            "sat_stop": self.saturation.stop_reason if self.saturation
            else ("cached" if self.cache_status == "hit" else "disabled"),
            "ssa_ms": self.ssa_wall_s * 1e3,
            "sat_s": self.saturation.wall_s if self.saturation else 0.0,
            "extract_s": self.extraction.wall_s,
            "codegen_ms": self.codegen_wall_s * 1e3,
            "verify": (self.verify_report.summary()
                       if self.verify_report is not None else None),
        }


def predict_choice(ssa: SSAResult, choice, roots, n_stores: int,
                   profile=None):
    """Roofline prediction of an extraction choice in the pipeline's
    reporting units: shape/dtype-aware load pricing bound to the SSA
    e-graph, plus the root stores' write traffic (per-store operand info
    when the SSA store count matches codegen's). Shared with
    ``benchmarks/saturation_stats.py`` so beam-vs-hillclimb deltas are
    always computed in these exact units. ``profile`` reports in a
    calibrated device profile's units instead of the analytic ones."""
    store_infos = ssa.store_infos()
    return ssa.egraph.choice_stats(
        choice, roots, n_stores=n_stores,
        store_infos=store_infos if len(store_infos) == n_stores else None,
        cost_model=RooflineCostModel(
            dtype=getattr(ssa.prog, "dtype", "f32"), egraph=ssa.egraph,
            profile=profile))


def _resolve_cache(cfg: SaturatorConfig):
    """The configured SaturationCache, or None (off). ``cache_dir=None``
    consults the REPRO_SAT_CACHE environment variable; ``False`` is the
    resolved "explicitly off" form (``SaturatorConfig.from_env`` with
    ``--no-cache``) and never falls back to the environment."""
    cdir = cfg.cache_dir
    if cdir is False:
        return None
    if cdir is None:
        cdir = os.environ.get(CACHE_ENV_VAR) or None
        if cdir is None:
            return None
    from repro.cache import SaturationCache
    if isinstance(cdir, SaturationCache):
        return cdir
    return SaturationCache(cdir)


def _schedule_cm(cfg: SaturatorConfig, prog, eg):
    """The schedule-pricing model the generator would use (None for flat
    models — compute_schedule then defaults to the analytic roofline)."""
    cm = cfg.make_schedule_cost_model(prog)
    if not hasattr(cm, "latency"):
        return None
    if hasattr(cm, "bind_egraph"):
        cm.bind_egraph(eg)
    return cm


def _maybe_verify(sk: SaturatedKernel) -> SaturatedKernel:
    """Run the static verifier when configured ("off" = no work at all,
    keeping the cache warm-hit path overhead-free)."""
    if sk.config.verify != "off":
        chaos.maybe_raise("verify_error", sk.ssa.prog.name
                          if sk.ssa is not None else None)
        from repro.verify import verify_saturated
        sk.verify_report = verify_saturated(sk)
    return sk


def _replay_cached(prog, cfg: SaturatorConfig, ssa: SSAResult,
                   ssa_wall: float, entry: Dict[str, Any], extra_fns
                   ) -> Optional[SaturatedKernel]:
    """Exact-hit path: graft the cached choice into the *unsaturated*
    SSA e-graph, replay the cached statement order, and re-emit. Skips
    run_rules, the beam, and the schedule search entirely. Returns None
    (caller goes cold) when the entry doesn't validate."""
    from repro.cache import CacheInvalid, graft_choice, orders_from_doc
    from repro.cache.serialize import index_to_cid
    try:
        t0 = time.perf_counter()
        choice, roots = graft_choice(ssa.egraph, entry["choice"],
                                     ssa.roots())
        sched = None
        sched_doc = entry.get("schedule")
        if sched_doc is not None:
            node_cids = index_to_cid(ssa.egraph, entry["choice"])
            fixed = orders_from_doc(sched_doc, node_cids)
            try:
                sched = compute_schedule(
                    ssa, dict(choice), mode=cfg.schedule_mode,
                    cost_model=_schedule_cm(cfg, prog, ssa.egraph),
                    fixed_orders=fixed)
            except ValueError as e:
                raise CacheInvalid(f"cached order rejected: {e}") from e
            by = sched_doc.get("predicted_by_mode") or {}
            sched.predicted_by_mode.update(
                {k: float(v) for k, v in by.items()})
        elif cfg.schedule_mode == "cost":
            # without a persisted order the cost search would have to
            # re-run — that's a miss, not a hit
            raise CacheInvalid("entry lacks schedule orders")
        extract_wall = time.perf_counter() - t0
        extraction = ExtractionResult(
            choice=choice, roots=roots,
            dag_cost=float(entry.get("dag_cost") or 0.0),
            tree_cost=float(entry.get("tree_cost") or 0.0),
            wall_s=extract_wall, search="cache")
        t1 = time.perf_counter()
        gen = JaxCodeGenerator(
            ssa, extraction, bulk=cfg.use_bulk, extra_fns=extra_fns,
            reuse_temps=cfg.use_cse,
            schedule=sched if sched is not None else cfg.schedule,
            sched_cost_model=cfg.make_schedule_cost_model(prog)
            ).generate()
        codegen_wall = time.perf_counter() - t1
    except CacheInvalid as e:
        telemetry().record_invalid(prog.name, str(e))
        return None
    predicted = predict_choice(ssa, extraction.choice, extraction.roots,
                               gen.stats.n_stores,
                               profile=cfg.device_profile
                               if cfg.cost_model == "roofline" else None)
    if predicted is not None:
        extraction.predicted = predicted
    return _maybe_verify(SaturatedKernel(
        kernel=gen, ssa=ssa, extraction=extraction,
        saturation=None, config=cfg,
        ssa_wall_s=ssa_wall, codegen_wall_s=codegen_wall,
        cache_status="hit"))


def _store_entry(cache, key, cfg: SaturatorConfig, prog,
                 sk: SaturatedKernel):
    """Persist a cold/warm result (best-effort: never raises)."""
    from repro.cache import (CacheInvalid, choice_to_doc, make_entry,
                             schedule_to_doc)
    try:
        eg = sk.ssa.egraph
        choice_doc, index_of = choice_to_doc(
            eg, sk.extraction.choice, sk.extraction.roots)
        sr = sk.kernel.schedule
        if sr is None:
            # non-cost modes keep the legacy emitters; the named order
            # is reconstructed searchlessly (move_budget=0) so the hit
            # path can replay it explicitly, bit-identically
            sr = compute_schedule(
                sk.ssa, dict(sk.extraction.choice),
                mode=cfg.schedule_mode,
                cost_model=_schedule_cm(cfg, prog, eg), move_budget=0)
        sched_doc = schedule_to_doc(sr, eg, index_of)
        entry = make_entry(
            key, choice_doc=choice_doc, schedule_doc=sched_doc,
            predicted=sk.extraction.predicted,
            dag_cost=sk.extraction.dag_cost, report=sk.report())
        entry["tree_cost"] = sk.extraction.tree_cost
        cache.put(key, entry)
    except (CacheInvalid, ValueError, OSError) as e:
        telemetry().record_invalid(prog.name, f"store failed: {e}")


def _saturate_attempt(prog: KernelProgram, cfg: SaturatorConfig,
                      extra_fns: Optional[Dict[str, Callable]] = None
                      ) -> SaturatedKernel:
    """One un-guarded build of the configured pipeline (the pre-PR-10
    ``saturate_program`` body). May raise; the ladder wrapper catches."""
    cache = _resolve_cache(cfg)
    t_begin = time.perf_counter()
    ssa = build_ssa(prog)
    ssa_wall = time.perf_counter() - t_begin

    key = entry = None
    status = "off"
    if cache is not None:
        from repro.cache import cache_key_for
        key = cache_key_for(prog, cfg)
        entry, status = cache.lookup(key)
        if status == "warm" and not cfg.cache_warm_start:
            entry, status = None, "miss"
        if status == "hit":
            sk = _replay_cached(prog, cfg, ssa, ssa_wall, entry, extra_fns)
            if sk is not None:
                telemetry().record_cache(
                    "hit", prog.name, time.perf_counter() - t_begin)
                return sk
            # invalid exact entry (already counted): rebuild cold on a
            # fresh e-graph — the failed graft may have dirtied this one
            entry, status = None, "miss"
            ssa = build_ssa(prog)

    sat_report = None
    if cfg.use_sat:
        sat_report = run_rules(ssa.egraph, cfg.rules(),
                               iter_limit=cfg.iter_limit,
                               node_limit=cfg.node_limit,
                               time_limit_s=cfg.time_limit_s)
    roots = ssa.roots()
    cm = cfg.make_cost_model(prog)
    seed_choices = None
    seed_order_keys = None
    if entry is not None and status == "warm":
        # near miss (same kernel/rules/config, other shapes): graft the
        # cached choice into the saturated graph as a beam seed and keep
        # its statement order as a schedule-search seed
        from repro.cache import CacheInvalid, graft_choice, orders_from_doc
        from repro.cache.serialize import index_to_cid
        try:
            wchoice, _ = graft_choice(ssa.egraph, entry["choice"], roots)
            seed_choices = [wchoice]
            if entry.get("schedule") is not None:
                node_cids = index_to_cid(ssa.egraph, entry["choice"])
                seed_order_keys = orders_from_doc(entry["schedule"],
                                                  node_cids)
        except CacheInvalid as e:
            telemetry().record_invalid(prog.name, str(e))
            status = "miss"
            seed_choices = seed_order_keys = None
            # the failed graft may have mutated the saturated e-graph
            # (grafted nodes, possibly root unions) before validation
            # tripped — rebuild and re-saturate so the cold search never
            # runs on a graph a bad entry touched (mirrors the exact-hit
            # fallback's fresh build_ssa)
            ssa = build_ssa(prog)
            if cfg.use_sat:
                sat_report = run_rules(ssa.egraph, cfg.rules(),
                                       iter_limit=cfg.iter_limit,
                                       node_limit=cfg.node_limit,
                                       time_limit_s=cfg.time_limit_s)
            roots = ssa.roots()
    extraction = extract_dag(
        ssa.egraph, tuple(roots) if roots else (),
        cost_model=cm,
        time_limit_s=cfg.extract_time_limit_s,
        local_search=cfg.local_search and cfg.use_cse,
        search=cfg.search, beam_width=cfg.beam_width,
        beam_expansions=cfg.beam_expansions,
        hillclimb_evals=cfg.hillclimb_evals,
        coordinated=cfg.beam_coordinated,
        seed_choices=seed_choices)
    t1 = time.perf_counter()
    # the cost scheduler prices statement orders with the same (possibly
    # calibrated) model extraction minimized — one objective end to end
    sched_arg: Any = cfg.schedule
    if cfg.schedule_mode == "cost" and seed_order_keys is not None:
        try:
            sched_arg = compute_schedule(
                ssa, dict(extraction.choice), mode="cost",
                cost_model=_schedule_cm(cfg, prog, ssa.egraph),
                seed_orders=seed_order_keys)
        except ValueError:
            sched_arg = cfg.schedule
    gen = JaxCodeGenerator(ssa, extraction, bulk=cfg.use_bulk,
                           extra_fns=extra_fns,
                           reuse_temps=cfg.use_cse,
                           schedule=sched_arg,
                           sched_cost_model=cfg.make_schedule_cost_model(prog)
                           ).generate()
    codegen_wall = time.perf_counter() - t1
    # Roofline prediction of the chosen term including root-store write
    # traffic (known only post-codegen), regardless of which cost model
    # drove extraction — ablations compare in the same units. Stores are
    # priced per target operand (shape after indexing, declared dtype).
    # A configured device profile reports in its calibrated units.
    predicted = predict_choice(ssa, extraction.choice, extraction.roots,
                               gen.stats.n_stores,
                               profile=cfg.device_profile
                               if cfg.cost_model == "roofline" else None)
    if predicted is not None:
        extraction.predicted = predicted
    sk = SaturatedKernel(kernel=gen, ssa=ssa, extraction=extraction,
                         saturation=sat_report, config=cfg,
                         ssa_wall_s=ssa_wall, codegen_wall_s=codegen_wall,
                         cache_status=status)
    if cache is not None and key is not None:
        telemetry().record_cache("warm" if status == "warm" else "miss",
                                 prog.name,
                                 time.perf_counter() - t_begin)
        _store_entry(cache, key, cfg, prog, sk)
    return _maybe_verify(sk)


def _cheap_config(cfg: SaturatorConfig) -> SaturatorConfig:
    """The ladder's "cheap" rung: beam width 1 with tiny deterministic
    budgets, the mode's legacy emission with *no* schedule search
    (``schedule=None`` — the effective bulk order for accsat), verify
    off, cache off, default emitter. Same mode/rules, so semantics are
    unchanged; only search effort and optional machinery drop away."""
    return SaturatorConfig(
        mode=cfg.mode, cost_model=cfg.cost_model,
        extended_rules=cfg.extended_rules, tpu_rules=cfg.tpu_rules,
        search_cfg=dataclasses.replace(
            cfg.search_cfg, search="beam", beam_width=1,
            beam_coordinated=False, local_search=False,
            beam_expansions=min(cfg.beam_expansions, 2_000),
            hillclimb_evals=min(cfg.hillclimb_evals, 2_000)),
        schedule_cfg=ScheduleConfig(),
        cache_cfg=CacheConfig(cache_dir=False),
        verify_cfg=VerifyConfig(verify="off"),
        guard_cfg=dataclasses.replace(cfg.guard_cfg, ladder=False))


def _reference_kernel(prog: KernelProgram, cfg: SaturatorConfig,
                      extra_fns: Optional[Dict[str, Callable]] = None
                      ) -> SaturatedKernel:
    """The ladder's floor: a SaturatedKernel whose callable is the
    reference interpreter (``core/reference.py``) wrapped in the
    generated-kernel calling convention (all declared arrays in order,
    then scalars; returns the out/inout tuple, cast to each out
    buffer's dtype). Eager numpy — not jit-traceable; inside traced
    code the kernels layer falls back to the jnp oracles in
    ``kernels/ref.py`` instead (see ``repro.kernels.ops``)."""
    import numpy as np

    from .reference import run_reference
    t0 = time.perf_counter()
    names = list(prog.arrays)
    scalar_names = list(prog.scalars)
    out_names = [a.name for a in prog.arrays.values()
                 if a.role in ("out", "inout")]
    calls = dict(extra_fns or {})

    def ref_fn(*args):
        arrays = {n: np.asarray(a) for n, a in zip(names, args)}
        inputs: Dict[str, Any] = dict(arrays)
        inputs.update(zip(scalar_names, args[len(names):]))
        out = run_reference(prog, inputs, calls=calls)
        return tuple(np.asarray(out[n], dtype=arrays[n].dtype)
                     for n in out_names)

    gen = GeneratedKernel(
        name=prog.name, source=f"# reference-interpreter fallback for "
        f"{prog.name!r} (degradation-ladder floor)\n",
        fn=ref_fn, in_arrays=names, scalars=scalar_names,
        out_arrays=out_names, stats=GenStats(), bulk=False,
        schedule_mode="source", schedule=None)
    try:
        ssa = build_ssa(prog)
    except Exception:   # even SSA may be the failing stage
        ssa = None
    extraction = ExtractionResult(choice={}, roots=(), dag_cost=0.0,
                                  tree_cost=0.0, search="reference")
    return SaturatedKernel(
        kernel=gen, ssa=ssa, extraction=extraction, saturation=None,
        config=cfg, codegen_wall_s=time.perf_counter() - t0,
        cache_status="off", ladder_level="ref")


def _breaker_key(prog: KernelProgram, cfg: SaturatorConfig):
    """Cheap stable key: same kernel under a meaningfully different
    configuration fails (and cools down) independently."""
    return (prog.name, cfg.mode, cfg.cost_model, cfg.schedule_mode,
            cfg.emitter, cfg.tpu_rules, cfg.extended_rules)


def saturate_program(prog: KernelProgram,
                     config: Optional[SaturatorConfig] = None,
                     extra_fns: Optional[Dict[str, Callable]] = None
                     ) -> SaturatedKernel:
    """Guarded front door: the full configured build under a
    :class:`repro.runtime.guard.SaturationGuard`, degrading down the
    ladder (hit/warm/cold -> cheap -> ref) instead of raising, with a
    per-(kernel, config) circuit breaker skipping the full path after
    repeated failures. ``guard_cfg.ladder=False`` restores the raw
    single-attempt behavior (the ladder uses it internally)."""
    cfg = config or SaturatorConfig()
    gcfg = cfg.guard_cfg
    with chaos.plan_scope(gcfg.chaos):
        if not gcfg.ladder:
            return _saturate_attempt(prog, cfg, extra_fns)
        breaker = breaker_for(_breaker_key(prog, cfg),
                              threshold=gcfg.breaker_threshold,
                              cooldown=gcfg.breaker_cooldown)
        level, sk = run_ladder(
            prog.name,
            [("full", lambda: _saturate_attempt(prog, cfg, extra_fns)),
             ("cheap", lambda: _saturate_attempt(
                 prog, _cheap_config(cfg), extra_fns)),
             ("ref", lambda: _reference_kernel(prog, cfg, extra_fns))],
            cfg=gcfg, breaker=breaker)
        if level == "full":
            level = sk.cache_status if sk.cache_status in ("hit", "warm") \
                else "cold"
        sk.ladder_level = level
        telemetry().record_ladder(prog.name, level)
        return sk


def saturate_all_modes(prog: KernelProgram, base: Optional[SaturatorConfig]
                       = None, extra_fns=None) -> Dict[str, SaturatedKernel]:
    """All four paper configurations + baseline, for ablation benchmarks."""
    base = base or SaturatorConfig()
    out = {}
    for mode in MODES:
        cfg = dataclasses.replace(base, mode=mode)
        out[mode] = saturate_program(prog, cfg, extra_fns=extra_fns)
    return out
