# The paper's primary contribution: equality saturation for directive-style
# parallel code, adapted to JAX/TPU (see DESIGN.md).
from repro.analysis import (LatencyModel, OpStats, RooflineCostModel,
                            node_stats)
from .cost import CostModel, TPUCostModel, count_flops, count_ops, instruction_mix
from .dsl import (ArrayHandle, Expr, KernelProgram, c, call, exp, fma,
                  gelu_tanh, log, maximum, minimum, recip, rmax, rmean,
                  rothalf, rsqrt, rsum, select, sigmoid, silu, softplus,
                  sqrt, square, tanh, toint, v)
from .beam import BeamStats, beam_search
from .egraph import EGraph, P, Pattern, PatVar, V, add_expr
from .extract import (ExtractionResult, extract_dag, extract_exact,
                      optimality_gap)
from .emit import EMITTER_NAMES, Emitter, EmitterInfo, get_emitter
from .ir import ENode
from .jaxpr_bridge import BridgeUnsupported, maybe_saturate, saturate_jax_fn
from .pallasgen import (PallasGenerator,  # deprecated-ok (re-export)
                        PipelinedPallasGenerator, SyncPallasGenerator,
                        TileOp, make_tile_op, pick_row_block)
from .pipeline import (CACHE_ENV_VAR, MODES, VERIFY_ENV_VAR, CacheConfig,
                       SaturatedKernel, SaturatorConfig, ScheduleConfig,
                       SearchConfig, VerifyConfig, saturate_all_modes,
                       saturate_program)
from .reference import run_reference
from .rules import (EXTENDED_RULES, PAPER_RULES, TPU_RULES, Rule, run_rules)
from .schedule import (SCHEDULE_MODES, ScheduleResult, compute_schedule,
                       is_legal_order, random_topological_order)
from .ssa import SSAResult, build_ssa
from .telemetry import SaturationTelemetry, reset_telemetry, telemetry

__all__ = [
    "CACHE_ENV_VAR", "SaturationTelemetry", "reset_telemetry", "telemetry",
    "LatencyModel", "OpStats", "RooflineCostModel", "node_stats",
    "CostModel", "TPUCostModel", "count_flops", "count_ops",
    "instruction_mix", "ArrayHandle", "Expr", "KernelProgram", "EGraph",
    "ENode", "ExtractionResult", "extract_dag", "extract_exact",
    "BeamStats", "beam_search", "optimality_gap",
    "BridgeUnsupported", "maybe_saturate", "saturate_jax_fn",
    "EMITTER_NAMES", "Emitter", "EmitterInfo", "get_emitter",
    "PallasGenerator", "SyncPallasGenerator", "PipelinedPallasGenerator",
    "TileOp", "make_tile_op", "pick_row_block", "MODES", "VERIFY_ENV_VAR",
    "SearchConfig", "ScheduleConfig", "CacheConfig", "VerifyConfig",
    "SaturatedKernel", "SaturatorConfig", "saturate_all_modes",
    "saturate_program", "run_reference", "PAPER_RULES", "EXTENDED_RULES",
    "TPU_RULES", "Rule", "run_rules", "build_ssa", "SSAResult",
    "add_expr", "P", "V", "Pattern", "PatVar", "toint",
    "SCHEDULE_MODES", "ScheduleResult", "compute_schedule",
    "is_legal_order", "random_topological_order",
]
