"""Beam-search extraction over per-class node choices (ILP stand-in, v2).

The paper solves CSE-aware extraction as a global optimization (ILP/CBC);
PR 2 approximated it with first-improvement hill climbing, which stalls
on plateaus of the non-additive roofline objective — exactly where "one
more load but one fewer pass" trades sit. This module keeps a *beam* of
the ``width`` best complete selections per generation instead of a single
incumbent:

* every state is a full, acyclic choice map, scored with the true DAG
  objective (shared e-classes counted once; non-additive models are
  exact, never surrogated);
* a generation proposes every single-class node swap of every state over
  that state's live (root-reachable) classes;
* survivors are the ``width`` best *distinct* states — equal-cost
  siblings are retained, which is what lets the beam walk plateaus that
  first-improvement hill climbing cannot cross.

Scoring runs through :class:`Evaluator`, which precomputes each e-node's
canonical children and hardware-statistics tuple once and then walks a
candidate selection with plain dict/int operations — no per-trial
allocation beyond the DFS bookkeeping. Trials mutate the state in place
and revert, so a swap costs one DFS, not a dict copy. ``max_expansions``
bounds the number of scored swaps, which makes a run deterministic and
machine-independent whenever the wall-clock deadline does not bind (the
benchmark-regression CI gate relies on this).

The search is monotone — the best state only ever improves — so seeding
the beam with the tree fixed point and the flat-model restart guarantees
the result is never worse than its seeds.
:func:`repro.core.extract.extract_dag` runs this as the main search and
demotes the old hill climb to a polish pass on the winner.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.runtime.guard import guard_tick

from .egraph import EGraph
from .ir import ENode

INF = float("inf")


class Evaluator:
    """Fast DAG-cost evaluation of choice maps against one cost model.

    Supports both model families of :mod:`repro.core.extract`:

    * roofline-style models (``node_stats`` + ``latency``): the cost of a
      selection is the predicted latency of the *summed* statistics of
      the chosen nodes;
    * flat models (``node_cost`` only): the cost is the per-node weight
      sum.

    Results match :func:`repro.core.extract.dag_cost_of` (asserted by
    ``tests/test_beam_extraction.py``); this class exists because the
    generic path allocates an OpStats per node per trial, which dominates
    beam-search wall time on saturated kernels.
    """

    def __init__(self, eg: EGraph, cm):
        self.eg = eg
        self.cm = cm
        self._children: Dict[ENode, Tuple[int, ...]] = {}
        self._cands: Dict[int, List[ENode]] = {}
        self.roofline = (hasattr(cm, "node_stats")
                         and hasattr(cm, "latency"))
        # duck-typed aggregate models without the roofline internals:
        # collect the node multiset and defer to their aggregate_cost
        self.generic = (not self.roofline
                        and getattr(cm, "aggregate_cost", None) is not None)
        if self.roofline:
            lat = cm.latency
            self._tile = float(lat.tile_elems)
            self._vpu = float(lat.chip.vpu_elems_per_s)
            self._mxu_peak = float(lat.mxu_peak_flops())
            # calibrated models (LatencyModel.from_profile) carry an HBM
            # efficiency factor, per-bound overlap slack, and a constant
            # launch overhead; the defaults reduce to the analytic model
            self._hbm = (float(lat.chip.hbm_bw)
                         * float(getattr(lat, "hbm_efficiency", 1.0)))
            self._slack_c = float(getattr(lat, "slack_compute",
                                          lat.overlap_slack))
            self._slack_m = float(getattr(lat, "slack_memory",
                                          lat.overlap_slack))
            self._base = float(getattr(lat, "base_ns", 0.0))
            # schedule-aware profiles: the downstream scheduler hides up
            # to eff × compute of the memory axis (best-schedule bound);
            # None keeps the PR-4 formula — mirrors LatencyModel.latency_ns
            self._overlap_eff = getattr(lat, "overlap_efficiency", None)
            self._stats: Dict[ENode, Tuple[float, float, float]] = {}
        else:
            self._weights: Dict[ENode, float] = {}

    # -- per-node caches ------------------------------------------------------
    def children_of(self, node: ENode) -> Tuple[int, ...]:
        ch = self._children.get(node)
        if ch is None:
            find = self.eg.find
            ch = tuple(find(c) for c in node.children)
            self._children[node] = ch
        return ch

    def candidates(self, cid: int) -> List[ENode]:
        """Canonical nodes of a class in a stable, deterministic order."""
        lst = self._cands.get(cid)
        if lst is None:
            ec = self.eg.classes.get(self.eg.find(cid))
            lst = sorted((self.eg.canonicalize(n) for n in ec.nodes),
                         key=repr) if ec is not None else []
            self._cands[cid] = lst
        return lst

    def _stats_of(self, node: ENode) -> Tuple[float, float, float]:
        t = self._stats.get(node)
        if t is None:
            st = self.cm.node_stats(node)
            t = (st.vpu_passes, st.mxu_flops,
                 st.bytes_read + st.bytes_written)
            self._stats[node] = t
        return t

    def _weight_of(self, node: ENode) -> float:
        w = self._weights.get(node)
        if w is None:
            w = float(self.cm.node_cost(node))
            self._weights[node] = w
        return w

    # -- DAG cost of a selection ----------------------------------------------
    def cost(self, get: Callable[[int], Optional[ENode]],
             roots: Sequence[int]) -> float:
        """Objective of the selection ``get`` over ``roots`` (inf on a
        cyclic or incomplete selection). ``get`` maps a canonical class
        id to its chosen node (e.g. ``choice.get``)."""
        passes = mxu = nbytes = weight = 0.0
        roofline = self.roofline
        nodes: Optional[List[ENode]] = [] if self.generic else None
        state: Dict[int, int] = {}  # 0 = on stack, 1 = done
        stack: List[Tuple[int, bool]] = [(r, False) for r in roots]
        while stack:
            cid, processed = stack.pop()
            if processed:
                state[cid] = 1
                continue
            st = state.get(cid)
            if st == 1:
                continue
            if st == 0:
                return INF  # cycle
            node = get(cid)
            if node is None:
                return INF  # incomplete
            state[cid] = 0
            stack.append((cid, True))
            if roofline:
                p, m, b = self._stats_of(node)
                passes += p
                mxu += m
                nbytes += b
            elif nodes is not None:
                nodes.append(node)
            else:
                weight += self._weight_of(node)
            for ch in self.children_of(node):
                st_ch = state.get(ch)
                if st_ch is None:
                    stack.append((ch, False))
                elif st_ch == 0:
                    return INF
        if nodes is not None:
            return self.cm.aggregate_cost(nodes)
        if not roofline:
            return weight
        compute = (passes * self._tile / self._vpu
                   + mxu / self._mxu_peak) * 1e9
        memory = nbytes / self._hbm * 1e9
        if self._overlap_eff is not None:
            memory -= min(memory, self._overlap_eff * compute)
        if compute >= memory:
            return self._base + compute + self._slack_c * memory
        return self._base + memory + self._slack_m * compute


class EvalBudget:
    """Deterministic evaluation budget shared across search passes.

    Wall-clock deadlines make search results depend on machine speed and
    load; every search pass therefore counts objective evaluations
    against one of these and stops when it is spent, so a run is
    reproducible anywhere as long as the (generous) time limit does not
    bind first."""
    __slots__ = ("remaining",)

    def __init__(self, evals: int):
        self.remaining = int(evals)

    def take(self) -> bool:
        """Consume one evaluation; False once the budget is spent."""
        if self.remaining <= 0:
            return False
        self.remaining -= 1
        return True

    @property
    def exhausted(self) -> bool:
        return self.remaining <= 0


@dataclasses.dataclass
class BeamStats:
    """Telemetry of one beam run (reported by the benchmark layer)."""
    width: int = 0
    generations: int = 0
    expanded: int = 0            # candidate swaps scored (all kinds)
    coordinated_expanded: int = 0  # of which: coordinated 2-class moves
    seed_cost: float = INF       # best seed before any search
    best_cost: float = INF       # best complete selection found
    hit_deadline: bool = False
    hit_expansion_cap: bool = False


class _Chain:
    """Two-level choice lookup (state overrides a shared baseline)
    without copying either dict."""
    __slots__ = ("a", "b")

    def __init__(self, a: Dict[int, ENode], b: Dict[int, ENode]):
        self.a, self.b = a, b

    def get(self, cid, default=None):
        n = self.a.get(cid)
        return n if n is not None else self.b.get(cid, default)


def _live_state(eg: EGraph, choice, roots: Sequence[int]
                ) -> Optional[Dict[int, ENode]]:
    """Project ``choice`` onto its root-reachable classes (None if a live
    class has no binding)."""
    from .extract import reachable
    state: Dict[int, ENode] = {}
    get = choice.get
    for cid in reachable(eg, choice, roots):
        node = get(cid)
        if node is None:
            return None
        state[cid] = node
    return state


def beam_search(eg: EGraph, cm, seeds: Sequence[Dict[int, ENode]],
                roots: Sequence[int], *, width: int = 8,
                deadline: Optional[float] = None,
                patience: int = 2,
                max_generations: int = 64,
                max_expansions: int = 200_000,
                coordinated: bool = True,
                evaluator: Optional[Evaluator] = None,
                stats: Optional[BeamStats] = None
                ) -> Tuple[Dict[int, ENode], float]:
    """Beam search over per-class node choices against ``cm``'s objective.

    ``seeds`` are complete selections (cyclic/incomplete ones are scored
    inf and dropped); the first seed doubles as the fallback binding for
    classes a swap newly reaches. Returns the best ``(choice, cost)``
    found — possibly a seed itself. Stops at ``max_expansions`` scored
    swaps (the deterministic budget), at the wall-clock ``deadline``
    (the safety net), after ``patience`` generations without strict
    improvement, or when a generation yields no unseen states.

    ``coordinated`` additionally proposes **2-class moves**: for every
    edge (class, chosen child) of a state's DAG, every pair of
    alternative nodes for the two classes is scored as one move. A
    non-additive objective (the roofline ``max``) has plateaus where a
    load and its consumer must change *together* — either single swap
    is strictly worse, so no 1-swap beam state survives to bridge them;
    the coordinated neighborhood crosses in one step.
    """
    if width < 1:
        raise ValueError(f"beam width must be >= 1, got {width}")
    ev = evaluator if evaluator is not None else Evaluator(eg, cm)
    roots = tuple(eg.find(r) for r in roots)
    st = stats if stats is not None else BeamStats()
    st.width = width

    base: Dict[int, ENode] = dict(seeds[0]) if seeds else {}
    base_get = base.get
    beam: List[Tuple[float, Dict[int, ENode]]] = []
    seen: set = set()
    for seed in seeds:
        state = _live_state(eg, seed, roots)
        if state is None:
            continue
        cost = ev.cost(seed.get, roots)
        if cost == INF:
            continue
        sig = frozenset(state.items())
        if sig in seen:
            continue
        seen.add(sig)
        beam.append((cost, state))
    if not beam:
        return {}, INF
    beam.sort(key=lambda s: s[0])
    beam = beam[:width]
    best_cost, best_choice = beam[0][0], dict(beam[0][1])
    st.seed_cost = st.best_cost = best_cost

    def out_of_budget() -> bool:
        # guard hook: one deterministic tick per budget check, so a
        # runaway extraction trips the ambient SaturationGuard's
        # eval_budget even if max_expansions is misconfigured
        guard_tick("beam")
        if st.expanded >= max_expansions:
            st.hit_expansion_cap = True
            return True
        if deadline is not None and time.perf_counter() >= deadline:
            st.hit_deadline = True
            return True
        return False

    stale = 0
    for _ in range(max_generations):
        if out_of_budget():
            break
        frontier: List[Tuple[float, Dict[int, ENode]]] = []
        # prune bar: no point keeping states worse than the width-th best
        bar = beam[-1][0] if len(beam) >= width else INF
        stop = False
        for _, state in beam:
            # trials mutate `state` in place and revert; classes newly
            # reached by a swap fall back to the seed baseline
            def get(cid, _s=state, _b=base_get):
                n = _s.get(cid)
                return n if n is not None else _b(cid)

            def trial(_s=state, _g=get):
                """Score the mutated state; keep it if it clears the
                frontier bar and is unseen. Caller reverts."""
                nonlocal frontier, bar
                cost = ev.cost(_g, roots)
                st.expanded += 1
                # once the frontier holds a full beam of plateau
                # states, only strictly better candidates may enter —
                # keeps plateau churn (and the seen-set) bounded
                full = len(frontier) >= 2 * width
                if cost == INF or cost > bar + 1e-9 \
                        or (full and cost >= bar - 1e-9):
                    return
                tstate = _live_state(eg, _Chain(_s, base), roots)
                if tstate is None:
                    return
                sig = frozenset(tstate.items())
                if sig in seen:
                    return
                seen.add(sig)
                frontier.append((cost, tstate))
                if len(frontier) >= 4 * width:
                    frontier.sort(key=lambda s: s[0])
                    frontier = frontier[:2 * width]
                    bar = min(bar, frontier[-1][0])

            for cid in sorted(state):
                cands = ev.candidates(cid)
                if len(cands) <= 1:
                    continue
                current = state[cid]
                for cand in cands:
                    if cand == current:
                        continue
                    state[cid] = cand
                    trial()
                    state[cid] = current
                if out_of_budget():
                    stop = True
                    break
            if not stop and coordinated:
                # 2-class neighborhood: a chosen-DAG edge's two classes
                # move together (only both-change pairs — single swaps
                # were already scored above)
                for cid in sorted(state):
                    cur_p = state[cid]
                    for ch in ev.children_of(cur_p):
                        if ch == cid or ch not in state:
                            continue
                        p_cands = ev.candidates(cid)
                        c_cands = ev.candidates(ch)
                        if len(p_cands) <= 1 or len(c_cands) <= 1:
                            continue
                        cur_c = state[ch]
                        for np_ in p_cands:
                            if np_ == cur_p:
                                continue
                            for nc in c_cands:
                                if nc == cur_c:
                                    continue
                                state[cid], state[ch] = np_, nc
                                trial()
                                st.coordinated_expanded += 1
                                state[cid], state[ch] = cur_p, cur_c
                        if out_of_budget():
                            stop = True
                            break
                    if stop:
                        break
            if stop:
                break
        if not frontier:
            break
        st.generations += 1
        # survivors: width best distinct states across old beam + frontier
        merged = beam + frontier
        merged.sort(key=lambda s: s[0])
        beam = merged[:width]
        if beam[0][0] < best_cost - 1e-9:
            best_cost, best_choice = beam[0][0], dict(beam[0][1])
            stale = 0
        else:
            stale += 1
            if stale >= patience:
                break
        if stop:
            break
    st.best_cost = best_cost
    # re-complete the winner against the fallback so downstream consumers
    # (codegen walks children through the choice map) see every class
    out = dict(base)
    out.update(best_choice)
    return out, best_cost
