"""E-graph with union-find, hash-consing, congruence closure and e-matching.

Follows the egg design [Willsey et al., POPL'21] the paper builds on
(§II-D): deferred rebuilding, a constant-folding e-class analysis, and
batched rule application with node/iteration/time limits (§VII uses
10 000 e-nodes, 10 iterations, 10 s saturation).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.opstats import ArrayInfo
from repro.runtime.guard import guard_tick

from .ir import ENode, try_const_eval


class UnionFind:
    __slots__ = ("parent", "rank")

    def __init__(self):
        self.parent: List[int] = []
        self.rank: List[int] = []

    def make(self) -> int:
        self.parent.append(len(self.parent))
        self.rank.append(0)
        return len(self.parent) - 1

    def find(self, x: int) -> int:
        root = x
        p = self.parent
        while p[root] != root:
            root = p[root]
        # path compression
        while p[x] != root:
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1
        return ra


class EClass:
    __slots__ = ("id", "nodes", "parents", "data", "ainfo")

    def __init__(self, cid: int):
        self.id = cid
        self.nodes: Set[ENode] = set()
        # (parent_enode_as_added, parent_class_id)
        self.parents: List[Tuple[ENode, int]] = []
        self.data: Any = None  # analysis value: folded constant or None
        # array-operand analysis: the (shape, dtype) this class denotes
        # when realized as an array symbol or load (None = not a memory
        # operand / unknown). Priced by the roofline cost model.
        self.ainfo: Optional[ArrayInfo] = None


class EGraph:
    """E-graph over :class:`repro.core.ir.ENode` terms."""

    def __init__(self, enable_const_fold: bool = True):
        self.uf = UnionFind()
        self.classes: Dict[int, EClass] = {}
        self.hashcons: Dict[ENode, int] = {}
        self.pending: List[int] = []  # classes whose parents need re-canon
        self.enable_const_fold = enable_const_fold
        self.n_unions = 0
        # SSA array table: base array name -> declared (shape, dtype).
        # Version symbols ("f@2", "f@L0") all resolve through their base
        # name, so every load of any version prices the same operand.
        self.array_info: Dict[str, ArrayInfo] = {}
        # bumped on every (re)declaration so bound cost models can tell
        # their cached load prices are stale (RooflineCostModel checks
        # this on bind_egraph; extract_dag rebinds per extraction)
        self.ainfo_version = 0

    def set_array_info(self, name: str, info: ArrayInfo) -> None:
        """Register an array declaration; re-derives (and overwrites) the
        analysis for any already-added symbol/load classes of that
        array, so late or corrected declarations take effect. Cost
        models bound to this graph pick the change up on their next
        ``bind_egraph`` (which every ``extract_dag`` call performs)."""
        self.array_info[name] = info
        self.ainfo_version += 1
        for node, cid in list(self.hashcons.items()):
            if node.op == "array" and self._array_base(node.payload) == name:
                self._analyze_ainfo(cid, node, overwrite=True)
                for pnode, pcid in self.classes[self.find(cid)].parents:
                    self._analyze_ainfo(pcid, self.canonicalize(pnode),
                                        overwrite=True)

    @staticmethod
    def _array_base(version_sym: Any) -> str:
        return str(version_sym).split("@", 1)[0]

    # -- basics ---------------------------------------------------------------
    def find(self, cid: int) -> int:
        return self.uf.find(cid)

    def canonicalize(self, node: ENode) -> ENode:
        return node.map_children(self.uf.find)

    def num_classes(self) -> int:
        return len({self.find(c) for c in self.classes})

    def num_nodes(self) -> int:
        return len(self.hashcons)

    # -- insertion ------------------------------------------------------------
    def add(self, node: ENode) -> int:
        node = self.canonicalize(node)
        existing = self.hashcons.get(node)
        if existing is not None:
            return self.find(existing)
        cid = self.uf.make()
        ec = EClass(cid)
        ec.nodes.add(node)
        self.classes[cid] = ec
        self.hashcons[node] = cid
        for ch in set(node.children):
            self.classes[self.find(ch)].parents.append((node, cid))
        self._analyze_node(cid, node)
        self._analyze_ainfo(cid, node)
        return cid

    def add_term(self, op: str, children: Iterable[int] = (),
                 payload: Any = None) -> int:
        return self.add(ENode(op, tuple(self.find(c) for c in children),
                              payload))

    # -- analysis (constant folding, paper §V-A) -------------------------------
    def _analyze_node(self, cid: int, node: ENode) -> None:
        if not self.enable_const_fold:
            return
        child_vals = tuple(self.classes[self.find(c)].data
                           for c in node.children)
        val = try_const_eval(node.op, child_vals, node.payload)
        if val is None:
            return
        ec = self.classes[self.find(cid)]
        if ec.data is None:
            ec.data = val
            # materialize the constant so extraction can pick it (cost 0)
            const_id = self.add(ENode("const", (), val))
            self.union(cid, const_id)

    def operand_info(self, info: Optional[ArrayInfo],
                     index_cids) -> Optional[ArrayInfo]:
        """Operand actually moved by an access of ``info`` at
        ``index_cids``.

        A *uniform* index (constant-folded e-class) selects one
        coordinate, shrinking the operand; a varying index (anything
        else, e.g. the thread/grid scalar) addresses a distinct element
        per lane, so the access still moves a full tile — only the
        declared dtype survives. This is what makes broadcast scalars/
        rows cheap without under-pricing per-lane gathers.
        """
        if info is None:
            return None
        index_cids = tuple(index_cids)
        if not index_cids:
            return info
        for c in index_cids:
            ec = self.classes.get(self.find(c))
            if ec is None or ec.data is None:
                return ArrayInfo(shape=None, dtype=info.dtype)
        return info.index(len(index_cids))

    def load_operand_info(self, node: ENode) -> Optional[ArrayInfo]:
        """Operand a ``load`` e-node moves (resolved at query time, so
        constants folded after the load was added are honored)."""
        if node.op != "load" or not node.children:
            return None
        ec = self.classes.get(self.find(node.children[0]))
        info = ec.ainfo if ec is not None else None
        return self.operand_info(info, node.children[1:])

    def _infer_ainfo(self, node: ENode) -> Optional[ArrayInfo]:
        """Array-operand analysis of one e-node (None = not an operand)."""
        if node.op == "array":
            return self.array_info.get(self._array_base(node.payload))
        if node.op == "load":
            return self.load_operand_info(node)
        return None

    def _analyze_ainfo(self, cid: int, node: ENode,
                       overwrite: bool = False) -> None:
        info = self._infer_ainfo(node)
        if info is None:
            return
        ec = self.classes[self.find(cid)]
        if ec.ainfo is None or overwrite:
            ec.ainfo = info

    # -- union + rebuild --------------------------------------------------------
    def union(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        self.n_unions += 1
        root = self.uf.union(ra, rb)
        other = rb if root == ra else ra
        ec_root, ec_other = self.classes[root], self.classes[other]
        ec_root.nodes |= ec_other.nodes
        ec_root.parents.extend(ec_other.parents)
        # analysis merge: constants must agree; propagate if one-sided
        if ec_root.data is None and ec_other.data is not None:
            ec_root.data = ec_other.data
        # array-operand analysis: one-sided propagation; on disagreement
        # keep the root's (classes only merge when semantically equal, so
        # either description of the operand is a valid pricing basis)
        if ec_root.ainfo is None and ec_other.ainfo is not None:
            ec_root.ainfo = ec_other.ainfo
        del self.classes[other]
        self.pending.append(root)
        return root

    def rebuild(self) -> None:
        """Restore congruence: re-canonicalize parents of merged classes."""
        # guard hook (repro.runtime.guard): the node/class ceilings are
        # enforced here too — rebuild is where congruence closure can
        # blow a graph up past what run_rules' per-iteration check saw
        guard_tick("egraph", nodes=self.num_nodes(),
                   classes=self.num_classes())
        while self.pending:
            todo, self.pending = self.pending, []
            seen_roots = set()
            for cid in todo:
                root = self.find(cid)
                if root in seen_roots or root not in self.classes:
                    continue
                seen_roots.add(root)
                self._repair(root)

    def _repair(self, cid: int) -> None:
        ec = self.classes[cid]
        new_parents: Dict[ENode, int] = {}
        for pnode, pcid in ec.parents:
            # stale hashcons entry: remove then re-canonicalize
            self.hashcons.pop(pnode, None)
            canon = self.canonicalize(pnode)
            pcid = self.find(pcid)
            if canon in new_parents:
                # congruence: two parents became identical → union them
                self.union(pcid, new_parents[canon])
                pcid = self.find(pcid)
            prev = self.hashcons.get(canon)
            if prev is not None and self.find(prev) != pcid:
                self.union(prev, pcid)
                pcid = self.find(pcid)
            self.hashcons[canon] = pcid
            new_parents[canon] = pcid
        ec = self.classes[self.find(cid)]
        ec.parents = [(n, self.find(c)) for n, c in new_parents.items()]
        # re-run analysis over nodes of this class (children may have folded)
        if self.enable_const_fold and self.classes[self.find(cid)].data is None:
            for node in list(self.classes[self.find(cid)].nodes):
                self._analyze_node(self.find(cid), self.canonicalize(node))

    # -- invariant checking ------------------------------------------------------
    def check_invariants(self, *, strict: bool = False) -> list:
        """Static invariant audit (repro.verify pass 2): union-find
        structure, hashcons/congruence closure, const-fold and ainfo
        analysis consistency. Returns the findings; with ``strict=True``
        raises AssertionError on any error-severity finding — the form
        tests call after run_rules and after a cache graft."""
        from repro.verify.egraph_check import check_egraph
        findings = check_egraph(self)
        if strict:
            errors = [f for f in findings if f.severity == "error"]
            if errors:
                raise AssertionError(
                    "e-graph invariants violated:\n  " +
                    "\n  ".join(str(f) for f in errors))
        return findings

    # -- iteration ---------------------------------------------------------------
    def eclasses(self) -> Dict[int, EClass]:
        """Canonical (root) classes only."""
        return {cid: ec for cid, ec in self.classes.items()
                if self.find(cid) == cid}

    def nodes_of(self, cid: int) -> List[ENode]:
        return [self.canonicalize(n) for n in self.classes[self.find(cid)].nodes]

    # -- e-matching ----------------------------------------------------------------
    def ematch(self, pattern: "Pattern") -> List[Tuple[int, Dict[str, int]]]:
        """Return (root_class, substitution) pairs for every match."""
        out: List[Tuple[int, Dict[str, int]]] = []
        for cid, ec in list(self.eclasses().items()):
            for node in list(ec.nodes):
                node = self.canonicalize(node)
                for sub in self._match_node(pattern, node):
                    out.append((cid, sub))
        return out

    def _match_node(self, pat: "Pattern", node: ENode) -> List[Dict[str, int]]:
        if pat.op != node.op or len(pat.children) != len(node.children):
            return []
        if pat.payload is not _ANY and pat.payload != node.payload:
            return []
        subs = [dict()]
        for pchild, ccid in zip(pat.children, node.children):
            ccid = self.find(ccid)
            new_subs: List[Dict[str, int]] = []
            for sub in subs:
                new_subs.extend(self._match_class(pchild, ccid, sub))
            subs = new_subs
            if not subs:
                return []
        return subs

    def _match_class(self, pat: "PatTerm", cid: int,
                     sub: Dict[str, int]) -> List[Dict[str, int]]:
        if isinstance(pat, PatVar):
            bound = sub.get(pat.name)
            if bound is not None:
                return [sub] if self.find(bound) == cid else []
            s2 = dict(sub)
            s2[pat.name] = cid
            return [s2]
        out: List[Dict[str, int]] = []
        for node in self.nodes_of(cid):
            for s in self._match_node(pat, node):
                merged = dict(sub)
                ok = True
                for k, v in s.items():
                    if k in merged and self.find(merged[k]) != self.find(v):
                        ok = False
                        break
                    merged[k] = v
                if ok:
                    out.append(merged)
        return out

    # -- pattern instantiation ----------------------------------------------------
    def instantiate(self, pat: "PatTerm", sub: Dict[str, int]) -> int:
        if isinstance(pat, PatVar):
            return self.find(sub[pat.name])
        kids = tuple(self.instantiate(c, sub) for c in pat.children)
        payload = None if pat.payload is _ANY else pat.payload
        return self.add(ENode(pat.op, kids, payload))

    # -- extraction entry (delegates) ----------------------------------------------
    def extract(self, roots, cost_model=None, **kw):
        """Extract minimum-cost terms (roofline-predicted latency unless a
        flat cost model is passed explicitly)."""
        from .extract import extract_dag
        return extract_dag(self, roots, cost_model=cost_model, **kw)

    def choice_stats(self, choice, roots, n_stores: int = 0,
                     store_infos=None, cost_model=None):
        """Roofline statistics (flops/bytes/latency) of an extraction
        choice map — the unified analysis view of a selected term.

        ``n_stores`` adds the root stores' HBM write traffic (constant
        across choices, so reported but never minimized); ``store_infos``
        (one :class:`ArrayInfo` or None per store) prices each store at
        its target's true extent/byte width instead of a full f32 tile.
        ``cost_model`` overrides the default shape/dtype-aware roofline
        model bound to this e-graph.
        """
        from repro.analysis import RooflineCostModel, store_stats
        from .extract import choice_nodes
        if isinstance(roots, int):
            roots = (roots,)
        nodes = choice_nodes(self, choice, roots)
        if nodes is None:
            return None
        cm = cost_model if cost_model is not None \
            else RooflineCostModel(egraph=self)
        stats = cm.choice_stats(nodes) + store_stats(
            n_stores, dtype_bytes=getattr(cm, "dtype_bytes", 4),
            infos=store_infos)
        return cm.latency.report(stats)


# -- patterns -------------------------------------------------------------------
class _Any:
    def __repr__(self):
        return "<any>"


_ANY = _Any()


class PatVar:
    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"?{self.name}"


class Pattern:
    __slots__ = ("op", "children", "payload")

    def __init__(self, op: str, children=(), payload=_ANY):
        self.op = op
        self.children = tuple(children)
        self.payload = payload

    def __repr__(self):
        return f"{self.op}({','.join(map(repr, self.children))})"


PatTerm = Any  # Pattern | PatVar


def P(op: str, *children, payload=_ANY) -> Pattern:
    return Pattern(op, children, payload)


def V(name: str) -> PatVar:
    return PatVar(name)


# -- term <-> egraph helpers ------------------------------------------------------
def add_expr(eg: EGraph, expr) -> int:
    """Add a nested-tuple term: ('add', ('var','x'), ('const', 1.0))."""
    if isinstance(expr, (int, float, bool)):
        return eg.add(ENode("const", (), expr))
    op = expr[0]
    if op in ("var", "array"):
        return eg.add(ENode(op, (), expr[1]))
    if op == "const":
        return eg.add(ENode("const", (), expr[1]))
    payload = None
    rest = expr[1:]
    if op == "call":
        payload, rest = expr[1], expr[2:]
    kids = tuple(add_expr(eg, e) for e in rest)
    return eg.add(ENode(op, kids, payload))


def extract_to_term(node_choice: Dict[int, ENode], eg: EGraph, cid: int):
    """Rebuild nested-tuple term from an extraction choice map."""
    cid = eg.find(cid)
    node = node_choice[cid]
    if node.op in ("var", "array"):
        return (node.op, node.payload)
    if node.op == "const":
        return ("const", node.payload)
    kids = tuple(extract_to_term(node_choice, eg, c) for c in node.children)
    if node.op == "call":
        return ("call", node.payload) + kids
    return (node.op,) + kids
