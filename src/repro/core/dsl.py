"""Kernel DSL — the 'sequential body of a parallel loop' (paper §IV).

A :class:`KernelProgram` is the analogue of the code under an OpenACC
``loop vector`` directive: straight-line assignments, array loads/stores,
``if`` and sequential ``for``, over scalars or whole VMEM tiles.  The
framework's model hot-spots (RMSNorm, SwiGLU, rotary, AdamW, ...) and the
NPB-style benchmark kernels are all written in this DSL, saturated, and
re-emitted as JAX or Pallas code.

Expression building uses operator overloading and returns nested-tuple
terms consumed by :mod:`repro.core.ssa`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union


class Expr:
    """Wrapper over nested-tuple terms with operator overloading."""

    __slots__ = ("t",)

    def __init__(self, t):
        self.t = t if isinstance(t, tuple) else ("const", t)

    # arithmetic ------------------------------------------------------------
    def _bin(self, op, other, rev=False):
        o = other.t if isinstance(other, Expr) else ("const", other)
        return Expr((op, o, self.t) if rev else (op, self.t, o))

    def __add__(self, o):      return self._bin("add", o)
    def __radd__(self, o):     return self._bin("add", o, rev=True)
    def __sub__(self, o):      return self._bin("sub", o)
    def __rsub__(self, o):     return self._bin("sub", o, rev=True)
    def __mul__(self, o):      return self._bin("mul", o)
    def __rmul__(self, o):     return self._bin("mul", o, rev=True)
    def __truediv__(self, o):  return self._bin("div", o)
    def __rtruediv__(self, o): return self._bin("div", o, rev=True)
    def __mod__(self, o):      return self._bin("mod", o)
    def __pow__(self, o):      return self._bin("pow", o)
    def __neg__(self):         return Expr(("neg", self.t))
    # comparisons ------------------------------------------------------------
    def __lt__(self, o):       return self._bin("lt", o)
    def __le__(self, o):       return self._bin("le", o)
    def __gt__(self, o):       return self._bin("gt", o)
    def __ge__(self, o):       return self._bin("ge", o)

    def eq(self, o):           return self._bin("eq", o)
    def ne(self, o):           return self._bin("ne", o)

    def __repr__(self):
        return f"Expr{self.t}"


def _t(x) -> tuple:
    return x.t if isinstance(x, Expr) else ("const", x)


# functional builders ---------------------------------------------------------
def v(name: str) -> Expr:
    return Expr(("var", name))


def c(val) -> Expr:
    return Expr(("const", val))


def exp(x): return Expr(("exp", _t(x)))
def log(x): return Expr(("log", _t(x)))
def sqrt(x): return Expr(("sqrt", _t(x)))
def rsqrt(x): return Expr(("rsqrt", _t(x)))
def tanh(x): return Expr(("tanh", _t(x)))
def sigmoid(x): return Expr(("sigmoid", _t(x)))
def abs_(x): return Expr(("abs", _t(x)))
def floor(x): return Expr(("floor", _t(x)))
def square(x): return Expr(("square", _t(x)))
def recip(x): return Expr(("recip", _t(x)))
def toint(x): return Expr(("toint", _t(x)))
def minimum(a, b): return Expr(("min", _t(a), _t(b)))
def maximum(a, b): return Expr(("max", _t(a), _t(b)))
def select(cond, a, b): return Expr(("select", _t(cond), _t(a), _t(b)))
def fma(a, b, c_): return Expr(("fma", _t(a), _t(b), _t(c_)))
def call(fn: str, *args): return Expr(("call", fn) + tuple(_t(a) for a in args))
# tile reductions (last axis, keepdims) and structural ops — TPU tile DSL
def rsum(x): return Expr(("rsum", _t(x)))
def rmean(x): return Expr(("rmean", _t(x)))
def rmax(x): return Expr(("rmax", _t(x)))
def rothalf(x): return Expr(("rothalf", _t(x)))  # rotate_half for RoPE
# composites used by models (stay as DSL so the saturator sees through them)
def silu(x):
    xe = _t(x)
    return Expr(("mul", xe, ("sigmoid", xe)))
def gelu_tanh(x):
    # 0.5*x*(1+tanh(sqrt(2/pi)*(x+0.044715*x^3)))
    xe = Expr(_t(x))
    inner = c(0.7978845608028654) * (xe + c(0.044715) * xe * xe * xe)
    return c(0.5) * xe * (c(1.0) + tanh(inner))
def softplus(x):
    return log(c(1.0) + exp(x))


# statements -------------------------------------------------------------------
@dataclasses.dataclass
class ArrayRef:
    name: str
    indices: Tuple[tuple, ...]  # index terms; () = whole tile


@dataclasses.dataclass
class Assign:
    target: Union[str, ArrayRef]
    expr: tuple


@dataclasses.dataclass
class If:
    cond: tuple
    then: List[Any]
    orelse: List[Any]


@dataclasses.dataclass
class For:
    var: str
    start: tuple
    stop: tuple
    body: List[Any]


@dataclasses.dataclass
class ArraySpec:
    name: str
    role: str  # 'in' | 'out' | 'inout'
    # Declared operand geometry, consumed by the analysis layer to price
    # HBM traffic at the true extent/byte width (None = unknown: a full
    # f32 tile). A dim may be None for a symbolic/runtime extent.
    shape: Optional[Tuple[Optional[int], ...]] = None
    dtype: str = "f32"


class KernelProgram:
    """Builder for one saturable kernel (body of one parallel region).

    ``dtype`` is the kernel's default element type; per-array ``shape`` /
    ``dtype`` declarations refine it so the extraction cost model prices
    bf16/f8 tiles and broadcast scalars/rows honestly.
    """

    def __init__(self, name: str, dtype: str = "f32"):
        self.name = name
        self.dtype = dtype
        self.arrays: Dict[str, ArraySpec] = {}
        self.scalars: List[str] = []
        self.body: List[Any] = []
        self._stack: List[List[Any]] = [self.body]

    # ---- declarations -----------------------------------------------------
    def _declare(self, name: str, role: str, shape, dtype) -> "ArrayHandle":
        self.arrays[name] = ArraySpec(
            name, role, shape=tuple(shape) if shape is not None else None,
            dtype=dtype or self.dtype)
        return ArrayHandle(self, name)

    def array_in(self, name: str, shape: Optional[Sequence[Optional[int]]]
                 = None, dtype: Optional[str] = None) -> "ArrayHandle":
        return self._declare(name, "in", shape, dtype)

    def array_out(self, name: str, shape: Optional[Sequence[Optional[int]]]
                  = None, dtype: Optional[str] = None) -> "ArrayHandle":
        return self._declare(name, "out", shape, dtype)

    def array_inout(self, name: str, shape: Optional[Sequence[Optional[int]]]
                    = None, dtype: Optional[str] = None) -> "ArrayHandle":
        return self._declare(name, "inout", shape, dtype)

    def scalar(self, name: str) -> Expr:
        if name not in self.scalars:
            self.scalars.append(name)
        return v(name)

    # ---- statement emission --------------------------------------------------
    def let(self, name: str, expr) -> Expr:
        self._stack[-1].append(Assign(name, _t(expr)))
        return v(name)

    def store(self, array: Union[str, "ArrayHandle"], expr,
              *indices) -> None:
        name = array.name if isinstance(array, ArrayHandle) else array
        if name not in self.arrays:
            self.arrays[name] = ArraySpec(name, "out", dtype=self.dtype)
        idx = tuple(_t(i) for i in indices)
        self._stack[-1].append(Assign(ArrayRef(name, idx), _t(expr)))

    # ---- control flow (context managers) ---------------------------------------
    def if_(self, cond) -> "_BlockCtx":
        stmt = If(_t(cond), [], [])
        self._stack[-1].append(stmt)
        return _BlockCtx(self, stmt.then)

    def else_(self) -> "_BlockCtx":
        last = self._stack[-1][-1]
        assert isinstance(last, If), "else_ must follow if_"
        return _BlockCtx(self, last.orelse)

    def for_(self, var: str, start, stop) -> "_BlockCtx":
        stmt = For(var, _t(start), _t(stop), [])
        self._stack[-1].append(stmt)
        return _BlockCtx(self, stmt.body)

    def __repr__(self):
        return (f"KernelProgram({self.name}, arrays={list(self.arrays)}, "
                f"scalars={self.scalars}, stmts={len(self.body)})")


class _BlockCtx:
    def __init__(self, prog: KernelProgram, block: List[Any]):
        self.prog, self.block = prog, block

    def __enter__(self):
        self.prog._stack.append(self.block)
        return self

    def __exit__(self, *exc):
        self.prog._stack.pop()
        return False


class ArrayHandle:
    """Array symbol supporting h[i, j] loads and whole-tile h.load()."""

    def __init__(self, prog: KernelProgram, name: str):
        self.prog, self.name = prog, name

    def load(self, *indices) -> Expr:
        idx = tuple(_t(i) for i in indices)
        return Expr(("aload", self.name) + idx)

    def __getitem__(self, idx) -> Expr:
        if not isinstance(idx, tuple):
            idx = (idx,)
        return self.load(*idx)
