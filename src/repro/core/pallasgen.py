"""Pallas/TPU code generation for saturated tile programs (paper §VI on TPU).

A *tile program* is a straight-line :class:`KernelProgram` over whole-tile
arrays (every load/store is un-indexed). The generator reuses the JAX
scheduler in :mod:`repro.core.codegen` — including **bulk load** — but
emits a Pallas kernel body where:

* whole-tile loads become ``ref[...]`` VMEM reads. With ``bulk=True`` every
  read is issued before the first compute op (sorted by array name), which
  on TPU front-loads the HBM→VMEM traffic exactly like the paper's
  bulk-load front-loads global-memory requests on the GPU;
* whole-tile stores become ``out_ref[...] = value``;
* the surrounding ``pl.pallas_call`` tiles the leading (row) dimension with
  an explicit BlockSpec, keeping the working set inside VMEM and the lane
  dimension a multiple of 128.

The companion ``make_tile_op`` wrapper builds a jitted op that reshapes
``(..., d)`` operands into rows, runs the kernel over a 1-D grid, and
reshapes back. On CPU it runs in interpret mode (kernel body executed in
Python) — bit-identical semantics, used by all tests.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .codegen import CodeGenerator, GenStats, _PRELUDE, _sanitize
from .dsl import KernelProgram
from .extract import ExtractionResult
from .pipeline import SaturatorConfig, saturate_program
from .ssa import LoopRegion, Region, SSAResult, StoreEffect
from .hardware import DEFAULT_CHIP


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@dataclasses.dataclass
class PallasKernel:
    name: str
    source: str
    kernel_body: Callable      # fn(*in_refs, *out_refs) with scalars closed over
    in_arrays: List[str]       # tile inputs (order of pallas_call operands)
    weight_arrays: List[str]   # rank-deficient inputs broadcast over rows
    out_arrays: List[str]
    scalars: List[str]
    stats: GenStats
    bulk: bool
    schedule_mode: str = "bulk"
    schedule: Optional[Any] = None   # ScheduleResult for explicit orders


class PallasGenerator(CodeGenerator):
    """Emit a Pallas kernel body instead of a jnp function."""

    def __init__(self, ssa: SSAResult, extraction: ExtractionResult, *,
                 bulk: bool = True, fn_name: Optional[str] = None,
                 reuse_temps: bool = True, schedule=None,
                 sched_cost_model=None):
        super().__init__(ssa, extraction, bulk=bulk, fn_name=fn_name,
                         reuse_temps=reuse_temps, schedule=schedule,
                         sched_cost_model=sched_cost_model)

    def _check_tilable(self):
        def walk(region: Region):
            for item in region.items:
                if isinstance(item, LoopRegion):
                    raise ValueError(
                        "Pallas tile programs must be straight-line; "
                        f"kernel {self.ssa.prog.name!r} has a for-loop "
                        "(use the JAX generator or lift the loop to the grid)")
                if item.index_cids:
                    raise ValueError(
                        "Pallas tile programs use whole-tile stores; "
                        f"kernel {self.ssa.prog.name!r} stores with indices")
        walk(self.ssa.region)
        for cid, n in list(self.choice.items()):
            if n.op == "load" and len(n.children) > 1:
                raise ValueError("Pallas tile programs use whole-tile loads")
            if n.op == "call":
                raise ValueError("calls not supported in Pallas tile programs")

    # loads read refs --------------------------------------------------------
    def emit_value(self, cid: int, lines: List[str], indent: str) -> str:
        cid = self.eg.find(cid)
        memo_ok = (self.reuse_temps is True
                   or (self.reuse_temps in (False, "lets")
                       and cid in self._let_set))
        bound = self.scope.get(cid, memo=memo_ok)
        if bound is not None:
            return bound
        n = self.node(cid)
        if n.op == "load":
            arr = self.emit_value(n.children[0], lines, indent)
            name = self._fresh()
            self.stats.n_temps += 1
            self.stats.n_loads += 1
            self.stats.instruction_mix["load"] = \
                self.stats.instruction_mix.get("load", 0) + 1
            lines.append(f"{indent}{name} = {arr}[...]")
            self.scope.bind(cid, name)
            return name
        return super().emit_value(cid, lines, indent)

    def _emit_store(self, eff: StoreEffect, lines: List[str], indent: str):
        val = self.emit_value(eff.value_cid, lines, indent)
        dst_ref = f"{eff.array}_oref"
        if eff.pred_cid is not None:
            pred = self.emit_value(eff.pred_cid, lines, indent)
            src = self.scope.get_sym(eff.version_in)
            old = f"{src}[...]" if src else f"{dst_ref}[...]"
            val = f"jnp.where({pred}, {val}, {old})"
        lines.append(f"{indent}{dst_ref}[...] = {val}")
        # later loads of this array read the ref we just wrote
        self.scope.bind_sym(eff.version_out, dst_ref)
        self.stats.n_stores += 1

    def generate_pallas(self) -> PallasKernel:
        self._check_tilable()
        prog = self.ssa.prog
        in_arrays = [a.name for a in prog.arrays.values()
                     if a.role in ("in", "inout")]
        out_arrays = [a.name for a in prog.arrays.values()
                      if a.role in ("out", "inout")]
        scalars = list(prog.scalars)
        ref_params = ([f"{n}_ref" for n in in_arrays]
                      + [f"{n}_oref" for n in out_arrays])
        lines: List[str] = []
        indent = "    "
        for a in in_arrays:
            self.scope.bind_sym(f"{a}@0", f"{a}_ref")
        for a in out_arrays:
            self.scope.bind_sym(f"{a}@undef", f"{a}_oref")
        sched = self._resolve_schedule()
        if sched is None and self.bulk:
            self._collect_load_regions()
        self.emit_region(self.ssa.region, (), lines, indent)
        body = "\n".join(lines) if lines else "    pass"
        sig = ", ".join(ref_params + scalars)
        src = (f"{_PRELUDE}\n"
               f"def {self.fn_name}_body({sig}):\n{body}\n")
        glb: Dict[str, Any] = {}
        exec(compile(src, f"<pallas:{self.fn_name}>", "exec"), glb)
        return PallasKernel(
            name=self.fn_name, source=src, kernel_body=glb[f"{self.fn_name}_body"],
            in_arrays=in_arrays, weight_arrays=[], out_arrays=out_arrays,
            scalars=scalars, stats=self.stats, bulk=self.bulk,
            schedule_mode=self.schedule_mode, schedule=sched)


@dataclasses.dataclass
class TileOp:
    """Jitted op wrapping a saturated Pallas kernel over a row grid."""
    name: str
    pk: PallasKernel
    jax_ref: Callable          # pure-jnp oracle built from the same program
    row_block: int
    source: str
    # full pipeline result the kernel was generated from — the timing/
    # calibration harness (benchmarks/measure.py) extracts its feature
    # vector from this exact extraction choice
    sk: Optional[Any] = None

    def __call__(self, *arrays, interpret: Optional[bool] = None, **scalars):
        return self.apply(*arrays, interpret=interpret, **scalars)

    def apply(self, *arrays, interpret: Optional[bool] = None, **scalars):
        interpret = _on_cpu() if interpret is None else interpret
        return _apply_tile_op(self, arrays, tuple(sorted(scalars.items())),
                              interpret)


def _apply_tile_op(op: TileOp, arrays, scalar_items, interpret: bool):
    pk = op.pk
    scalars = dict(scalar_items)
    lead = arrays[0]
    d = lead.shape[-1]
    rows = math.prod(lead.shape[:-1]) if lead.ndim > 1 else 1
    row_block = min(op.row_block, rows)
    # pad rows to a multiple of the block
    padded = _ceil_to(rows, row_block)
    ins2d = []
    for name, a in zip(pk.in_arrays, arrays):
        if a.ndim >= 2 and math.prod(a.shape[:-1]) == rows:
            a2 = a.reshape(rows, a.shape[-1])
            if padded != rows:
                a2 = jnp.pad(a2, ((0, padded - rows), (0, 0)))
            ins2d.append(("row", a2))
        else:  # broadcast weight (g, b, ...) — same block every row-tile
            ins2d.append(("bcast", a.reshape(1, -1)))
    grid = (padded // row_block,)

    def body(*refs):
        pk.kernel_body(*refs, **scalars)

    in_specs = []
    for kind, a2 in ins2d:
        if kind == "row":
            in_specs.append(pl.BlockSpec((row_block, a2.shape[-1]),
                                         lambda i: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, a2.shape[-1]), lambda i: (0, 0)))
    out_specs = [pl.BlockSpec((row_block, d), lambda i: (i, 0))
                 for _ in pk.out_arrays]
    out_shapes = [jax.ShapeDtypeStruct((padded, d), lead.dtype)
                  for _ in pk.out_arrays]
    call = pl.pallas_call(
        body, grid=grid, in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
        interpret=interpret)
    outs = call(*[a2 for _, a2 in ins2d])
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    outs = [o[:rows].reshape(lead.shape[:-1] + (d,)) for o in outs]
    return outs[0] if len(outs) == 1 else tuple(outs)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def vmem_estimate(row_block: int, d: int, n_tiles: int,
                  dtype_bytes: int = 4) -> int:
    """Conservative VMEM working-set estimate for a tile kernel."""
    return row_block * d * dtype_bytes * n_tiles


def pick_row_block(d: int, n_tiles: int, dtype_bytes: int = 4,
                   chip=DEFAULT_CHIP) -> int:
    """Largest row block (multiple of 8, ≤512) fitting the VMEM budget.

    8 sublanes × 128 lanes is the fp32 native tile; we keep ~4x headroom
    for temporaries the compiler materializes (the TPU analogue of the
    paper's register-pressure concern, §VIII)."""
    budget = chip.vmem_bytes // 4
    blk = 512
    while blk > 8 and vmem_estimate(blk, d, n_tiles, dtype_bytes) > budget:
        blk //= 2
    return max(blk, 8)


def make_tile_op(prog: KernelProgram,
                 config: Optional[SaturatorConfig] = None,
                 row_block: Optional[int] = None) -> TileOp:
    """Saturate ``prog`` and build both the Pallas op and its jnp oracle."""
    cfg = config or SaturatorConfig(mode="accsat", cost_model="tpu_v5e")
    sk = saturate_program(prog, cfg)
    # reuse the pipeline's ScheduleResult when it computed one (cost
    # mode, or a cache-hit replay): the schedule depends only on the
    # choice + cost model, not the emitter, so this skips a second
    # identical search and keeps the Pallas emission aligned with the
    # cached statement order
    pgen = PallasGenerator(sk.ssa, sk.extraction, bulk=cfg.use_bulk,
                           reuse_temps=cfg.use_cse,
                           schedule=sk.kernel.schedule
                           if sk.kernel.schedule is not None
                           else cfg.schedule,
                           sched_cost_model=cfg.make_schedule_cost_model(
                               prog))
    pk = pgen.generate_pallas()

    jax_fn = sk.kernel.fn
    in_names = sk.kernel.in_arrays
    scalar_names = sk.kernel.scalars

    def jax_ref(*arrays, **scalars):
        args = list(arrays) + [scalars[s] for s in scalar_names]
        # out arrays in the jnp path need explicit buffers
        full_args = []
        ai = iter(arrays)
        for name in in_names:
            spec = prog.arrays[name]
            if spec.role == "out":
                full_args.append(jnp.zeros_like(arrays[0]))
            else:
                full_args.append(next(ai))
        full_args += [scalars[s] for s in scalar_names]
        out = jax_fn(*full_args)
        return out[0] if len(out) == 1 else out

    n_tiles = len(pk.in_arrays) + len(pk.out_arrays) + 2
    rb = row_block or pick_row_block(256, n_tiles)
    return TileOp(name=prog.name, pk=pk, jax_ref=jax_ref, row_block=rb,
                  source=pk.source, sk=sk)
