"""Pallas/TPU code generation for saturated tile programs (paper §VI on TPU).

A *tile program* is a straight-line :class:`KernelProgram` over whole-tile
arrays (every load/store is un-indexed). The generator reuses the JAX
scheduler in :mod:`repro.core.codegen` — including **bulk load** — but
emits a Pallas kernel body where:

* whole-tile loads become ``ref[...]`` VMEM reads. With ``bulk=True`` every
  read is issued before the first compute op (sorted by array name), which
  on TPU front-loads the HBM→VMEM traffic exactly like the paper's
  bulk-load front-loads global-memory requests on the GPU;
* whole-tile stores become ``out_ref[...] = value``;
* the surrounding ``pl.pallas_call`` tiles the leading (row) dimension with
  an explicit BlockSpec, keeping the working set inside VMEM and the lane
  dimension a multiple of 128.

The companion ``make_tile_op`` wrapper builds a jitted op that reshapes
``(..., d)`` operands into rows, runs the kernel over a 1-D grid, and
reshapes back. On CPU it runs in interpret mode (kernel body executed in
Python) — bit-identical semantics, used by all tests.

Since PR 8 two Pallas emitters exist (see :mod:`repro.core.emit`):

* ``"pallas"`` — :class:`SyncPallasGenerator`, the synchronous emitter
  described above (known as ``PallasGenerator`` before the registry);
* ``"pallas_pipelined"`` — :class:`PipelinedPallasGenerator`, which turns
  the schedule's load→first-consumer overlap windows into explicit
  double-buffered ``pltpu.make_async_copy`` start/wait pairs: the copy
  *starts* at the load's scheduled slot and the matching *wait* lands at
  the first consumer (or earlier, when its semaphore parity is needed for
  a later copy — the classic two-deep double-buffer discipline). Its
  interpret-mode fallback degrades to the synchronous emitter
  bit-identically.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.runtime import chaos

from .codegen import JaxCodeGenerator, GenStats, _PRELUDE, _sanitize
from .dsl import KernelProgram
from .extract import ExtractionResult
from .pipeline import SaturatorConfig, saturate_program
from .schedule import compute_schedule
from .ssa import LoopRegion, Region, SSAResult, StoreEffect
from .hardware import DEFAULT_CHIP

try:  # the TPU primitive set is optional at import time (CPU-only hosts)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - depends on the jax build
    pltpu = None


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@dataclasses.dataclass
class AsyncCopy:
    """One pipelined load: an async HBM/VMEM copy start/wait pair.

    ``start_slot``/``wait_slot`` are positions in the region's scheduled
    unit order (``ScheduleResult.ordered_units``); the verifier certifies
    the pairing against these (every start has exactly one wait, the wait
    dominates the first buffer read, semaphore parity alternates)."""
    index: int          # emission order (0, 1, ...) — _cp{index} in source
    array: str          # source array name (copies {array}_ref -> {array}_buf)
    buf: str            # destination scratch buffer parameter name
    sem: int            # semaphore parity: index % 2 (double buffering)
    cid: int            # load e-class the copy materializes
    start_slot: int     # scheduled unit slot where the copy starts
    wait_slot: int = -1  # slot whose emission waited the copy (-1 = pending)

    def to_doc(self) -> Dict[str, Any]:
        return {"index": self.index, "array": self.array, "buf": self.buf,
                "sem": self.sem, "start_slot": self.start_slot,
                "wait_slot": self.wait_slot}


@dataclasses.dataclass
class PallasKernel:
    name: str
    source: str
    kernel_body: Callable      # fn(*in_refs, *out_refs) with scalars closed over
    in_arrays: List[str]       # tile inputs (order of pallas_call operands)
    weight_arrays: List[str]   # rank-deficient inputs broadcast over rows
    out_arrays: List[str]
    scalars: List[str]
    stats: GenStats
    bulk: bool
    schedule_mode: str = "bulk"
    schedule: Optional[Any] = None   # ScheduleResult for explicit orders
    # -- PR-8 emitter metadata -------------------------------------------
    emitter: str = "pallas"          # registry name that produced this
    # pipelined emitter only: arrays with an async copy, in the order the
    # body's scratch buffer parameters appear (drives scratch_shapes)
    async_arrays: Tuple[str, ...] = ()
    async_plan: Tuple[AsyncCopy, ...] = ()
    # synchronous interpret-mode fallback (bit-identical to the "pallas"
    # emitter under the same schedule); None for the sync emitter itself
    fallback_source: Optional[str] = None
    fallback_body: Optional[Callable] = None


class SyncPallasGenerator(JaxCodeGenerator):
    """The ``"pallas"`` emitter: a synchronous Pallas kernel body instead
    of a jnp function. Known as ``PallasGenerator`` before the PR-8
    emitter registry (:mod:`repro.core.emit`); that name remains as a
    deprecated alias."""

    def __init__(self, ssa: SSAResult, extraction: ExtractionResult, *,
                 bulk: bool = True, fn_name: Optional[str] = None,
                 reuse_temps: bool = True, schedule=None,
                 sched_cost_model=None):
        super().__init__(ssa, extraction, bulk=bulk, fn_name=fn_name,
                         reuse_temps=reuse_temps, schedule=schedule,
                         sched_cost_model=sched_cost_model)
        self._extraction = extraction

    def _check_tilable(self):
        def walk(region: Region):
            for item in region.items:
                if isinstance(item, LoopRegion):
                    raise ValueError(
                        "Pallas tile programs must be straight-line; "
                        f"kernel {self.ssa.prog.name!r} has a for-loop "
                        "(use the JAX generator or lift the loop to the grid)")
                if item.index_cids:
                    raise ValueError(
                        "Pallas tile programs use whole-tile stores; "
                        f"kernel {self.ssa.prog.name!r} stores with indices")
        walk(self.ssa.region)
        for cid, n in list(self.choice.items()):
            if n.op == "load" and len(n.children) > 1:
                raise ValueError("Pallas tile programs use whole-tile loads")
            if n.op == "call":
                raise ValueError("calls not supported in Pallas tile programs")

    # loads read refs --------------------------------------------------------
    def emit_value(self, cid: int, lines: List[str], indent: str) -> str:
        cid = self.eg.find(cid)
        memo_ok = (self.reuse_temps is True
                   or (self.reuse_temps in (False, "lets")
                       and cid in self._let_set))
        bound = self.scope.get(cid, memo=memo_ok)
        if bound is not None:
            return bound
        n = self.node(cid)
        if n.op == "load":
            arr = self.emit_value(n.children[0], lines, indent)
            name = self._fresh()
            self.stats.n_temps += 1
            self.stats.n_loads += 1
            self.stats.instruction_mix["load"] = \
                self.stats.instruction_mix.get("load", 0) + 1
            lines.append(f"{indent}{name} = {arr}[...]")
            self.scope.bind(cid, name)
            return name
        return super().emit_value(cid, lines, indent)

    def _emit_store(self, eff: StoreEffect, lines: List[str], indent: str):
        val = self.emit_value(eff.value_cid, lines, indent)
        dst_ref = f"{eff.array}_oref"
        if eff.pred_cid is not None:
            pred = self.emit_value(eff.pred_cid, lines, indent)
            src = self.scope.get_sym(eff.version_in)
            old = f"{src}[...]" if src else f"{dst_ref}[...]"
            val = f"jnp.where({pred}, {val}, {old})"
        lines.append(f"{indent}{dst_ref}[...] = {val}")
        # later loads of this array read the ref we just wrote
        self.scope.bind_sym(eff.version_out, dst_ref)
        self.stats.n_stores += 1

    # hooks the pipelined subclass specializes ---------------------------
    def _prelude(self) -> str:
        return _PRELUDE

    def _body_params(self, ref_params: List[str]) -> List[str]:
        """Positional parameters before the scalars (pipelined emission
        appends scratch buffers + DMA semaphores here)."""
        return ref_params

    def generate_pallas(self) -> PallasKernel:
        self._check_tilable()
        prog = self.ssa.prog
        in_arrays = [a.name for a in prog.arrays.values()
                     if a.role in ("in", "inout")]
        out_arrays = [a.name for a in prog.arrays.values()
                      if a.role in ("out", "inout")]
        scalars = list(prog.scalars)
        ref_params = ([f"{n}_ref" for n in in_arrays]
                      + [f"{n}_oref" for n in out_arrays])
        lines: List[str] = []
        indent = "    "
        for a in in_arrays:
            self.scope.bind_sym(f"{a}@0", f"{a}_ref")
        for a in out_arrays:
            self.scope.bind_sym(f"{a}@undef", f"{a}_oref")
        sched = self._resolve_schedule()
        if sched is None and self.bulk:
            self._collect_load_regions()
        self.emit_region(self.ssa.region, (), lines, indent)
        body = "\n".join(lines) if lines else "    pass"
        sig = ", ".join(self._body_params(ref_params) + scalars)
        src = (f"{self._prelude()}\n"
               f"def {self.fn_name}_body({sig}):\n{body}\n")
        glb: Dict[str, Any] = {}
        chaos.maybe_raise("exec_fail", prog.name,
                          "generated Pallas source")
        exec(compile(src, f"<pallas:{self.fn_name}>", "exec"), glb)
        return self._finalize_kernel(
            src, glb[f"{self.fn_name}_body"], in_arrays, out_arrays,
            scalars, sched)

    def _finalize_kernel(self, src, body_fn, in_arrays, out_arrays,
                         scalars, sched) -> PallasKernel:
        return PallasKernel(
            name=self.fn_name, source=src, kernel_body=body_fn,
            in_arrays=in_arrays, weight_arrays=[], out_arrays=out_arrays,
            scalars=scalars, stats=self.stats, bulk=self.bulk,
            schedule_mode=self.schedule_mode, schedule=sched)


class PallasGenerator(SyncPallasGenerator):
    """Deprecated alias of :class:`SyncPallasGenerator`.

    Use ``repro.core.emit.get_emitter("pallas")`` (or
    ``SyncPallasGenerator`` directly) instead; this name is kept so
    pre-PR-8 imports keep working."""

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro.core.pallasgen.PallasGenerator is deprecated; use "
            "repro.core.emit.get_emitter('pallas') or SyncPallasGenerator",
            DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)


_PIPELINED_PRELUDE = _PRELUDE + """
try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # CPU-only build: callers run the sync fallback body
    pltpu = None
"""


class PipelinedPallasGenerator(SyncPallasGenerator):
    """The ``"pallas_pipelined"`` emitter: double-buffered async copies.

    Every whole-tile load of an *input* ref becomes an explicit
    ``pltpu.make_async_copy({a}_ref, {a}_buf, _sem{k%2})`` whose
    ``.start()`` is emitted at the load's scheduled slot and whose
    ``.wait()`` lands at the first consumer — the textual realization of
    the overlap window ``ScheduleResult.load_windows`` prices. Two DMA
    semaphores are rotated (``index % 2``); starting a copy on a parity
    that is still in flight first drains it, bounding outstanding copies
    to two, the double-buffer invariant the verifier certifies.

    Emission *always* follows an explicit :class:`ScheduleResult` (named
    source/bulk orders are reconstructed searchlessly when no cost
    schedule is attached) so every load has a well-defined slot. The
    kernel also carries a synchronous fallback body — generated by
    :class:`SyncPallasGenerator` under the *same* schedule, hence
    bit-identical to the ``"pallas"`` emitter — which the interpret path
    (CPU) executes.
    """

    EMITTER_NAME = "pallas_pipelined"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._async_copies: List[AsyncCopy] = []
        self._pending: Dict[int, AsyncCopy] = {}   # load cid -> copy
        self._waited: Dict[int, AsyncCopy] = {}    # waited, not yet read
        self._inflight: Dict[int, AsyncCopy] = {}  # sem parity -> copy
        self._slot = -1

    def _prelude(self) -> str:
        return _PIPELINED_PRELUDE

    def _body_params(self, ref_params: List[str]) -> List[str]:
        bufs = [c.buf for c in self._async_copies]
        sems = ["_sem0", "_sem1"] if self._async_copies else []
        return ref_params + bufs + sems

    def _resolve_schedule(self):
        sched = super()._resolve_schedule()
        if sched is None:
            # named legacy order: reconstruct it explicitly (searchless,
            # bit-identical to the legacy emission) so every load has a
            # scheduled slot to hang its copy start on
            cm = self._sched_cm if hasattr(self._sched_cm, "latency") \
                else None
            if cm is not None and hasattr(cm, "bind_egraph"):
                cm.bind_egraph(self.eg)
            self._explicit = compute_schedule(
                self.ssa, self.choice, mode=self.schedule_mode,
                cost_model=cm, move_budget=0)
        return self._explicit

    # -- async copy placement -------------------------------------------
    def _pipelineable(self, cid: int) -> Optional[str]:
        """The input array name when the load can become an async copy
        (whole-tile load of an ``*_ref`` input), else None."""
        cid = self.eg.find(cid)
        if self.scope.get(cid) is not None:
            return None   # already materialized
        n = self.node(cid)
        if n.op != "load" or len(n.children) != 1:
            return None
        arr_n = self.node(n.children[0])
        if arr_n.op != "array":
            return None
        bound = self.scope.get_sym(arr_n.payload)
        if bound is None or not bound.endswith("_ref"):
            return None   # re-read of a written oref: keep synchronous
        return bound[:-len("_ref")]

    def _start_copy(self, cid: int, arr: str, lines: List[str],
                    indent: str):
        cid = self.eg.find(cid)
        k = len(self._async_copies)
        parity = k % 2
        # double-buffer discipline: at most one copy in flight per
        # semaphore — drain the previous same-parity copy before reusing
        prev = self._inflight.get(parity)
        if prev is not None and prev.wait_slot < 0:
            lines.append(f"{indent}_cp{prev.index}.wait()")
            prev.wait_slot = self._slot
            self._waited[self.eg.find(prev.cid)] = \
                self._pending.pop(self.eg.find(prev.cid))
        cp = AsyncCopy(index=k, array=arr, buf=f"{arr}_buf", sem=parity,
                       cid=cid, start_slot=self._slot)
        lines.append(f"{indent}_cp{k} = pltpu.make_async_copy("
                     f"{arr}_ref, {arr}_buf, _sem{parity})")
        lines.append(f"{indent}_cp{k}.start()")
        self._async_copies.append(cp)
        self._pending[cid] = cp
        self._inflight[parity] = cp

    def emit_value(self, cid: int, lines: List[str], indent: str) -> str:
        cid = self.eg.find(cid)
        cp = self._pending.pop(cid, None) or self._waited.pop(cid, None)
        if cp is not None:
            if cp.wait_slot < 0:
                lines.append(f"{indent}_cp{cp.index}.wait()")
                cp.wait_slot = self._slot
            if self._inflight.get(cp.sem) is cp:
                del self._inflight[cp.sem]
            name = self._fresh()
            self.stats.n_temps += 1
            self.stats.n_loads += 1
            self.stats.instruction_mix["load"] = \
                self.stats.instruction_mix.get("load", 0) + 1
            lines.append(f"{indent}{name} = {cp.buf}[...]")
            self.scope.bind(cid, name)
            return name
        return super().emit_value(cid, lines, indent)

    def _emit_scheduled(self, sched, path, lines, indent):
        for u in sched.ordered_units():
            self._slot += 1
            if u.kind == "load":
                arr = self._pipelineable(u.cid)
                if arr is not None:
                    self._start_copy(u.cid, arr, lines, indent)
                else:
                    self.emit_value(u.cid, lines, indent)
                if not self._region_first_compute.get(path, False):
                    self.stats.loads_before_compute += 1
            elif u.kind == "compute":
                self.emit_value(u.cid, lines, indent)
                self._region_first_compute[path] = True
            elif u.kind == "store":
                self._emit_store(u.item, lines, indent)
                self._region_first_compute[path] = True
            else:
                self._emit_loop(u.item, path, lines, indent)
                self._region_first_compute[path] = True
        # drain copies the region never consumed (defensive: keeps the
        # start/wait pairing total even for dead loads)
        self._slot += 1
        for cid, cp in list(self._pending.items()):
            lines.append(f"{indent}_cp{cp.index}.wait()")
            cp.wait_slot = self._slot
            self._waited[cid] = self._pending.pop(cid)
            if self._inflight.get(cp.sem) is cp:
                del self._inflight[cp.sem]

    def _finalize_kernel(self, src, body_fn, in_arrays, out_arrays,
                         scalars, sched) -> PallasKernel:
        # the interpret-mode fallback: the synchronous emitter run under
        # the *same* resolved schedule — bit-identical to "pallas"
        sync = SyncPallasGenerator(
            self.ssa, self._extraction, bulk=self.bulk,
            fn_name=self.fn_name, reuse_temps=self.reuse_temps,
            schedule=sched, sched_cost_model=self._sched_cm)
        fb = sync.generate_pallas()
        return PallasKernel(
            name=self.fn_name, source=src, kernel_body=body_fn,
            in_arrays=in_arrays, weight_arrays=[], out_arrays=out_arrays,
            scalars=scalars, stats=self.stats, bulk=self.bulk,
            schedule_mode=self.schedule_mode, schedule=sched,
            emitter=self.EMITTER_NAME,
            async_arrays=tuple(c.array for c in self._async_copies),
            async_plan=tuple(self._async_copies),
            fallback_source=fb.source, fallback_body=fb.kernel_body)


@dataclasses.dataclass
class TileOp:
    """Jitted op wrapping a saturated Pallas kernel over a row grid.

    ``pk=None`` marks a degraded op (Pallas emission failed under the
    guarded runtime): ``apply`` then delegates to ``jax_ref`` — the
    kernel still runs, one ladder rung down (see docs/robustness.md)."""
    name: str
    pk: Optional[PallasKernel]
    jax_ref: Callable          # pure-jnp oracle built from the same program
    row_block: int
    source: str
    # full pipeline result the kernel was generated from — the timing/
    # calibration harness (benchmarks/measure.py) extracts its feature
    # vector from this exact extraction choice
    sk: Optional[Any] = None

    def __call__(self, *arrays, interpret: Optional[bool] = None, **scalars):
        return self.apply(*arrays, interpret=interpret, **scalars)

    def apply(self, *arrays, interpret: Optional[bool] = None, **scalars):
        if self.pk is None:
            return self.jax_ref(*arrays, **scalars)
        interpret = _on_cpu() if interpret is None else interpret
        return _apply_tile_op(self, arrays, tuple(sorted(scalars.items())),
                              interpret)


def _row_index_map(i):
    """Row-tiled operand: grid step ``i`` owns row-block ``i``."""
    return (i, 0)


def _bcast_index_map(i):
    """Broadcast weight row: every grid step reads block (0, 0)."""
    return (0, 0)


@dataclasses.dataclass(frozen=True)
class TileEntry:
    """One operand of a planned tile-op ``pallas_call``."""
    name: str
    kind: str                            # "row" | "bcast"
    block_shape: Tuple[int, int]
    buffer_shape: Tuple[int, int]        # post-pad 2-D operand shape
    index_map: Callable


@dataclasses.dataclass(frozen=True)
class TileCallPlan:
    """The launch geometry of one tile-op call: grid, per-operand block
    shapes, buffer shapes (post-``_ceil_to`` padding) and index maps.

    Built by :func:`plan_tile_call` and consumed by *both* the runtime
    (``_apply_tile_op`` constructs its BlockSpecs from it) and the
    static verifier (``repro.verify.grid_check`` certifies exactly this
    plan) — one source of truth, so what is certified is what runs."""
    rows: int
    d: int
    row_block: int
    padded: int
    grid: Tuple[int, ...]
    inputs: Tuple[TileEntry, ...]
    outputs: Tuple[TileEntry, ...]


def plan_tile_call(pk: PallasKernel, in_shapes: Sequence[Tuple[int, ...]],
                   row_block: int) -> TileCallPlan:
    """Plan the grid/BlockSpec layout for ``pk`` over operands of the
    given (pre-reshape) shapes. Inputs whose leading extents multiply to
    the lead operand's row count tile over rows; anything else is a
    broadcast weight row re-read by every grid step."""
    lead = tuple(in_shapes[0])
    d = lead[-1]
    rows = math.prod(lead[:-1]) if len(lead) > 1 else 1
    rb = min(row_block, rows)
    padded = _ceil_to(rows, rb)
    inputs = []
    for name, shp in zip(pk.in_arrays, in_shapes):
        if len(shp) >= 2 and math.prod(shp[:-1]) == rows:
            inputs.append(TileEntry(name, "row", (rb, shp[-1]),
                                    (padded, shp[-1]), _row_index_map))
        else:
            w = math.prod(shp)
            inputs.append(TileEntry(name, "bcast", (1, w), (1, w),
                                    _bcast_index_map))
    outputs = tuple(TileEntry(name, "row", (rb, d), (padded, d),
                              _row_index_map) for name in pk.out_arrays)
    return TileCallPlan(rows=rows, d=d, row_block=rb, padded=padded,
                        grid=(padded // rb,), inputs=tuple(inputs),
                        outputs=outputs)


def _apply_tile_op(op: TileOp, arrays, scalar_items, interpret: bool):
    pk = op.pk
    # pipelined kernels carry a synchronous twin: interpret mode (and
    # hosts without the TPU primitive set) run it — bit-identical to the
    # "pallas" emitter — while the compiled path gets the async body
    use_async = (pk.fallback_body is None
                 or (not interpret and pltpu is not None
                     and bool(pk.async_arrays)))
    body_fn = pk.kernel_body if use_async else pk.fallback_body
    scalars = dict(scalar_items)
    lead = arrays[0]
    plan = plan_tile_call(pk, [a.shape for a in arrays], op.row_block)
    rows, padded, d = plan.rows, plan.padded, plan.d
    ins2d = []
    for e, a in zip(plan.inputs, arrays):
        if e.kind == "row":
            a2 = a.reshape(rows, a.shape[-1])
            if padded != rows:
                a2 = jnp.pad(a2, ((0, padded - rows), (0, 0)))
        else:  # broadcast weight (g, b, ...) — same block every row-tile
            a2 = a.reshape(1, -1)
        ins2d.append(a2)

    def body(*refs):
        body_fn(*refs, **scalars)

    in_specs = [pl.BlockSpec(e.block_shape, e.index_map)
                for e in plan.inputs]
    block_shapes = {e.name: e.block_shape for e in plan.inputs}
    out_specs = [pl.BlockSpec(e.block_shape, e.index_map)
                 for e in plan.outputs]
    out_shapes = [jax.ShapeDtypeStruct(e.buffer_shape, lead.dtype)
                  for e in plan.outputs]
    scratch_shapes = None
    if use_async and pk.async_arrays:
        # one VMEM staging buffer per pipelined input (block-shaped) plus
        # the two rotating DMA-completion semaphores
        scratch_shapes = [pltpu.VMEM(block_shapes[a], lead.dtype)
                          for a in pk.async_arrays]
        scratch_shapes += [pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA]
    grid = plan.grid
    call = pl.pallas_call(
        body, grid=grid, in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
        **({"scratch_shapes": scratch_shapes}
           if scratch_shapes is not None else {}),
        interpret=interpret)
    outs = call(*ins2d)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    outs = [o[:rows].reshape(lead.shape[:-1] + (d,)) for o in outs]
    return outs[0] if len(outs) == 1 else tuple(outs)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def vmem_estimate(row_block: int, d: int, n_tiles: int,
                  dtype_bytes: int = 4) -> int:
    """Conservative VMEM working-set estimate for a tile kernel.

    A heuristic — ``n_tiles`` overcounts broadcast rows as full tiles.
    The exact footprint (per-operand block shapes × double-buffer
    multiplicity) lives in ``repro.verify.grid_check``, whose VMEM pass
    flags configs where this estimate and the exact model disagree
    (``vmem-heuristic-drift``)."""
    return row_block * d * dtype_bytes * n_tiles


def pick_row_block(d: int, n_tiles: int, dtype_bytes: int = 4,
                   chip=DEFAULT_CHIP) -> int:
    """Largest row block (multiple of 8, ≤512) fitting the VMEM budget.

    8 sublanes × 128 lanes is the fp32 native tile; we keep ~4x headroom
    for temporaries the compiler materializes (the TPU analogue of the
    paper's register-pressure concern, §VIII). ``dtype_bytes`` scales
    the budget to the element width actually stored (bf16 tiles cost
    half the VMEM of f32 — pass 2, not the f32 default)."""
    budget = chip.vmem_bytes // 4
    blk = 512
    while blk > 8 and vmem_estimate(blk, d, n_tiles, dtype_bytes) > budget:
        blk //= 2
    return max(blk, 8)


def _declared_feature_dim(prog: KernelProgram) -> Optional[int]:
    """Widest declared last-dim extent across the program's arrays
    (None when nothing is declared — callers fall back to 256)."""
    dims = [s.shape[-1] for s in prog.arrays.values()
            if s.shape and s.shape[-1] is not None]
    return max(dims) if dims else None


def _declared_dtype_bytes(prog: KernelProgram) -> int:
    """Widest declared element byte width — the conservative width for
    the VMEM budget (arrays inherit the program default, f32)."""
    from repro.analysis.opstats import dtype_byte_width
    widths = []
    for s in prog.arrays.values():
        try:
            widths.append(dtype_byte_width(s.dtype))
        except ValueError:
            pass   # unknown dtype name: budget it as f32 below
    return max(widths, default=4)


def make_tile_op(prog: KernelProgram,
                 config: Optional[SaturatorConfig] = None,
                 row_block: Optional[int] = None) -> TileOp:
    """Saturate ``prog`` and build both the Pallas op and its jnp oracle.

    The Pallas emitter is picked by ``config.emitter`` through the PR-8
    registry (:mod:`repro.core.emit`): ``None``/``"pallas"`` keeps the
    synchronous emitter, ``"pallas_pipelined"`` emits double-buffered
    async copies (with a bit-identical interpret fallback)."""
    cfg = config or SaturatorConfig(mode="accsat", cost_model="tpu_v5e")
    sk = saturate_program(prog, cfg)
    # emission follows the configuration that actually *built* sk: a
    # ladder-degraded build (repro.runtime.guard) carries its cheap
    # config in sk.config, and re-running the full schedule search /
    # pipelined emitter here would re-hit whatever failed
    ecfg = sk.config
    from .emit import get_emitter
    emitter = get_emitter(ecfg.emitter or "pallas")
    if emitter.info.target != "pallas":
        raise ValueError(f"make_tile_op needs a pallas emitter, got "
                         f"{emitter.info.name!r}")
    pk = None
    if sk.ladder_level != "ref":
        # reuse the pipeline's ScheduleResult when it computed one (cost
        # mode, or a cache-hit replay): the schedule depends only on the
        # choice + cost model, not the emitter, so this skips a second
        # identical search and keeps the Pallas emission aligned with
        # the cached statement order
        try:
            pgen = emitter.generator_cls(
                sk.ssa, sk.extraction, bulk=ecfg.use_bulk,
                reuse_temps=ecfg.use_cse,
                schedule=sk.kernel.schedule
                if sk.kernel.schedule is not None
                else ecfg.schedule,
                sched_cost_model=ecfg.make_schedule_cost_model(prog))
            pk = pgen.generate_pallas()
        except Exception as e:   # ladder contract: emission never fatal
            from repro.runtime.guard import classify_failure
            from .telemetry import telemetry
            telemetry().record_degradation(
                prog.name, "jax", classify_failure(e, "pallas_emit"))
            pk = None

    jax_fn = sk.kernel.fn
    in_names = sk.kernel.in_arrays
    scalar_names = sk.kernel.scalars

    def jax_ref(*arrays, **scalars):
        args = list(arrays) + [scalars[s] for s in scalar_names]
        # out arrays in the jnp path need explicit buffers
        full_args = []
        ai = iter(arrays)
        for name in in_names:
            spec = prog.arrays[name]
            if spec.role == "out":
                full_args.append(jnp.zeros_like(arrays[0]))
            else:
                full_args.append(next(ai))
        full_args += [scalars[s] for s in scalar_names]
        out = jax_fn(*full_args)
        return out[0] if len(out) == 1 else out

    if pk is None:
        # degraded op: no Pallas kernel — apply() delegates to jax_ref
        # (the saturated JAX kernel, or the reference interpreter when
        # the ladder bottomed out at "ref")
        return TileOp(name=prog.name, pk=None, jax_ref=jax_ref,
                      row_block=row_block or 8,
                      source=sk.kernel.source, sk=sk)

    n_tiles = len(pk.in_arrays) + len(pk.out_arrays) + 2
    # autosize from the *declared* operand geometry: the feature width
    # and element byte width the program actually stores, not the
    # hardcoded (256, f32) the pre-PR-9 heuristic assumed — a d=1024
    # f32 program now picks a smaller, VMEM-fitting block while bf16
    # keeps the larger one its halved bytes afford
    rb = row_block or pick_row_block(_declared_feature_dim(prog) or 256,
                                     n_tiles, _declared_dtype_bytes(prog))
    op = TileOp(name=prog.name, pk=pk, jax_ref=jax_ref, row_block=rb,
                source=pk.source, sk=sk)
    if cfg.verify != "off":
        # the grid pass (PR 9): statically certify the launch plan this
        # op will run — coverage, write disjointness, bounds (incl. the
        # pad region), exact VMEM fit — before anything executes
        from repro.verify import verify_tile_op
        verify_tile_op(op)
    return op
