"""Process-wide saturation telemetry (PR 6).

One tiny registry counts what the saturation subsystem actually did at
runtime — persistent-cache hits / misses / warm starts with their wall
times, and jaxpr-bridge fallbacks per unsupported primitive (the
coverage gaps ``maybe_saturate`` used to swallow silently). It has no
dependencies so every layer (core pipeline, cache store, jaxpr bridge,
launch drivers, benchmarks) can report into the same counters without
import cycles.

Consumers: ``launch/serve.py`` / ``launch/train.py`` surface
``snapshot()`` in their metrics, ``benchmarks/saturation_stats.py``
records it per run, and ``examples/serve_decode.py`` commits it to
``BENCH_6.json``.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Deque, Dict

# Retained event dicts are a debugging aid, not the record of truth (the
# counters are); cap them so a long-lived serve/train process with
# recurring bridge fallbacks or cache lookups doesn't leak memory.
EVENT_LIMIT = 512


@dataclasses.dataclass
class SaturationTelemetry:
    """Counters for one process. All methods are thread-safe."""
    cache_hits: int = 0
    cache_misses: int = 0
    cache_warm_starts: int = 0
    cache_stores: int = 0
    cache_invalid: int = 0         # entries rejected (corrupt/stale/version)
    cold_wall_s: float = 0.0       # saturate+extract+schedule, no cache help
    warm_wall_s: float = 0.0       # same, seeded from a near-miss entry
    hit_wall_s: float = 0.0        # replay-only wall time on exact hits
    bridge_fallbacks: Dict[str, int] = dataclasses.field(
        default_factory=dict)  # primitive name -> count
    # static-verification counters (repro.verify, PR 7)
    verify_runs: int = 0
    verify_errors: int = 0
    verify_findings_by_pass: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # pass name -> finding count
    rules_checked: int = 0
    schedules_certified: int = 0
    grids_checked: int = 0
    events: Deque[Dict[str, Any]] = dataclasses.field(
        default_factory=lambda: deque(maxlen=EVENT_LIMIT))

    def __post_init__(self):
        self._lock = threading.Lock()

    # -- cache events -------------------------------------------------------
    def record_cache(self, status: str, kernel: str, wall_s: float):
        """status in {"hit", "warm", "miss"} — one saturate_program call."""
        with self._lock:
            if status == "hit":
                self.cache_hits += 1
                self.hit_wall_s += wall_s
            elif status == "warm":
                self.cache_warm_starts += 1
                self.warm_wall_s += wall_s
            else:
                self.cache_misses += 1
                self.cold_wall_s += wall_s
            self.events.append({"kind": "cache", "status": status,
                                "kernel": kernel, "wall_s": wall_s})

    def record_store(self, kernel: str):
        with self._lock:
            self.cache_stores += 1

    def record_invalid(self, kernel: str, reason: str):
        with self._lock:
            self.cache_invalid += 1
            self.events.append({"kind": "cache_invalid", "kernel": kernel,
                                "reason": reason})

    # -- bridge events ------------------------------------------------------
    def record_bridge_fallback(self, primitive: str, fn_name: str = ""):
        with self._lock:
            self.bridge_fallbacks[primitive] = \
                self.bridge_fallbacks.get(primitive, 0) + 1
            self.events.append({"kind": "bridge_fallback",
                                "primitive": primitive, "fn": fn_name})

    # -- verification events ------------------------------------------------
    def record_verify(self, report):
        """Fold one :class:`repro.verify.VerifyReport` into the counters."""
        with self._lock:
            self.verify_runs += 1
            for f in report.findings:
                self.verify_findings_by_pass[f.pass_name] = \
                    self.verify_findings_by_pass.get(f.pass_name, 0) + 1
                if f.severity == "error":
                    self.verify_errors += 1
            self.rules_checked += report.rules_checked
            self.schedules_certified += report.schedules_certified
            self.grids_checked += getattr(report, "grids_checked", 0)
            if not report.ok:
                self.events.append({"kind": "verify_errors",
                                    "errors": [str(f) for f
                                               in report.errors()][:8]})

    # -- reporting ----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            lookups = self.cache_hits + self.cache_misses \
                + self.cache_warm_starts
            return {
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "cache_warm_starts": self.cache_warm_starts,
                "cache_stores": self.cache_stores,
                "cache_invalid": self.cache_invalid,
                "cache_hit_rate": (self.cache_hits / lookups
                                   if lookups else 0.0),
                "cold_wall_s": self.cold_wall_s,
                "warm_wall_s": self.warm_wall_s,
                "hit_wall_s": self.hit_wall_s,
                "bridge_fallbacks": dict(sorted(
                    self.bridge_fallbacks.items())),
                "verify": {
                    "runs": self.verify_runs,
                    "errors": self.verify_errors,
                    "findings_by_pass": dict(sorted(
                        self.verify_findings_by_pass.items())),
                    "rules_checked": self.rules_checked,
                    "schedules_certified": self.schedules_certified,
                    "grids_checked": self.grids_checked,
                },
            }

    def reset(self):
        with self._lock:
            self.cache_hits = self.cache_misses = 0
            self.cache_warm_starts = self.cache_stores = 0
            self.cache_invalid = 0
            self.cold_wall_s = self.warm_wall_s = self.hit_wall_s = 0.0
            self.bridge_fallbacks.clear()
            self.verify_runs = self.verify_errors = 0
            self.verify_findings_by_pass.clear()
            self.rules_checked = self.schedules_certified = 0
            self.grids_checked = 0
            self.events.clear()


_TELEMETRY = SaturationTelemetry()


def telemetry() -> SaturationTelemetry:
    """The process-wide registry."""
    return _TELEMETRY


def reset_telemetry():
    _TELEMETRY.reset()
