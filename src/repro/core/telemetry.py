"""Process-wide saturation telemetry (PR 6).

One tiny registry counts what the saturation subsystem actually did at
runtime — persistent-cache hits / misses / warm starts with their wall
times, and jaxpr-bridge fallbacks per unsupported primitive (the
coverage gaps ``maybe_saturate`` used to swallow silently). It has no
dependencies so every layer (core pipeline, cache store, jaxpr bridge,
launch drivers, benchmarks) can report into the same counters without
import cycles.

Consumers: ``launch/serve.py`` / ``launch/train.py`` surface
``snapshot()`` in their metrics, ``benchmarks/saturation_stats.py``
records it per run, and ``examples/serve_decode.py`` commits it to
``BENCH_6.json``.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Deque, Dict

# Retained event dicts are a debugging aid, not the record of truth (the
# counters are); cap them so a long-lived serve/train process with
# recurring bridge fallbacks or cache lookups doesn't leak memory.
EVENT_LIMIT = 512


@dataclasses.dataclass
class SaturationTelemetry:
    """Counters for one process. All methods are thread-safe."""
    cache_hits: int = 0
    cache_misses: int = 0
    cache_warm_starts: int = 0
    cache_stores: int = 0
    cache_invalid: int = 0         # entries rejected (corrupt/stale/version)
    cold_wall_s: float = 0.0       # saturate+extract+schedule, no cache help
    warm_wall_s: float = 0.0       # same, seeded from a near-miss entry
    hit_wall_s: float = 0.0        # replay-only wall time on exact hits
    bridge_fallbacks: Dict[str, int] = dataclasses.field(
        default_factory=dict)  # primitive name -> count
    # static-verification counters (repro.verify, PR 7)
    verify_runs: int = 0
    verify_errors: int = 0
    verify_findings_by_pass: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # pass name -> finding count
    rules_checked: int = 0
    schedules_certified: int = 0
    grids_checked: int = 0
    # guarded-runtime counters (repro.runtime.guard / .chaos, PR 10)
    ladder_levels: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # final ladder level -> build count
    degradations: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # degraded level (cheap/ref/...) -> count
    degradation_triggers: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # trigger label -> count
    guard_failures: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # "level:trigger" -> failed-attempt count
    breaker_events: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # open/close/half_open/skip -> count
    chaos_fires: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # injection site -> fire count
    runtime_fallbacks: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # kernel -> ops-layer ref-fallback count
    elastic_recoveries: int = 0
    events: Deque[Dict[str, Any]] = dataclasses.field(
        default_factory=lambda: deque(maxlen=EVENT_LIMIT))

    def __post_init__(self):
        self._lock = threading.Lock()

    # -- cache events -------------------------------------------------------
    def record_cache(self, status: str, kernel: str, wall_s: float):
        """status in {"hit", "warm", "miss"} — one saturate_program call."""
        with self._lock:
            if status == "hit":
                self.cache_hits += 1
                self.hit_wall_s += wall_s
            elif status == "warm":
                self.cache_warm_starts += 1
                self.warm_wall_s += wall_s
            else:
                self.cache_misses += 1
                self.cold_wall_s += wall_s
            self.events.append({"kind": "cache", "status": status,
                                "kernel": kernel, "wall_s": wall_s})

    def record_store(self, kernel: str):
        with self._lock:
            self.cache_stores += 1

    def record_invalid(self, kernel: str, reason: str):
        with self._lock:
            self.cache_invalid += 1
            self.events.append({"kind": "cache_invalid", "kernel": kernel,
                                "reason": reason})

    # -- bridge events ------------------------------------------------------
    def record_bridge_fallback(self, primitive: str, fn_name: str = ""):
        with self._lock:
            self.bridge_fallbacks[primitive] = \
                self.bridge_fallbacks.get(primitive, 0) + 1
            self.events.append({"kind": "bridge_fallback",
                                "primitive": primitive, "fn": fn_name})

    # -- verification events ------------------------------------------------
    def record_verify(self, report):
        """Fold one :class:`repro.verify.VerifyReport` into the counters."""
        with self._lock:
            self.verify_runs += 1
            for f in report.findings:
                self.verify_findings_by_pass[f.pass_name] = \
                    self.verify_findings_by_pass.get(f.pass_name, 0) + 1
                if f.severity == "error":
                    self.verify_errors += 1
            self.rules_checked += report.rules_checked
            self.schedules_certified += report.schedules_certified
            self.grids_checked += getattr(report, "grids_checked", 0)
            if not report.ok:
                self.events.append({"kind": "verify_errors",
                                    "errors": [str(f) for f
                                               in report.errors()][:8]})

    # -- guarded-runtime events (PR 10) -------------------------------------
    def record_ladder(self, kernel: str, level: str):
        """Final degradation-ladder level of one saturate call."""
        with self._lock:
            self.ladder_levels[level] = self.ladder_levels.get(level, 0) + 1

    def record_degradation(self, kernel: str, level: str, trigger: str):
        """One build landed below the full path: at ``level``, pushed
        there by ``trigger`` (the first failure's classified label)."""
        with self._lock:
            self.degradations[level] = self.degradations.get(level, 0) + 1
            self.degradation_triggers[trigger] = \
                self.degradation_triggers.get(trigger, 0) + 1
            self.events.append({"kind": "degradation", "kernel": kernel,
                                "level": level, "trigger": trigger})

    def record_guard_failure(self, kernel: str, level: str, trigger: str):
        with self._lock:
            k = f"{level}:{trigger}"
            self.guard_failures[k] = self.guard_failures.get(k, 0) + 1
            self.events.append({"kind": "guard_failure", "kernel": kernel,
                                "level": level, "trigger": trigger})

    def record_breaker(self, key: Any, event: str):
        """event in {"open", "close", "half_open", "skip"}."""
        with self._lock:
            self.breaker_events[event] = \
                self.breaker_events.get(event, 0) + 1
            self.events.append({"kind": "breaker", "key": str(key),
                                "event": event})

    def record_chaos(self, site: str, kernel: Any = None):
        with self._lock:
            self.chaos_fires[site] = self.chaos_fires.get(site, 0) + 1
            self.events.append({"kind": "chaos", "site": site,
                                "kernel": kernel})

    def record_runtime_fallback(self, kernel: str, reason: str):
        """ops-layer safety net: an op call fell back to its named
        reference oracle at apply time."""
        with self._lock:
            self.runtime_fallbacks[kernel] = \
                self.runtime_fallbacks.get(kernel, 0) + 1
            self.events.append({"kind": "runtime_fallback",
                                "kernel": kernel, "reason": reason})

    def record_recovery(self, step: int, kind: str, shards: Any = None):
        """ft.ElasticTrainer completed a recovery (state preserved)."""
        with self._lock:
            self.elastic_recoveries += 1
            self.events.append({"kind": "elastic_recovery", "step": step,
                                "event": kind, "shards": shards})

    # -- reporting ----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            lookups = self.cache_hits + self.cache_misses \
                + self.cache_warm_starts
            return {
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "cache_warm_starts": self.cache_warm_starts,
                "cache_stores": self.cache_stores,
                "cache_invalid": self.cache_invalid,
                "cache_hit_rate": (self.cache_hits / lookups
                                   if lookups else 0.0),
                "cold_wall_s": self.cold_wall_s,
                "warm_wall_s": self.warm_wall_s,
                "hit_wall_s": self.hit_wall_s,
                "bridge_fallbacks": dict(sorted(
                    self.bridge_fallbacks.items())),
                "verify": {
                    "runs": self.verify_runs,
                    "errors": self.verify_errors,
                    "findings_by_pass": dict(sorted(
                        self.verify_findings_by_pass.items())),
                    "rules_checked": self.rules_checked,
                    "schedules_certified": self.schedules_certified,
                    "grids_checked": self.grids_checked,
                },
                "guard": {
                    "ladder_levels": dict(sorted(
                        self.ladder_levels.items())),
                    "degradations": dict(sorted(
                        self.degradations.items())),
                    "degradation_triggers": dict(sorted(
                        self.degradation_triggers.items())),
                    "guard_failures": dict(sorted(
                        self.guard_failures.items())),
                    "breaker_events": dict(sorted(
                        self.breaker_events.items())),
                    "chaos_fires": dict(sorted(self.chaos_fires.items())),
                    "runtime_fallbacks": dict(sorted(
                        self.runtime_fallbacks.items())),
                    "elastic_recoveries": self.elastic_recoveries,
                },
            }

    def reset(self):
        with self._lock:
            self.cache_hits = self.cache_misses = 0
            self.cache_warm_starts = self.cache_stores = 0
            self.cache_invalid = 0
            self.cold_wall_s = self.warm_wall_s = self.hit_wall_s = 0.0
            self.bridge_fallbacks.clear()
            self.verify_runs = self.verify_errors = 0
            self.verify_findings_by_pass.clear()
            self.rules_checked = self.schedules_certified = 0
            self.grids_checked = 0
            self.ladder_levels.clear()
            self.degradations.clear()
            self.degradation_triggers.clear()
            self.guard_failures.clear()
            self.breaker_events.clear()
            self.chaos_fires.clear()
            self.runtime_fallbacks.clear()
            self.elastic_recoveries = 0
            self.events.clear()


_TELEMETRY = SaturationTelemetry()


def telemetry() -> SaturationTelemetry:
    """The process-wide registry."""
    return _TELEMETRY


def reset_telemetry():
    _TELEMETRY.reset()
