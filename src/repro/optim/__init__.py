from .adamw import (OptConfig, init_opt_state, apply_updates, global_norm,
                    lr_at)

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "global_norm",
           "lr_at"]
