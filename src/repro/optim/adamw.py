"""AdamW with the saturator-generated fused update kernel.

The per-parameter update is the saturated ``adamw`` tile program (paper's
technique in the optimizer hot loop: FMA-fused moments, bulk-loaded reads,
reciprocal-sqrt denominator). Supports:

* f32 / bf16 / int8 moment states — int8 uses per-row absmax block
  quantization with error-free requantization each step (the
  distributed-optimization trick that fits arctic-480B training in
  16 GB/chip; see DESIGN.md §5);
* global-norm clipping via the saturated ``l2_clip`` kernel;
* linear-warmup + cosine decay schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax import lax

from repro.kernels import ops
from repro.kernels.tile_programs import get_tile_op

# leaves above this many elements update via lax.map over the leading
# axis, bounding the f32 dequant/update transients (arctic's 156e9-element
# expert stacks would otherwise materialize 4 full f32 copies)
CHUNKED_UPDATE_ELEMS = 2 ** 31


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "f32"       # f32 | bf16 | int8


# -- int8 block quantization ----------------------------------------------------
def _quant_i8(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Per-last-axis absmax block quantization. Shape-preserving (no
    reshape) so sharding propagates cleanly through the quant/dequant."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequant_i8(s: Dict[str, jnp.ndarray], shape) -> jnp.ndarray:
    return s["q"].astype(jnp.float32) * s["scale"]


def _moment_init(p, dtype: str):
    if dtype == "int8":
        return _quant_i8(jnp.zeros(p.shape, jnp.float32))
    dt = jnp.bfloat16 if dtype == "bf16" else jnp.float32
    return jnp.zeros(p.shape, dt)


def _moment_get(s, shape, dtype: str) -> jnp.ndarray:
    if dtype == "int8":
        return _dequant_i8(s, shape)
    return s.astype(jnp.float32)


def _moment_put(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _quant_i8(x)
    dt = jnp.bfloat16 if dtype == "bf16" else jnp.float32
    return x.astype(dt)


# -- public API --------------------------------------------------------------------
def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _moment_init(p, cfg.moment_dtype),
                          params),
        "v": jax.tree.map(lambda p: _moment_init(p, cfg.moment_dtype),
                          params),
    }


def lr_at(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(grads) -> jnp.ndarray:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def apply_updates(params, grads, state, cfg: OptConfig,
                  ) -> Tuple[Any, Dict[str, Any]]:
    """One fused AdamW step. Returns (new_params, new_state)."""
    step = state["step"] + 1
    lr = lr_at(step, cfg)
    norm = global_norm(grads)
    inv_bc1 = 1.0 / (1.0 - cfg.b1 ** step.astype(jnp.float32))
    inv_bc2 = 1.0 / (1.0 - cfg.b2 ** step.astype(jnp.float32))

    def upd_core(p, g, m_s, v_s):
        g32 = _clip(g.astype(jnp.float32), norm, cfg.clip_norm)
        m = _moment_get(m_s, p.shape, cfg.moment_dtype)
        v = _moment_get(v_s, p.shape, cfg.moment_dtype)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        m2, v2, p2 = ops.adamw_update(
            p.astype(jnp.float32), g32, m, v, lr=lr, b1=cfg.b1, b2=cfg.b2,
            eps=cfg.eps, wd=wd, inv_bc1=inv_bc1, inv_bc2=inv_bc2)
        return (p2.astype(p.dtype), _moment_put(m2, cfg.moment_dtype),
                _moment_put(v2, cfg.moment_dtype))

    def upd(p, g, m_s, v_s):
        if p.ndim >= 3 and p.size >= CHUNKED_UPDATE_ELEMS:
            return lax.map(lambda t: upd_core(*t), (p, g, m_s, v_s))
        return upd_core(p, g, m_s, v_s)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}


def _clip(g32, norm, max_norm):
    """Saturated l2_clip kernel (scale by min(1, c/(norm+eps)))."""
    op = get_tile_op("l2_clip")
    if g32.ndim >= 2:
        return op.jax_ref(g32, norm=norm, max_norm=max_norm, eps=1e-9)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return g32 * scale
