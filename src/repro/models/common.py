"""Model configuration and shared utilities (RoPE/M-RoPE, init, losses)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Arctic-style dense residual MLP running in parallel with the experts
    residual_ffn_dim: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128        # N
    head_dim: int = 64          # P
    expand: int = 2             # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "swiglu"         # swiglu | gelu
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one *shared* attention+MLP block applied every k SSM
    # blocks (parameter tying, arXiv:2411.15242)
    shared_attn_every: int = 0
    # encdec (whisper): encoder depth; frontend is a stub (precomputed
    # frame embeddings are model inputs)
    n_enc_layers: int = 0
    # vlm (qwen2-vl): M-RoPE section split of head_dim/2 (t, h, w)
    mrope_sections: Optional[Tuple[int, int, int]] = None
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    # training-memory policy
    remat: bool = True
    # Megatron-style sequence parallelism for the residual stream: the
    # scan carry (saved activations) is sharded over the model axis along
    # S; XLA all-gathers at layer entry / reduce-scatters at exit
    seq_shard: bool = False
    loss_chunk: int = 512       # sequence-chunked cross-entropy (large vocab)
    # decode KV-cache storage dtype: "bf16" (default) or "f8" (e4m3 —
    # halves the cache; attention math upcasts, standard for long-context
    # serving of 100B+ models)
    kv_cache_dtype: str = "bf16"
    max_seq: int = 131_072
    sub_quadratic: bool = False  # supports long_500k shapes

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers), for rooflines."""
        d, v = self.d_model, self.vocab
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.act == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family in ("dense", "vlm"):
            n += self.n_layers * (attn + mlp + 2 * d)
        elif self.family == "moe":
            e = self.moe.n_experts
            moe_mlp = e * 3 * d * self.d_ff + d * e
            res = 3 * d * self.moe.residual_ffn_dim
            n += self.n_layers * (attn + moe_mlp + res + 2 * d)
        elif self.family == "ssm":
            n += self.n_layers * (self._ssm_block_params() + d)
        elif self.family == "hybrid":
            n += self.n_layers * (self._ssm_block_params() + d)
            n += attn + mlp + 2 * d  # one shared block
        elif self.family == "encdec":
            n += self.n_layers * (2 * attn + mlp + 3 * d)      # dec w/ cross
            n += self.n_enc_layers * (attn + mlp + 2 * d)
        return n

    def _ssm_block_params(self) -> int:
        d = self.d_model
        di = self.ssm.d_inner(d)
        nh = self.ssm.n_heads(d)
        ns = self.ssm.state_dim
        # in_proj: z, x, B, C, dt; out_proj; conv; A, D, dt_bias; norm
        in_proj = d * (2 * di + 2 * ns + nh)
        return (in_proj + di * d + self.ssm.conv_width * (di + 2 * ns)
                + 3 * nh + di)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        e, k = self.moe.n_experts, self.moe.top_k
        dead = self.n_layers * (e - k) * 3 * d * self.d_ff
        return self.param_count() - dead


# -- RoPE -----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim) in rotate-half layout."""
    freqs = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., hd/2)
    ang = jnp.concatenate([ang, ang], axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]):
    """Qwen2-VL M-RoPE. positions (3, B, S) for (t, h, w); sections sum to
    head_dim/2. Text tokens use identical t/h/w positions (equivalent to
    1-D RoPE); vision patches get distinct h/w — the frontend stub supplies
    the position ids."""
    assert sum(sections) == head_dim // 2
    freqs = rope_freqs(head_dim, theta)                        # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (3,B,S,hd/2)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang[i, ..., start:start + sec])
        start += sec
    ang1 = jnp.concatenate(parts, axis=-1)                     # (B,S,hd/2)
    ang2 = jnp.concatenate([ang1, ang1], axis=-1)
    return jnp.cos(ang2), jnp.sin(ang2)


# -- init helpers ------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# -- loss ---------------------------------------------------------------------------
def chunked_softmax_xent(hidden: jnp.ndarray, unembed: jnp.ndarray,
                         labels: jnp.ndarray, mask: jnp.ndarray,
                         chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks; per chunk the (B, c, V) logits live only
    inside the scan body (essential for 256k vocabularies at 4k seq).
    hidden: (B, S, D) f32/bf16; unembed: (D, V); labels/mask: (B, S).
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = math.gcd(S, chunk) or S
    n = S // chunk
    hid = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)
    lab = labels.reshape(B, n, chunk).swapaxes(0, 1)
    msk = mask.reshape(B, n, chunk).swapaxes(0, 1)

    from repro.parallel import ctx

    # pad vocab so the logits' V axis shards over the model axis even for
    # odd vocab sizes (whisper's 51865); padded columns are masked to -inf
    V = unembed.shape[-1]
    Vp = (V + 2047) // 2048 * 2048
    if Vp != V:
        unembed = jnp.pad(unembed, ((0, 0), (0, Vp - V)))

    @jax.checkpoint
    def body(carry, xs):
        # rematerialized: backward recomputes the (B, chunk, V) logits
        # instead of saving softmax probs for every chunk (the whole point
        # of chunking at 256k vocab)
        h, y, m = xs
        h = ctx.constrain(h, "dp", None, None)
        logits = (h.astype(jnp.float32) @ unembed.astype(jnp.float32))
        logits = ctx.constrain(logits, "dp", None, "tp")
        if Vp != V:
            col = jnp.arange(Vp)
            logits = jnp.where(col[None, None, :] < V, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                             (hid, lab, msk))
    return tot / jnp.maximum(cnt, 1.0)
