"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model) supplied by
``input_specs()``. LayerNorm + GELU + MHA (no RoPE; sinusoidal encoder
positions, learned decoder positions) to match the Whisper architecture.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.parallel import ctx
from .common import ModelConfig, chunked_softmax_xent, dense_init, split_keys
from . import layers as L


def sinusoidal_pos(S: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "encdec"
        self.cfg = cfg

    # -- init ----------------------------------------------------------------
    def _enc_layer_init(self, key):
        cfg = self.cfg
        ka, km = jax.random.split(key)
        return {"ln1": L.norm_init(cfg), "attn": L.attn_init(ka, cfg),
                "ln2": L.norm_init(cfg), "mlp": L.mlp_init(km, cfg)}

    def _dec_layer_init(self, key):
        cfg = self.cfg
        ka, kc, km = jax.random.split(key, 3)
        return {"ln1": L.norm_init(cfg), "self_attn": L.attn_init(ka, cfg),
                "ln2": L.norm_init(cfg), "cross_attn": L.attn_init(kc, cfg),
                "ln3": L.norm_init(cfg), "mlp": L.mlp_init(km, cfg)}

    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        ks = split_keys(rng, ["embed", "pos", "enc", "dec", "unembed"])
        keys_enc = jax.random.split(ks["enc"], cfg.n_enc_layers)
        keys_dec = jax.random.split(ks["dec"], cfg.n_layers)
        return {
            "embed": dense_init(ks["embed"], (cfg.vocab, cfg.d_model),
                                cfg.dtype, scale=0.02),
            "dec_pos": dense_init(ks["pos"], (cfg.max_seq, cfg.d_model),
                                  cfg.dtype, scale=0.02),
            "enc_layers": jax.vmap(self._enc_layer_init)(keys_enc),
            "dec_layers": jax.vmap(self._dec_layer_init)(keys_dec),
            "enc_norm": L.norm_init(cfg),
            "final_norm": L.norm_init(cfg),
        }

    def _unembed(self, params):
        return params["embed"].T  # whisper ties output to token embedding

    # -- encoder -----------------------------------------------------------------
    def encode(self, params, frames):
        """frames: (B, S_enc, d_model) precomputed embeddings (stub)."""
        cfg = self.cfg
        B, S, _ = frames.shape
        h = frames.astype(cfg.dtype) + sinusoidal_pos(S, cfg.d_model,
                                                      cfg.dtype)
        h = ctx.constrain(h, "dp", None, None)

        def body(h, lp):
            h = h + L.attn_apply(lp["attn"],
                                 L.norm_apply(lp["ln1"], h, cfg),
                                 None, None, cfg, causal=False)
            h = h + L.mlp_apply(lp["mlp"],
                                L.norm_apply(lp["ln2"], h, cfg), cfg)
            return ctx.constrain(h, "dp",
                                 "tp" if cfg.seq_shard else None,
                                 None), None
        step = jax.checkpoint(body) if cfg.remat else body
        h, _ = lax.scan(step, h, params["enc_layers"])
        return L.norm_apply(params["enc_norm"], h, cfg)

    # -- decoder (training) ----------------------------------------------------------
    def forward(self, params, tokens, enc_out):
        cfg = self.cfg
        B, S = tokens.shape
        h = params["embed"][tokens] + params["dec_pos"][:S][None]
        h = ctx.constrain(h, "dp", None, None)

        def body(h, lp):
            h = h + L.attn_apply(lp["self_attn"],
                                 L.norm_apply(lp["ln1"], h, cfg),
                                 None, None, cfg, causal=True)
            h = h + L.attn_apply(lp["cross_attn"],
                                 L.norm_apply(lp["ln2"], h, cfg),
                                 None, None, cfg, kv_x=enc_out)
            h = h + L.mlp_apply(lp["mlp"],
                                L.norm_apply(lp["ln3"], h, cfg), cfg)
            return ctx.constrain(h, "dp",
                                 "tp" if cfg.seq_shard else None,
                                 None), None
        step = jax.checkpoint(body) if cfg.remat else body
        h, _ = lax.scan(step, h, params["dec_layers"])
        return L.norm_apply(params["final_norm"], h, cfg)

    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        h = self.forward(params, batch["tokens"], enc_out)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(batch["labels"], jnp.float32)
        return chunked_softmax_xent(h, self._unembed(params),
                                    batch["labels"], mask,
                                    chunk=cfg.loss_chunk)

    # -- serving ------------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        Lc = cfg.n_layers
        return {
            "pos": jnp.zeros((), jnp.int32),
            "k": jnp.zeros((Lc, batch, cfg.n_kv_heads, max_seq,
                            cfg.head_dim), cfg.dtype),
            "v": jnp.zeros((Lc, batch, cfg.n_kv_heads, max_seq,
                            cfg.head_dim), cfg.dtype),
            # cross-attention K/V precomputed from the encoder output
            "xk": jnp.zeros((Lc, batch, cfg.n_kv_heads, max_seq,
                             cfg.head_dim), cfg.dtype),
            "xv": jnp.zeros((Lc, batch, cfg.n_kv_heads, max_seq,
                             cfg.head_dim), cfg.dtype),
        }

    def prefill(self, params, tokens, frames=None,
                max_seq: Optional[int] = None):
        """Encode + run decoder over prompt tokens, building caches."""
        cfg = self.cfg
        B, S = tokens.shape
        max_seq = max_seq or (S + 256)
        if frames is None:
            frames = jnp.zeros((B, S, cfg.d_model), cfg.dtype)
        enc_out = self.encode(params, frames)
        h = params["embed"][tokens] + params["dec_pos"][:S][None]

        def body(h, lp):
            xn = L.norm_apply(lp["ln1"], h, cfg)
            a, kv = L.attn_prefill(lp["self_attn"], xn, None, None, cfg)
            h = h + a
            xk = L._split_heads(enc_out @ lp["cross_attn"]["wk"],
                                cfg.n_kv_heads, cfg.head_dim)
            xv = L._split_heads(enc_out @ lp["cross_attn"]["wv"],
                                cfg.n_kv_heads, cfg.head_dim)
            xn2 = L.norm_apply(lp["ln2"], h, cfg)
            c = L.attn_apply(lp["cross_attn"], xn2, None, None, cfg,
                             kv_x=enc_out)
            h = h + c
            h = h + L.mlp_apply(lp["mlp"],
                                L.norm_apply(lp["ln3"], h, cfg), cfg)
            return h, (kv[0], kv[1], xk, xv)
        h, (k, v, xk, xv) = lax.scan(body, h, params["dec_layers"])
        k, v = jax.tree.map(
            lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 0),
                                  (0, max_seq - S), (0, 0))), (k, v))
        h = L.norm_apply(params["final_norm"], h, cfg)
        logits = (h[:, -1:].astype(jnp.float32)
                  @ self._unembed(params).astype(jnp.float32))
        cache = {"pos": jnp.int32(S), "k": k, "v": v, "xk": xk, "xv": xv}
        return logits, cache

    def decode_step(self, params, cache, token):
        cfg = self.cfg
        pos = cache["pos"]
        h = params["embed"][token] + params["dec_pos"][pos][None, None]

        def body(h, xs):
            lp, kc, vc, xk, xv = xs
            xn = L.norm_apply(lp["ln1"], h, cfg)
            a, (kc, vc) = L.attn_decode(lp["self_attn"], xn, (kc, vc), pos,
                                        cfg)
            h = h + a
            xn2 = L.norm_apply(lp["ln2"], h, cfg)
            q = L._split_heads(xn2 @ lp["cross_attn"]["wq"], cfg.n_heads,
                               cfg.head_dim)
            o = ops.attention_decode(q, xk, xv)
            h = h + L._merge_heads(o) @ lp["cross_attn"]["wo"]
            h = h + L.mlp_apply(lp["mlp"],
                                L.norm_apply(lp["ln3"], h, cfg), cfg)
            return h, (kc, vc)
        h, (ks, vs) = lax.scan(body, h, (params["dec_layers"], cache["k"],
                                         cache["v"], cache["xk"],
                                         cache["xv"]))
        cache = dict(cache, k=ks, v=vs, pos=pos + 1)
        h = L.norm_apply(params["final_norm"], h, cfg)
        logits = (h.astype(jnp.float32)
                  @ self._unembed(params).astype(jnp.float32))
        return logits, cache
