"""Model zoo: uniform factory over all assigned architecture families."""
from .common import ModelConfig, MoEConfig, SSMConfig
from .lm import LM
from .whisper import EncDecLM


def get_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return LM(cfg)


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "LM", "EncDecLM",
           "get_model"]
