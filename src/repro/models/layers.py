"""Model building blocks: attention (GQA/M-RoPE), MLP, MoE, Mamba2 SSD.

Every elementwise hot-spot routes through the saturated kernels in
:mod:`repro.kernels.ops`; matmuls stay as einsums (MXU territory the
saturator deliberately leaves alone, exactly as the paper leaves loop
structure to the compiler).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.parallel import ctx
from .common import ModelConfig, dense_init, rope_cos_sin, split_keys


def _tp_size() -> int:
    mesh = ctx.active_mesh()
    return 1 if mesh is None else mesh.shape.get("model", 1)


def _pad_heads_kv(k, v, H: int, Hp: int):
    """Repeat GQA KV to full (padded) head count locally: KV is replicated
    over the model axis (wk/wv are row-replicated), so the repeat+pad is a
    local slice-free broadcast; the subsequent head-shard constraint is a
    free local slice."""
    KH = k.shape[1]
    if KH != H:
        rep = H // KH
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if Hp != H:
        pad = ((0, 0), (0, Hp - H), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    k = ctx.constrain(k, "dp", "tp", None, None)
    v = ctx.constrain(v, "dp", "tp", None, None)
    return k, v


def _padded_H(cfg) -> int:
    """Attention heads padded to the model axis (bounded ≤4/3 compute on
    the attention core; avoids mid-head SPMD shardings whose per-block
    collectives measured 20s+/step on minitron — see EXPERIMENTS.md §Perf).
    The padding lives in the WEIGHTS (zero wq columns / zero wo rows), so
    results are exact and no activation pad/slice resharding appears."""
    tp = _tp_size()
    return ((cfg.n_heads + tp - 1) // tp) * tp


def _wq_padded(p, cfg, Hp):
    if Hp == cfg.n_heads:
        return p["wq"], p["wo"]
    extra = (Hp - cfg.n_heads) * cfg.head_dim
    wq = jnp.pad(p["wq"], ((0, 0), (0, extra)))
    wo = jnp.pad(p["wo"], ((0, extra), (0, 0)))
    return wq, wo



# ---------------------------------------------------------------------------
# blocked attention (pure jnp, memory-bounded): the CPU/dry-run path.
# Same online-softmax math as the Pallas flash kernel; flash-2 style
# custom VJP recomputes block scores instead of saving (S x S) probs.
# ---------------------------------------------------------------------------
def blocked_attention(q, k, v, *, causal=True, scale=None,
                      q_block=512, kv_block=512):
    B, H, S, D = q.shape
    KH = k.shape[1]
    if KH != H:
        rep = H // KH
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = (D ** -0.5) if scale is None else scale
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    if S % q_block or S % kv_block:
        return _naive(q, k, v, causal, scale)
    return _flash(q, k, v, causal, scale, q_block, kv_block)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, scale, q_block, kv_block):
    o, _ = _flash_fwd_impl(q, k, v, causal, scale, q_block, kv_block)
    return o


def _block_ids(nq, nk, q_block, kv_block):
    qpos = lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
    kpos = lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
    return qpos, kpos


def _flash_fwd_impl(q, k, v, causal, scale, q_block, kv_block):
    B, H, S, D = q.shape
    nq, nk = S // q_block, S // kv_block
    qb = q.reshape(B, H, nq, q_block, D).transpose(2, 0, 1, 3, 4)
    kb = k.reshape(B, H, nk, kv_block, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, nk, kv_block, D).transpose(2, 0, 1, 3, 4)
    qpos0, kpos0 = _block_ids(nq, nk, q_block, kv_block)

    def q_step(_, qi_and_q):
        qi, qt = qi_and_q

        def kv_step(carry, ki_and_kv):
            m_p, l_p, acc = carry
            ki, kt, vt = ki_and_kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qt, kt,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                msk = (qi * q_block + qpos0) >= (ki * kv_block + kpos0)
                s = jnp.where(msk[None, None], s, -1e30)
            m_c = jnp.max(s, -1, keepdims=True)
            m_n = jnp.maximum(m_p, m_c)
            alpha = jnp.exp(m_p - m_n)
            pmat = jnp.exp(s - m_n)
            l_n = alpha * l_p + pmat.sum(-1, keepdims=True)
            acc = alpha * acc + jnp.einsum(
                "bhqk,bhkd->bhqd", pmat.astype(vt.dtype), vt,
                preferred_element_type=jnp.float32)
            return (m_n, l_n, acc), None

        B_, H_ = qt.shape[0], qt.shape[1]
        init = (jnp.full((B_, H_, q_block, 1), -1e30, jnp.float32),
                jnp.zeros((B_, H_, q_block, 1), jnp.float32),
                jnp.zeros((B_, H_, q_block, D), jnp.float32))
        (m_f, l_f, acc), _ = lax.scan(kv_step, init,
                                      (jnp.arange(nk), kb, vb))
        l_safe = jnp.where(l_f == 0, 1.0, l_f)
        o = (acc / l_safe).astype(qt.dtype)
        lse = (m_f + jnp.log(l_safe))[..., 0]          # (B,H,qb)
        return None, (o, lse)

    _, (ob, lseb) = lax.scan(q_step, None, (jnp.arange(nq), qb))
    o = ob.transpose(1, 2, 0, 3, 4).reshape(B, H, S, D)
    lse = lseb.transpose(1, 2, 0, 3).reshape(B, H, S)
    return o, lse


def _flash_fwd(q, k, v, causal, scale, q_block, kv_block):
    o, lse = _flash_fwd_impl(q, k, v, causal, scale, q_block, kv_block)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, scale, q_block, kv_block, res, do):
    q, k, v, o, lse = res
    B, H, S, D = q.shape
    nq, nk = S // q_block, S // kv_block
    qb = q.reshape(B, H, nq, q_block, D).transpose(2, 0, 1, 3, 4)
    kb = k.reshape(B, H, nk, kv_block, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, nk, kv_block, D).transpose(2, 0, 1, 3, 4)
    dob = do.reshape(B, H, nq, q_block, D).transpose(2, 0, 1, 3, 4)
    lseb = lse.reshape(B, H, nq, q_block).transpose(2, 0, 1, 3)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)
    deltab = delta.reshape(B, H, nq, q_block).transpose(2, 0, 1, 3)
    qpos0, kpos0 = _block_ids(nq, nk, q_block, kv_block)

    def kv_outer(_, ki_and_kv):
        ki, kt, vt = ki_and_kv

        def q_inner(carry, qi_pack):
            dk_a, dv_a = carry
            qi, qt, dot_, lse_i, delta_i = qi_pack
            s = jnp.einsum("bhqd,bhkd->bhqk", qt, kt,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                msk = (qi * q_block + qpos0) >= (ki * kv_block + kpos0)
                s = jnp.where(msk[None, None], s, -1e30)
            pmat = jnp.exp(s - lse_i[..., None])
            dp = jnp.einsum("bhqd,bhkd->bhqk", dot_.astype(jnp.float32),
                            vt.astype(jnp.float32))
            ds = pmat * (dp - delta_i[..., None]) * scale
            dk_a = dk_a + jnp.einsum("bhqk,bhqd->bhkd", ds,
                                     qt.astype(jnp.float32))
            dv_a = dv_a + jnp.einsum("bhqk,bhqd->bhkd", pmat,
                                     dot_.astype(jnp.float32))
            dq_i = jnp.einsum("bhqk,bhkd->bhqd", ds, kt.astype(jnp.float32))
            return (dk_a, dv_a), dq_i

        B_, H_ = kt.shape[0], kt.shape[1]
        init = (jnp.zeros((B_, H_, kv_block, D), jnp.float32),
                jnp.zeros((B_, H_, kv_block, D), jnp.float32))
        (dk_b, dv_b), dq_parts = lax.scan(
            q_inner, init, (jnp.arange(nq), qb, dob, lseb, deltab))
        return None, (dk_b, dv_b, dq_parts)

    _, (dk_b, dv_b, dq_all) = lax.scan(kv_outer, None,
                                       (jnp.arange(nk), kb, vb))
    dq = dq_all.sum(0).transpose(1, 2, 0, 3, 4).reshape(B, H, S, D)
    dk = dk_b.transpose(1, 2, 0, 3, 4).reshape(B, H, S, D)
    dv = dv_b.transpose(1, 2, 0, 3, 4).reshape(B, H, S, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def _naive(q, k, v, causal, scale):
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        S = q.shape[2]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    pmat = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", pmat.astype(q.dtype), v)


def full_attention(q, k, v, *, causal=True, scale=None):
    """Dispatch: Pallas flash on TPU, blocked jnp elsewhere."""
    if ops.current_impl() == "pallas":
        return ops.attention(q, k, v, causal=causal, scale=scale)
    return blocked_attention(q, k, v, causal=causal, scale=scale)


# ---------------------------------------------------------------------------
# Attention block (GQA + RoPE), with prefill/decode cache paths
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    ks = split_keys(key, ["wq", "wk", "wv", "wo"])
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": dense_init(ks["wq"], (d, qd), cfg.dtype),
        "wk": dense_init(ks["wk"], (d, kvd), cfg.dtype),
        "wv": dense_init(ks["wv"], (d, kvd), cfg.dtype),
        "wo": dense_init(ks["wo"], (qd, d), cfg.dtype,
                         scale=(qd ** -0.5) / math.sqrt(2 * cfg.n_layers)),
    }


def _split_heads(x, n_heads, head_dim):
    B, S, _ = x.shape
    return x.reshape(B, S, n_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    B, H, S, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * D)


def attn_apply(p, x, cos, sin, cfg: ModelConfig, *, causal=True,
               kv_x: Optional[jnp.ndarray] = None):
    """Full-sequence attention. kv_x (encoder states) enables cross-attn."""
    src = x if kv_x is None else kv_x
    Hp = _padded_H(cfg)
    wq, wo = _wq_padded(p, cfg, Hp)
    q = _split_heads(x @ wq, Hp, cfg.head_dim)
    k = _split_heads(src @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(src @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    if cos is not None and kv_x is None:
        q = ops.rotary(q, cos[:, None], sin[:, None]).astype(x.dtype)
        k = ops.rotary(k, cos[:, None], sin[:, None]).astype(x.dtype)
    q = ctx.constrain(q, "dp", "tp", None, None)
    k, v = _pad_heads_kv(k, v, cfg.n_heads, Hp)
    o = full_attention(q, k, v, causal=causal and kv_x is None)
    return _merge_heads(o) @ wo


def attn_prefill(p, x, cos, sin, cfg: ModelConfig):
    """Returns (out, (k_cache, v_cache)) for subsequent decode."""
    Hp = _padded_H(cfg)
    wq, wo = _wq_padded(p, cfg, Hp)
    q = _split_heads(x @ wq, Hp, cfg.head_dim)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    if cos is not None:
        q = ops.rotary(q, cos[:, None], sin[:, None]).astype(x.dtype)
        k = ops.rotary(k, cos[:, None], sin[:, None]).astype(x.dtype)
    kv_cache = (k, v)
    q = ctx.constrain(q, "dp", "tp", None, None)
    kp, vp = _pad_heads_kv(k, v, cfg.n_heads, Hp)
    o = full_attention(q, kp, vp, causal=True)
    return _merge_heads(o) @ wo, kv_cache


def attn_decode(p, x1, kv_cache, pos, cfg: ModelConfig,
                cos1=None, sin1=None):
    """One-token decode. x1:(B,1,D); kv_cache: (k,v) each (B,KH,S,hd);
    pos: () current position. Cache updated in place at pos."""
    k_c, v_c = kv_cache
    q = _split_heads(x1 @ p["wq"], cfg.n_heads, cfg.head_dim)
    k1 = _split_heads(x1 @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v1 = _split_heads(x1 @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    if cos1 is not None:
        q = ops.rotary(q, cos1[:, None], sin1[:, None]).astype(x1.dtype)
        k1 = ops.rotary(k1, cos1[:, None], sin1[:, None]).astype(x1.dtype)
    k_c = lax.dynamic_update_slice(k_c, k1.astype(k_c.dtype),
                                   (0, 0, pos, 0))
    v_c = lax.dynamic_update_slice(v_c, v1.astype(v_c.dtype),
                                   (0, 0, pos, 0))
    S = k_c.shape[2]
    # mask out positions beyond pos
    valid = jnp.arange(S) <= pos
    scale = cfg.head_dim ** -0.5
    KH = cfg.n_kv_heads
    rep = cfg.n_heads // KH
    B = q.shape[0]
    # GQA-grouped einsum: never materialize the head-repeated KV cache
    # (for mistral-large decode_32k that repeat was ~100 GB of temps)
    qg = q.reshape(B, KH, rep, 1, cfg.head_dim)
    k_r = k_c.astype(qg.dtype) if k_c.dtype != qg.dtype else k_c
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg, k_r,
                        preferred_element_type=jnp.float32) * scale
    # keep the decode logits sharded like the cache (batch×sequence);
    # left unpinned they came back replicated (16 GiB of temps at 32k)
    logits = ctx.constrain(logits, "dp", None, None, None, "tp")
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    # Mirror the flash kernel's order of operations exactly — unnormalized
    # exp weights cast to the value dtype, PV accumulated in f32, then the
    # f32 normalizer applied — so decode reproduces teacher-forcing logits
    # bitwise instead of drifting one bf16 ulp per layer.
    m = jnp.max(logits, -1, keepdims=True)
    pmat = jnp.exp(logits - m)
    l = pmat.sum(-1, keepdims=True)
    v_r = v_c.astype(x1.dtype) if v_c.dtype != x1.dtype else v_c
    acc = jnp.einsum("bkgqs,bksd->bkgqd", pmat.astype(v_r.dtype), v_r,
                     preferred_element_type=jnp.float32)
    o = (acc / l).astype(x1.dtype)
    o = o.reshape(B, cfg.n_heads, 1, cfg.head_dim)
    o = ctx.constrain(o, "dp", None, None, None)
    return _merge_heads(o) @ p["wo"], (k_c, v_c)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.act == "swiglu":
        ks = split_keys(key, ["wg", "wu", "wd"])
        return {"wg": dense_init(ks["wg"], (d, d_ff), cfg.dtype),
                "wu": dense_init(ks["wu"], (d, d_ff), cfg.dtype),
                "wd": dense_init(ks["wd"], (d_ff, d), cfg.dtype,
                                 scale=(d_ff ** -0.5)
                                 / math.sqrt(2 * cfg.n_layers))}
    ks = split_keys(key, ["wi", "wd"])
    return {"wi": dense_init(ks["wi"], (d, d_ff), cfg.dtype),
            "wd": dense_init(ks["wd"], (d_ff, d), cfg.dtype,
                             scale=(d_ff ** -0.5)
                             / math.sqrt(2 * cfg.n_layers))}


def mlp_apply(p, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        return ops.swiglu(x @ p["wg"], x @ p["wu"]) @ p["wd"]
    return ops.gelu(x @ p["wi"]) @ p["wd"]


# ---------------------------------------------------------------------------
# MoE (capacity-based sorted dispatch; EP-shardable over the expert axis)
# ---------------------------------------------------------------------------
def moe_init(key, cfg: ModelConfig):
    mc = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, mc.n_experts
    names = ["router", "wg", "wu", "wd"]
    if mc.residual_ffn_dim:
        names.append("res")
    ks = split_keys(key, names)
    p = {
        "router": dense_init(ks["router"], (d, e), jnp.float32),
        "wg": dense_init(ks["wg"], (e, d, f), cfg.dtype),
        "wu": dense_init(ks["wu"], (e, d, f), cfg.dtype),
        "wd": dense_init(ks["wd"], (e, f, d), cfg.dtype,
                         scale=(f ** -0.5) / math.sqrt(2 * cfg.n_layers)),
    }
    if mc.residual_ffn_dim:
        rcfg = cfg
        p["res"] = mlp_init(ks["res"], rcfg, d_ff=mc.residual_ffn_dim)
    return p


def moe_apply(p, x, cfg: ModelConfig):
    """Grouped token-capacity MoE (GShard-style, dropless up to the
    capacity factor). Tokens are split into G groups aligned with the
    data-parallel axis so routing gathers stay shard-local; the expert
    einsums are sharded over the expert axis (EP) — the cross-shard
    exchange is the canonical MoE all-to-all, left to SPMD.
    Compute cost ~= top_k x one-expert cost per token."""
    from repro.parallel import ctx
    mc = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    G = 32
    while T % G:
        G //= 2
    TG = T // G
    C = max(int(math.ceil(TG * K / E * mc.capacity_factor)), 1)
    xf = x.reshape(G, TG, D)
    xf = ctx.constrain(xf, "dp", None, None)
    logits = xf.astype(jnp.float32) @ p["router"]       # (G,TG,E)
    probs = ops.moe_router_probs(logits)                # saturated softmax
    wts, idx = lax.top_k(probs, K)                      # (G,TG,K)
    wts = wts / jnp.maximum(wts.sum(-1, keepdims=True), 1e-9)

    fe = idx.reshape(G, TG * K)                         # expert ids
    order = jnp.argsort(fe, axis=-1)                    # (G,TG*K)
    counts = jax.vmap(lambda f: jnp.bincount(f, length=E))(fe)   # (G,E)
    starts = jnp.cumsum(counts, axis=-1) - counts
    eidx = jnp.repeat(jnp.arange(E), C)                 # (E*C,)
    cpos = jnp.tile(jnp.arange(C), E)
    gpos = starts[:, eidx] + cpos[None]                 # (G,E*C)
    valid = cpos[None] < counts[:, eidx]                # (G,E*C)
    gpos = jnp.where(valid, gpos, 0)
    slot = jnp.take_along_axis(order, gpos, axis=-1)    # (G,E*C) into TG*K
    tok = slot // K                                     # (G,E*C) into TG

    xg = jnp.take_along_axis(
        xf, tok[..., None], axis=1) * valid[..., None].astype(xf.dtype)
    xg = xg.reshape(G, E, C, D)
    xg = ctx.constrain(xg, "dp", "tp", None, None)      # EP dispatch
    h = jnp.einsum("gecd,edf->gecf", xg, p["wg"])
    u = jnp.einsum("gecd,edf->gecf", xg, p["wu"])
    a = ops.swiglu(h, u)
    y = jnp.einsum("gecf,efd->gecd", a, p["wd"])
    y = y.reshape(G, E * C, D)
    w_flat = jnp.take_along_axis(wts.reshape(G, TG * K), slot, axis=-1)
    y = y * (w_flat * valid)[..., None].astype(y.dtype)
    out = jnp.zeros((G, TG, D), x.dtype)
    out = jax.vmap(lambda o, t, yy: o.at[t].add(yy))(out, tok,
                                                     y.astype(x.dtype))
    out = ctx.constrain(out, "dp", None, None)
    # router aux loss (load balancing)
    me = probs.mean((0, 1))                             # (E,)
    ce = counts.sum(0).astype(jnp.float32) / (T * K)
    aux = E * jnp.sum(me * ce)
    if mc.residual_ffn_dim:
        out = out + mlp_apply(p["res"], xf, cfg)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------
def mamba_init(key, cfg: ModelConfig):
    """Separate per-stream projections (z/x/B/C/dt) so tensor parallelism
    can shard the d_inner streams without slicing through a fused dim."""
    sc = cfg.ssm
    d = cfg.d_model
    di = sc.d_inner(d)
    nh = sc.n_heads(d)
    N = sc.state_dim
    ks = split_keys(key, ["z", "x", "B", "C", "dt", "out", "cx", "cb",
                          "cc", "bias"])
    return {
        "w_z": dense_init(ks["z"], (d, di), cfg.dtype),
        "w_x": dense_init(ks["x"], (d, di), cfg.dtype),
        "w_B": dense_init(ks["B"], (d, N), cfg.dtype),
        "w_C": dense_init(ks["C"], (d, N), cfg.dtype),
        "w_dt": dense_init(ks["dt"], (d, nh), cfg.dtype),
        "w_out": dense_init(ks["out"], (di, d), cfg.dtype,
                            scale=(di ** -0.5) / math.sqrt(2 * cfg.n_layers)),
        "conv_x": dense_init(ks["cx"], (sc.conv_width, di), cfg.dtype,
                             scale=0.5),
        "conv_b": dense_init(ks["cb"], (sc.conv_width, N), cfg.dtype,
                             scale=0.5),
        "conv_c": dense_init(ks["cc"], (sc.conv_width, N), cfg.dtype,
                             scale=0.5),
        "a_log": jnp.zeros((nh,), jnp.float32)
        + jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jax.random.uniform(ks["bias"], (nh,), jnp.float32,
                                      -4.0, -1.0),
        "norm_g": jnp.ones((di,), cfg.dtype),
    }


def _causal_conv(u, w):
    """Depthwise causal conv. u:(B,S,Ch) w:(W,Ch)."""
    W = w.shape[0]
    pads = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for t in range(W):
        out = out + pads[:, t:t + u.shape[1]] * w[t]
    return out


def _mamba_proj(p, x, cfg):
    """Input projections: z, xs, b, c, dt_raw (separate streams)."""
    sc = cfg.ssm
    d = cfg.d_model
    di = sc.d_inner(d)
    N = sc.state_dim
    nh = sc.n_heads(d)
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    b = x @ p["w_B"]
    c = x @ p["w_C"]
    dt_raw = x @ p["w_dt"]
    return z, xs, b, c, dt_raw, di, N, nh


def mamba_apply(p, x, cfg: ModelConfig):
    sc = cfg.ssm
    B, S, _ = x.shape
    z, xs, b, c, dt_raw, di, N, nh = _mamba_proj(p, x, cfg)
    xs = _causal_conv(xs, p["conv_x"])
    b = _causal_conv(b, p["conv_b"])
    c = _causal_conv(c, p["conv_c"])
    xs = xs * lax.logistic(xs)                          # silu
    b = b * lax.logistic(b)
    c = c * lax.logistic(c)
    b_mat = b.astype(jnp.float32)
    c_mat = c.astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                # (B,S,nh)
    y = ops.ssd(xs.reshape(B, S, nh, sc.head_dim).astype(jnp.float32),
                dt, p["a_log"], b_mat, c_mat, p["d_skip"],
                chunk=sc.chunk)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = ops.rmsnorm_gated(y, z, p["norm_g"])
    return y @ p["w_out"]


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    sc = cfg.ssm
    d = cfg.d_model
    di = sc.d_inner(d)
    nh = sc.n_heads(d)
    return {
        "h": jnp.zeros((batch, nh, sc.state_dim, sc.head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, sc.conv_width - 1, di), dtype),
        "conv_b": jnp.zeros((batch, sc.conv_width - 1, sc.state_dim), dtype),
        "conv_c": jnp.zeros((batch, sc.conv_width - 1, sc.state_dim), dtype),
    }


def mamba_decode(p, x1, state, cfg: ModelConfig):
    """One-token recurrent step. x1:(B,1,D); state from mamba_init_state."""
    sc = cfg.ssm
    B = x1.shape[0]
    z, xs, b, c, dt_raw, di, N, nh = _mamba_proj(p, x1, cfg)

    def conv_step(hist, new, w):
        hist = jnp.concatenate([hist, new], axis=1)       # (B,W,Ch)
        out = jnp.einsum("bwc,wc->bc", hist, w)[:, None]
        return out * lax.logistic(out), hist[:, 1:]

    xs_c, cx = conv_step(state["conv_x"], xs, p["conv_x"])
    b_c, cb = conv_step(state["conv_b"], b, p["conv_b"])
    c_c, cc = conv_step(state["conv_c"], c, p["conv_c"])
    b_t = b_c[:, 0].astype(jnp.float32)
    c_t = c_c[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    h, y = ops.ssd_decode(state["h"],
                          xs_c[:, 0].reshape(B, nh, sc.head_dim)
                          .astype(jnp.float32),
                          dt, p["a_log"], b_t, c_t, p["d_skip"])
    y = y.reshape(B, 1, di).astype(x1.dtype)
    y = ops.rmsnorm_gated(y, z, p["norm_g"])
    new_state = {"h": h, "conv_x": cx, "conv_b": cb, "conv_c": cc}
    return y @ p["w_out"], new_state


# ---------------------------------------------------------------------------
# Norm dispatcher
# ---------------------------------------------------------------------------
def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"g": jnp.ones((d,), cfg.dtype), "b": jnp.zeros((d,), cfg.dtype)}
    return {"g": jnp.ones((d,), cfg.dtype)}


def norm_apply(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        out = ops.layernorm(xf, p["g"].astype(jnp.float32),
                            p["b"].astype(jnp.float32))
    else:
        out = ops.rmsnorm(xf, p["g"].astype(jnp.float32))
    return out.astype(x.dtype)
