"""Decoder-only language models: dense GQA, MoE, Mamba2 SSD, Zamba2 hybrid,
and Qwen2-VL text backbone (M-RoPE). One scan-compiled layer stack per
family — 88-layer configs compile one layer body.

Uniform API (used by launch/train.py, launch/serve.py, launch/dryrun.py):
  init(rng) -> params
  loss(params, batch) -> scalar            batch: tokens/labels[/positions]
  prefill(params, tokens) -> (logits, cache)
  init_cache(batch, seq) -> cache          (decode dry-run entry)
  decode_step(params, cache, token) -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.kernels.ssd_scan import ssd_scan_jnp
from repro.parallel import ctx
from .common import (ModelConfig, chunked_softmax_xent, dense_init,
                     mrope_cos_sin, rope_cos_sin, split_keys)
from . import layers as L


def _stacked_init(layer_init_fn, key, n_layers):
    keys = jax.random.split(key, n_layers)
    return jax.vmap(layer_init_fn)(keys)


class LM:
    """Decoder-only LM. Family-specific blocks, shared skeleton."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.family in ("dense", "moe", "ssm", "hybrid", "vlm")
        self.cfg = cfg

    # -- parameters ------------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        ks = split_keys(rng, ["embed", "unembed", "layers", "shared",
                              "final"])
        params: Dict[str, Any] = {
            "embed": dense_init(ks["embed"], (cfg.vocab, cfg.d_model),
                                cfg.dtype, scale=0.02),
            "final_norm": L.norm_init(cfg),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(
                ks["unembed"], (cfg.d_model, cfg.vocab), cfg.dtype)
        params["layers"] = _stacked_init(
            lambda k: self._layer_init(k), ks["layers"], cfg.n_layers)
        if cfg.family == "hybrid":
            params["shared"] = self._shared_block_init(ks["shared"])
        return params

    def _layer_init(self, key):
        cfg = self.cfg
        if cfg.family in ("dense", "vlm"):
            ka, km = jax.random.split(key)
            return {"ln1": L.norm_init(cfg), "attn": L.attn_init(ka, cfg),
                    "ln2": L.norm_init(cfg), "mlp": L.mlp_init(km, cfg)}
        if cfg.family == "moe":
            ka, km = jax.random.split(key)
            return {"ln1": L.norm_init(cfg), "attn": L.attn_init(ka, cfg),
                    "ln2": L.norm_init(cfg), "moe": L.moe_init(km, cfg)}
        # ssm / hybrid: pure mamba2 block
        return {"ln1": L.norm_init(cfg), "mamba": L.mamba_init(key, cfg)}

    def _shared_block_init(self, key):
        cfg = self.cfg
        ka, km = jax.random.split(key)
        return {"ln1": L.norm_init(cfg), "attn": L.attn_init(ka, cfg),
                "ln2": L.norm_init(cfg), "mlp": L.mlp_init(km, cfg)}

    # -- rope ---------------------------------------------------------------------
    def _cos_sin(self, positions, batch_positions=None):
        cfg = self.cfg
        if cfg.family == "vlm":
            pos3 = batch_positions
            if pos3 is None:
                pos3 = jnp.broadcast_to(positions[None, None, :],
                                        (3, 1, positions.shape[-1]))
            return mrope_cos_sin(pos3, cfg.head_dim, cfg.rope_theta,
                                 cfg.mrope_sections)
        cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
        return cos[None], sin[None]       # (1, S, hd)

    # -- forward (full sequence) ------------------------------------------------------
    def _layer_apply(self, p, h, cos, sin):
        cfg = self.cfg
        if cfg.family in ("dense", "vlm"):
            h = h + L.attn_apply(p["attn"], L.norm_apply(p["ln1"], h, cfg),
                                 cos, sin, cfg)
            h = h + L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], h, cfg), cfg)
            return h, jnp.float32(0.0)
        if cfg.family == "moe":
            h = h + L.attn_apply(p["attn"], L.norm_apply(p["ln1"], h, cfg),
                                 cos, sin, cfg)
            y, aux = L.moe_apply(p["moe"], L.norm_apply(p["ln2"], h, cfg), cfg)
            return h + y, aux
        # ssm / hybrid
        h = h + L.mamba_apply(p["mamba"], L.norm_apply(p["ln1"], h, cfg), cfg)
        return h, jnp.float32(0.0)

    def forward(self, params, tokens, positions3=None):
        """tokens (B, S) -> final hidden (B, S, D), aux loss."""
        cfg = self.cfg
        B, S = tokens.shape
        h = ctx.constrain(params["embed"][tokens], "dp", None, None)
        pos = jnp.arange(S)
        cos, sin = self._cos_sin(pos, positions3)

        seq_ax = "tp" if cfg.seq_shard else None

        def body(h, lp):
            out, aux = self._layer_apply(lp, h, cos, sin)
            return ctx.constrain(out, "dp", seq_ax, None), aux

        step = jax.checkpoint(body) if cfg.remat else body

        if cfg.family == "hybrid" and cfg.shared_attn_every:
            k = cfg.shared_attn_every
            n_out = cfg.n_layers // k
            grouped = jax.tree.map(
                lambda a: a.reshape((n_out, k) + a.shape[1:]),
                params["layers"])
            shared = params["shared"]

            def outer(h, gp):
                h, auxs = lax.scan(step, h, gp)
                h = h + L.attn_apply(
                    shared["attn"], L.norm_apply(shared["ln1"], h, cfg),
                    cos, sin, cfg)
                h = h + L.mlp_apply(
                    shared["mlp"], L.norm_apply(shared["ln2"], h, cfg), cfg)
                return h, auxs.sum()

            outer_step = jax.checkpoint(outer) if cfg.remat else outer
            h, auxs = lax.scan(outer_step, h, grouped)
        else:
            h, auxs = lax.scan(step, h, params["layers"])
        h = L.norm_apply(params["final_norm"], h, cfg)
        return h, auxs.sum()

    def _unembed(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["unembed"]

    def loss(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(labels, jnp.float32)
        h, aux = self.forward(params, tokens, batch.get("positions"))
        xent = chunked_softmax_xent(h, self._unembed(params), labels, mask,
                                    chunk=cfg.loss_chunk)
        return xent + 0.01 * aux

    def logits(self, params, tokens, positions3=None):
        h, _ = self.forward(params, tokens, positions3)
        return h.astype(jnp.float32) @ self._unembed(params).astype(
            jnp.float32)

    # -- caches ------------------------------------------------------------------------
    def _cache_dtype(self):
        return jnp.float8_e4m3fn if self.cfg.kv_cache_dtype == "f8" \
            else self.cfg.dtype

    def init_cache(self, batch: int, max_seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        Lc = cfg.n_layers
        cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        if cfg.family in ("dense", "moe", "vlm"):
            cache["k"] = jnp.zeros((Lc, batch, cfg.n_kv_heads, max_seq,
                                    cfg.head_dim), self._cache_dtype())
            cache["v"] = jnp.zeros_like(cache["k"])
        elif cfg.family == "ssm":
            st = L.mamba_init_state(cfg, batch, cfg.dtype)
            cache["ssm"] = jax.tree.map(
                lambda a: jnp.zeros((Lc,) + a.shape, a.dtype), st)
        elif cfg.family == "hybrid":
            st = L.mamba_init_state(cfg, batch, cfg.dtype)
            cache["ssm"] = jax.tree.map(
                lambda a: jnp.zeros((Lc,) + a.shape, a.dtype), st)
            n_shared = cfg.n_layers // cfg.shared_attn_every
            cache["k"] = jnp.zeros((n_shared, batch, cfg.n_kv_heads,
                                    max_seq, cfg.head_dim),
                                   self._cache_dtype())
            cache["v"] = jnp.zeros_like(cache["k"])
        return cache

    # -- prefill ---------------------------------------------------------------------------
    def prefill(self, params, tokens, positions3=None,
                max_seq: Optional[int] = None):
        """Full-sequence pass building a decode cache; returns last logits.
        ``max_seq`` reserves cache room for decode growth (default S+256)."""
        cfg = self.cfg
        B, S = tokens.shape
        max_seq = max_seq or (S + 256)
        assert max_seq >= S
        h = params["embed"][tokens]
        pos = jnp.arange(S)
        cos, sin = self._cos_sin(pos, positions3)
        cache = self.init_cache(B, max_seq)
        cache["pos"] = jnp.int32(S)

        if cfg.family in ("dense", "moe", "vlm"):
            def body(h, lp):
                xn = L.norm_apply(lp["ln1"], h, cfg)
                a, kv = L.attn_prefill(lp["attn"], xn, cos, sin, cfg)
                h = h + a
                if cfg.family == "moe":
                    y, _ = L.moe_apply(lp["moe"],
                                       L.norm_apply(lp["ln2"], h, cfg), cfg)
                else:
                    y = L.mlp_apply(lp["mlp"],
                                    L.norm_apply(lp["ln2"], h, cfg), cfg)
                return h + y, kv
            h, kvs = lax.scan(body, h, params["layers"])
            cdt = self._cache_dtype()
            cache["k"], cache["v"] = jax.tree.map(
                lambda a: jnp.pad(a.astype(cdt),
                                  ((0, 0), (0, 0), (0, 0),
                                   (0, max_seq - S), (0, 0))), kvs)
        elif cfg.family in ("ssm", "hybrid"):
            h, cache = self._prefill_ssm(params, h, cos, sin, cache)
        h = L.norm_apply(params["final_norm"], h, cfg)
        logits = (h[:, -1:].astype(jnp.float32)
                  @ self._unembed(params).astype(jnp.float32))
        return logits, cache

    def _prefill_ssm(self, params, h, cos, sin, cache):
        cfg = self.cfg
        sc = cfg.ssm
        B, S, _ = h.shape

        def mamba_prefill(lp, h):
            xn = L.norm_apply(lp["ln1"], h, cfg)
            mp = lp["mamba"]
            z, xs, b, c, dt_raw, di, N, nh = L._mamba_proj(mp, xn, cfg)
            w = sc.conv_width - 1
            st = {"conv_x": xs[:, -w:, :].astype(cfg.dtype),
                  "conv_b": b[:, -w:, :].astype(cfg.dtype),
                  "conv_c": c[:, -w:, :].astype(cfg.dtype)}
            xs_c = L._causal_conv(xs, mp["conv_x"])
            b_c = L._causal_conv(b, mp["conv_b"])
            c_c = L._causal_conv(c, mp["conv_c"])
            xs_c = xs_c * lax.logistic(xs_c)
            b_mat = (b_c * lax.logistic(b_c)).astype(jnp.float32)
            c_mat = (c_c * lax.logistic(c_c)).astype(jnp.float32)
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                                 + mp["dt_bias"])
            y, hf = ssd_scan_jnp(
                xs_c.reshape(B, S, nh, sc.head_dim).astype(jnp.float32), dt,
                mp["a_log"], b_mat, c_mat, mp["d_skip"],
                chunk=sc.chunk, return_state=True)
            y = y.reshape(B, S, di).astype(h.dtype)
            y = ops.rmsnorm_gated(y, z, mp["norm_g"])
            st["h"] = hf
            return h + y @ mp["w_out"], st

        if cfg.family == "ssm":
            def body(h, lp):
                return mamba_prefill(lp, h)
            h, states = lax.scan(body, h, params["layers"])
            cache["ssm"] = states
            return h, cache
        # hybrid
        k = cfg.shared_attn_every
        n_out = cfg.n_layers // k
        grouped = jax.tree.map(lambda a: a.reshape((n_out, k) + a.shape[1:]),
                               params["layers"])
        shared = params["shared"]

        def outer(h, gp):
            h, states = lax.scan(lambda hh, lp: mamba_prefill(lp, hh), h, gp)
            xn = L.norm_apply(shared["ln1"], h, cfg)
            a, kv = L.attn_prefill(shared["attn"], xn, cos, sin, cfg)
            h = h + a
            h = h + L.mlp_apply(shared["mlp"],
                                L.norm_apply(shared["ln2"], h, cfg), cfg)
            return h, (states, kv)
        h, (states, kvs) = lax.scan(outer, h, grouped)
        cache["ssm"] = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), states)
        pad = cache["k"].shape[3] - kvs[0].shape[3]
        cdt = self._cache_dtype()
        cache["k"], cache["v"] = jax.tree.map(
            lambda a: jnp.pad(a.astype(cdt),
                              ((0, 0), (0, 0), (0, 0), (0, pad),
                               (0, 0))), kvs)
        return h, cache

    # -- decode -------------------------------------------------------------------------------
    def decode_step(self, params, cache, token):
        """token (B, 1) int32; returns (logits (B,1,V), cache)."""
        cfg = self.cfg
        B = token.shape[0]
        h = params["embed"][token]
        pos = cache["pos"]
        cos1, sin1 = self._cos_sin(pos[None].astype(jnp.int32))
        if cfg.family in ("dense", "moe", "vlm"):
            def body(h, xs):
                lp, kc, vc = xs
                xn = L.norm_apply(lp["ln1"], h, cfg)
                a, (kc, vc) = L.attn_decode(lp["attn"], xn, (kc, vc), pos,
                                            cfg, cos1, sin1)
                h = h + a
                if cfg.family == "moe":
                    y, _ = L.moe_apply(lp["moe"],
                                       L.norm_apply(lp["ln2"], h, cfg), cfg)
                else:
                    y = L.mlp_apply(lp["mlp"],
                                    L.norm_apply(lp["ln2"], h, cfg), cfg)
                return h + y, (kc, vc)
            h, (ks, vs) = lax.scan(body, h, (params["layers"], cache["k"],
                                             cache["v"]))
            cache = dict(cache, k=ks, v=vs, pos=pos + 1)
        elif cfg.family == "ssm":
            def body(h, xs):
                lp, st = xs
                xn = L.norm_apply(lp["ln1"], h, cfg)
                y, st = L.mamba_decode(lp["mamba"], xn, st, cfg)
                return h + y, st
            h, states = lax.scan(body, h, (params["layers"], cache["ssm"]))
            cache = dict(cache, ssm=states, pos=pos + 1)
        else:  # hybrid
            k = cfg.shared_attn_every
            n_out = cfg.n_layers // k
            grouped = jax.tree.map(
                lambda a: a.reshape((n_out, k) + a.shape[1:]),
                params["layers"])
            gstates = jax.tree.map(
                lambda a: a.reshape((n_out, k) + a.shape[1:]), cache["ssm"])
            shared = params["shared"]

            def outer(h, xs):
                gp, st, kc, vc = xs

                def inner(hh, ys):
                    lp, s1 = ys
                    xn = L.norm_apply(lp["ln1"], hh, cfg)
                    y, s1 = L.mamba_decode(lp["mamba"], xn, s1, cfg)
                    return hh + y, s1
                h, st = lax.scan(inner, h, (gp, st))
                xn = L.norm_apply(shared["ln1"], h, cfg)
                a, (kc, vc) = L.attn_decode(shared["attn"], xn, (kc, vc),
                                            pos, cfg, cos1, sin1)
                h = h + a
                h = h + L.mlp_apply(shared["mlp"],
                                    L.norm_apply(shared["ln2"], h, cfg), cfg)
                return h, (st, kc, vc)
            h, (gstates, ks, vs) = lax.scan(
                outer, h, (grouped, gstates, cache["k"], cache["v"]))
            cache = dict(cache,
                         ssm=jax.tree.map(
                             lambda a: a.reshape((-1,) + a.shape[2:]),
                             gstates),
                         k=ks, v=vs, pos=pos + 1)
        h = L.norm_apply(params["final_norm"], h, cfg)
        logits = (h.astype(jnp.float32)
                  @ self._unembed(params).astype(jnp.float32))
        return logits, cache
