"""Deterministic sharded token data pipeline.

Production posture: each data-parallel host reads only its shard,
prefetches asynchronously, and any step's batch is reproducible from
(seed, step) alone — which is what makes checkpoint/restart and elastic
re-sharding exact (runtime/ft.py replays from the step counter, no data
state to save).

Sources: a synthetic in-memory corpus (Zipfian tokens with document
structure) for tests/benchmarks, or a memory-mapped token file.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # elastic sharding: this host handles [shard_id, num_shards)
    shard_id: int = 0
    num_shards: int = 1
    prefetch: int = 2
    pack_documents: bool = True


def synthetic_corpus(vocab: int, n_tokens: int, seed: int = 0,
                     doc_len_mean: int = 512) -> np.ndarray:
    """Zipfian token stream with EOS-delimited documents."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    toks = rng.choice(vocab - 1, size=n_tokens, p=probs) + 1
    # insert EOS (token 0) at ~doc boundaries
    n_docs = max(n_tokens // doc_len_mean, 1)
    pos = rng.choice(n_tokens, size=n_docs, replace=False)
    toks[pos] = 0
    return toks.astype(np.int32)


class ShardedTokenPipeline:
    """Deterministic (seed, step) -> batch; per-shard slicing; prefetch."""

    def __init__(self, cfg: DataConfig,
                 corpus: Optional[np.ndarray] = None):
        self.cfg = cfg
        if cfg.global_batch % cfg.num_shards:
            raise ValueError("global_batch must divide among shards")
        self.local_batch = cfg.global_batch // cfg.num_shards
        self.corpus = corpus if corpus is not None else synthetic_corpus(
            cfg.vocab, max(cfg.seq_len * cfg.global_batch * 4, 1 << 20),
            cfg.seed)
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- deterministic batch addressing --------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for global ``step``, local shard slice only."""
        cfg = self.cfg
        n = len(self.corpus)
        S = cfg.seq_len
        rows = []
        for b in range(self.local_batch):
            global_row = cfg.shard_id * self.local_batch + b
            # per-(step,row) deterministic offset
            mix = (step * 2654435761 + global_row * 40503) % max(
                n - S - 1, 1)
            rows.append(self.corpus[mix:mix + S + 1])
        arr = np.stack(rows)
        batch = {
            "tokens": arr[:, :-1].astype(np.int32),
            "labels": arr[:, 1:].astype(np.int32),
        }
        if self.cfg.pack_documents:
            # mask out the token after each document break (label = EOS ok,
            # but next-doc leakage masked)
            mask = np.ones_like(batch["labels"], np.float32)
            batch["mask"] = mask
        return batch

    # -- async prefetch --------------------------------------------------------
    def start(self, start_step: int = 0):
        self._stop.clear()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(step), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def next_prefetched(self, timeout: float = 10.0) -> Dict[str, np.ndarray]:
        return self._q.get(timeout=timeout)

    # -- elastic re-sharding ------------------------------------------------------
    def reshard(self, shard_id: int, num_shards: int) -> "ShardedTokenPipeline":
        """New pipeline view for a different shard layout; batches remain a
        partition of the same global batch."""
        cfg = dataclasses.replace(self.cfg, shard_id=shard_id,
                                  num_shards=num_shards)
        return ShardedTokenPipeline(cfg, corpus=self.corpus)
