"""Gradient compression for the DP all-reduce, with error feedback.

At multi-pod scale the gradient all-reduce over the pod (DCI) axis is the
bandwidth bottleneck; compressing the pod-axis reduction is the standard
trick. Implemented jittable and exact-shape-preserving:

  * bf16 compression — halves wire bytes, negligible quality loss;
  * int8 block compression — per-row absmax scale (4x fewer bytes), with
    **error feedback**: the quantization residual is carried into the next
    step's gradient so bias does not accumulate (Seide et al., 1-bit SGD
    lineage).

Usage in the train step:
    comp = Compressor("int8_ef")
    g_c, new_state = comp.compress(grads, state)      # before all-reduce
    grads = comp.decompress(g_c)                      # after
The wire-byte saving shows up in the roofline collective term (§Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

MODES = ("none", "bf16", "int8", "int8_ef")


@dataclasses.dataclass(frozen=True)
class Compressor:
    mode: str = "none"

    def __post_init__(self):
        assert self.mode in MODES

    def init_state(self, grads):
        if self.mode != "int8_ef":
            return None
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                            grads)

    def compress(self, grads, state=None) -> Tuple[Any, Any]:
        if self.mode == "none":
            return grads, state
        if self.mode == "bf16":
            return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), \
                state
        if self.mode == "int8":
            return jax.tree.map(_q8, grads), state

        # int8 with error feedback
        def q_ef(g, e):
            corrected = g.astype(jnp.float32) + e
            q = _q8(corrected)
            back = _dq8(q)
            return q, corrected - back
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(state)
        pairs = [q_ef(g, e) for g, e in zip(flat_g, flat_e)]
        qs = tdef.unflatten([p[0] for p in pairs])
        errs = tdef.unflatten([p[1] for p in pairs])
        return qs, errs

    def decompress(self, comp):
        if self.mode == "none":
            return comp
        if self.mode == "bf16":
            return jax.tree.map(lambda g: g.astype(jnp.float32), comp)
        return jax.tree.map(_dq8, comp,
                            is_leaf=lambda x: isinstance(x, dict)
                            and "q" in x)

    def wire_bytes(self, grads) -> int:
        """Bytes on the wire per all-reduce pass (for roofline accounting)."""
        def nbytes(g):
            n = 1
            for d in g.shape:
                n *= d
            if self.mode == "none":
                return n * g.dtype.itemsize
            if self.mode == "bf16":
                return n * 2
            rows = n // g.shape[-1] if g.ndim else 1
            return n + 4 * rows          # int8 payload + f32 scales
        return sum(nbytes(g) for g in jax.tree.leaves(grads))


def _q8(g) -> Dict[str, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    flat = g32.reshape(-1, g32.shape[-1]) if g32.ndim > 1 \
        else g32.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(g.shape), "scale": scale.astype(jnp.float32),
            "shape": jnp.zeros((g32.ndim,), jnp.int8)}  # static ndim tag


def _dq8(c: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    q = c["q"]
    flat = q.reshape(-1, q.shape[-1]) if q.ndim > 1 else q.reshape(1, -1)
    return (flat.astype(jnp.float32) * c["scale"]).reshape(q.shape)
