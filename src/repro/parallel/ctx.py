"""Sharding context: lets model code place activation sharding constraints
without threading the mesh through every call.

``activate(mesh)`` (context manager) is set by the launcher/dry-run; model
code calls ``constrain(x, "data", None, "model")``-style hints which are
no-ops when no mesh is active (smoke tests, single device).

Axis aliases: "dp" expands to all data axes of the active mesh
(("pod","data") on the multi-pod mesh), "tp" to the model axis.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def activate(mesh: Optional[Mesh]):
    prev = active_mesh()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def _expand(mesh: Mesh, axis):
    if axis == "dp":
        dp = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
        return dp if len(dp) > 1 else (dp[0] if dp else None)
    if axis == "tp":
        return "model" if "model" in mesh.axis_names else None
    return axis if axis in (None,) or axis in mesh.axis_names else None


def _fits(mesh: Mesh, dim: int, axis) -> bool:
    if axis is None:
        return True
    names = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return dim % size == 0


def constrain(x, *axes):
    """with_sharding_constraint if a mesh is active and dims divide."""
    mesh = active_mesh()
    if mesh is None:
        return x
    resolved = []
    for dim, ax in zip(x.shape, axes):
        ax = _expand(mesh, ax)
        resolved.append(ax if _fits(mesh, dim, ax) else None)
    spec = P(*resolved)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def dp_size() -> int:
    mesh = active_mesh()
    if mesh is None:
        return 1
    out = 1
    for n in mesh.axis_names:
        if n in ("pod", "data"):
            out *= mesh.shape[n]
    return out
