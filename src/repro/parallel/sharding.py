"""Sharding rules: DP / TP (Megatron) / EP (experts) / SP (sequence) / FSDP.

Spec construction is *path-based*: every parameter leaf is matched by its
pytree path and gets a PartitionSpec aligned with the mesh axes
``(pod, data, model)`` (multi-pod) or ``(data, model)`` (single pod).

Rules (with automatic divisibility fallback — a non-dividing axis is
dropped to replication rather than failing):

  embed (V, D)            -> (model, fsdp)         vocab-parallel
  unembed (D, V)          -> (fsdp, model)
  wq/wg/wu/w_z/w_x (D, F) -> (fsdp, model)         column-parallel
  wo/wd/w_out (F, D)      -> (model, fsdp)         row-parallel
  wk/wv (D, KVD)          -> (fsdp, None)          GQA KV replicated
  moe wg/wu (E, D, F)     -> (model, fsdp, None)   expert-parallel
  moe wd (E, F, D)        -> (model, None, fsdp)
  router, norms, scalars  -> replicated
  mamba conv_x (W, di)    -> (None, model); per-head vectors (nh,) -> model

FSDP (sharding the non-TP dim over the data axes) turns on automatically
for configs above ``FSDP_THRESHOLD`` parameters; under ``lax.scan`` XLA
all-gathers one layer at a time, overlapping with compute (the standard
ZeRO-3 schedule).

Activations: tokens/labels shard batch over (pod, data). Decode caches
shard batch over data, KV heads over model when divisible, else the
sequence axis (SP).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import ModelConfig

FSDP_THRESHOLD = 30e9


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...]       # data-parallel axes (("pod","data") or ("data",))
    tp: str = "model"

    @property
    def dp_spec(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]


def mesh_axes(mesh: Mesh) -> MeshAxes:
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    return MeshAxes(dp=dp)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return axis is None or dim % _axis_size(mesh, axis) == 0


def _spec(mesh: Mesh, shape, *axes):
    """Build a PartitionSpec, dropping axes that don't divide."""
    out = []
    for dim, ax in zip(shape, axes):
        out.append(ax if (ax is not None and _fits(dim, mesh, ax)) else None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params_tree, mesh: Mesh,
                fsdp: Optional[bool] = None):
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays)."""
    ax = mesh_axes(mesh)
    if fsdp is None:
        fsdp = cfg.param_count() > FSDP_THRESHOLD
    fs = ax.dp_spec if fsdp else None
    tp = ax.tp

    def leaf_spec(path, leaf):
        name = _path_str(path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        stacked = 1 if _is_stacked(name) else 0

        def S(*axes):  # pad for the stacked layer axis
            return _spec(mesh, shape, *([None] * stacked + list(axes)))

        base = name.rsplit("/", 1)[-1]
        if "embed" == base:
            return _spec(mesh, shape, tp, fs)
        if "unembed" == base:
            return _spec(mesh, shape, fs, tp)
        if "dec_pos" == base:
            return _spec(mesh, shape, None, None)
        if base in ("wq", "wg", "wu", "wi", "w_z", "w_x"):
            if "moe" in name and nd - stacked == 3:   # (E, D, F)
                return S(tp, fs, None)
            return S(fs, tp)
        if base in ("wo", "wd", "w_out"):
            if "moe" in name and nd - stacked == 3:   # (E, F, D)
                return S(tp, None, fs)
            return S(tp, fs)
        if base in ("wk", "wv"):
            return S(fs, None)
        if base == "router":
            return S(None, None)
        if base in ("w_B", "w_C", "w_dt"):
            return S(None, None)
        if base == "conv_x":
            return S(None, tp)
        if base in ("conv_b", "conv_c"):
            return S(None, None)
        if base in ("a_log", "d_skip", "dt_bias"):
            return S(tp)
        if base == "norm_g":                          # (di,) gated norm
            return S(tp)
        # norms (g, b), scalars
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)


def _is_stacked(name: str) -> bool:
    return ("layers" in name or "enc_layers" in name
            or "dec_layers" in name)


def opt_state_specs(cfg: ModelConfig, opt_state_tree, param_spec_tree,
                    mesh: Mesh):
    """Optimizer moments inherit the param spec; int8 scale rows follow the
    leading axes; step is replicated."""
    def match(ps, leaf_tree):
        if isinstance(leaf_tree, dict) and "q" in leaf_tree:  # int8 moments
            # scale has the q shape with last dim 1: inherit all but last
            axes = list(ps) + [None] * (len(leaf_tree["q"].shape) - len(ps))
            scale_spec = P(*(axes[:-1] + [None])) if axes else P()
            return {"q": ps, "scale": scale_spec}
        return ps

    return {
        "step": P(),
        "m": jax.tree.map(match, param_spec_tree, opt_state_tree["m"],
                          is_leaf=lambda x: isinstance(x, P)),
        "v": jax.tree.map(match, param_spec_tree, opt_state_tree["v"],
                          is_leaf=lambda x: isinstance(x, P)),
    }


def batch_specs(cfg: ModelConfig, batch_tree, mesh: Mesh):
    """Token batches: shard batch dim over all data axes (drop if it does
    not divide, e.g. long_500k batch=1)."""
    ax = mesh_axes(mesh)

    def leaf(path, leaf):
        shape = tuple(leaf.shape)
        name = _path_str(path)
        if name == "positions":        # (3, B, S) for vlm
            return _spec(mesh, shape, None, ax.dp_spec, None)
        if len(shape) >= 1:
            return _spec(mesh, shape, ax.dp_spec,
                         *([None] * (len(shape) - 1)))
        return P()
    return jax.tree_util.tree_map_with_path(leaf, batch_tree)


def cache_specs(cfg: ModelConfig, cache_tree, mesh: Mesh):
    """Decode caches: batch over data; KV heads over model when divisible,
    else sequence (SP); SSM states shard heads over model."""
    ax = mesh_axes(mesh)
    tp = ax.tp
    tp_n = _axis_size(mesh, tp)

    def leaf(path, leaf):
        name = _path_str(path)
        shape = tuple(leaf.shape)
        base = name.rsplit("/", 1)[-1]
        if base in ("k", "v", "xk", "xv"):            # (L,B,KH,S,hd)
            _, B, KH, S, _ = shape
            if KH % tp_n == 0:
                return _spec(mesh, shape, None, ax.dp_spec, tp, None, None)
            return _spec(mesh, shape, None, ax.dp_spec, None, tp, None)
        if base == "h":                               # (L,B,nh,N,P)
            return _spec(mesh, shape, None, ax.dp_spec, tp, None, None)
        if base in ("conv_x",):                       # (L,B,W-1,di)
            return _spec(mesh, shape, None, ax.dp_spec, None, tp)
        if base in ("conv_b", "conv_c"):
            return _spec(mesh, shape, None, ax.dp_spec, None, None)
        if base == "pos":
            return P()
        return P(*([None] * len(shape)))
    return jax.tree_util.tree_map_with_path(leaf, cache_tree)


def to_named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
