"""Distribution layer: sharding rules (DP/TP/EP/SP/FSDP), activation
sharding context, pipeline parallelism, gradient compression."""
from . import ctx
from .sharding import (batch_specs, cache_specs, mesh_axes, opt_state_specs,
                       param_specs, to_named)

__all__ = ["ctx", "batch_specs", "cache_specs", "mesh_axes",
           "opt_state_specs", "param_specs", "to_named"]
