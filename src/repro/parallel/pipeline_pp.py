"""Pipeline parallelism (GPipe-style) over ``shard_map`` +
``collective_permute``.

For meshes deeper than DP×TP (e.g. 1000+ nodes where a 123B model wants
PP=8), the layer stack is split into S stages along a ``stage`` mesh axis;
microbatches stream through stages with ``ppermute`` hand-offs. The
schedule below is the classic GPipe fill-drain loop expressed as one
``lax.fori_loop`` inside ``shard_map`` — every stage executes the same
program (SPMD), idle ticks are masked, so it lowers cleanly at any mesh
size.

Bubble fraction = (S-1)/(M+S-1) for M microbatches; compute/comm overlap
comes from XLA scheduling the ppermute of microbatch i+1 against the
stage compute of microbatch i (async collective-permute).

Used by tests/test_pipeline_pp.py (equivalence vs single-device stack)
and selectable in launch/train.py via ``--pp``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(mesh: Mesh, stage_fn: Callable, n_stages: int,
                   n_micro: int, x, stage_params, *, axis: str = "stage"):
    """Run ``stage_fn(params_s, micro_x) -> micro_y`` as a GPipe pipeline.

    x: (n_micro, micro_batch, ...) input microbatches (all on stage 0);
    stage_params: pytree with leading stage axis, sharded over ``axis``.
    Returns (n_micro, micro_batch, ...) outputs (from the last stage,
    gathered to all).
    """
    assert x.shape[0] == n_micro

    def per_stage(params_local, x_local):
        # params_local: this stage's params (leading axis 1) ; x_local: full
        # microbatch stream (only stage 0's copy is meaningful)
        params_s = jax.tree.map(lambda a: a[0], params_local)
        stage = lax.axis_index(axis)
        S = n_stages
        M = n_micro
        T = M + S - 1                      # fill-drain ticks
        micro_shape = x_local.shape[1:]

        def tick(t, carry):
            buf, outs = carry
            # stage s works on microbatch (t - s) when 0 <= t-s < M
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 ingests a fresh microbatch; others use the handed-off
            inp = jnp.where(
                stage == 0,
                x_local[jnp.clip(mb_idx, 0, M - 1)],
                buf)
            out = stage_fn(params_s, inp)
            out = jnp.where(active, out, buf)
            # hand off to the next stage
            nxt = lax.ppermute(out, axis,
                               [(i, (i + 1) % S) for i in range(S)])
            # last stage records its finished microbatch
            done_idx = t - (S - 1)
            is_done = (stage == S - 1) & (done_idx >= 0) & (done_idx < M)
            outs = lax.cond(
                is_done,
                lambda o: lax.dynamic_update_slice(
                    o, out[None].astype(o.dtype),
                    (jnp.clip(done_idx, 0, M - 1),) + (0,) * len(micro_shape)),
                lambda o: o, outs)
            return (nxt, outs)

        buf0 = jnp.zeros(micro_shape, x_local.dtype)
        outs0 = jnp.zeros((M,) + micro_shape, x_local.dtype)
        _, outs = lax.fori_loop(0, T, tick, (buf0, outs0))
        # broadcast final outputs from the last stage to all stages
        outs = lax.all_gather(outs, axis)[S - 1]
        return outs

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),          # params sharded by stage; x replicated
        out_specs=P(),
        check_rep=False,
    )(stage_params, x)


def split_layers_to_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-major."""
    def re(a):
        L = a.shape[0]
        assert L % n_stages == 0, f"{L} layers not divisible by {n_stages}"
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree.map(re, stacked_params)


def make_stage_fn(layer_fn: Callable):
    """Wrap a single-layer fn into a stage fn scanning its layer slice."""
    def stage_fn(stage_params, x):
        def body(h, lp):
            return layer_fn(lp, h), None
        h, _ = lax.scan(body, x, stage_params)
        return h
    return stage_fn
