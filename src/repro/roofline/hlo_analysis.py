"""Trip-count-aware HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` visits a ``while`` body ONCE, so a
scan-over-layers model under-reports FLOPs/bytes by the layer count
(verified empirically — see EXPERIMENTS.md §Roofline methodology). This
module re-walks the optimized HLO text with execution-count propagation:

* parse computations + call graph (while bodies/conds — trip counts taken
  from the ``known_trip_count`` backend config, falling back to the
  loop-condition constant — plus call/fusion/conditional);
* execution count of a computation = Σ over callers (× trip count);
* FLOPs: every ``dot`` = 2 · |result| · K (× exec count); convolutions
  likewise. Elementwise flops are secondary and omitted (documented
  under-count; these models are MXU-dominated);
* HBM bytes (traffic model, per instruction × exec count):
    dot/conv/reduce      -> result + full operands
    dynamic-update-slice -> 2 × update-operand bytes (in-place cache write)
    fusion w/ DUS root   -> same, resolved through the fused computation
    everything else      -> result + Σ min(operand, result)
  (the min() caps slice-style fusions that read a window of a big buffer);
* collectives: result bytes × ring wire factor ((g-1)/g per pass; 2× for
  all-reduce; ×(g-1) for reduce-scatter whose HLO result is the shard)
  × exec count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
# opcode follows the result type, which ends with ')', '}' or ']'
_OPCODE_RES = [re.compile(r"[\)\}\]]\s*([a-z][\w\-]*)\(")]

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_FULL_READ_OPS = ("dot", "convolution", "reduce", "reduce-window", "sort",
                  "scatter")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    rest: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    is_entry: bool = False
    root: Optional[Instr] = None


def _opcode_of(body: str) -> Tuple[str, str, str]:
    best = None
    for rex in _OPCODE_RES:
        m = rex.search(body)
        if m and (best is None or m.start(1) < best.start(1)):
            best = m
    if best is None:
        return body, "", ""
    return body[:best.start(1)], best.group(1), body[best.start(1):]


def _parse_operands(opcode: str, rest: str) -> List[str]:
    """Operand names of ``opcode(...)``.

    Full-form HLO spells each operand as ``f32[128,128]{1,0} %name`` —
    commas appear inside shape brackets and tuple types, so the argument
    list must be split at top-level commas only, and the operand name is
    the trailing token of each piece.
    """
    if not rest.startswith(opcode + "("):
        return []
    depth = 0
    args: List[str] = []
    cur: List[str] = []
    for ch in rest[len(opcode) + 1:]:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0 and ch == ")":
                break
            depth -= 1
        elif ch == "," and depth == 0:
            args.append("".join(cur))
            cur = []
            continue
        cur.append(ch)
    args.append("".join(cur))
    out = []
    for a in args:
        toks = a.split()
        if not toks:
            continue
        out.append(toks[-1].lstrip("%"))
    return out


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and line.rstrip().endswith("{"):
            cur = Computation(name=mc.group(2), is_entry=bool(mc.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, body = mi.group(1), mi.group(2)
        type_str, opcode, rest = _opcode_of(body)
        operands: List[str] = []
        if opcode:
            operands = _parse_operands(opcode, rest)
        ins = Instr(name=name, opcode=opcode, type_str=type_str,
                    rest=rest, operands=operands)
        cur.instrs.append(ins)
        if "ROOT" in line.split("=")[0]:
            cur.root = ins
    return comps


def _attr_comp(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.rest)
    if m:
        return int(m.group(1))
    cond_name = _attr_comp(ins.rest, "condition")
    cond = comps.get(cond_name)
    best = 1
    if cond is not None:
        for cins in cond.instrs:
            for mm in re.finditer(r"constant\((\d+)\)",
                                  cins.type_str + cins.rest):
                best = max(best, int(mm.group(1)))
    return best


def execution_counts(comps: Dict[str, Computation]) -> Dict[str, float]:
    counts: Dict[str, float] = {c.name: 0.0 for c in comps.values()}
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {name: 1.0 for name in comps}
    counts[entry.name] = 1.0
    for _ in range(len(comps) + 2):
        new = {c.name: 0.0 for c in comps.values()}
        new[entry.name] = 1.0
        for comp in comps.values():
            base = counts[comp.name]
            if base == 0:
                continue
            for ins in comp.instrs:
                if ins.opcode == "while":
                    trips = _trip_count(ins, comps)
                    body = _attr_comp(ins.rest, "body")
                    cond = _attr_comp(ins.rest, "condition")
                    if body in comps:
                        new[body] += base * trips
                    if cond in comps:
                        new[cond] += base * (trips + 1)
                elif ins.opcode == "call":
                    tgt = _attr_comp(ins.rest, "to_apply")
                    if tgt in comps:
                        new[tgt] += base
                elif ins.opcode == "conditional":
                    for key in ("true_computation", "false_computation"):
                        tgt = _attr_comp(ins.rest, key)
                        if tgt in comps:
                            new[tgt] += base
                    m = re.search(r"branch_computations=\{([^}]*)\}",
                                  ins.rest)
                    if m:
                        for t in m.group(1).split(","):
                            t = t.strip().lstrip("%")
                            if t in comps:
                                new[t] += base
        if all(abs(new[k] - counts[k]) <= 1e-9 for k in counts):
            counts = new
            break
        counts = new
    return counts


def _group_size(rest: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,\s]*)\}", rest)
    if m and m.group(1).strip():
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class HLOReport:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_count: int = 0
    n_while: int = 0
    trip_counts: List[int] = dataclasses.field(default_factory=list)
    # (op, result_shape, group, execs, wire_bytes, metadata_hint)
    collectives: List[tuple] = dataclasses.field(default_factory=list)

    def top_collectives(self, n: int = 10) -> List[tuple]:
        return sorted(self.collectives, key=lambda t: -t[4])[:n]


def analyze(text: str, n_devices: int = 1) -> HLOReport:
    comps = parse_hlo(text)
    counts = execution_counts(comps)
    shapes: Dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shapes[ins.name] = ins.type_str

    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                tgt = _attr_comp(ins.rest, "calls")
                if tgt:
                    fusion_bodies.add(tgt)

    rep = HLOReport()
    for comp in comps.values():
        execs = counts.get(comp.name, 0.0)
        if execs <= 0 or comp.name in fusion_bodies:
            continue
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                rep.n_while += 1
                rep.trip_counts.append(_trip_count(ins, comps))
            if op in ("dot", "convolution"):
                out_elems = _shape_elems(ins.type_str)
                k = _contraction_size(ins, shapes)
                rep.dot_flops += 2.0 * out_elems * k * execs
            rep.hbm_bytes += _traffic_bytes(ins, shapes, comps) * execs
            base_op = op.replace("-start", "")
            if base_op in COLLECTIVES:
                size = _shape_bytes(ins.type_str)
                g = _group_size(ins.rest, n_devices)
                if base_op == "all-reduce":
                    wire = 2.0 * size * (g - 1) / max(g, 1)
                elif base_op == "all-gather":
                    wire = size * (g - 1) / max(g, 1)
                elif base_op == "reduce-scatter":
                    wire = size * (g - 1)
                elif base_op == "all-to-all":
                    wire = size * (g - 1) / max(g, 1)
                else:
                    wire = size
                rep.collective_wire_bytes += wire * execs
                rep.collective_breakdown[base_op] = \
                    rep.collective_breakdown.get(base_op, 0.0) + wire * execs
                rep.collective_count += 1
                mmeta = re.search(r'op_name="([^"]*)"', ins.rest)
                shape_m = _SHAPE_RE.search(ins.type_str)
                rep.collectives.append(
                    (base_op,
                     shape_m.group(0) if shape_m else "?", g, execs,
                     wire * execs,
                     (mmeta.group(1)[-80:] if mmeta else "")))
    return rep


def _traffic_bytes(ins: Instr, shapes: Dict[str, str],
                   comps: Dict[str, Computation]) -> float:
    op = ins.opcode
    if op in ("parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "copy", "while", "", "iota", "after-all",
              "custom-call", "partition-id", "replica-id"):
        return 0.0
    if op == "dynamic-update-slice" and len(ins.operands) >= 2:
        return 2.0 * _shape_bytes(shapes.get(ins.operands[1], ""))
    if op == "fusion":
        body = comps.get(_attr_comp(ins.rest, "calls") or "")
        if body is not None and body.root is not None:
            if body.root.opcode == "dynamic-update-slice" and \
                    len(body.root.operands) >= 2:
                upd = _shape_bytes(shapes.get(body.root.operands[1], ""))
                return 2.0 * upd
    rb = _shape_bytes(ins.type_str)
    if op in _FULL_READ_OPS:
        return rb + sum(_shape_bytes(shapes.get(o, ""))
                        for o in ins.operands)
    reads = sum(min(_shape_bytes(shapes.get(o, "")), rb)
                for o in ins.operands)
    return rb + reads


def _contraction_size(ins: Instr, shapes: Dict[str, str]) -> float:
    m = re.search(r"lhs_contracting_dims=\{([\d,\s]*)\}", ins.rest)
    if not m or not ins.operands:
        if ins.operands and len(ins.operands) >= 2:
            kshape = shapes.get(ins.operands[1], "")
            sm = _SHAPE_RE.search(kshape)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                out_f = max(dims) if dims else 1
                total = 1
                for d in dims:
                    total *= d
                return total / max(out_f, 1)
        return 1.0
    dims_idx = [int(d) for d in m.group(1).split(",") if d.strip()]
    lhs_shape = shapes.get(ins.operands[0], "")
    sm = _SHAPE_RE.search(lhs_shape)
    if not sm or not sm.group(2):
        return 1.0
    dims = [int(d) for d in sm.group(2).split(",")]
    k = 1.0
    for i in dims_idx:
        if i < len(dims):
            k *= dims[i]
    return k
