"""Three-term roofline report from a compiled dry-run artifact.

  compute    = FLOPs / (peak FLOP/s)          [per chip; SPMD program]
  memory     = HBM bytes / HBM bandwidth
  collective = wire bytes / ICI link bandwidth

FLOPs/bytes come from the trip-count-aware HLO walk
(:mod:`repro.roofline.hlo_analysis`); XLA's own cost_analysis numbers are
reported alongside for reference (they undercount scan bodies).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core.hardware import DEFAULT_CHIP, ChipSpec
from .hlo_analysis import analyze


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # per-device quantities
    flops: float
    hbm_bytes: float
    wire_bytes: float
    # seconds
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # model-level accounting
    model_flops: float            # 6·N_active·tokens (train) / 2·N·tokens
    useful_ratio: float           # model_flops / (flops × devices)
    step_time_s: float            # max of the three terms (no overlap)
    roofline_frac: float          # compute_s / step_time_s
    # memory fit
    bytes_per_device: int = 0
    fits_hbm: bool = True
    # raw XLA numbers for reference
    xla_flops: float = 0.0
    xla_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    trip_counts: tuple = ()

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["trip_counts"] = list(self.trip_counts)[:12]
        return d


def roofline_from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                           n_devices: int, model_flops_global: float,
                           chip: ChipSpec = DEFAULT_CHIP) -> RooflineTerms:
    hlo = analyze(compiled.as_text(), n_devices=n_devices)
    ca = {}
    try:
        ca = compiled.cost_analysis() or {}
    except Exception:
        pass
    mem_stats = None
    try:
        mem_stats = compiled.memory_analysis()
    except Exception:
        pass
    bytes_per_device = 0
    if mem_stats is not None:
        bytes_per_device = int(
            getattr(mem_stats, "argument_size_in_bytes", 0)
            + getattr(mem_stats, "temp_size_in_bytes", 0)
            + getattr(mem_stats, "output_size_in_bytes", 0)
            - getattr(mem_stats, "alias_size_in_bytes", 0))

    compute_s = hlo.dot_flops / chip.peak_flops_bf16
    memory_s = hlo.hbm_bytes / chip.hbm_bw
    collective_s = hlo.collective_wire_bytes / chip.ici_bw_per_link
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step = max(compute_s, memory_s, collective_s)
    model_flops_dev = model_flops_global / max(n_devices, 1)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops=hlo.dot_flops, hbm_bytes=hlo.hbm_bytes,
        wire_bytes=hlo.collective_wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops_global,
        useful_ratio=(model_flops_dev / hlo.dot_flops
                      if hlo.dot_flops else 0.0),
        step_time_s=step,
        roofline_frac=(model_flops_dev / chip.peak_flops_bf16) / step
        if step > 0 else 0.0,
        bytes_per_device=bytes_per_device,
        fits_hbm=bytes_per_device <= chip.hbm_bytes,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
        collective_breakdown=dict(hlo.collective_breakdown),
        trip_counts=tuple(hlo.trip_counts),
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D_tokens for training, 2·N_active·tokens for
    one decode step, 2·N_active·tokens for prefill."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence (+ attention over the cache, excluded
    # from the 2ND model-flops convention)
    return 2.0 * n_act * shape.global_batch
