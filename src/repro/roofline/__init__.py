from .hlo_analysis import HLOReport, analyze, parse_hlo
from .report import RooflineTerms, roofline_from_compiled

__all__ = ["HLOReport", "analyze", "parse_hlo", "RooflineTerms",
           "roofline_from_compiled"]
