"""Sharded, async, resharding-capable checkpointing.

Layout (one directory per step):
  step_000123/
    manifest.json      — pytree structure, shapes, dtypes, mesh, step
    shard_<host>.npz   — this host's param/opt shards (flattened leaves)
    _COMMITTED         — atomic commit marker (written last)

Properties needed at 1000-node scale, all implemented here:
  * per-host shard files (no single-writer bottleneck);
  * async save (background thread; training continues, `wait()` joins);
  * atomic commit marker so a killed run never restores a torn checkpoint;
  * restore with *resharding*: a checkpoint saved on N hosts restores onto
    M hosts (elastic) by reading the union of shards and re-slicing;
  * keeps the newest K checkpoints, deletes older ones only after commit.

On this single-process container every "host" writes to the same
filesystem — identical code paths, exercised by tests/test_checkpoint.py
including kill-before-commit and N→M elastic restore.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class CheckpointMeta:
    step: int
    n_hosts: int
    tree_def: str
    leaf_info: List[Tuple[str, list, str]]  # (name, shape, dtype)
    extra: Dict[str, Any]


def _leaf_names(tree) -> List[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", "?"))))
        names.append("/".join(parts))
    return names


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # -- save --------------------------------------------------------------------
    def save(self, step: int, tree, *, host_id: int = 0, n_hosts: int = 1,
             extra: Optional[Dict[str, Any]] = None,
             async_: bool = True) -> None:
        """Save this host's shard of ``tree`` (host slices along leading
        axis round-robin; a real deployment passes each host's local
        addressable shards)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        names = _leaf_names(tree)
        arrays = [np.asarray(x) for x in leaves]

        def work():
            step_dir = self.dir / f"step_{step:09d}"
            step_dir.mkdir(parents=True, exist_ok=True)
            shard: Dict[str, np.ndarray] = {}
            for i, (name, arr) in enumerate(zip(names, arrays)):
                lo, hi = _host_slice(arr.shape, host_id, n_hosts)
                piece = arr[lo:hi] if arr.ndim else arr
                # npz cannot round-trip ml_dtypes (bf16 loads as raw void):
                # store a uint16 view, restored by manifest dtype
                if str(piece.dtype) == "bfloat16":
                    piece = piece.view(np.uint16)
                shard[f"{i}"] = piece
            np.savez(step_dir / f"shard_{host_id}.npz", **shard)
            if host_id == 0:
                meta = CheckpointMeta(
                    step=step, n_hosts=n_hosts,
                    tree_def=str(treedef),
                    leaf_info=[(n, list(a.shape), str(a.dtype))
                               for n, a in zip(names, arrays)],
                    extra=extra or {})
                (step_dir / "manifest.json").write_text(
                    json.dumps(dataclasses.asdict(meta)))
            # commit marker written LAST (atomicity)
            (step_dir / f"_COMMITTED_{host_id}").touch()
            self._gc()

        if async_:
            self.wait()
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # -- restore ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if any(p.glob("_COMMITTED_*")) and (p / "manifest.json").exists():
                steps.append(int(p.name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                ) -> Tuple[Any, Dict[str, Any]]:
        """Rebuild full arrays from ALL committed shards (any host count),
        shaped like ``tree_like``. Returns (tree, extra)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no committed checkpoint found")
        step_dir = self.dir / f"step_{step:09d}"
        meta = json.loads((step_dir / "manifest.json").read_text())
        n_hosts = meta["n_hosts"]
        shards = []
        for h in range(n_hosts):
            f = step_dir / f"shard_{h}.npz"
            if not (step_dir / f"_COMMITTED_{h}").exists():
                raise IOError(f"shard {h} of step {step} uncommitted")
            shards.append(np.load(f))
        leaves, treedef = jax.tree_util.tree_flatten(tree_like)
        import ml_dtypes
        out = []
        for i, ref in enumerate(leaves):
            parts = [sh[f"{i}"] for sh in shards]
            if np.ndim(parts[0]) == 0:
                full = parts[0]
            else:
                full = np.concatenate(parts, axis=0)
            saved_dtype = meta["leaf_info"][i][2]
            if saved_dtype == "bfloat16" and full.dtype == np.uint16:
                full = full.view(ml_dtypes.bfloat16)
            ref_shape = tuple(ref.shape)
            if tuple(full.shape) != ref_shape:
                raise ValueError(
                    f"leaf {i}: checkpoint {full.shape} vs model {ref_shape}")
            dtype = ref.dtype if hasattr(ref, "dtype") else full.dtype
            out.append(full.astype(dtype))
        return jax.tree_util.tree_unflatten(treedef, out), meta["extra"]

    # -- gc ------------------------------------------------------------------------
    def _gc(self):
        steps = sorted(
            (int(p.name.split("_")[1]), p) for p in self.dir.glob("step_*")
            if any(p.glob("_COMMITTED_*")))
        for _, p in steps[:-self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(p, ignore_errors=True)


def _host_slice(shape, host_id: int, n_hosts: int) -> Tuple[int, int]:
    if not shape:
        return 0, 1
    n = shape[0]
    per = (n + n_hosts - 1) // n_hosts
    lo = min(host_id * per, n)
    return lo, min(lo + per, n)
