# Pallas TPU kernels for the compute hot-spots the framework saturates:
# - saturated elementwise tile programs (rmsnorm/swiglu/rotary/adamw/...)
#   generated from the e-graph pipeline with bulk-load VMEM scheduling;
# - flash attention (online softmax, causal skip, GQA);
# - Mamba2 SSD chunked scan.
# ops.py = dispatching wrappers; ref.py = pure-jnp oracles.
from . import ops, ref
from .flash_attention import decode_attention, flash_attention
from .ssd_scan import ssd_decode_step, ssd_scan, ssd_scan_jnp
from .tile_programs import PROGRAMS, get_tile_op

__all__ = ["ops", "ref", "flash_attention", "decode_attention", "ssd_scan",
           "ssd_scan_jnp", "ssd_decode_step", "PROGRAMS", "get_tile_op"]
