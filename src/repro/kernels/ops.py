"""Public kernel API — jitted wrappers dispatching per backend.

Every op has three implementations:
  * ``pallas``  — the Pallas TPU kernel (interpret-mode on CPU): saturated
                  tile programs via :mod:`repro.core.pallasgen`, plus the
                  handwritten flash-attention / SSD kernels;
  * ``jnp``     — the *saturated generated JAX code* (the paper's optimized
                  output, CPU-fast, used inside jitted model steps);
  * ``ref``     — the independent oracle in :mod:`repro.kernels.ref`.

Default: pallas on TPU, jnp elsewhere. ``set_impl(...)`` overrides
globally (tests sweep all three).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.telemetry import telemetry
from repro.runtime.guard import breaker_for

from . import ref as _ref
from .flash_attention import decode_attention, flash_attention
from .ssd_scan import ssd_decode_step, ssd_scan, ssd_scan_jnp
from .tile_programs import get_tile_op

_IMPL: Optional[str] = None  # None = auto
_SAT_CACHE: Optional[str] = None  # persistent saturation cache directory
_SAT_VERIFY: Optional[str] = None  # static-verification level for builds

# runtime degradation floor (PR 10): the named jnp oracle each tile op
# falls back to when building or applying the optimized op fails — the
# serve/train hot paths must never see a saturator exception. jnp
# oracles are jit-traceable, so the fallback also works mid-trace
# (where the pipeline's numpy reference interpreter cannot run).
_REF_FNS: dict = {
    "rmsnorm": _ref.rmsnorm_ref, "rmsnorm_gated": _ref.rmsnorm_gated_ref,
    "layernorm": _ref.layernorm_ref, "swiglu": _ref.swiglu_ref,
    "gelu": _ref.gelu_ref, "rotary": _ref.rotary_ref,
    "residual_scale": _ref.residual_scale_ref,
    "softmax": _ref.softmax_ref, "moe_router": _ref.softmax_ref,
    "adamw": _ref.adamw_ref, "ssd_gate": _ref.ssd_gate_ref,
}


def set_impl(impl: Optional[str]):
    """impl in {None(auto), 'pallas', 'jnp', 'ref'}."""
    global _IMPL
    assert impl in (None, "auto", "pallas", "jnp", "ref")
    _IMPL = None if impl == "auto" else impl


def set_saturation_cache(path: Optional[str]):
    """Point every tile op built after this call at a persistent
    saturation cache directory (repro.cache): saturation/beam results
    are replayed from disk instead of re-searched per process. None
    disables (the default; the REPRO_SAT_CACHE env var still applies
    at the pipeline level). The launch drivers call this at startup so
    the serve/train hot paths are warm across boots."""
    global _SAT_CACHE
    _SAT_CACHE = str(path) if path is not None else None


def current_saturation_cache() -> Optional[str]:
    return _SAT_CACHE


def set_saturation_verify(level: Optional[str]):
    """Static-verification level ("off" | "cheap" | "full", see
    repro.verify) applied to every tile op built after this call. The
    launch drivers resolve --verify / REPRO_VERIFY through
    SaturatorConfig.from_env and thread the result here; None/"off"
    adds zero overhead (the default)."""
    global _SAT_VERIFY
    _SAT_VERIFY = None if level in (None, "off") else str(level)


def current_saturation_verify() -> Optional[str]:
    return _SAT_VERIFY


def _op(name: str):
    return get_tile_op(name, cache_dir=_SAT_CACHE, verify=_SAT_VERIFY)


def current_impl() -> str:
    if _IMPL is not None:
        return _IMPL
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _guarded(name: str, optimized: Callable, reference: Callable):
    """Run the optimized path under the runtime floor: any failure
    (building the tile op, tracing, or applying it) falls back to the
    named jnp oracle instead of raising to the caller. A per-kernel
    circuit breaker skips the optimized attempt entirely after repeated
    failures, so a pathological kernel doesn't pay the failure cost on
    every request."""
    br = breaker_for(("apply", name))
    if br.admit() is not None:
        telemetry().record_runtime_fallback(name, "breaker_open")
        return reference()
    try:
        out = optimized()
    except Exception as e:  # ladder floor: degrade, never raise
        br.record_failure(fallback_level="ref")
        telemetry().record_runtime_fallback(name, type(e).__name__)
        return reference()
    br.record_success()
    return out


def _tile(name: str, *arrays, **scalars):
    impl = current_impl()
    ref_fn = _REF_FNS[name]
    if impl == "ref":
        return ref_fn(*arrays, **scalars)
    if impl == "pallas":
        return _guarded(name, lambda: _op(name).apply(*arrays, **scalars),
                        lambda: ref_fn(*arrays, **scalars))
    return _guarded(name, lambda: _op(name).jax_ref(*arrays, **scalars),
                    lambda: ref_fn(*arrays, **scalars))


# -- saturated tile ops ---------------------------------------------------------
def rmsnorm(x, g, eps=1e-6):
    return _tile("rmsnorm", x, g, eps=eps)


def rmsnorm_gated(x, z, g, eps=1e-6):
    return _tile("rmsnorm_gated", x, z, g, eps=eps)


def layernorm(x, g, b, eps=1e-6):
    return _tile("layernorm", x, g, b, eps=eps)


def swiglu(a, b):
    return _tile("swiglu", a, b)


def gelu(a):
    return _tile("gelu", a)


def rotary(q, cos, sin):
    """q:(..., d); cos/sin broadcastable to q. Tile rows = flattened lead."""
    impl = current_impl()
    if impl == "ref":
        return _ref.rotary_ref(q, cos, sin)

    def _opt():
        op = _op("rotary")
        cosb = jnp.broadcast_to(cos, q.shape)
        sinb = jnp.broadcast_to(sin, q.shape)
        if impl == "pallas":
            return op.apply(q, cosb, sinb)
        return op.jax_ref(q, cosb, sinb)

    return _guarded("rotary", _opt, lambda: _ref.rotary_ref(q, cos, sin))


def residual_scale(x, y, alpha=1.0):
    return _tile("residual_scale", x, y, alpha=alpha)


def softmax(x):
    return _tile("softmax", x)


def moe_router_probs(logits):
    return _tile("moe_router", logits)


def adamw_update(param, grad, m, v, *, lr, b1, b2, eps, wd,
                 inv_bc1, inv_bc2):
    """Returns (m_new, v_new, param_new) — saturated fused update."""
    return _tile("adamw", param, grad, m, v, lr=lr, b1=b1, b2=b2,
                 eps=eps, wd=wd, inv_bc1=inv_bc1, inv_bc2=inv_bc2)


def ssd_gate(dt_raw, a_log, bias=0.0):
    """Returns (dt, decay) with shared softplus. a_log broadcast to dt_raw."""
    impl = current_impl()
    if impl == "ref":
        return _ref.ssd_gate_ref(dt_raw, a_log, bias=bias)

    def _opt():
        op = _op("ssd_gate")
        a_b = jnp.broadcast_to(a_log, dt_raw.shape)
        if impl == "pallas":
            return op.apply(dt_raw, a_b, bias=bias)
        return op.jax_ref(dt_raw, a_b, bias=bias)

    return _guarded("ssd_gate", _opt,
                    lambda: _ref.ssd_gate_ref(dt_raw, a_log, bias=bias))


# -- structured kernels -----------------------------------------------------------
def attention(q, k, v, *, causal=True, scale=None, q_block=128,
              kv_block=128):
    impl = current_impl()
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, scale=scale,
                               q_block=q_block, kv_block=kv_block)
    return _ref.attention_ref(q, k, v, causal=causal, scale=scale)


def attention_decode(q, k_cache, v_cache, *, scale=None):
    return decode_attention(q, k_cache, v_cache, scale=scale)


def ssd(x, dt, a_log, b_mat, c_mat, d_skip, *, chunk=128):
    impl = current_impl()
    if impl == "pallas":
        return ssd_scan(x, dt, a_log, b_mat, c_mat, d_skip, chunk=chunk)
    if impl == "ref":
        return _ref.ssd_ref(x, dt, a_log, b_mat, c_mat, d_skip)
    return ssd_scan_jnp(x, dt, a_log, b_mat, c_mat, d_skip, chunk=chunk)


def ssd_decode(h, x_t, dt_t, a_log, b_t, c_t, d_skip):
    return ssd_decode_step(h, x_t, dt_t, a_log, b_t, c_t, d_skip)
