"""The framework's elementwise hot-spots, written in the saturator DSL.

Every program here is the 'sequential body' the paper optimizes: it is
saturated (Table I rules + cost model), extracted with CSE, and emitted
twice — as a Pallas TPU kernel with bulk-load VMEM scheduling and as a
saturated pure-JAX function (the CPU / oracle path).

These are the TPU analogues of the paper's NPB/SPEC kernel bodies: heavy
on FMA opportunities, shared subexpressions, and front-loadable loads.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

from repro.core import (CacheConfig, KernelProgram, SaturatorConfig,
                        ScheduleConfig, VerifyConfig, c, gelu_tanh, log,
                        make_tile_op, exp, recip, rmax, rmean, rothalf,
                        rsqrt, rsum, select, sigmoid, silu, sqrt, square,
                        TileOp, v)

_DEFAULT_CFG = SaturatorConfig(mode="accsat", cost_model="tpu_v5e",
                               tpu_rules=True)

# Declared operand geometry for the analysis layer: the model hot-spots
# run on one (8, 128) vreg tile; norm gains/biases are broadcast rows,
# so a load of them moves one row of HBM, not a full tile.
TILE = (8, 128)
ROW = (1, 128)


def rmsnorm_program() -> KernelProgram:
    """y = x * rsqrt(mean(x^2) + eps) * g   (pre-norm used by all LMs here)."""
    p = KernelProgram("rmsnorm")
    x = p.array_in("x", shape=TILE)
    g = p.array_in("g", shape=ROW)   # gain: one broadcast row per tile
    p.array_out("o", shape=TILE)
    eps = p.scalar("eps")
    xv = x.load()
    inv = rsqrt(rmean(xv * xv) + eps)
    p.store("o", xv * inv * g.load())
    return p


def rmsnorm_gated_program() -> KernelProgram:
    """Mamba2 gated norm: y = rmsnorm(x * silu(z)) * g."""
    p = KernelProgram("rmsnorm_gated")
    x = p.array_in("x", shape=TILE)
    z = p.array_in("z", shape=TILE)
    g = p.array_in("g", shape=ROW)
    p.array_out("o", shape=TILE)
    eps = p.scalar("eps")
    xg = x.load() * silu(z.load())
    inv = rsqrt(rmean(xg * xg) + eps)
    p.store("o", xg * inv * g.load())
    return p


def layernorm_program() -> KernelProgram:
    """Whisper uses true LayerNorm: y = (x - mu) * rsqrt(var + eps) * g + b."""
    p = KernelProgram("layernorm")
    x = p.array_in("x", shape=TILE)
    g = p.array_in("g", shape=ROW)
    b = p.array_in("b", shape=ROW)
    p.array_out("o", shape=TILE)
    eps = p.scalar("eps")
    xv = x.load()
    mu = rmean(xv)
    xc = xv - mu
    inv = rsqrt(rmean(xc * xc) + eps)
    p.store("o", xc * inv * g.load() + b.load())
    return p


def swiglu_program() -> KernelProgram:
    """SwiGLU combine: o = silu(a) * b (a = gate proj, b = up proj)."""
    p = KernelProgram("swiglu")
    a = p.array_in("a", shape=TILE)
    b = p.array_in("b", shape=TILE)
    p.array_out("o", shape=TILE)
    p.store("o", silu(a.load()) * b.load())
    return p


def geglu_program() -> KernelProgram:
    """GELU(tanh) combine for whisper MLP: o = gelu(a) * 1 + b*0 — plain gelu."""
    p = KernelProgram("gelu")
    a = p.array_in("a")
    p.array_out("o")
    p.store("o", gelu_tanh(a.load()))
    return p


def rotary_program() -> KernelProgram:
    """RoPE application: o = q*cos + rotate_half(q)*sin — a pure FMA chain."""
    p = KernelProgram("rotary")
    q = p.array_in("q")
    cos = p.array_in("cos")
    sin = p.array_in("sin")
    p.array_out("o")
    qv = q.load()
    p.store("o", qv * cos.load() + rothalf(qv) * sin.load())
    return p


def residual_scale_program() -> KernelProgram:
    """o = x + alpha * y (residual with scale; alpha=1 folds)."""
    p = KernelProgram("residual_scale")
    x = p.array_in("x")
    y = p.array_in("y")
    p.array_out("o")
    alpha = p.scalar("alpha")
    p.store("o", x.load() + alpha * y.load())
    return p


def softmax_program() -> KernelProgram:
    """Row softmax via reciprocal-multiply (div is 100-cost, §V-B)."""
    p = KernelProgram("softmax")
    x = p.array_in("x", shape=TILE)
    p.array_out("o", shape=TILE)
    xv = x.load()
    e = exp(xv - rmax(xv))
    p.store("o", e * recip(rsum(e)))
    return p


def adamw_program() -> KernelProgram:
    """Fused AdamW update — the optimizer's hot loop, saturated.

    Inputs are precomputed scalars: inv_bc1 = 1/(1-b1^t), inv_bc2 likewise,
    so the kernel body is pure FMA + rsqrt territory.
    Outputs: new param, new m, new v.
    """
    p = KernelProgram("adamw")
    w = p.array_in("param")
    gr = p.array_in("grad")
    m = p.array_in("m")
    vv = p.array_in("v")
    p.array_out("m_out")
    p.array_out("v_out")
    p.array_out("param_out")
    lr = p.scalar("lr")
    b1 = p.scalar("b1")
    b2 = p.scalar("b2")
    eps = p.scalar("eps")
    wd = p.scalar("wd")
    inv_bc1 = p.scalar("inv_bc1")
    inv_bc2 = p.scalar("inv_bc2")
    g_ = gr.load()
    m_new = b1 * m.load() + (c(1.0) - b1) * g_
    v_new = b2 * vv.load() + (c(1.0) - b2) * g_ * g_
    p.store("m_out", m_new)
    p.store("v_out", v_new)
    mhat = m_new * inv_bc1
    vhat = v_new * inv_bc2
    wv = w.load()
    update = mhat * recip(sqrt(vhat) + eps) + wd * wv
    p.store("param_out", wv - lr * update)
    return p


def sgd_momentum_program() -> KernelProgram:
    """Fused SGD+momentum (baseline optimizer): m' = mu*m + g; w' = w - lr*m'."""
    p = KernelProgram("sgd_momentum")
    w = p.array_in("param")
    gr = p.array_in("grad")
    m = p.array_in("m")
    p.array_out("m_out")
    p.array_out("param_out")
    lr = p.scalar("lr")
    mu = p.scalar("mu")
    m_new = mu * m.load() + gr.load()
    p.store("m_out", m_new)
    p.store("param_out", w.load() - lr * m_new)
    return p


def ssd_gate_program() -> KernelProgram:
    """Mamba2 input gating: dt = softplus(dt_raw + bias); decay = exp(dt*A).

    Emits both dt (for dB·x) and the per-step decay — shares the softplus.
    """
    p = KernelProgram("ssd_gate")
    dtr = p.array_in("dt_raw")
    a = p.array_in("a_log")       # A = -exp(a_log), stored log-space
    p.array_out("dt")
    p.array_out("decay")
    bias = p.scalar("bias")
    x = dtr.load() + bias
    dt = log(c(1.0) + exp(x))  # softplus
    p.store("dt", dt)
    p.store("decay", exp(dt * (c(0.0) - exp(a.load()))))
    return p


def moe_router_program() -> KernelProgram:
    """Router logits → probabilities (softmax) with jitter-free scaling."""
    p = KernelProgram("moe_router")
    x = p.array_in("logits")
    p.array_out("probs")
    xv = x.load()
    e = exp(xv - rmax(xv))
    p.store("probs", e * recip(rsum(e)))
    return p


def l2_clip_program() -> KernelProgram:
    """Gradient scale for global-norm clipping: o = g * min(1, c/ (n + eps))."""
    p = KernelProgram("l2_clip")
    g = p.array_in("g")
    p.array_out("o")
    norm = p.scalar("norm")
    max_norm = p.scalar("max_norm")
    eps = p.scalar("eps")
    from repro.core import minimum
    scale = minimum(c(1.0), max_norm * recip(norm + eps))
    p.store("o", g.load() * scale)
    return p


PROGRAMS: Dict[str, Callable[[], KernelProgram]] = {
    "rmsnorm": rmsnorm_program,
    "rmsnorm_gated": rmsnorm_gated_program,
    "layernorm": layernorm_program,
    "swiglu": swiglu_program,
    "gelu": geglu_program,
    "rotary": rotary_program,
    "residual_scale": residual_scale_program,
    "softmax": softmax_program,
    "adamw": adamw_program,
    "sgd_momentum": sgd_momentum_program,
    "ssd_gate": ssd_gate_program,
    "moe_router": moe_router_program,
    "l2_clip": l2_clip_program,
}


@functools.lru_cache(maxsize=None)
def get_tile_op(name: str, mode: str = "accsat",
                schedule: str = None,
                device_profile: str = None,
                cache_dir: str = None,
                emitter: str = None,
                verify: str = None) -> TileOp:
    """Build (and cache) the saturated TileOp for a named program.

    ``schedule`` picks the statement order of the emitted kernel
    (``"source" | "bulk" | "cost"``; None keeps the mode's default —
    bulk for accsat). Extraction stays on the flat TPU model either
    way, so the *selected term* is identical across schedules; only the
    emission order moves. ``device_profile`` prices the cost-driven
    schedule search with a calibrated model (name/path of a profile
    under ``experiments/device_profiles/``). ``emitter`` selects the
    Pallas emission backend (``"pallas" | "pallas_pipelined"``, see
    :mod:`repro.core.emit`; None = synchronous ``"pallas"``).

    ``cache_dir`` (see :mod:`repro.cache`) persists the saturation
    result on disk: this ``lru_cache`` only amortizes within a process,
    the directory amortizes across processes and boots. Use
    ``repro.kernels.ops.set_saturation_cache`` to set it globally for
    the model hot paths. ``verify`` ("off" | "cheap" | "full", see
    :mod:`repro.verify`) statically audits the build; the launch
    drivers thread their resolved ``--verify``/``REPRO_VERIFY`` level
    here via ``ops.set_saturation_verify``."""
    cfg = SaturatorConfig(
        mode=mode, cost_model="tpu_v5e",
        tpu_rules=(mode in ("cse_sat", "accsat")),
        schedule_cfg=ScheduleConfig(schedule=schedule,
                                    device_profile=device_profile,
                                    emitter=emitter),
        cache_cfg=CacheConfig(cache_dir=cache_dir),
        verify_cfg=VerifyConfig(verify=verify) if verify else None)
    return make_tile_op(PROGRAMS[name](), cfg)
